"""Serving example: prefill a batch of prompts, then decode with a
transprecision KV cache (the paper's storage-format knob applied to the
dominant serving memory term).

Runs a reduced config on CPU; the same code path lowers the decode_32k /
long_500k dry-run cells on the production meshes.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch gemma2-9b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--policy", default="tp_bf16")
    args = ap.parse_args()

    model = build_model(args.arch, policy=args.policy, reduced=True)
    cfg = model.cfg
    params = model.init(jax.random.key(0))
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)

    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    step = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos))

    t0 = time.time()
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # greedy decode
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = step(params, tok, caches, args.prompt_len + i)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    kv_fmt = model.policy.kv_fmt.name if model.policy.kv_fmt else "param fmt"
    print(f"arch {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f} ms; {args.gen-1} greedy steps in "
          f"{t_dec*1e3:.0f} ms ({(args.gen-1)*args.batch/t_dec:.1f} tok/s "
          f"on CPU)")
    print(f"KV cache format: {kv_fmt} (policy '{model.policy.name}')")
    print("generated ids (row 0):", gen[0].tolist())
    assert gen.shape == (args.batch, args.gen)
    assert int(gen.max()) < cfg.vocab


if __name__ == "__main__":
    main()
