"""Serving example: prefill a batch of prompts, then decode with a
transprecision KV cache (the paper's storage-format knob applied to the
dominant serving memory term).

Decoding runs as ONE compiled ``lax.scan`` (``Model.generate``) — the whole
generation is a single XLA dispatch; with ``--decode-backend pallas`` the
per-step attention additionally runs the fused in-kernel KV-dequant Pallas
kernel (kernels/decode_attention.py).

Runs a reduced config on CPU; the same code path lowers the decode_32k /
long_500k dry-run cells on the production meshes.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch gemma2-9b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--policy", default="tp_bf16")
    ap.add_argument("--decode-backend", choices=("dense", "pallas"),
                    default="dense")
    args = ap.parse_args()

    model = build_model(args.arch, policy=args.policy, reduced=True)
    model = model.with_cfg(decode_backend=args.decode_backend)
    cfg = model.cfg
    params = model.init(jax.random.key(0))
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)

    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    gen_fn = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=args.gen, max_len=max_len)[0])

    t0 = time.time()
    logits, _ = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    jax.block_until_ready(gen_fn(params, prompts))   # compile the scan
    t0 = time.time()
    gen = np.asarray(jax.block_until_ready(gen_fn(params, prompts)))
    t_dec = time.time() - t0

    kv_fmt = model.policy.kv_fmt.name if model.policy.kv_fmt else "param fmt"
    print(f"arch {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f} ms; one-dispatch scan generated "
          f"{args.gen} tokens/row in {t_dec*1e3:.0f} ms "
          f"({args.gen*args.batch/t_dec:.1f} tok/s on CPU, prefill incl.)")
    print(f"KV cache format: {kv_fmt} (policy '{model.policy.name}', "
          f"decode backend {cfg.decode_backend})")
    print("generated ids (row 0):", gen[0].tolist())
    assert gen.shape == (args.batch, args.gen)
    assert int(gen.max()) < cfg.vocab
    assert np.array_equal(
        gen[:, 0], np.asarray(jnp.argmax(logits[:, -1], -1)))


if __name__ == "__main__":
    main()
