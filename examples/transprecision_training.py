"""End-to-end driver: train the ~110M-parameter case-study LM for a few
hundred steps under three precision policies and reproduce the paper's
Table-III claim at training scale: the expanding-FMA policy (narrow
multiply, fp32 accumulate) tracks the fp32 baseline's loss while the
energy model predicts a large energy saving.

Run:  PYTHONPATH=src python examples/transprecision_training.py \
          [--steps 300] [--policy tp_bf16] [--compare]
"""
import argparse
import time

import jax
import numpy as np

from repro.core import energy
from repro.core.policy import PRESETS
from repro.data.pipeline import DataConfig
from repro.models.registry import build_model
from repro.optim.optimizer import OptConfig
from repro.train.loop import LoopConfig, TrainLoop


def train_one(policy: str, steps: int, ckpt_dir=None, reduced=True):
    model = build_model("fpnew-case-study", policy=policy, reduced=reduced)
    cfg = model.cfg
    opt = OptConfig(lr=3e-3, warmup_steps=20, total_steps=steps,
                    weight_decay=0.0)
    data = DataConfig(vocab=cfg.vocab, seq_len=256, global_batch=16,
                      noise=0.02)
    lc = LoopConfig(total_steps=steps, log_every=max(steps // 10, 1),
                    ckpt_every=0, ckpt_dir=ckpt_dir)
    loop = TrainLoop(model, opt, data, lc)
    t0 = time.time()
    log = loop.run()
    wall = time.time() - t0
    losses = [m["loss"] for m in log]
    n = cfg.param_counts()["flops"]
    tokens = steps * data.global_batch * data.seq_len
    flops = 6 * n * tokens
    src = PRESETS[policy].matmul.src_fmt.name
    pj = energy.TPU_PJ_PER_FLOP.get(src, energy.TPU_PJ_PER_FLOP["fp32"])
    joules = flops * pj * 1e-12
    return dict(policy=policy, first=float(np.mean(losses[:10])),
                last=float(np.mean(losses[-10:])), wall_s=wall,
                train_flops=flops, model_joules=joules)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--policy", default="tp_bf16")
    ap.add_argument("--compare", action="store_true",
                    help="run fp32 / tp_bf16 / em_fp8 and compare")
    ap.add_argument("--full", action="store_true",
                    help="full 110M config (slow on CPU)")
    args = ap.parse_args()

    policies = (["fp32", "tp_bf16", "em_fp8"] if args.compare
                else [args.policy])
    results = [train_one(p, args.steps, reduced=not args.full)
               for p in policies]

    print("\n=== transprecision training (paper Table III, at LM scale) ===")
    print(f"{'policy':10s} {'loss first':>11s} {'loss last':>10s} "
          f"{'modelled energy':>16s}")
    base = results[0]
    for r in results:
        print(f"{r['policy']:10s} {r['first']:11.3f} {r['last']:10.3f} "
              f"{r['model_joules']:13.2f} J "
              f"({r['model_joules']/base['model_joules']:.2f}x)")
    if args.compare and len(results) >= 2:
        # the paper's claim: narrow-multiply/wide-accumulate keeps accuracy
        assert abs(results[1]["last"] - results[0]["last"]) < 0.35, results
        print("claim: tp_bf16 (expanding FMA) matches fp32 loss  [OK]")


if __name__ == "__main__":
    main()
