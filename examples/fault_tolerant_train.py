"""Fault-tolerance example: train with checkpoints, inject a node failure
mid-run, restart from the latest checkpoint, and verify the final weights
are bit-identical to an uninterrupted run (exactly-once semantics).

Run:  PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.data.pipeline import DataConfig
from repro.models.registry import build_model
from repro.optim.optimizer import OptConfig
from repro.train.fault import FailurePlan, run_with_restarts
from repro.train.loop import LoopConfig, TrainLoop

STEPS = 20


def build(tmp, fail_at=()):
    model = build_model("fpnew-case-study", policy="tp_bf16", reduced=True)
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=STEPS,
                    weight_decay=0.0)
    data = DataConfig(vocab=model.cfg.vocab, seq_len=64, global_batch=8,
                      noise=0.0)
    lc = LoopConfig(total_steps=STEPS, log_every=5, ckpt_every=6,
                    ckpt_dir=tmp)
    loop = TrainLoop(model, opt, data, lc,
                     failure_plan=FailurePlan(fail_at=fail_at)
                     if fail_at else None)
    return loop


def main():
    tmp_a = tempfile.mkdtemp()
    tmp_b = tempfile.mkdtemp()
    try:
        print("--- reference run (no failures) ---")
        ref = build(tmp_a)
        ref.run()

        print("\n--- faulty run: node failure injected at step 10 ---")
        plan = FailurePlan(fail_at=(10,))

        def make():
            loop = build(tmp_b)
            loop.failure_plan = plan
            return loop

        loop, restarts = run_with_restarts(make, max_restarts=2)
        print(f"\nrecovered with {restarts} restart(s); resumed from step "
              f"{loop.metrics_log[0]['step']} (latest checkpoint)")

        for x, y in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(loop.params)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
        print("final weights BIT-IDENTICAL to the uninterrupted run  [OK]")
        if loop.monitor.flagged:
            print("stragglers flagged:", loop.monitor.flagged)
    finally:
        shutil.rmtree(tmp_a, ignore_errors=True)
        shutil.rmtree(tmp_b, ignore_errors=True)


if __name__ == "__main__":
    main()
