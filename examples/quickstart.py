"""Quickstart: the transprecision numerics layer in five minutes.

Shows the paper's primitives as JAX ops: arbitrary-format quantization with
all rounding modes, the expanding FMA (multiply narrow, accumulate wide,
one rounding), policy-driven matmuls, cast-and-pack, and the per-format
energy model — then one transprecision layer forward.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, softfloat
from repro.core.formats import get_format
from repro.core.ops import cast_and_pack, tp_einsum, tp_fma
from repro.core.policy import PRESETS


def main():
    # 1. arbitrary IEEE-style formats -------------------------------------
    x = jnp.linspace(-3, 3, 8)
    for fmt in ("fp16", "fp16alt", "fp8", (4, 3)):
        q = softfloat.quantize(x, fmt)
        f = get_format(fmt)
        print(f"{str(f):16s} width {f.width:2d}  q(x) = "
              f"{np.asarray(q).round(4)}")

    # rounding modes bracket the value
    v = jnp.float32(1.2345)
    for mode in ("rne", "rtz", "rdn", "rup", "stochastic"):
        q = softfloat.quantize(v, "fp8", mode,
                               key=jax.random.key(0) if mode == "stochastic"
                               else None)
        print(f"  fp8[{mode:10s}] {float(v):.6f} -> {float(q):.6f}")

    # 2. the expanding FMA (paper §II.B.4): fp16 multiply, fp32 accumulate
    pol = PRESETS["em_fp16"]
    a, b, c = jnp.float32(1.0009765625), jnp.float32(1.0009765625), \
        jnp.float32(100.0)
    print(f"\nexpanding FMA fmacex.s.h: {float(tp_fma(a, b, c, pol)):.10f}"
          f"  (fp16 accumulate would lose the product tail)")

    # 3. policy-driven matmul: same code, different formats per op group
    k1, k2 = jax.random.split(jax.random.key(0))
    A = jax.random.normal(k1, (64, 128))
    B = jax.random.normal(k2, (128, 32))
    exact = A @ B
    for name in ("fp32", "tp_bf16", "tp_fp8", "em_fp8"):
        r = tp_einsum("ij,jk->ik", A, B, PRESETS[name])
        err = float(jnp.max(jnp.abs(r.astype(jnp.float32) - exact)))
        print(f"policy {name:8s} mode {PRESETS[name].mode:7s} "
              f"src {PRESETS[name].matmul.src_fmt.name:8s} max|err| {err:.4f}")

    # 4. cast-and-pack (paper §III.A.2c)
    s1 = jnp.arange(4, dtype=jnp.float32)[None]
    s2 = -s1
    packed = cast_and_pack(s1, s2, "fp8", PRESETS["em_fp8"])
    print(f"\ncast-and-pack fp8: {np.asarray(packed)[0]}")

    # 5. the energy model (paper Table IV): why narrow formats pay
    print("\nFMA energy/efficiency (paper's silicon, 0.8V):")
    for fmt in ("fp64", "fp32", "fp16alt", "fp8"):
        print(f"  {fmt:8s} scalar {energy.fma_energy_pj(fmt):6.2f} pJ   "
              f"{energy.fma_efficiency_gflops_w(fmt):8.1f} Gflop/sW")
    print(f"  fp8 SIMD  {energy.fma_energy_pj('fp8', True):6.2f} pJ   "
          f"{energy.fma_efficiency_gflops_w('fp8', True):8.1f} Gflop/sW "
          f"(16.6x fp64)")


if __name__ == "__main__":
    main()
