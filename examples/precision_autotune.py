"""Beyond-paper example: per-layer precision autotuning.

FPnew gives software per-op-group format knobs; this example turns the
knob automatically: starting from the fp32 policy, greedily lower the
matmul source format (fp32 -> bf16 -> fp8) per op-class as long as a
held-out loss degrades less than a tolerance — the transprecision analogue
of AMP search, driven by the paper's energy model as the objective.

Run:  PYTHONPATH=src python examples/precision_autotune.py
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.core.formats import get_format
from repro.core.policy import MatmulPolicy, PrecisionPolicy
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.registry import build_model

LADDER = ["fp32", "fp16alt", "fp8"]


def eval_loss(model, params, batch):
    return float(model.forward_train(params, batch["tokens"],
                                     batch["labels"], remat=False))


def policy_for(src: str, elem: str) -> PrecisionPolicy:
    return PrecisionPolicy(
        name=f"auto_{src}_{elem}", mode="emulate",
        matmul=MatmulPolicy(get_format(src), get_format("fp32"),
                            get_format(src)),
        elem_fmt=elem, param_fmt="fp32")


def modeled_pj_per_flop(src: str) -> float:
    return energy.TPU_PJ_PER_FLOP.get(src, energy.TPU_PJ_PER_FLOP["fp32"])


def main():
    base = build_model("fpnew-case-study", policy="fp32", reduced=True)
    params = base.init(jax.random.key(0))
    data = SyntheticLMData(DataConfig(vocab=base.cfg.vocab, seq_len=128,
                                      global_batch=8, noise=0.0))
    batch = data.batch_at(0)

    tol = 0.02     # allowed loss degradation vs fp32
    ref = None
    print("=== greedy per-op-class precision descent (emulated grids) ===")
    print(f"{'matmul src':11s} {'elem fmt':9s} {'loss':>8s} {'dloss':>8s} "
          f"{'pJ/flop':>8s} {'accepted':>9s}")
    best = ("fp32", "fp32")
    for src, elem in itertools.product(LADDER, ["fp32", "fp16alt"]):
        model = build_model("fpnew-case-study",
                            policy=policy_for(src, elem), reduced=True)
        loss = eval_loss(model, params, batch)
        if ref is None:
            ref = loss
        d = loss - ref
        ok = d <= tol
        cur_e = modeled_pj_per_flop(best[0])
        new_e = modeled_pj_per_flop(src)
        accept = ok and new_e <= cur_e
        if accept:
            best = (src, elem)
        print(f"{src:11s} {elem:9s} {loss:8.4f} {d:+8.4f} "
              f"{new_e:8.2f} {str(accept):>9s}")
    print(f"\nselected: matmul src={best[0]}, elem={best[1]} "
          f"({modeled_pj_per_flop('fp32')/modeled_pj_per_flop(best[0]):.1f}x "
          f"modeled matmul-energy saving vs fp32)")
    assert best[0] != "fp32", "autotune should find a narrower format"


if __name__ == "__main__":
    main()
