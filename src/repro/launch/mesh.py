"""Production and serving mesh definitions.

``make_production_mesh`` / ``make_serving_mesh`` are FUNCTIONS (not module
constants) so importing this module never touches jax device state —
mandatory because the dry-run must set XLA_FLAGS before any jax
initialization.

Version gates (both paths unit-tested by monkeypatching, not just the
installed version's branch):
  * ``jax.make_mesh`` (new in 0.4.35ish) vs. hand-reshaping
    ``jax.devices()`` into ``jax.sharding.Mesh`` — ``_mk_mesh``.
  * ``jax.sharding.AxisType`` (jax >= 0.5 explicit-sharding types) —
    probed with ``hasattr``; 0.4.x meshes take no ``axis_types``.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def _mk_mesh(shape, axes, **kw):
    """Build a Mesh over the first ``prod(shape)`` devices, via
    ``jax.make_mesh`` when this jax has it, else the classic
    ``jax.sharding.Mesh(np.reshape(devices), axes)`` construction."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, **kw)
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"mesh {shape} needs {n} devices, "
                         f"have {len(devs)}")
    return jax.sharding.Mesh(np.array(devs[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
    a second data-parallel axis crossing the DCN/ICI boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    kw = {}
    if hasattr(jax.sharding, "AxisType"):   # jax >= 0.5 (Auto is the
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return _mk_mesh(shape, axes, **kw)      # 0.4.x default)


def make_serving_mesh(dp: int, tp: int):
    """Serving mesh ``(data=dp, model=tp)`` over the first ``dp * tp``
    devices: the ``model`` axis tensor-parallelizes attention heads and
    the paged KV pools inside each engine replica; the ``data`` axis
    indexes data-parallel engine replicas (request queues are partitioned
    host-side — see ``launch/engine.py: ReplicatedEngine``)."""
    if dp < 1 or tp < 1:
        raise ValueError(f"mesh axes must be >= 1, got dp={dp} tp={tp}")
    return _mk_mesh((dp, tp), ("data", "model"))


def replica_meshes(mesh, n: int = None) -> list:
    """One single-axis ``("model",)`` sub-mesh per ``data`` row of a
    serving mesh — each data-parallel engine replica runs its
    tensor-parallel attention over its OWN row of devices, so replicas
    never share a collective.

    ``mesh=None`` with ``n`` set is the MESHLESS fleet: ``n`` unsharded
    engine replicas time-slicing the default device (disjoint page pools,
    no collectives — exactly the replica topology, minus the placement).
    That is how the HA suite exercises replica loss and live-request
    migration on a single-device CPU host."""
    if mesh is None:
        if n is None or n < 1:
            raise ValueError("replica_meshes: mesh=None needs an explicit "
                             f"replica count n >= 1, got {n!r}")
        return [None] * n
    devs = np.asarray(mesh.devices)
    if mesh.axis_names == ("model",):
        return [mesh]
    if mesh.axis_names != ("data", "model"):
        raise ValueError(f"expected a (data, model) serving mesh, got "
                         f"axes {mesh.axis_names}")
    subs = [jax.sharding.Mesh(devs[i], ("model",))
            for i in range(devs.shape[0])]
    if n is not None and n != len(subs):
        raise ValueError(f"mesh data axis has {len(subs)} replicas but "
                         f"replicas={n} was requested")
    return subs


def dp_axes_of(mesh) -> tuple:
    """The batch-sharding axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
