"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — mandatory because the dry-run
must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
    a second data-parallel axis crossing the DCN/ICI boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    kw = {}
    if hasattr(jax.sharding, "AxisType"):   # jax >= 0.5 (Auto is the
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)  # 0.4.x default)


def dp_axes_of(mesh) -> tuple:
    """The batch-sharding axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
