import os


def force_dryrun_devices() -> None:
    """Spawn 512 placeholder CPU devices for production-mesh lowering.

    MUST run before jax's first backend initialization (jax locks the
    device count on first init).  Fired automatically when this module is
    executed as the dry-run tool (``python -m repro.launch.dryrun``), and
    called explicitly by in-process consumers (benchmarks/perf_report)
    before they touch jax.  Deliberately NOT a plain-import side effect:
    importing the parsing helpers from a pytest process must not
    reconfigure that process's devices — tests must see the real single
    CPU device (see conftest.py), and the 512-device layout perturbs XLA:CPU
    codegen enough to break bit-exact kernel-vs-oracle comparisons.
    """
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"


if __name__ == "__main__":
    force_dryrun_devices()

"""Multi-pod dry-run + roofline cost extraction.

Required dry-run (deliverable e): for every (architecture x input-shape)
cell, ``jit(step).lower(...).compile()`` must succeed on BOTH the single-pod
(16, 16) = 256-chip mesh and the multi-pod (2, 16, 16) = 512-chip mesh,
recording ``memory_analysis()`` (fits-per-device proof) and
``cost_analysis()`` + the collective schedule for §Roofline.

Scan-aware cost extraction: XLA's cost_analysis counts a ``while`` body
ONCE regardless of trip count (verified empirically), so raw numbers from a
scan-over-layers program undercount by ~n_layers.  We therefore lower
*R-differential variants* (1 and 2 scanned layer-groups) and reconstruct

    total = V1 + (repeats - 1) * (V2 - V1)                  [exact]

which is exact whenever every *inner* scan has trip count 1 in the variant.
Attention archs achieve that by setting the attention/loss chunk sizes to
the full sequence (same flops/bytes as the chunked schedule — chunking
reassociates, it does not change totals).  SSM/hybrid mixers (mamba2,
mLSTM: chunkwise state recurrence; sLSTM: per-token recurrence) cannot —
their per-layer costs are measured from component variants at S = chunk
(where the trip count IS 1) and scaled linearly (their cost is provably
linear in S), with the sLSTM per-token body separated by a second
S-differential.  Decode steps have no inner scans: the R-differential is
exact for every architecture.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --costs
  python -m repro.launch.dryrun --all --out results/
"""
import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
import traceback

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

ARCH_IDS = [
    "internvl2-26b", "deepseek-v2-lite-16b", "qwen3-moe-30b-a3b",
    "whisper-small", "xlstm-1.3b", "granite-20b", "gemma2-9b",
    "minicpm3-4b", "gemma3-12b", "zamba2-1.2b",
]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e5m2": 1, "f8e4m3fn": 1,
    "f8e4m3": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_LINE = re.compile(
    r"=\s*(\(?[a-z0-9_,\[\]{}\s]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def parse_collectives(hlo_text: str) -> dict:
    """Sum output bytes of every collective op, keyed by (op, group_size).
    Counts each op ONCE (scan bodies are handled by the R-differential)."""
    out: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_LINE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        lhs, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_TOK.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        gm = _GROUPS_IOTA.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            gl = _GROUPS_LIST.search(line)
            gsize = len(gl.group(1).split(",")) if gl else 2
        key = f"{op}@{gsize}"
        rec = out.setdefault(key, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def _coll_diff(a: dict, b: dict) -> dict:
    """a - b per key, clipped at 0."""
    keys = set(a) | set(b)
    out = {}
    for k in keys:
        c = a.get(k, {"count": 0, "bytes": 0})
        d = b.get(k, {"count": 0, "bytes": 0})
        out[k] = {"count": max(c["count"] - d["count"], 0),
                  "bytes": max(c["bytes"] - d["bytes"], 0)}
    return out


def _coll_scale_add(*terms):
    """terms: list of (coeff, coll_dict); returns the weighted sum."""
    out: dict = {}
    for coeff, d in terms:
        for k, v in d.items():
            rec = out.setdefault(k, {"count": 0, "bytes": 0})
            rec["count"] += coeff * v["count"]
            rec["bytes"] += coeff * v["bytes"]
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def build_step(arch_cfg, shape_name, mesh, policy, *, loss_chunk=1024,
               compress=None):
    """Returns (lowered,) for the given cell on the given mesh."""
    import jax
    from ..core.policy import get_policy
    from ..models.transformer import Model
    from ..launch.mesh import dp_axes_of
    from ..optim.optimizer import OptConfig
    from ..train.train_step import jit_train_step
    from ..train.serve_step import make_decode_step, make_prefill

    sh = SHAPES[shape_name]
    pol = get_policy(policy)
    if arch_cfg.narrow_partials:
        pol = pol.replace(narrow_partials=True)
    from ..models.layers import set_seq_parallel
    set_seq_parallel(arch_cfg.seq_parallel)
    model = Model(cfg=arch_cfg, policy=pol)
    dp = dp_axes_of(mesh)
    if sh["kind"] == "train":
        jitted, args, _ = jit_train_step(
            model, OptConfig(), mesh, batch_size=sh["batch"],
            seq_len=sh["seq"], dp_axes=dp, remat=True,
            loss_chunk=loss_chunk, compress_grads=compress)
    elif sh["kind"] == "prefill":
        jitted, args = make_prefill(model, mesh, batch=sh["batch"],
                                    seq_len=sh["seq"], max_len=sh["seq"],
                                    dp_axes=dp)
    else:
        jitted, args = make_decode_step(model, mesh, batch=sh["batch"],
                                        max_len=sh["seq"], dp_axes=dp)
    return jitted, args


def lower_and_compile(arch_cfg, shape_name, mesh, policy, **kw):
    jitted, args = build_step(arch_cfg, shape_name, mesh, policy, **kw)
    t0 = time.time()
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return lowered, compiled, {"lower_s": round(t1 - t0, 2),
                               "compile_s": round(t2 - t1, 2)}


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict (jax >= 0.5) or a one-element
    list of dicts (0.4.x)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def compiled_record(compiled, times) -> dict:
    ma = compiled.memory_analysis()
    ca = _cost_dict(compiled)
    txt = compiled.as_text()
    return {
        "times": times,
        "memory": {
            # jax 0.4.x CompiledMemoryStats has no peak_memory_in_bytes;
            # temp+args+output is the standard upper-bound proxy there
            "peak_bytes": getattr(
                ma, "peak_memory_in_bytes",
                ma.temp_size_in_bytes + ma.argument_size_in_bytes
                + ma.output_size_in_bytes),
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "hlo": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
            "transcendentals": ca.get("transcendentals", 0.0),
        },
        "collectives_static": parse_collectives(txt),
    }


# ---------------------------------------------------------------------------
# required dry-run (one cell x one mesh)
# ---------------------------------------------------------------------------
def _apply_sets(cfg, sets):
    """Apply --set key=value overrides (typed by the dataclass field)."""
    if not sets:
        return cfg
    kw = {}
    for kv in sets:
        k, v = kv.split("=", 1)
        obj, attr = cfg, k
        if "." in k:                      # nested sub-config (mlstm.chunk=...)
            head, attr = k.split(".", 1)
            obj = getattr(cfg, head)
        cur = getattr(obj, attr)
        if isinstance(cur, bool):
            v = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        if obj is cfg:
            kw[attr] = v
        else:
            kw[k.split(".")[0]] = dataclasses.replace(obj, **{attr: v})
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, multi_pod: bool, policy: str,
             compress=None, sets=None) -> dict:
    import jax
    from ..core import ops as tpops
    from ..models.registry import get_config
    from .mesh import make_production_mesh

    tpops.set_mixed_dot(True)   # HLO carries the MXU-native mixed dots
    cfg = _apply_sets(get_config(arch), sets)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "n_devices": mesh.devices.size, "policy": policy,
           "compress": compress, "sets": sets or []}
    if SHAPES[shape_name]["kind"] != "train" and compress:
        rec.update(ok=False, skipped="compress only applies to train")
        return rec
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec.update(ok=False,
                   skipped="full-attention arch: long_500k per assignment")
        return rec
    lowered, compiled, times = lower_and_compile(cfg, shape_name, mesh,
                                                 policy, compress=compress)
    rec.update(ok=True, **compiled_record(compiled, times))
    return rec


# ---------------------------------------------------------------------------
# roofline cost extraction (single-pod mesh only)
# ---------------------------------------------------------------------------
def _variant(cfg, groups: int, *, enc_layers=None, seq_chunks=None,
             drop_suffix=False, pattern=None, full_seq=None):
    kw = {}
    pat = pattern if pattern is not None else cfg.pattern
    prefix = cfg.prefix
    suffix = () if drop_suffix else cfg.suffix
    kw["pattern"] = pat
    kw["prefix"] = prefix
    kw["suffix"] = suffix
    kw["n_layers"] = len(prefix) + len(suffix) + len(pat) * groups
    kw["unroll_scan"] = True   # exact cost_analysis (no while-body undercount)
    if cfg.encoder is not None and enc_layers is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder,
                                            n_layers=enc_layers)
    if full_seq is not None:
        kw["attn_chunk"] = full_seq
    return dataclasses.replace(cfg, **kw)


def _measure(cfg, shape_name, mesh, policy, *, seq=None, batch=None,
             loss_chunk=None):
    """Lower one variant and return its per-device cost terms."""
    sh = dict(SHAPES[shape_name])
    if seq is not None:
        sh = dict(sh, seq=seq)
    if batch is not None:
        sh = dict(sh, batch=batch)
    name = "__tmp"
    local_shapes = {name: sh}
    SHAPES[name] = sh
    try:
        lowered, compiled, times = lower_and_compile(
            cfg, name, mesh, policy,
            loss_chunk=loss_chunk or sh["seq"])
        ca = _cost_dict(compiled)
        return {
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "transcendentals": ca.get("transcendentals", 0.0),
            "coll": parse_collectives(compiled.as_text()),
            "times": times,
        }
    finally:
        del SHAPES[name]


def _lin(v1, v2, repeats):
    """v1 + (repeats-1)*(v2-v1) on scalar terms + collectives."""
    out = {}
    for k in ("flops", "bytes", "transcendentals"):
        out[k] = v1[k] + (repeats - 1) * max(v2[k] - v1[k], 0.0)
    out["coll"] = _coll_scale_add((1, v1["coll"]),
                                  (repeats - 1, _coll_diff(v2["coll"],
                                                           v1["coll"])))
    return out


def _scaled_diff(v1, v2, scale, count):
    """count * scale * (v2-v1)."""
    d = {k: max(v2[k] - v1[k], 0.0) * scale * count
         for k in ("flops", "bytes", "transcendentals")}
    d["coll"] = _coll_scale_add(
        (scale * count, _coll_diff(v2["coll"], v1["coll"])))
    return d


def _add(*terms):
    out = {k: sum(t[k] for t in terms)
           for k in ("flops", "bytes", "transcendentals")}
    out["coll"] = _coll_scale_add(*[(1, t["coll"]) for t in terms])
    return out


def cost_cell(arch: str, shape_name: str, policy: str, sets=None,
              compress=None) -> dict:
    """Scan-corrected per-device cost terms on the single-pod mesh."""
    import jax
    from ..configs.base import LayerSpec
    from ..core import ops as tpops
    from ..models.registry import get_config
    from .mesh import make_production_mesh

    tpops.set_mixed_dot(True)
    cfg = _apply_sets(get_config(arch), sets)
    mesh = make_production_mesh(multi_pod=False)
    sh = SHAPES[shape_name]
    seq = sh["seq"]
    kind = sh["kind"]
    rec = {"arch": arch, "shape": shape_name, "policy": policy,
           "mesh": "16x16", "n_devices": 256, "sets": sets or [],
           "compress": compress}
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec.update(ok=False, skipped="full-attention arch")
        return rec

    ssm_like = cfg.name.startswith(("xlstm", "zamba2"))
    windowed = (cfg.windowed_slice and kind != "decode" and not ssm_like
                and any(s.window for s in cfg.pattern))
    if windowed:
        # windowed-slice recipe: with KV slicing a local layer's cost is
        # LINEAR in S (each query chunk sees a fixed window+chunk slice),
        # so locals are measured by S-differential at small S (the inner
        # chunk map is counted once at both sizes and cancels into the
        # per-chunk body term, exactly like the sLSTM recipe) and globals
        # exactly at full S with chunk = S.
        s1 = max(4 * cfg.attn_chunk, 2048)
        local = tuple(s for s in cfg.pattern if s.window)[:1]
        glob = tuple(s for s in cfg.pattern if not s.window)[:1]
        n_local = sum(1 for s in cfg.layer_list() if s.window)
        n_glob = sum(1 for s in cfg.layer_list()
                     if not s.window and s.mixer in ("gqa", "mla"))
        v1 = _measure(_variant(cfg, 1, full_seq=seq), shape_name, mesh,
                      policy)
        v2 = _measure(_variant(cfg, 2, full_seq=seq), shape_name, mesh,
                      policy)
        base = {k: max(2 * v1[k] - v2[k], 0.0)
                for k in ("flops", "bytes", "transcendentals")}
        base["coll"] = _coll_diff(v1["coll"], _coll_diff(v2["coll"],
                                                         v1["coll"]))
        g1 = _measure(_variant(cfg, 1, pattern=glob, full_seq=seq),
                      shape_name, mesh, policy)
        g2 = _measure(_variant(cfg, 2, pattern=glob, full_seq=seq),
                      shape_name, mesh, policy)
        l1a = _measure(_variant(cfg, 1, pattern=local), shape_name, mesh,
                       policy, seq=s1)
        l2a = _measure(_variant(cfg, 2, pattern=local), shape_name, mesh,
                       policy, seq=s1)
        l1b = _measure(_variant(cfg, 1, pattern=local), shape_name, mesh,
                       policy, seq=2 * s1)
        l2b = _measure(_variant(cfg, 2, pattern=local), shape_name, mesh,
                       policy, seq=2 * s1)
        # d_a = proj(s1) + body (chunk map counted once); d_b = 2proj + body
        d_a = {k: max(l2a[k] - l1a[k], 0.0)
               for k in ("flops", "bytes", "transcendentals")}
        d_b = {k: max(l2b[k] - l1b[k], 0.0)
               for k in ("flops", "bytes", "transcendentals")}
        loc = {k: n_local * ((seq / s1) * max(d_b[k] - d_a[k], 0.0)
                             + (seq / cfg.attn_chunk)
                             * max(2 * d_a[k] - d_b[k], 0.0))
               for k in ("flops", "bytes", "transcendentals")}
        # collectives get the same proj/body decomposition: the layer
        # measured at 2*s1 carries 2x the token-proportional collectives
        c_a = _coll_diff(l2a["coll"], l1a["coll"])   # proj(s1)+body colls
        c_b = _coll_diff(l2b["coll"], l1b["coll"])   # 2 proj(s1)+body
        c_proj = _coll_diff(c_b, c_a)
        c_body = _coll_diff(c_a, c_proj)
        loc["coll"] = _coll_scale_add(
            (n_local * seq / s1, c_proj),
            (n_local * seq / cfg.attn_chunk, c_body))
        total = _add(base, _scaled_diff(g1, g2, 1.0, n_glob), loc)
        rec["method"] = (f"windowed (locals S-diff@{s1} x{n_local}, "
                         f"globals exact x{n_glob})")
    elif kind == "decode" or not ssm_like:
        # EXACT: R-differential; attention/loss chunks at full seq so every
        # inner scan in the variants has trip count 1.
        full_seq = seq if kind != "decode" else None
        enc1 = 1 if cfg.encoder is not None else None
        v1 = _measure(_variant(cfg, 1, enc_layers=enc1, full_seq=full_seq),
                      shape_name, mesh, policy)
        v2 = _measure(_variant(cfg, 2, enc_layers=enc1, full_seq=full_seq),
                      shape_name, mesh, policy)
        total = _lin(v1, v2, cfg.repeats)
        if cfg.encoder is not None:
            v3 = _measure(_variant(cfg, 1, enc_layers=2, full_seq=full_seq),
                          shape_name, mesh, policy)
            total = _add(total,
                         _scaled_diff(v1, v3, 1.0,
                                      cfg.encoder.n_layers - 1))
        rec["method"] = "R-diff exact" + (" +enc-diff" if cfg.encoder
                                          else "")
    elif cfg.name.startswith("zamba2"):
        # base from 2*V1 - V2 at full seq (miscounted inner bodies cancel),
        # + 32 mamba layers measured at S=chunk (trip 1) scaled by S/chunk,
        # + 6 shared-attention layers measured exactly at full seq.
        c = cfg.mamba.chunk
        v1 = _measure(_variant(cfg, 1, drop_suffix=True, full_seq=seq),
                      shape_name, mesh, policy)
        v2 = _measure(_variant(cfg, 2, drop_suffix=True, full_seq=seq),
                      shape_name, mesh, policy)
        base = {k: max(2 * v1[k] - v2[k], 0.0)
                for k in ("flops", "bytes", "transcendentals")}
        base["coll"] = _coll_diff(v1["coll"], _coll_diff(v2["coll"],
                                                         v1["coll"]))
        m_pat = (LayerSpec(mixer="mamba2", ffn="none"),)
        m1 = _measure(_variant(cfg, 1, pattern=m_pat, drop_suffix=True),
                      shape_name, mesh, policy, seq=c)
        m2 = _measure(_variant(cfg, 2, pattern=m_pat, drop_suffix=True),
                      shape_name, mesh, policy, seq=c)
        a_pat = (cfg.shared_block,)
        a1 = _measure(_variant(cfg, 1, pattern=a_pat, drop_suffix=True,
                               full_seq=seq), shape_name, mesh, policy)
        a2 = _measure(_variant(cfg, 2, pattern=a_pat, drop_suffix=True,
                               full_seq=seq), shape_name, mesh, policy)
        n_mamba = sum(1 for s in cfg.layer_list() if s.mixer == "mamba2")
        n_sh = sum(1 for s in cfg.layer_list() if s.mixer == "shared_attn")
        total = _add(base,
                     _scaled_diff(m1, m2, seq / c, n_mamba),
                     _scaled_diff(a1, a2, 1.0, n_sh))
        rec["method"] = f"ssm-decomposed (mamba@S={c} x{seq//c}, attn exact)"
    else:  # xlstm
        c = cfg.mlstm.chunk
        v1 = _measure(_variant(cfg, 1, full_seq=seq), shape_name, mesh,
                      policy)
        v2 = _measure(_variant(cfg, 2, full_seq=seq), shape_name, mesh,
                      policy)
        base = {k: max(2 * v1[k] - v2[k], 0.0)
                for k in ("flops", "bytes", "transcendentals")}
        base["coll"] = _coll_diff(v1["coll"], _coll_diff(v2["coll"],
                                                         v1["coll"]))
        m_pat = (LayerSpec(mixer="mlstm", ffn="none"),)
        m1 = _measure(_variant(cfg, 1, pattern=m_pat), shape_name, mesh,
                      policy, seq=c)
        m2 = _measure(_variant(cfg, 2, pattern=m_pat), shape_name, mesh,
                      policy, seq=c)
        # sLSTM: exact 1-layer cost at small S with the time scan fully
        # unrolled, scaled linearly (everything in the layer is linear in
        # S).  The earlier S-differential decomposition amplified fusion
        # noise by ~S and was abandoned (see EXPERIMENTS.md §Perf).
        from ..models import ssm as ssm_mod
        s_pat = (LayerSpec(mixer="slstm", ffn="none"),)
        s_small = 32
        ssm_mod.set_unroll_time(True)
        try:
            s1u = _measure(_variant(cfg, 1, pattern=s_pat), shape_name,
                           mesh, policy, seq=s_small)
            s2u = _measure(_variant(cfg, 2, pattern=s_pat), shape_name,
                           mesh, policy, seq=s_small)
        finally:
            ssm_mod.set_unroll_time(False)
        n_m = sum(1 for s in cfg.layer_list() if s.mixer == "mlstm")
        n_s = sum(1 for s in cfg.layer_list() if s.mixer == "slstm")
        slstm = _scaled_diff(s1u, s2u, seq / s_small, n_s)
        total = _add(base, _scaled_diff(m1, m2, seq / c, n_m), slstm)
        rec["method"] = (f"ssm-decomposed (mlstm@S={c} x{seq//c}, "
                         f"slstm unrolled@S=32 x{n_s})")
    rec.update(ok=True, **{k: total[k]
                           for k in ("flops", "bytes", "transcendentals")})
    rec["coll"] = total["coll"]
    counts = cfg.param_counts()
    rec["params"] = counts
    return rec


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def all_cells():
    from ..models.registry import get_config
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape == "long_500k" and not cfg.sub_quadratic:
                continue
            yield arch, shape


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--policy", default="tp_bf16")
    p.add_argument("--compress", default=None)
    p.add_argument("--costs", action="store_true",
                   help="roofline cost extraction instead of plain compile")
    p.add_argument("--set", action="append", dest="sets", default=[],
                   help="config override key=value (repeatable)")
    p.add_argument("--json", default=None, help="write record to this file")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="results")
    p.add_argument("--skip-existing", action="store_true", default=True)
    args = p.parse_args(argv)

    if args.all:
        os.makedirs(args.out, exist_ok=True)
        jobs = []
        for arch, shape in all_cells():
            for mp in (False, True):
                tag = f"dryrun_{arch}_{shape}_{'pod2' if mp else 'pod1'}"
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--policy",
                       args.policy, "--json",
                       os.path.join(args.out, tag + ".json")]
                if mp:
                    cmd.append("--multi-pod")
                jobs.append((tag, cmd))
            tag = f"costs_{arch}_{shape}"
            jobs.append((tag, [sys.executable, "-m", "repro.launch.dryrun",
                               "--arch", arch, "--shape", shape, "--costs",
                               "--policy", args.policy, "--json",
                               os.path.join(args.out, tag + ".json")]))
        for tag, cmd in jobs:
            outfile = cmd[cmd.index("--json") + 1]
            if args.skip_existing and os.path.exists(outfile):
                print(f"[skip] {tag}")
                continue
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               env={**os.environ})
            ok = r.returncode == 0 and os.path.exists(outfile)
            print(f"[{'ok' if ok else 'FAIL'}] {tag} "
                  f"({time.time()-t0:.0f}s)")
            if not ok:
                err = {"tag": tag, "returncode": r.returncode,
                       "stderr": r.stderr[-4000:]}
                with open(outfile + ".err", "w") as f:
                    json.dump(err, f, indent=1)
        return

    assert args.arch and args.shape
    try:
        if args.costs:
            rec = cost_cell(args.arch, args.shape, args.policy,
                            sets=args.sets, compress=args.compress)
        else:
            rec = run_cell(args.arch, args.shape, args.multi_pod,
                           args.policy, compress=args.compress,
                           sets=args.sets)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "ok": False,
               "error": traceback.format_exc()[-4000:]}
        print(json.dumps(rec, indent=1))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rec, f, indent=1)
        sys.exit(1)
    print(json.dumps(rec, indent=1, default=float))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=1, default=float)


if __name__ == "__main__":
    main()
