"""Crash-consistent request journal for the serving engine.

The continuous-batching engine is a pure function of its request queue —
same queue, same tokens — but that determinism only helps RECOVERY if
someone remembers how far each request got before the crash.  This module
is that memory: a host-side, append-only journal of scheduler FACTS
(admissions, per-burst emitted-token deltas, preempt/swap/escalation/
migration events, completions) that a restarted engine replays to resume
every unfinished request from its last journaled token.

Design rules (what makes it crash-consistent rather than merely a log):

  * **Append-only, facts only.**  A record is written AFTER the work it
    describes completed on the host (a burst's tokens are journaled once
    the burst returned, an admission once the slot is installed).  The
    journal never records intent, so replay never has to undo anything.
  * **Atomic-enough appends.**  File-backed journals write one JSON line
    per record and flush+fsync before ``append`` returns.  A crash can
    tear at most the line being written; :meth:`load` discards a torn
    tail (the unparseable last line) and everything before it is intact.
  * **Replay = re-ingest.**  ``emitted(rid)`` reconstructs each request's
    journaled token stream; a recovering engine seeds its queue entry
    with exactly the free-and-reingest resume state the preemption path
    already bit-parity-tests (prompt + emitted[:-1] re-prefilled, the
    last journaled token re-fed) — so a crash/restart run's tokens are
    bit-identical to the run that never failed.  A request whose
    ``finish`` record made it to the journal is not re-served at all:
    its tokens come straight from the record.

The journal deliberately does NOT checkpoint device state (KV pages,
caches): pages are derived data, recomputable bit-exactly from tokens.
Journaling tokens instead of tensors is what keeps the write path cheap
enough to sit on every burst boundary.

``launch/engine.py`` writes the records; ``train.fault.run_with_restarts``
over a journaled ``ReplicatedEngine`` is the end-to-end recovery story.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional


class RequestJournal:
    """Append-only journal of serving events, optionally file-backed.

    ``path=None`` keeps the journal in memory (tests, single-process
    recovery: the object outlives the engine).  With a path, every
    record is appended as one JSON line and fsync'd, so the journal
    survives a process crash; :meth:`load` recovers it, discarding a
    torn tail line.

    Record shape: ``{"kind": <str>, ...payload}``.  Kinds written by the
    engine: ``admit``, ``tokens`` (the per-burst emitted delta),
    ``preempt``, ``migrate``, ``escalate``, ``finish``, ``replay``.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.records: List[dict] = []
        self._fh = open(path, "a", encoding="utf-8") if path else None

    # -- write side -------------------------------------------------------
    def append(self, kind: str, **payload) -> None:
        rec = {"kind": kind, **payload}
        self.records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, separators=(",", ":")) + "\n")
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- recovery side ----------------------------------------------------
    @classmethod
    def load(cls, path: str) -> "RequestJournal":
        """Recover a file-backed journal.  A torn tail (crash mid-append:
        the last line fails to parse, or parses but its newline never
        landed) is dropped AND truncated from the file — otherwise the
        recovery run's first append would concatenate onto the
        half-written line and corrupt the journal for the NEXT recovery.
        A torn line anywhere else means the file was damaged by something
        other than an append crash and is a hard error."""
        j = cls.__new__(cls)
        j.path = path
        j.records = []
        j._fh = None
        with open(path, "rb") as f:
            data = f.read()
        off, n = 0, len(data)
        while off < n:
            nl = data.find(b"\n", off)
            end = n if nl < 0 else nl
            line = data[off:end]
            if line.strip():
                try:
                    rec = json.loads(line.decode("utf-8"))
                except ValueError:
                    if nl >= 0 and data[end + 1:].strip():
                        raise ValueError(
                            f"journal {path} corrupt at byte {off} (not "
                            f"the tail): {line[:80]!r}")
                    break               # torn tail: the crash-torn append
                if nl < 0:
                    break   # whole record, torn newline: same lost quantum
                j.records.append(rec)
            if nl < 0:
                off = n
                break
            off = nl + 1
        if off < n:
            with open(path, "r+b") as f:    # drop the torn tail from the
                f.truncate(off)             # file, not just from memory
        j._fh = open(path, "a", encoding="utf-8")
        return j

    # -- digests ----------------------------------------------------------
    def emitted(self, rid: int) -> List[int]:
        """The request's journaled token stream so far: every ``tokens``
        delta in append order.  This is the replay frontier — a recovery
        run resumes generation immediately after these tokens."""
        out: List[int] = []
        for r in self.records:
            if r["kind"] == "tokens" and r["rid"] == rid:
                out.extend(r["toks"])
        return out

    def finish_record(self, rid: int) -> Optional[dict]:
        """The ``finish`` record, if the request completed before the
        crash (its tokens need no re-serving at all)."""
        for r in self.records:
            if r["kind"] == "finish" and r["rid"] == rid:
                return r
        return None

    def unfinished(self, rids) -> List[int]:
        done = {r["rid"] for r in self.records if r["kind"] == "finish"}
        return [rid for rid in rids if rid not in done]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r["kind"]] = out.get(r["kind"], 0) + 1
        return out
