"""Serving launcher: batched prefill + decode.

The decode loop is a single compiled ``lax.scan`` (``Model.generate``) —
one XLA dispatch for the whole generation.  ``--loop python`` keeps the
seed per-step loop (one dispatch per token) for A/B comparison; the
benchmark in benchmarks/serve_decode.py tracks the two paths over time.

``--ragged`` packs MIXED-length prompts into one right-padded batch (row
``b`` gets a length cycling over 1/4, 1/2, 3/4 and 4/4 of ``--prompt-len``)
and serves it with per-sequence lengths: each row prefills, masks and
decodes at its OWN length, and the Pallas kernels prune each row's KV walk
there instead of paying the longest prompt's grid for every row.
``--stop-token`` enables per-row EOS early-exit: a row that emits the stop
token freezes (its outputs stay the stop token, its live cache stops
growing) while the rest of the batch keeps decoding.

``--paged`` serves from a paged KV cache (``--page-size`` tokens per page):
a shared page pool + per-row block tables instead of contiguous per-row
buffers.  When the batch is uniform, the launcher also runs the
PREFIX-SHARING demo: every row's prompt shares a common first half, the
common pages are allocated ONCE and aliased into every row's table
(``models.paged.build_tables``), and the launcher verifies that prefill
logits and generated tokens are bit-identical to the unshared identity
layout while the pool holds fewer live pages.  With ``--ragged`` the
identity table is used (per-row lengths + paged pool, no sharing demo).

``--continuous`` serves a REQUEST QUEUE through the continuous-batching
engine (``launch/engine.py``) instead of one fixed batch: requests are
admitted into freed batch slots mid-generation, prompts prefill in chunks
through the paged flash read path interleaved with decode rounds, each
row decodes only to its OWN budget (``while_loop`` bursts exit the round
any row finishes), and a finished row's pages return to the allocator
that round.  The queue comes from ``--arrival-trace`` (comma-separated
``arrival:prompt_len:max_new[:priority[:deadline]]`` tuples, arrivals
and deadlines in decode rounds) or defaults to the deterministic
heavy-tail trace of the benchmark (``engine.synthetic_trace``).
Implies ``--paged``; the printout shows per-request admit/finish
rounds, slot occupancy, and the page pool's high-water mark against
the fixed-batch equivalent.

The engine's overload controls are exposed directly: ``--priority``
and ``--deadline-ms`` annotate the queue (milliseconds are converted
to decode rounds via ``--round-ms``, the assumed per-round latency
budget), ``--pool-pages`` constrains the page pool so preemption and
shedding actually engage, ``--preempt free|swap`` picks the eviction
mechanism, ``--degrade-fmt fp8`` stores swapped victims' K/V in fp8 on
the host (transprecision graceful degradation; quality-sensitive
requests refuse it via the trace), ``--no-shed`` restores blocking
admission, and ``--soak`` swaps in the bursty overload trace with
injected faults (``--fault-exhaust/--fault-poison/--fault-slow``) —
the robustness counters (preempted/shed/degraded/deadline-miss) print
after the run.  Non-finite logits abort serving with
``PoisonedLogitsError`` unless a masking fault plan is active — the
solo path enables the same guard via ``generate(guard_nonfinite=)``.

``--speculate K`` (requires ``--continuous``) turns on self-speculative
decoding: every burst round drafts K tokens per row with a cheap pass
(``--draft-layers N`` runs only the first N repeats of the scanned layer
stack; ``--draft-fmt tp_bf16_kv8`` drafts under a narrower precision
policy — the FPnew energy-proportionality move applied to decoding),
then ONE chunk-scoring call at the serving policy verifies all K+1
positions and accepts the longest matching prefix.  Greedy-only: the
accepted stream is bit-identical to plain decode, a wrong draft can
only cost speed, never tokens.  The accept rate prints after the run.

Numerical health (requires ``--policy fp32``, the wide-container pool):
``--escalate fp8,fp16,fp16alt`` turns on flag-driven KV-precision
escalation — every row's K/V is quantized at write time to its current
ladder rung (saturating, so overflow clamps instead of poisoning the
logits) and the per-row IEEE OF/UF flag counts accumulate as pressure;
a row whose overflow pressure crosses ``--escalate-of-threshold`` is
re-ingested one rung wider.  ``--fault-overflow`` scales K/V writes by
``--overflow-scale`` at the listed decode rounds (the write-side twin
of ``--fault-poison``), and ``--fault-corrupt-swap`` flips one bit in
the listed swap-out events' host payloads — the swap-in checksum must
detect each corruption and recover via re-ingest.  ``--burst-cap``
bounds decode-burst length (escalation decisions happen between
bursts, so shorter bursts react faster).

Replica-level fault tolerance: ``--replicas N`` runs a meshless fleet
of N engine replicas over disjoint page pools (``--mesh DP,TP`` is the
placed equivalent), ``--fault-replica R:BURST[:MODE]`` kills (default)
or hangs replica R at its BURST-th compiled burst, ``--migrate
swap|reingest`` picks how a dead replica's in-flight requests move to a
survivor (CRC-verified swap-blob continuations need ``--preempt swap``
and a hang — a kill's device memory is gone, so migration always falls
back to free-and-reingest from host-side emitted tokens), and
``--journal PATH`` appends a crash-consistent JSON-line request journal
that a full restart replays so every unfinished request resumes from
its last journaled token with bit-identical results.  The replica HA
counters (kills/hangs/migrations + per-replica heartbeats) print after
the run.

``python -m repro.launch.serve --arch gemma2-9b --batch 4 --gen 32``
``python -m repro.launch.serve --arch gemma2-9b --ragged --stop-token 13``
``python -m repro.launch.serve --arch gemma2-9b --paged --page-size 16``
``python -m repro.launch.serve --arch gemma2-9b --continuous --slots 4``
``python -m repro.launch.serve --continuous --arrival-trace 0:32:8,2:16:24``
"""
from __future__ import annotations

import argparse
import time


def ragged_lengths(batch: int, prompt_len: int):
    """The mixed-length pack of ``--ragged``: rows cycle over 1/4, 1/2,
    3/4, 4/4 of ``prompt_len`` (clamped to >= 1), longest rows last so the
    printout reads like the padded batch."""
    fracs = (0.25, 0.5, 0.75, 1.0)
    return [max(1, int(prompt_len * fracs[i % len(fracs)]))
            for i in range(batch)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--policy", default="tp_bf16")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--loop", choices=("scan", "python"), default="scan")
    ap.add_argument("--decode-backend", choices=("dense", "pallas", "auto"),
                    default="auto",
                    help="pallas: fused in-kernel KV-dequant decode attention;"
                         " auto (default): pallas off-CPU, dense on CPU")
    ap.add_argument("--prefill-backend", choices=("dense", "pallas", "auto"),
                    default="auto",
                    help="pallas: pruned-grid flash-attention prefill kernel;"
                         " auto (default): pallas off-CPU, dense on CPU")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="> 0 enables sampling (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--top-p", type=float, default=None)
    ap.add_argument("--repetition-penalty", type=float, default=None,
                    help="> 1 discourages re-emitting seen tokens (HF "
                         "semantics; prompt + generated counts)")
    ap.add_argument("--presence-penalty", type=float, default=None,
                    help="> 0 flat-penalizes every seen token (OpenAI "
                         "semantics)")
    ap.add_argument("--seed", type=int, default=0, help="sampling PRNG seed")
    ap.add_argument("--ragged", action="store_true",
                    help="pack mixed-length prompts (1/4..4/4 of "
                         "--prompt-len) into one padded batch and serve "
                         "each row at its own length (scan loop only)")
    ap.add_argument("--stop-token", type=int, default=None,
                    help="per-row EOS early-exit: rows freeze after "
                         "emitting this token id (scan loop only)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: shared page pool + per-row block "
                         "tables; uniform batches also run the "
                         "prefix-sharing parity demo (scan loop only)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (the paged decode kernel's KV "
                         "block; use >= 128 on real TPUs)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine: admission queue, "
                         "chunked prefill, per-request budgets, page "
                         "recycling (implies --paged)")
    ap.add_argument("--arrival-trace", default=None,
                    help="comma-separated arrival:prompt_len:max_new"
                         "[:priority[:deadline]] tuples (arrival/deadline "
                         "in decode rounds); default: the benchmark's "
                         "synthetic heavy-tail trace")
    ap.add_argument("--priority", type=int, default=0,
                    help="priority class stamped on default-trace requests "
                         "(higher admits first and preempts lower)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline in milliseconds, converted "
                         "to decode rounds via --round-ms and applied as "
                         "arrival + rounds; missed deadlines are counted "
                         "per request and in the stats")
    ap.add_argument("--round-ms", type=float, default=1.0,
                    help="assumed per-decode-round latency budget used to "
                         "convert --deadline-ms to the engine's round clock")
    ap.add_argument("--shed", dest="shed", action="store_true", default=True,
                    help="defer unplaceable requests with jittered "
                         "exponential backoff instead of blocking (default)")
    ap.add_argument("--no-shed", dest="shed", action="store_false",
                    help="head-of-line blocking admission (no backoff)")
    ap.add_argument("--preempt", choices=("free", "swap"), default="free",
                    help="eviction mechanism under pressure: free pages + "
                         "re-ingest on resume, or swap K/V pages to a "
                         "host-side store and restore them")
    ap.add_argument("--degrade-fmt", default=None,
                    help="store swapped victims' K/V in this format on the "
                         "host (e.g. fp8) — transprecision graceful "
                         "degradation; implies --preempt swap")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="page-pool size override (small pools exercise "
                         "preemption/shedding; default: worst-case fit)")
    ap.add_argument("--soak", action="store_true",
                    help="overload soak: bursty synthetic trace (priorities,"
                         " deadlines, long documents) + injected faults")
    ap.add_argument("--fault-exhaust", default=None,
                    help="comma-separated rounds at which the fault plan "
                         "grabs the whole free page list for a few rounds")
    ap.add_argument("--fault-poison", default=None,
                    help="comma-separated decode rounds whose logits are "
                         "NaN-poisoned inside the burst (masked + counted)")
    ap.add_argument("--fault-slow", default=None,
                    help="comma-separated rounds stalled before their burst "
                         "(straggler injection)")
    ap.add_argument("--escalate", default=None,
                    help="comma-separated KV-format ladder (e.g. "
                         "fp8,fp16,fp16alt): flag-driven precision "
                         "escalation on a fp32 pool — rows quantize K/V "
                         "writes at their rung (saturating) and escalate "
                         "one rung when overflow pressure crosses the "
                         "threshold (requires --policy fp32)")
    ap.add_argument("--escalate-of-threshold", type=int, default=8,
                    help="per-request overflow-flag count that triggers "
                         "escalation one rung up the ladder")
    ap.add_argument("--fault-overflow", default=None,
                    help="comma-separated decode rounds whose K/V writes "
                         "are scaled by --overflow-scale before write-time "
                         "quantization (drives the escalation path)")
    ap.add_argument("--overflow-scale", type=float, default=65536.0,
                    help="multiplier applied to K/V writes at "
                         "--fault-overflow rounds")
    ap.add_argument("--fault-corrupt-swap", default=None,
                    help="comma-separated swap-out event indices (0-based) "
                         "whose host payloads get one bit flipped — the "
                         "swap-in checksum must detect and re-ingest")
    ap.add_argument("--burst-cap", type=int, default=64,
                    help="max decode rounds per compiled burst (escalation "
                         "acts between bursts; smaller reacts faster)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per "
                         "row with the cheap pass, verify the whole chunk "
                         "at target precision in ONE call, accept the "
                         "longest matching prefix (greedy-only; accepted "
                         "tokens are bit-identical to plain decode)")
    ap.add_argument("--draft-layers", type=int, default=None, metavar="N",
                    help="layer-skip draft: run only the first N repeats "
                         "of the scanned layer pattern in the draft pass "
                         "(default: full depth — the draft is then the "
                         "target model and every proposal is accepted)")
    ap.add_argument("--draft-fmt", default=None, metavar="POLICY",
                    help="precision-policy preset the DRAFT pass runs "
                         "under (e.g. tp_bf16_kv8: fp8 KV reads for "
                         "proposals; verify stays at the serving policy)")
    ap.add_argument("--slots", type=int, default=4,
                    help="batch slots of the continuous engine")
    ap.add_argument("--requests", type=int, default=16,
                    help="request count of the default synthetic trace")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk width of the continuous engine")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="serving mesh dp,tp: tp-way tensor-parallel "
                         "attention heads + paged KV pools per replica, "
                         "dp data-parallel engine replicas (dp > 1 "
                         "requires --continuous)")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="simulate N host devices (prepends "
                         "--xla_force_host_platform_device_count=N to "
                         "XLA_FLAGS before jax initializes — CPU bring-up "
                         "for --mesh; no effect on real accelerators)")
    ap.add_argument("--replicas", type=int, default=None, metavar="N",
                    help="meshless HA fleet: N unsharded engine replicas "
                         "time-slicing the default device (disjoint page "
                         "pools; the replica topology without --mesh "
                         "placement — requires --continuous)")
    ap.add_argument("--fault-replica", default=None, metavar="R:BURST[:MODE]",
                    help="replica-level fault injection: replica R dies at "
                         "its BURST-th compiled burst; MODE is kill "
                         "(device memory gone, raised through dispatch — "
                         "default) or hang (stops stepping, declared dead "
                         "after missed heartbeats, memory still readable)")
    ap.add_argument("--migrate", choices=("swap", "reingest"),
                    default="swap",
                    help="live-request migration mode when a replica is "
                         "lost: adopt CRC-verified swap-blob continuations "
                         "on a survivor (needs --preempt swap and readable "
                         "victim memory) or free-and-reingest from emitted "
                         "tokens (always available)")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="append-only crash-consistent request journal "
                         "(JSON lines): admissions, per-burst token "
                         "deltas, preemptions, migrations, finishes — "
                         "replayed on engine start so a full restart "
                         "resumes every unfinished request from its last "
                         "journaled token with bit-identical results")
    args = ap.parse_args(argv)
    if ((args.ragged or args.paged or args.stop_token is not None
         or args.continuous) and args.loop != "scan"):
        ap.error("--ragged / --paged / --stop-token / --continuous require "
                 "--loop scan (the per-step python loop is the "
                 "uniform-batch seed path)")
    if args.arrival_trace and not args.continuous:
        args.continuous = True          # a request queue implies the engine
    if args.continuous and args.ragged:
        ap.error("--continuous subsumes --ragged (per-request lengths)")
    pen = (args.repetition_penalty is not None
           or args.presence_penalty is not None)
    if pen and args.loop != "scan":
        ap.error("--repetition-penalty / --presence-penalty apply to the "
                 "scan/while generate() and continuous-engine paths only")
    if args.speculate:
        if not args.continuous:
            ap.error("--speculate requires --continuous (the draft/verify "
                     "rounds live in the engine's burst program)")
        if args.temperature > 0.0 or pen:
            ap.error("--speculate is greedy-only: temperature and "
                     "penalties would change the verified stream")
    mesh_dims = None
    if args.mesh is not None:
        try:
            dp, tp = (int(x) for x in args.mesh.split(","))
        except ValueError:
            ap.error("--mesh expects DP,TP (e.g. --mesh 2,4)")
        if dp < 1 or tp < 1:
            ap.error(f"--mesh axes must be >= 1, got {dp},{tp}")
        if dp > 1 and not args.continuous:
            ap.error("--mesh with dp > 1 requires --continuous (the data "
                     "axis is engine replication)")
        if args.mesh is not None and args.loop != "scan":
            ap.error("--mesh requires --loop scan")
        mesh_dims = (dp, tp)
    if args.replicas is not None:
        if args.replicas < 1:
            ap.error(f"--replicas must be >= 1, got {args.replicas}")
        if not args.continuous:
            ap.error("--replicas requires --continuous (replicas are "
                     "engine instances over the request queue)")
    fault_replica = None
    if args.fault_replica is not None:
        parts = args.fault_replica.split(":")
        if len(parts) not in (2, 3):
            ap.error("--fault-replica expects R:BURST[:MODE] "
                     "(e.g. 0:3 or 1:5:hang)")
        try:
            fr, fb = int(parts[0]), int(parts[1])
        except ValueError:
            ap.error("--fault-replica R and BURST must be integers")
        fmode = parts[2] if len(parts) == 3 else "kill"
        if fmode not in ("kill", "hang"):
            ap.error(f"--fault-replica MODE must be kill|hang, "
                     f"got {fmode!r}")
        if args.replicas is None and (mesh_dims is None
                                      or mesh_dims[0] < 2):
            ap.error("--fault-replica needs a replicated engine "
                     "(--replicas N or --mesh with dp > 1) — a lone "
                     "replica's loss has no survivor to migrate to")
        fault_replica = (fr, fb, fmode)
    if args.devices is not None:
        # must land in the environment BEFORE jax initializes its backend
        import os
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        os.environ["XLA_FLAGS"] = \
            (flag + " " + os.environ.get("XLA_FLAGS", "")).strip()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..models.registry import build_model

    model = build_model(args.arch, policy=args.policy, reduced=args.reduced)
    model = model.with_cfg(decode_backend=args.decode_backend,
                           prefill_backend=args.prefill_backend)
    if args.paged or args.continuous:
        model = model.with_cfg(paged_kv=True, page_size=args.page_size)
    params = model.init(jax.random.key(0))

    mesh = rmesh = None
    dp = 1
    if mesh_dims is not None:
        from .mesh import make_serving_mesh, replica_meshes
        dp, tp = mesh_dims
        mesh = make_serving_mesh(dp, tp)
        rmesh = replica_meshes(mesh)[0]     # one replica's ("model",) row
        print(f"serving mesh: {dp} data-parallel replica(s) x {tp}-way "
              f"tensor parallel over {dp * tp} of {jax.device_count()} "
              f"devices")

    if args.continuous:
        import dataclasses as _dc

        from ..train.fault import ReplicaFaultPlan, ServeFaultPlan
        from .engine import (ContinuousEngine, ReplicatedEngine, Request,
                             synthetic_trace)
        from .journal import RequestJournal
        dl_rounds = (None if args.deadline_ms is None
                     else max(1, int(args.deadline_ms / args.round_ms)))
        if args.arrival_trace:
            reqs = []
            for i, tup in enumerate(args.arrival_trace.split(",")):
                parts = [int(x) for x in tup.split(":")]
                arr, plen, budget = parts[:3]
                pri = parts[3] if len(parts) > 3 else args.priority
                dl = (parts[4] if len(parts) > 4
                      else (arr + dl_rounds if dl_rounds else None))
                toks = jax.random.randint(jax.random.key(100 + i), (plen,),
                                          0, model.cfg.vocab)
                reqs.append(Request(rid=i, tokens=[int(t) for t in toks],
                                    max_new=budget, arrival=arr,
                                    priority=pri, deadline=dl))
        else:
            reqs = synthetic_trace(
                args.requests, args.slots, args.prompt_len, args.gen,
                model.cfg.vocab,
                flavor="soak" if args.soak else "chat")
            if args.priority or dl_rounds is not None:
                reqs = [_dc.replace(
                    r, priority=r.priority or args.priority,
                    deadline=(r.arrival + dl_rounds if dl_rounds
                              else r.deadline)) for r in reqs]
        plan = None
        rounds = lambda s: tuple(int(x) for x in s.split(",")) if s else ()
        if (args.fault_exhaust or args.fault_poison or args.fault_slow
                or args.fault_overflow or args.fault_corrupt_swap
                or args.soak):
            plan = ServeFaultPlan(
                exhaust_at=rounds(args.fault_exhaust) or
                ((args.gen,) if args.soak else ()),
                slow_at=rounds(args.fault_slow),
                poison_at=rounds(args.fault_poison),
                mask_poison=True,
                overflow_at=rounds(args.fault_overflow),
                overflow_scale=args.overflow_scale,
                corrupt_swap_at=rounds(args.fault_corrupt_swap))
        if args.degrade_fmt is not None:
            args.preempt = "swap"       # degradation rides the swap store
        esc = None
        if args.escalate is not None:
            from ..core.policy import EscalationPolicy
            esc = EscalationPolicy(
                ladder=tuple(args.escalate.split(",")),
                of_threshold=args.escalate_of_threshold)
        # speculative headroom: the verify chunk writes spec_k slots
        # past each row's budget, so the cache rows grow by K
        max_len = max(r.prompt_len + r.max_new for r in reqs) + args.speculate
        eng_kw = dict(slots=args.slots, max_len=max_len, chunk=args.chunk,
                      spec_k=args.speculate, draft_repeats=args.draft_layers,
                      draft_policy=args.draft_fmt,
                      n_pages=args.pool_pages, stop_token=args.stop_token,
                      temperature=args.temperature,
                      top_k=args.top_k, top_p=args.top_p,
                      seed=args.seed, burst_cap=args.burst_cap,
                      repetition_penalty=args.repetition_penalty,
                      presence_penalty=args.presence_penalty,
                      preempt=args.preempt, degrade_fmt=args.degrade_fmt,
                      shed=args.shed, fault_plan=plan, escalate=esc)
        rplan = None
        if fault_replica is not None:
            rplan = ReplicaFaultPlan(replica=fault_replica[0],
                                     at_burst=fault_replica[1],
                                     mode=fault_replica[2])
        journal = (RequestJournal(args.journal)
                   if args.journal is not None else None)
        replicated = dp > 1 or (args.replicas or 0) > 1
        if replicated:
            eng = ReplicatedEngine(model, params, mesh=mesh,
                                   replicas=args.replicas,
                                   migrate=args.migrate,
                                   replica_fault=rplan, journal=journal,
                                   **eng_kw)
        else:
            eng = ContinuousEngine(model, params, mesh=rmesh,
                                   journal=journal, **eng_kw)
        if rplan is not None or journal is not None:
            # single shot: the fault plan fires once per process and the
            # journal must stay one run's crash-consistent story — no
            # warm-up pass (compile time lands in the reported wall time)
            t0 = time.time()
            fin, stats = eng.run(reqs)
        else:
            fin, stats = eng.run(reqs)      # compile + warm
            t0 = time.time()
            fin, stats = eng.run(reqs)
        dt = time.time() - t0
        print(f"continuous engine: {args.slots} slots, page="
              f"{args.page_size}, chunk={args.chunk}, "
              f"{len(reqs)} requests, pool {stats['n_pages']} pages, "
              f"preempt={args.preempt}"
              + (f", degrade={args.degrade_fmt}" if args.degrade_fmt
                 else "")
              + (f", speculate k={args.speculate}"
                 + (f" draft_layers={args.draft_layers}"
                    if args.draft_layers is not None else "")
                 + (f" draft_fmt={args.draft_fmt}"
                    if args.draft_fmt else "")
                 if args.speculate else "")
              + (f", mesh {mesh_dims[0]}x{mesh_dims[1]}"
                 if mesh_dims else "")
              + (f", replicas={len(eng.engines)} migrate={args.migrate}"
                 if replicated else ""))
        for f in fin:
            trail = ""
            if f.preemptions:
                trail += f" preempted x{f.preemptions}"
            if f.sheds:
                trail += f" shed x{f.sheds}"
            if f.degraded:
                trail += " degraded"
            if f.escalated:
                trail += f" escalated L{f.escalated}"
            if f.deadline is not None:
                trail += (" DEADLINE MISS" if f.deadline_miss
                          else f" met r{f.deadline}")
            print(f"  req {f.rid:3d}: prompt {f.prompt_len:3d} -> "
                  f"{len(f.tokens):3d} tokens  (slot {f.slot}, admitted "
                  f"r{f.admit_round}, finished r{f.finish_round}){trail}")
        n_tok = sum(len(f.tokens) for f in fin)
        print(f"occupancy {stats['occupancy']:.2f} over "
              f"{stats['decode_rounds']} rounds / {stats['bursts']} "
              f"bursts; peak live pages {stats['peak_live_pages']} vs "
              f"{stats['fixed_equiv_pages']} fixed-batch equivalent "
              f"(pool {stats['n_pages']})")
        print(f"robustness: {stats['preemptions']} preemptions "
              f"({stats['preempt_swap']} swap / "
              f"{stats['preempt_reingest']} reingest), "
              f"{stats['shed_events']} sheds, {stats['degraded']} "
              f"degraded, {stats['deadline_misses']}/"
              f"{stats['deadline_total']} deadline misses, "
              f"{stats['poisoned_rounds']} poisoned rounds masked, "
              f"{stats['stragglers']} stragglers, "
              f"{stats['faults_exhaust']} exhaustion episodes")
        if args.speculate:
            print(f"speculative: accept rate "
                  f"{stats['spec_accept_rate']:.2f} over "
                  f"{stats['spec_rounds']} draft/verify row-rounds "
                  f"({stats['spec_emitted']} tokens emitted, chunk "
                  f"k+1={args.speculate + 1})")
        if esc is not None or plan is not None:
            print(f"numerical health: {stats.get('escalations', 0)} "
                  f"escalations ({stats.get('esc_deferred', 0)} deferred, "
                  f"{stats.get('esc_refused', 0)} refused), "
                  f"{stats.get('sdc_injected', 0)} SDC injected / "
                  f"{stats.get('sdc_detected', 0)} detected / "
                  f"{stats.get('sdc_reingest', 0)} recovered by reingest")
        if replicated:
            print(f"replica HA: {stats['ha_kills']} kills, "
                  f"{stats['ha_hangs']} hangs, {stats['ha_migrations']} "
                  f"migrations ({stats['ha_migrated_swap']} swap-blob / "
                  f"{stats['ha_migrated_reingest']} reingest); heartbeats "
                  + ", ".join(f"r{i}:{h['beats']}b/{h['missed']}m "
                              f"{h['status']}"
                              for i, h in enumerate(stats["heartbeats"])))
        if journal is not None:
            journal.close()
            print(f"journal {args.journal}: " + ", ".join(
                f"{v}x {k}" for k, v in sorted(journal.counts().items())))
        if plan is not None and plan.events:
            kinds = {}
            for k, _ in plan.events:
                kinds[k] = kinds.get(k, 0) + 1
            print(f"fault log: " + ", ".join(
                f"{v}x {k}" for k, v in sorted(kinds.items())))
        print(f"{args.arch} [continuous/{args.decode_backend}]: {n_tok} "
              f"tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        return
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 model.cfg.vocab)
    prompt_lens = None
    if args.ragged:
        lens = ragged_lengths(args.batch, args.prompt_len)
        prompt_lens = jnp.asarray(lens, jnp.int32)
        # zero the pad tail so the printed pack is honest about what's live
        live = jnp.arange(args.prompt_len)[None, :] < prompt_lens[:, None]
        prompts = jnp.where(live, prompts, 0)
        print(f"ragged pack: lengths {lens} padded to {args.prompt_len}")

    page_table, n_pages = None, None
    if args.paged and not args.ragged:
        # prefix-sharing demo: all rows share the first half of the prompt
        # (causal attention => identical K/V at shared positions), so every
        # page FULLY covered by the common prefix is stored once
        from ..models.paged import (PageAllocator, build_tables,
                                    identity_block_table, num_pages)
        common = args.prompt_len // 2
        prompts = jnp.concatenate(
            [jnp.broadcast_to(prompts[:1, :common],
                              (args.batch, common)), prompts[:, common:]], 1)
        mp = num_pages(max_len, args.page_size)
        n_pages = args.batch * mp
        alloc = PageAllocator(n_pages)
        shared = build_tables(alloc, args.batch, mp,
                              shared_pages=common // args.page_size)
        page_table = jnp.asarray(shared)
        print(f"paged pool: page={args.page_size}, "
              f"{alloc.n_live}/{n_pages} pages live with the shared "
              f"prefix ({common} common prompt tokens) vs {n_pages} "
              f"unshared")
        # parity gate: shared-prefix serving must be BIT-identical to the
        # unshared identity layout (prefill logits + generated tokens)
        par = jax.jit(lambda p, t, tb: model.generate(
            p, t, gen_len=args.gen, max_len=max_len, page_table=tb,
            n_pages=n_pages, return_logits=True))
        g_s, lg_s = par(params, prompts, page_table)
        g_u, lg_u = par(params, prompts,
                        jnp.asarray(identity_block_table(args.batch, mp)))
        d_tok = int(jnp.sum(g_s != g_u))
        d_lg = float(jnp.max(jnp.abs(lg_s - lg_u)))
        print(f"prefix-sharing parity: max |dlogits| = {d_lg:.1e}, "
              f"token mismatches = {d_tok} (both must be 0)")
        assert d_tok == 0 and d_lg == 0.0, "prefix sharing changed outputs"
    elif args.paged:
        print(f"paged pool: page={args.page_size}, identity table "
              f"(ragged rows keep private page runs)")

    if args.loop == "scan":
        key = jax.random.key(args.seed)
        # guard_nonfinite: every sampling site sanitizes its logits and
        # counts guarded rows — finite logits pass through bit-identical,
        # NaN/Inf ones abort serving instead of emitting garbage tokens
        gen_fn = jax.jit(lambda p, t, pl_, tb: model.generate(
            p, t, gen_len=args.gen, max_len=max_len,
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, key=key, prompt_lens=pl_,
            stop_token=args.stop_token, page_table=tb, n_pages=n_pages,
            repetition_penalty=args.repetition_penalty,
            presence_penalty=args.presence_penalty,
            guard_nonfinite=True, mesh=rmesh)[::2])
        gen, bad = jax.block_until_ready(
            gen_fn(params, prompts, prompt_lens, page_table))
        t0 = time.time()
        gen, bad = jax.block_until_ready(
            gen_fn(params, prompts, prompt_lens, page_table))
        dt = time.time() - t0
        if int(jnp.sum(bad)) > 0:
            from ..train.fault import PoisonedLogitsError
            raise PoisonedLogitsError(
                f"non-finite logits at {int(jnp.sum(bad))} sampling steps "
                f"(rows {np.nonzero(np.asarray(bad))[0].tolist()})")
        n_tok = args.batch * args.gen
        if args.stop_token is not None:
            live_tok = int(jnp.sum(gen != args.stop_token)
                           + jnp.sum(jnp.any(gen == args.stop_token, 1)))
            print(f"stop-token {args.stop_token}: {live_tok}/{n_tok} "
                  f"tokens live (rest frozen post-EOS)")
    else:
        # same sampling rule as the scan path so the A/B stays
        # apples-to-apples when sampling flags are set
        from ..models.transformer import sample_token
        key = jax.random.key(args.seed)
        pick = jax.jit(lambda lg, k: sample_token(
            lg, k, temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p))
        prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
        step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
        lg, caches = prefill(params, prompts)
        key, sk = jax.random.split(key)
        tok = pick(lg[:, -1], sk)[:, None]
        t0 = time.time()
        for i in range(args.gen - 1):
            lg, caches = step(params, tok, caches, args.prompt_len + i)
            key, sk = jax.random.split(key)
            tok = pick(lg[:, -1], sk)[:, None]
        jax.block_until_ready(tok)
        dt = time.time() - t0
        n_tok = args.batch * (args.gen - 1)
    tag = f"{args.loop}/{args.decode_backend}" + \
        (f"/paged{args.page_size}" if args.paged else "")
    print(f"{args.arch} [{tag}]: "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
