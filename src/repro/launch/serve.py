"""Serving launcher: batched prefill + decode.

The decode loop is a single compiled ``lax.scan`` (``Model.generate``) —
one XLA dispatch for the whole generation.  ``--loop python`` keeps the
seed per-step loop (one dispatch per token) for A/B comparison; the
benchmark in benchmarks/serve_decode.py tracks the two paths over time.

``python -m repro.launch.serve --arch gemma2-9b --batch 4 --gen 32``
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--policy", default="tp_bf16")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--loop", choices=("scan", "python"), default="scan")
    ap.add_argument("--decode-backend", choices=("dense", "pallas"),
                    default="dense",
                    help="pallas: fused in-kernel KV-dequant decode attention")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from ..models.registry import build_model

    model = build_model(args.arch, policy=args.policy, reduced=args.reduced)
    model = model.with_cfg(decode_backend=args.decode_backend)
    params = model.init(jax.random.key(0))
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 model.cfg.vocab)

    if args.loop == "scan":
        gen_fn = jax.jit(lambda p, t: model.generate(
            p, t, gen_len=args.gen, max_len=max_len)[0])
        gen = jax.block_until_ready(gen_fn(params, prompts))  # compile
        t0 = time.time()
        gen = jax.block_until_ready(gen_fn(params, prompts))
        dt = time.time() - t0
        n_tok = args.batch * args.gen
    else:
        prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
        step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
        lg, caches = prefill(params, prompts)
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        t0 = time.time()
        for i in range(args.gen - 1):
            lg, caches = step(params, tok, caches, args.prompt_len + i)
            tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        dt = time.time() - t0
        n_tok = args.batch * (args.gen - 1)
    print(f"{args.arch} [{args.loop}/{args.decode_backend}]: "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
