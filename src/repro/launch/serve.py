"""Serving launcher: batched prefill + decode.

The decode loop is a single compiled ``lax.scan`` (``Model.generate``) —
one XLA dispatch for the whole generation.  ``--loop python`` keeps the
seed per-step loop (one dispatch per token) for A/B comparison; the
benchmark in benchmarks/serve_decode.py tracks the two paths over time.

``python -m repro.launch.serve --arch gemma2-9b --batch 4 --gen 32``
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--policy", default="tp_bf16")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--loop", choices=("scan", "python"), default="scan")
    ap.add_argument("--decode-backend", choices=("dense", "pallas", "auto"),
                    default="auto",
                    help="pallas: fused in-kernel KV-dequant decode attention;"
                         " auto (default): pallas off-CPU, dense on CPU")
    ap.add_argument("--prefill-backend", choices=("dense", "pallas", "auto"),
                    default="auto",
                    help="pallas: pruned-grid flash-attention prefill kernel;"
                         " auto (default): pallas off-CPU, dense on CPU")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="> 0 enables sampling (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--top-p", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0, help="sampling PRNG seed")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from ..models.registry import build_model

    model = build_model(args.arch, policy=args.policy, reduced=args.reduced)
    model = model.with_cfg(decode_backend=args.decode_backend,
                           prefill_backend=args.prefill_backend)
    params = model.init(jax.random.key(0))
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 model.cfg.vocab)

    if args.loop == "scan":
        key = jax.random.key(args.seed)
        gen_fn = jax.jit(lambda p, t: model.generate(
            p, t, gen_len=args.gen, max_len=max_len,
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, key=key)[0])
        gen = jax.block_until_ready(gen_fn(params, prompts))  # compile
        t0 = time.time()
        gen = jax.block_until_ready(gen_fn(params, prompts))
        dt = time.time() - t0
        n_tok = args.batch * args.gen
    else:
        # same sampling rule as the scan path so the A/B stays
        # apples-to-apples when sampling flags are set
        from ..models.transformer import sample_token
        key = jax.random.key(args.seed)
        pick = jax.jit(lambda lg, k: sample_token(
            lg, k, temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p))
        prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
        step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
        lg, caches = prefill(params, prompts)
        key, sk = jax.random.split(key)
        tok = pick(lg[:, -1], sk)[:, None]
        t0 = time.time()
        for i in range(args.gen - 1):
            lg, caches = step(params, tok, caches, args.prompt_len + i)
            key, sk = jax.random.split(key)
            tok = pick(lg[:, -1], sk)[:, None]
        jax.block_until_ready(tok)
        dt = time.time() - t0
        n_tok = args.batch * (args.gen - 1)
    print(f"{args.arch} [{args.loop}/{args.decode_backend}]: "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
