"""Continuous-batching serving engine: admission, chunked prefill, bursts,
preemption, backpressure and transprecision graceful degradation.

The serving-loop half of the repo's energy-proportionality story.  PR 1-4
made every LAYER of the stack length-proportional — per-row ``kv_len``
vectors prune each sequence's attention walk, the paged pool makes HBM
scale with live tokens, EOS freezing stops a finished row's outputs — but
the LOOP still paid batch-max cost everywhere: generation was a fixed-trip
scan that kept stepping EOS-frozen rows to ``max_new_tokens``, a finished
row's pages stayed live until the whole batch exited, and new requests
waited for a full batch teardown.  This module closes that gap:

  * **Admission** — a host-side loop over a request queue.  A finished
    row's pages go back to the ``PageAllocator`` the round it finishes
    (``decode_burst`` exits the compiled loop that round), and the freed
    slot is refilled from the queue mid-generation.  Admission reuses the
    traced per-row write-index/``kv_len``/block-table plumbing, so slot
    churn never retraces: ONE compiled burst program serves the whole run.
  * **Chunked prefill** — an admitted prompt is consumed in fixed-width
    chunks through the paged flash read path
    (``Model.prefill_chunk``/``flash_attention(block_table=)``), one chunk
    per round, interleaved with single-round decode bursts so ongoing
    streams are never stalled behind a long new prompt.
  * **Page accounting** — pages are allocated LAZILY (prompt pages at
    admission, one page per row as its length crosses a page boundary), so
    the allocator's ``peak_live`` high-water mark tracks the sum of live
    sequence lengths, not ``slots x max_len``.  Admission reserves each
    request's worst case (``num_pages(prompt + budget)``) against the pool.

Overload is HANDLED, not assumed away (the FPnew stance: when resources
are tight, drop to a cheaper operating point instead of failing):

  * **Priorities + deadlines** — ``Request.priority`` orders admission
    (higher first; FIFO within a class), ``Request.deadline`` (a round
    number) bumps an at-risk request's effective priority and is
    accounted per request at finish (``Finished.deadline_miss``).
  * **Preemption** — when ``try_alloc`` fails or a higher-priority
    request can't fit, the weakest resident row is evicted: its pages are
    freed and it re-enters the queue.  ``preempt="free"`` re-ingests the
    victim's prompt + already-emitted tokens through the chunked-prefill
    path on resume (chunk boundaries are invisible, so a resumed row's
    remaining tokens are bit-identical to an un-preempted run);
    ``preempt="swap"`` copies its live K/V pages to a host-side numpy
    store instead and restores them on re-admission (no recompute).
  * **Degradation before shedding** — with ``degrade_fmt`` set (e.g.
    ``"fp8"``), a swapped victim's pages are stored in that format's
    native container on the host and widened back on resume — the paper's
    transprecision knob as a graceful-degradation axis.  It is tracked
    per row (``Finished.degraded``) and quality-sensitive requests refuse
    it via ``Request.no_degrade`` (they swap at full width).  When the
    pool itself is already fp8 (policy ``tp_bf16_kv8``), the round-trip
    is value-exact.
  * **Shedding with backoff** — a queue entry that cannot be placed is
    not allowed to block the loop: it is deferred with jittered
    exponential backoff (deterministic per rid/attempt) and retried.
  * **Fault injection + watchdog** — a ``ServeFaultPlan`` deterministically
    injects page-pool exhaustion episodes, slow-burst stragglers (flagged
    by a ``StragglerMonitor``) and NaN-poisoned logits inside the compiled
    burst (masked-and-counted, or fail-fast ``PoisonedLogitsError``);
    a ``ServeWatchdog`` turns a livelocked loop or a non-progressing
    burst into a clean ``EngineStuckError`` instead of a hang.
  * **Flag-driven precision escalation** — with an ``EscalationPolicy``,
    every cache write carries FPnew-style IEEE exception telemetry: the
    burst accumulates per-row OF/UF flag counts from the write-side CONV
    stage (saturating casts keep overflowed values finite, so logits never
    poison), and when a row's pressure crosses the policy threshold the
    scheduler escalates its KV format one ladder rung (fp8 -> fp16 -> ...)
    via the free-and-reingest path — the inverse of degradation, refusable
    per request (``Request.no_escalate``) and deferred under page pressure.
  * **SDC-checked swap** — every swapped-out page payload carries a CRC32
    computed at swap-out; swap-in verifies it, and a corrupted payload
    (bit flips in host memory — silent data corruption) is detected 100%
    of the time and recovered by falling back to free-and-reingest, which
    recomputes the K/V instead of restoring damaged bytes.

Dead-slot discipline (why idle/prefilling/finished slots are safe): every
row writes decode K/V only through its OWN table row, and a cache slot
becomes live for attention only AFTER the real token write to it — so
garbage writes (idle slots parked at ``max_len - 1``, frozen rows, pad
tails of prefill chunks) land either on the reserved scratch page or on
dead slots that real writes overwrite before any mask lets them be read.

The driver is deliberately host-side Python: admission, page churn,
preemption and fault release happen at burst boundaries, between compiled
steps, never inside them — the same boundary the ``PageAllocator``
already lives at.

Replica-level fault tolerance rides the same boundary.  ``run`` is a thin
wrapper over a re-entrant ``start()`` / ``step()`` / ``finalize()`` state
machine, so a fleet host (``ReplicatedEngine``) can interleave replicas
one scheduler iteration at a time and react to a replica dying MID-RUN:

  * **Failure injection** — a ``ReplicaFaultPlan`` deterministically
    kills a replica at a chosen burst (``ReplicaLostError`` raised
    through the burst dispatch: device memory gone) or hangs it (the
    replica stops stepping; the fleet's heartbeat view declares it dead
    after missed beats, device memory still readable).
  * **Live-request migration** — a dead replica's residents are captured
    by the SAME preemption machinery (``evacuate``): swap-to-host page
    payloads (CRC32-verified, tagged with their pool's provenance) become
    portable continuation blobs a survivor ``adopt``s into its own
    disjoint pool, with free-and-reingest as the fallback when the
    victim's pages are unreachable — so a migrated request's remaining
    tokens are bit-identical to the unfailed run.
  * **Crash-consistent journal** — with a ``launch/journal.py``
    ``RequestJournal`` attached, every admission, per-burst emitted-token
    delta, preempt/migrate/escalation event and completion is recorded
    AFTER it happened; a full restart (``train.fault.run_with_restarts``)
    replays unfinished requests from their last journaled token through
    the reingest resume path, bit-parity with the unfailed run.

``python -m repro.launch.serve --continuous`` drives this end to end.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.policy import EscalationPolicy
from ..train.fault import (EngineStuckError, PoisonedLogitsError,
                           ReplicaFaultPlan, ReplicaLostError,
                           ServeFaultPlan, ServeWatchdog, StragglerMonitor)


def _crc_blobs(blobs: list) -> list:
    """Per-layer (crc32(k), crc32(v)) checksums of swap payloads."""
    return [(zlib.crc32(k.tobytes()), zlib.crc32(v.tobytes()))
            for k, v in blobs]


@dataclasses.dataclass(frozen=True)
class Request:
    """One queued generation request.

    ``arrival`` is in DECODE ROUNDS (the engine's logical clock): the
    request becomes visible to admission once that many rounds have run —
    a deterministic stand-in for wall-clock arrival traces.  ``priority``
    orders admission and picks preemption victims (higher wins; FIFO
    within a class).  ``deadline`` is an absolute round number: finishing
    after it counts a deadline miss, and a request that can no longer
    make it gains one effective priority level (SLO-at-risk boost).
    ``no_degrade`` marks a quality-sensitive request that refuses the
    fp8 swap-store degradation (it is swapped at full width instead).
    ``no_escalate`` refuses flag-driven KV-precision escalation (a
    latency-sensitive request that prefers saturated-but-cheap KV over a
    reingest pause keeps its admission rung).  ``spec_k`` caps this
    request's speculative draft depth below the engine's (``None`` =
    engine default) and ``no_speculate`` opts the request out of
    drafting entirely — it still rides the speculative burst program,
    but with a per-row cap of 0 its every round is plain greedy decode."""
    rid: int
    tokens: Sequence[int]          # prompt token ids (>= 1)
    max_new: int                   # generation budget incl. the first token
    arrival: int = 0
    priority: int = 0
    deadline: Optional[int] = None
    no_degrade: bool = False
    no_escalate: bool = False
    spec_k: Optional[int] = None
    no_speculate: bool = False

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class Finished:
    """A served request: ``tokens`` holds the generated ids (first token
    included; a ``stop_token`` hit keeps the stop as the last element).
    The robustness trail rides along: how often the row was preempted or
    shed-deferred, whether its swapped K/V was format-degraded, and
    whether it met its deadline."""
    rid: int
    prompt_len: int
    tokens: List[int]
    admit_round: int
    finish_round: int
    slot: int
    preemptions: int = 0
    sheds: int = 0
    degraded: bool = False
    deadline: Optional[int] = None
    deadline_miss: bool = False
    escalated: int = 0             # final escalation-ladder level (0 = base)


@dataclasses.dataclass
class _Resume:
    """A preempted request's continuation state.  ``blobs`` present: the
    swap-to-host path (per-layer (k, v) page payloads covering ``written``
    tokens, possibly stored in the degrade format).  ``blobs`` absent:
    the free-and-reingest path — the prompt plus all but the last emitted
    token are re-fed through chunked prefill, and the last emitted token
    is re-fed through the normal decode round, so every K/V byte and
    every subsequent sample reproduces the un-preempted run.
    ``checksums`` (swap path): per-layer CRC32 pairs computed at swap-out,
    verified before swap-in — a mismatch means the host payload was
    silently corrupted, and the engine falls back to reingest.
    ``tag`` (swap path): the payload's pool provenance
    (``models.paged.SwapBlobTag``) — checked against the receiving pool
    before any cross-replica install."""
    emitted: List[int]
    blobs: Optional[list]
    written: int
    degraded: bool
    checksums: Optional[list] = None
    tag: Optional[object] = None


@dataclasses.dataclass
class _QEntry:
    """Queue bookkeeping around a Request: backoff gate, shed/preempt
    counters and (after a preemption) the resume state.  ``esc_level`` /
    ``esc_pressure`` persist the request's escalation rung and accumulated
    OF/UF flag pressure across preemptions (the rung is a property of the
    REQUEST, not the slot it happens to occupy)."""
    req: Request
    not_before: int
    sheds: int = 0
    preemptions: int = 0
    degraded: bool = False
    resume: Optional[_Resume] = None
    esc_level: int = 0
    esc_pressure: tuple = (0, 0)
    esc_refused: bool = False


def _finished_from_record(rec: dict) -> Finished:
    """Rebuild a ``Finished`` from its journal ``finish`` record — the
    restart path for a request that completed before the crash (its
    tokens need no re-serving)."""
    return Finished(
        rid=rec["rid"], prompt_len=rec.get("prompt_len", 0),
        tokens=list(rec["toks"]),
        admit_round=rec.get("admit_round", 0),
        finish_round=rec.get("finish_round", 0),
        slot=rec.get("slot", -1),
        preemptions=rec.get("preemptions", 0),
        sheds=rec.get("sheds", 0),
        degraded=bool(rec.get("degraded", False)),
        deadline=rec.get("deadline"),
        deadline_miss=bool(rec.get("deadline_miss", False)),
        escalated=rec.get("escalated", 0))


def synthetic_trace(n_req: int, slots: int, prompt_len: int, gen: int,
                    vocab: int, seed: int = 2,
                    flavor: str = "chat") -> List[Request]:
    """Deterministic workloads for the continuous-batching A/B and the
    robustness soak.

    ``flavor="chat"`` (default, unchanged): the mixed-length /
    mixed-budget / mixed-arrival heavy tail of benchmarks/serve_decode.py
    — every 8th request in the first 3/4 of the queue is LONG (budget
    ``gen``); the rest cycle short budgets (``gen/16``, ``gen/8``,
    ``gen/4``).  Prompt lengths cycle 1/4..4/4 of ``prompt_len``.
    Arrivals: the first ``slots`` requests at round 0, then clumps of
    four every ``gen/16`` rounds.

    ``flavor="soak"``: the overload scenario — arrivals in bursts of
    eight (far more than ``slots``), every 5th request a LONG document
    (full ``prompt_len``), every 4th a long budget, priorities mixed over
    {0,1,2}, deadlines on the priority-2 tier (tight enough to bind under
    faults), and every 11th request quality-sensitive (``no_degrade``).
    Driven with a constrained page pool + a ``ServeFaultPlan``, this is
    the trace that must drain to completion with zero stuck requests.

    ``flavor="session"``: multi-turn chat — requests group into sessions
    of up to three turns over a GROWING shared prefix: turn ``t``'s
    prompt is turn ``t-1``'s prompt + its (simulated) answer + a fresh
    user chunk, and turn ``t`` arrives only after turn ``t-1``'s budget
    could have drained.  Worst-case prompt length is therefore
    ``prompt_len + 2 * (gen // 4 + max(1, prompt_len // 4))`` — size
    ``max_len`` accordingly.  This is the trace the HA soak migrates:
    a session's later turns re-enter the queue carrying real shared
    history, so a killed replica's in-flight turn must resume elsewhere
    mid-conversation."""
    rng = np.random.RandomState(seed)
    fr_len = (0.25, 0.5, 0.75, 1.0)
    shorts = (gen // 16, gen // 8, gen // 4)
    reqs = []
    if flavor == "session":
        step_gap = max(2, gen // 8)
        rid = s = 0
        while rid < n_req:
            base_len = max(1, int(prompt_len * fr_len[s % 4]))
            hist = rng.randint(0, vocab, size=base_len).tolist()
            arrival = (s // max(1, slots)) * step_gap
            for t in range(min(3, n_req - rid)):
                budget = max(2, shorts[(s + t) % 3])
                reqs.append(Request(
                    rid=rid, tokens=list(hist), max_new=budget,
                    arrival=arrival, priority=(1 if t == 2 else 0),
                    no_degrade=(s % 5 == 3)))
                rid += 1
                # the turn's simulated answer + the next user message
                # extend the shared prefix the following turn re-sends
                hist += rng.randint(0, vocab, size=budget).tolist()
                hist += rng.randint(0, vocab,
                                    size=max(1, prompt_len // 4)).tolist()
                arrival += budget + step_gap
            s += 1
        return reqs
    if flavor == "soak":
        for i in range(n_req):
            plen = (prompt_len if i % 5 == 0
                    else max(1, int(prompt_len * fr_len[i % 4])))
            budget = gen if i % 4 == 0 else max(2, shorts[i % 3])
            arrival = (i // 8) * max(2, gen // 8)
            pri = 2 if i % 7 == 3 else (1 if i % 3 == 0 else 0)
            deadline = (arrival + 4 * budget + 2 * max(2, gen // 8)
                        if pri == 2 else None)
            reqs.append(Request(
                rid=i, tokens=rng.randint(0, vocab, size=plen).tolist(),
                max_new=budget, arrival=arrival, priority=pri,
                deadline=deadline, no_degrade=(i % 11 == 7)))
        return reqs
    if flavor != "chat":
        raise ValueError(f"flavor must be chat|soak|session, got {flavor!r}")
    for i in range(n_req):
        is_long = (i % 8 == 0) and i < (3 * n_req) // 4
        budget = gen if is_long else max(2, shorts[i % 3])
        plen = max(1, int(prompt_len * fr_len[i % 4]))
        arrival = (0 if i < slots
                   else ((i - slots) // 4 + 1) * max(2, gen // 16))
        reqs.append(Request(
            rid=i, tokens=rng.randint(0, vocab, size=plen).tolist(),
            max_new=budget, arrival=arrival))
    return reqs


_FAR = 1 << 30          # "no deadline" sort key


class ContinuousEngine:
    """Continuous-batching scheduler over ``slots`` paged batch rows.

    The model must be paged (``cfg.paged_kv``; attention-mixer archs
    only).  Requests must satisfy ``prompt_len + max_new <= max_len`` and
    ``max_new >= 1``.  Greedy by default; ``temperature``/``top_k``/
    ``top_p`` enable sampling with one PRNG key threaded deterministically
    through every sampling site (same queue -> same tokens).
    ``repetition_penalty``/``presence_penalty`` apply the same seen-token
    discounts as ``Model.generate`` (the count histograms ride the burst
    carry; the host re-seeds them across bursts and preemptions).

    Robustness knobs: ``preempt`` picks the eviction mechanism
    (``"free"`` re-ingests on resume, ``"swap"`` round-trips live pages
    through a host-side numpy store); ``degrade_fmt`` stores swapped
    pages in a narrow format (fp8) unless the request opted out;
    ``shed=False`` restores head-of-line blocking admission (no backoff
    deferrals); ``fault_plan`` injects deterministic faults; the
    watchdog aborts cleanly (``EngineStuckError``) after
    ``watchdog_patience`` loop iterations without progress."""

    def __init__(self, model, params, *, slots: int, max_len: int,
                 chunk: int = 32, n_pages: Optional[int] = None,
                 stop_token: Optional[int] = None, temperature: float = 0.0,
                 top_k: Optional[int] = None, top_p: Optional[float] = None,
                 seed: int = 0, burst_cap: int = 64,
                 prefill_rounds: int = 2, admit_wave: int = 2, mesh=None,
                 repetition_penalty: Optional[float] = None,
                 presence_penalty: Optional[float] = None,
                 preempt: str = "free",
                 degrade_fmt: Optional[str] = None,
                 shed: bool = True, shed_base: int = 2, shed_cap: int = 64,
                 min_resident: int = 2,
                 fault_plan: Optional[ServeFaultPlan] = None,
                 watchdog_patience: int = 200,
                 escalate: Optional[EscalationPolicy] = None,
                 spec_k: int = 0,
                 draft_repeats: Optional[int] = None,
                 draft_policy=None,
                 replica_id: int = 0,
                 replica_fault: Optional[ReplicaFaultPlan] = None,
                 journal=None):
        import functools

        import jax
        import jax.numpy as jnp

        from ..models.paged import PageAllocator, num_pages
        from ..models.transformer import (apply_penalties, caches_with_table,
                                          init_caches, sample_token,
                                          sanitize_logits)

        cfg = model.cfg
        if not cfg.paged_kv:
            raise ValueError("ContinuousEngine requires cfg.paged_kv "
                             "(admission allocates pages, not batch rows)")
        why = cfg.paged_unsupported_reason()
        if why is not None:
            raise ValueError(f"continuous batching is unsupported for "
                             f"{cfg.name}: {why} cannot page its cache")
        if preempt not in ("free", "swap"):
            raise ValueError(f"preempt must be free|swap, got {preempt!r}")
        assert slots >= 1 and chunk >= 1 and burst_cap >= 1
        self.model, self.params, self.mesh = model, params, mesh
        self.slots, self.max_len, self.chunk = slots, max_len, chunk
        self.page = cfg.page_size
        self.max_pages = num_pages(max_len, self.page)
        self.n_pages = (slots * self.max_pages + 1 if n_pages is None
                        else n_pages)
        self.stop_token = stop_token
        self.temperature, self.top_k, self.top_p = temperature, top_k, top_p
        self.seed, self.burst_cap = seed, burst_cap
        self.prefill_rounds = prefill_rounds
        self.admit_wave = max(1, admit_wave)
        self.repetition_penalty = repetition_penalty
        self.presence_penalty = presence_penalty
        self._use_pen = ((repetition_penalty is not None
                          and repetition_penalty != 1.0)
                         or (presence_penalty is not None
                             and presence_penalty != 0.0))
        self.preempt_mode = preempt
        self.degrade_fmt = degrade_fmt
        self._swap_dtype = None
        if degrade_fmt is not None:
            from ..models.attention import kv_swap_dtype
            self._swap_dtype = kv_swap_dtype(degrade_fmt)
        self.shed, self.shed_base, self.shed_cap = shed, shed_base, shed_cap
        self.min_resident = max(0, min_resident)
        self.fault_plan = fault_plan
        self.watchdog_patience = watchdog_patience
        # replica-level fault tolerance: identity in the fleet, the kill
        # plan consulted at every burst dispatch, the shared request
        # journal, and the pool-provenance fields swap blobs are tagged
        # with (models.paged.SwapBlobTag)
        self.replica_id = int(replica_id)
        self.replica_fault = replica_fault
        self.journal = journal
        from ..models.attention import kv_store_dtype
        self._pool_dtype = np.dtype(kv_store_dtype(model.policy))
        self.escalate = escalate
        self._esc_fmts = None
        if escalate is not None:
            if not isinstance(escalate, EscalationPolicy):
                raise TypeError(f"escalate must be an EscalationPolicy, "
                                f"got {type(escalate).__name__}")
            from ..models.attention import kv_store_dtype
            pool_dt = np.dtype(kv_store_dtype(model.policy))
            if model.policy.kv_fmt is not None or pool_dt != np.float32:
                raise ValueError(
                    f"escalation needs an f32 KV pool with no kv_fmt (the "
                    f"write path snaps each row to its OWN ladder rung "
                    f"inside a shared wide container); policy "
                    f"{model.policy.name!r} stores KV as {pool_dt}")
            self._esc_fmts = escalate.formats
        self.spec_k = int(spec_k)
        self.draft_repeats = draft_repeats
        if draft_policy is not None and isinstance(draft_policy, str):
            from ..core.policy import get_policy
            draft_policy = get_policy(draft_policy)
        self.draft_policy = draft_policy
        if self.spec_k:
            if self.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            model.speculate_check()
            if temperature > 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only (acceptance is "
                    "defined against the verify argmax); temperature "
                    f"{temperature} would change the sampled stream")
            if self._use_pen:
                raise ValueError(
                    "speculative decoding does not compose with "
                    "repetition/presence penalties yet: the verify chunk "
                    "scores k+1 positions against ONE histogram snapshot, "
                    "so mid-chunk accepts would see stale counts")
        self._num_pages = num_pages
        self._jnp, self._jax = jnp, jax

        self.alloc = PageAllocator(self.n_pages)
        self.scratch = self.alloc.alloc(1)[0]      # dead-write sink, forever
        self._table = np.full((slots, self.max_pages), self.scratch,
                              np.int32)
        self._table_dev = jnp.asarray(self._table)
        self._table_dirty = False
        self.caches = init_caches(cfg, slots, max_len, model.policy,
                                  page_table=self._table,
                                  n_pages=self.n_pages)
        # tensor-parallel placement: with a model axis > 1, pin the params
        # (Megatron col/row rules) and the paged pools (head-sharded) to
        # the mesh up front — the jitted burst/chunk programs then keep
        # those shardings through every donated carry instead of
        # rediscovering them per dispatch.  batch_axes=() because ONE
        # engine is one data replica: its slots never batch-shard, and
        # its block tables stay host-managed and model-replicated.
        self.tp = (mesh.shape["model"]
                   if mesh is not None
                   and "model" in getattr(mesh, "axis_names", ()) else 1)
        if self.tp > 1:
            from ..models.sharding import cache_specs, named, param_specs
            self.params = jax.device_put(
                params, named(mesh, param_specs(params,
                                                model_size=self.tp)))
            self.caches = jax.device_put(
                self.caches,
                named(mesh, cache_specs(cfg, self.caches, batch=slots,
                                        mesh=mesh, batch_axes=())))
        # per-slot host state (the scheduler's view; device state mirrors
        # it through the traced burst arguments)
        self.pos = np.full((slots,), max_len - 1, np.int32)
        self.lens = np.zeros((slots,), np.int32)
        self.done = np.ones((slots,), bool)
        self.limit = np.zeros((slots,), np.int32)
        self.tok = np.zeros((slots, 1), np.int32)
        self._req: List[Optional[Request]] = [None] * slots
        self._entry: List[Optional[_QEntry]] = [None] * slots
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self._prog = np.zeros((slots,), np.int32)   # prefill progress
        self._emitted: List[List[int]] = [[] for _ in range(slots)]
        # tokens chunked prefill consumes: the prompt, or on a reingest
        # resume the prompt + previously emitted tokens (minus the last)
        self._ingest: List[List[int]] = [[] for _ in range(slots)]
        self._resume_tok: List[Optional[int]] = [None] * slots
        self._admit_round = np.zeros((slots,), np.int32)
        self._cnt = (np.zeros((slots, model.vocab_out), np.int32)
                     if self._use_pen else None)
        # numerical-health state: each slot's escalation-ladder rung and
        # its accumulated OF/UF write-flag pressure (host mirror of the
        # telemetry the burst carries back)
        self.kv_levels = np.zeros((slots,), np.int32)
        self.flag_pressure = np.zeros((slots, 2), np.int64)
        # per-slot speculative draft cap (min(engine spec_k, request
        # spec_k); 0 = plain decode row inside the speculative batch)
        self._spec_rows = np.zeros((slots,), np.int32)
        self._pending: List[_QEntry] = []
        self._held: List[int] = []      # fault-plan page grab
        self._release_at: Optional[int] = None
        # run state (armed by start(), advanced by step(), closed by
        # finalize() — attributes, not locals, so a fleet host can
        # interleave replicas one step at a time)
        self._results: Dict[int, Finished] = {}
        self._requests: List[Request] = []
        self._counters: Dict[str, int] = {}
        self._round_no = self._decode_rounds = 0
        self._occ_accum = self._bursts = 0
        self._key = None
        self.reset_monitors()

        use_pen = self._use_pen
        rp, pp = repetition_penalty, presence_penalty
        esc_fmts = self._esc_fmts
        ovf_scale = float(getattr(fault_plan, "overflow_scale", 1.0)
                          if fault_plan is not None else 1.0)

        def burst(params, caches, table, state, counts, key):
            # ONE packed [10, B] int32 upload carries the whole scheduler
            # state (tok, pos, lens, limit, done, n_max, watch, poison,
            # kv_levels, ovf_round) and the table is installed inside the
            # compiled region — per-burst host->device traffic stays 2-3
            # small transfers, independent of model size
            caches = caches_with_table(caches, table)
            esc_kw = ({} if esc_fmts is None else
                      dict(esc_fmts=esc_fmts, kv_levels=state[8],
                           ovf_at=state[9, 0], ovf_scale=ovf_scale))
            r = model.decode_burst(
                params, state[0][:, None], caches, state[1], state[2],
                state[4] != 0, state[3], max_len=max_len,
                out_width=burst_cap, n_max=state[5, 0],
                exit_on_finish=state[6, 0], stop_token=stop_token,
                temperature=temperature, top_k=top_k, top_p=top_p,
                key=key, mesh=mesh,
                counts=counts if use_pen else None,
                repetition_penalty=rp, presence_penalty=pp,
                poison_at=state[7, 0], guard=True, **esc_kw)
            out, n, tok, caches, pos, lens, done, key = r[:8]
            bad = r[8]
            fl = (r[-1] if esc_fmts is not None
                  else jnp.zeros((slots, 2), jnp.int32))
            return (out, n,
                    jnp.stack([tok[:, 0], pos, lens, done.astype(jnp.int32)]),
                    caches, key, bad, fl)

        spec_k_, dr_, dpol_ = self.spec_k, draft_repeats, self.draft_policy

        def spec_burst(params, caches, table, state, counts, key):
            # the speculative twin: state grows row 10 (per-row draft
            # caps) and the packed-contiguous out layout means the host
            # accounting below consumes it exactly like the plain burst
            caches = caches_with_table(caches, table)
            esc_kw = ({} if esc_fmts is None else
                      dict(esc_fmts=esc_fmts, kv_levels=state[8],
                           ovf_at=state[9, 0], ovf_scale=ovf_scale))
            r = model.speculate_burst(
                params, state[0][:, None], caches, state[1], state[2],
                state[4] != 0, state[3], spec_k=spec_k_,
                draft_repeats=dr_, k_rows=state[10], max_len=max_len,
                out_width=burst_cap * (spec_k_ + 1), n_max=state[5, 0],
                exit_on_finish=state[6, 0], stop_token=stop_token,
                key=key, mesh=mesh, guard=True, poison_at=state[7, 0],
                draft_policy=dpol_, **esc_kw)
            out, n, tok, caches, pos, lens, done, key = r[:8]
            bad = r[8]
            fl = (r[9] if esc_fmts is not None
                  else jnp.zeros((slots, 2), jnp.int32))
            return (out, n,
                    jnp.stack([tok[:, 0], pos, lens, done.astype(jnp.int32)]),
                    caches, key, bad, fl, r[-1])

        # donate the caches operand: the page pools flow through every
        # burst/chunk as pure carries and the host never reuses the
        # pre-call object, so XLA aliases them in place instead of
        # holding two full pools across each dispatch
        self._burst = jax.jit(spec_burst if self.spec_k else burst,
                              donate_argnums=(1,))
        self._sample = functools.partial(
            sample_token, temperature=temperature, top_k=top_k, top_p=top_p)
        self._with_table = caches_with_table
        self._sanitize = sanitize_logits
        self._pen = functools.partial(apply_penalties,
                                      repetition_penalty=rp,
                                      presence_penalty=pp)
        self._chunk_fns: Dict[tuple, object] = {}

    # -- helpers ----------------------------------------------------------
    def reset_monitors(self) -> None:
        """Fresh watchdog + straggler-monitor state.  Called at engine
        construction, at every ``run()`` start, and by
        ``train.fault.run_with_restarts`` before each restart attempt —
        a restarted run must not inherit a pre-crash straggler EWMA (it
        would mis-flag warm-up bursts) or stale watchdog stall counts."""
        self.watchdog = ServeWatchdog(self.watchdog_patience)
        self.monitor = StragglerMonitor()

    def _chunk_fn(self, off: int, m: int):
        """Jitted prefill chunk for an ``m``-slot admission wave at static
        offset ``off`` (offsets step in multiples of ``self.chunk``, waves
        are at most ``slots`` wide, so few programs ever compile; slot
        indices, chunk lengths, tables and count histograms are traced —
        admission never retraces).  Folds the wave's first-token sampling
        into the same dispatch: the returned [m] tokens are each row's
        sample off its last live chunk position (only meaningful for a row
        whose final chunk this is), guarded against non-finite logits and
        penalized like every other sampling site."""
        fn = self._chunk_fns.get((off, m))
        if fn is None:
            model, sample, mesh = self.model, self._sample, self.mesh
            with_table = self._with_table
            sanitize, pen, use_pen = self._sanitize, self._pen, self._use_pen
            esc_fmts, jnp = self._esc_fmts, self._jnp

            def chunk_step(params, caches, table, t, meta, counts, key):
                caches = with_table(caches, table)
                esc_kw = ({} if esc_fmts is None else
                          dict(esc_fmts=esc_fmts, kv_levels=meta[2]))
                r = model.prefill_chunk(
                    params, t, caches, q_offset=off, row=meta[0],
                    chunk_lens=meta[1], mesh=mesh, **esc_kw)
                lg, caches = r[0], r[1]
                fl = (r[2] if esc_fmts is not None
                      else jnp.zeros((t.shape[0], 2), jnp.int32))
                lgv, bad = sanitize(lg[:, -1])
                if use_pen:
                    lgv = pen(lgv, counts)
                return sample(lgv, key), bad, caches, fl

            fn = self._jax.jit(chunk_step, donate_argnums=(1,))
            self._chunk_fns[(off, m)] = fn
        return fn

    def _reserved_pages(self) -> int:
        """Worst-case pages of every admitted-but-unfinished request —
        the admission guard that keeps lazy mid-burst allocation from
        failing in steady state (injected exhaustion can still race it;
        ``try_alloc`` is the ground truth and preemption the recovery).
        With speculation on, every resident row's verify chunk writes up
        to ``spec_k`` slots past its budget (dead until accepted), so
        the worst case grows by the draft lookahead."""
        return sum(self._num_pages(r.prompt_len + r.max_new + self.spec_k,
                                   self.page)
                   for r in self._req if r is not None)

    def _ensure_pages(self, b: int, last_idx: int) -> bool:
        """Lazily allocate slot ``b``'s pages covering token slots up to
        ``last_idx`` (inclusive) — the live-length-proportional part.
        Returns False when the pool can't supply them (pressure: the
        caller preempts a victim or slot ``b`` itself and retries)."""
        want = min(last_idx, self.max_len - 1) // self.page + 1
        while len(self._owned[b]) < want:
            got = self.alloc.try_alloc(1)
            if got is None:
                return False
            self._table[b, len(self._owned[b])] = got[0]
            self._owned[b].append(got[0])
            self._table_dirty = True
        return True

    def _table_device(self):
        """Device copy of the block table, re-uploaded only when the host
        table changed (admission, lazy page allocs, recycling)."""
        if self._table_dirty:
            self._table_dev = self._jnp.asarray(self._table)
            self._table_dirty = False
        return self._table_dev

    def _prompt_hist(self, b: int) -> None:
        """Seed slot ``b``'s penalty histogram: prompt + already-emitted
        tokens (resume) — exactly the count state an un-preempted
        ``generate`` carry would hold at this point."""
        if not self._use_pen:
            return
        v = self._cnt.shape[1]
        seen = list(self._req[b].tokens) + list(self._emitted[b])
        self._cnt[b] = np.bincount(np.asarray(seen, np.int64) % v,
                                   minlength=v).astype(np.int32)

    # -- priorities, deadlines, victims -----------------------------------
    def _pending_need(self, e: _QEntry) -> int:
        """Pages an entry needs AT ADMISSION (its resume/prompt length)."""
        if e.resume is not None:
            if e.resume.blobs is not None:
                return self._num_pages(e.resume.written, self.page)
            n = e.req.prompt_len + len(e.resume.emitted) - 1
            return self._num_pages(max(1, n), self.page)
        return self._num_pages(e.req.prompt_len, self.page)

    def _eff_pending(self, e: _QEntry, round_no: int) -> int:
        """Effective priority of a queued entry: its class, +1 when its
        deadline can no longer absorb any further waiting (SLO at risk)."""
        p = e.req.priority
        if e.req.deadline is not None:
            emitted = len(e.resume.emitted) if e.resume is not None else 0
            chunks = -(-e.req.prompt_len // self.chunk)
            need = (e.req.max_new - emitted) + chunks
            if round_no + need >= e.req.deadline:
                p += 1
        return p

    def _eff_resident(self, b: int, round_no: int) -> int:
        """Effective priority of a resident row (deadline-at-risk rows
        get the same +1 boost, protecting them from preemption)."""
        r = self._req[b]
        p = r.priority
        if r.deadline is not None:
            if self.done[b]:        # still prefilling
                rem = len(self._ingest[b]) - int(self._prog[b])
                need = r.max_new + -(-max(0, rem) // self.chunk)
            else:
                need = int(self.limit[b]) - int(self.pos[b]) + 1
            if round_no + need >= r.deadline:
                p += 1
        return p

    def _victims_for(self, eff: int, round_no: int, exclude=()):
        """Resident rows preemptible by effective priority ``eff``,
        weakest first (anti-thrash: rows resident < ``min_resident``
        rounds are protected).  Ties prefer the row donating the most
        pages, then the lowest slot (deterministic)."""
        cands = [b for b in range(self.slots)
                 if self._req[b] is not None and b not in exclude
                 and round_no - int(self._admit_round[b]) >= self.min_resident
                 and self._eff_resident(b, round_no) < eff]
        return sorted(cands, key=lambda b: (self._eff_resident(b, round_no),
                                            -len(self._owned[b]), b))

    def _backoff(self, e: _QEntry, round_no: int, counters: dict) -> None:
        """Shed: defer the entry with jittered exponential backoff —
        deterministic in (seed, rid, attempt), so replays are exact."""
        delay = min(self.shed_cap, self.shed_base * (2 ** min(e.sheds, 16)))
        rng = np.random.RandomState(
            (self.seed * 1000003 + e.req.rid * 9973 + e.sheds * 97)
            & 0x7FFFFFFF)
        e.not_before = round_no + delay + int(rng.randint(0, max(1, delay)))
        e.sheds += 1
        counters["shed_events"] += 1
        if self.fault_plan is not None:
            self.fault_plan.note("shed", round=round_no, rid=e.req.rid,
                                 until=e.not_before)

    # -- preemption / swap ------------------------------------------------
    def _paged_leaves(self, caches):
        from ..models.paged import PagedKVCache
        jax = self._jax
        return [c for c in jax.tree.leaves(
                    caches, is_leaf=lambda x: isinstance(x, PagedKVCache))
                if isinstance(c, PagedKVCache)]

    def _swap_out(self, caches, ids: List[int], degrade: bool):
        """Copy the live content of ``ids`` pages (every paged layer) to
        host numpy — in the degrade format's container when allowed.
        Returns ``(blobs, nbytes, checksums)``; the CRC32s are computed
        HERE, before the payload sits in host memory, so any later bit
        flip (injected or real) is detectable at swap-in."""
        jnp = self._jnp
        idx = jnp.asarray(ids, jnp.int32)
        blobs, nbytes = [], 0
        for c in self._paged_leaves(caches):
            ax = c.k_pool.ndim - 4          # page axis (stacked adds [R,...])
            k = np.asarray(jnp.take(c.k_pool, idx, axis=ax))
            v = np.asarray(jnp.take(c.v_pool, idx, axis=ax))
            if degrade:
                k = k.astype(self._swap_dtype)
                v = v.astype(self._swap_dtype)
            blobs.append((k, v))
            nbytes += k.nbytes + v.nbytes
        return blobs, nbytes, _crc_blobs(blobs)

    @staticmethod
    def _flip_bit(blobs: list, rid: int) -> None:
        """Deterministic single-bit corruption of a swap payload (SDC
        injection): byte and bit indices derive from the rid alone, so a
        plan replays to the identical corruption."""
        k, _ = blobs[0]
        flat = np.array(k, copy=True).view(np.uint8).reshape(-1)
        flat[(rid * 2654435761) % flat.size] ^= np.uint8(1 << (rid % 8))
        blobs[0] = (flat.view(k.dtype).reshape(k.shape), blobs[0][1])

    def _swap_in(self, caches, blobs: list, ids: List[int]):
        """Write swapped page payloads back into the pools at the victim's
        NEW page ids (the table already maps them), widening from the
        swap-store dtype to the pool dtype."""
        from ..models.paged import PagedKVCache
        jax, jnp = self._jax, self._jnp
        idx = jnp.asarray(ids, jnp.int32)
        it = iter(blobs)

        def one(c):
            if not isinstance(c, PagedKVCache):
                return c
            k, v = next(it)
            ax = c.k_pool.ndim - 4
            sel = (slice(None),) * ax + (idx,)
            kp = c.k_pool.at[sel].set(jnp.asarray(k).astype(c.k_pool.dtype))
            vp = c.v_pool.at[sel].set(jnp.asarray(v).astype(c.v_pool.dtype))
            return PagedKVCache(kp, vp, c.block_table)

        return jax.tree.map(one, caches,
                            is_leaf=lambda x: isinstance(x, PagedKVCache))

    def _preempt(self, b: int, round_no: int, caches, counters: dict,
                 reason: str, force_reingest: bool = False):
        """Evict resident row ``b``: capture its continuation (swap-out or
        reingest state), free its pages and slot, and re-queue it —
        immediately re-admissible, but only where it fits.
        ``force_reingest`` bypasses the swap path even in swap mode — an
        ESCALATING row must recompute its K/V at the wider rung, not
        restore the narrow saturated bytes the telemetry just condemned."""
        e = self._entry[b]
        req = self._req[b]
        e.preemptions += 1
        counters["preemptions"] += 1
        e.esc_level = int(self.kv_levels[b])
        e.esc_pressure = (int(self.flag_pressure[b, 0]),
                          int(self.flag_pressure[b, 1]))
        if (not self.done[b] and self.preempt_mode == "swap"
                and not force_reingest):
            written = int(self.lens[b])
            keep = self._owned[b][:self._num_pages(written, self.page)]
            degrade = self.degrade_fmt is not None and not req.no_degrade
            blobs, nbytes, sums = self._swap_out(caches, keep, degrade)
            if (self.fault_plan is not None
                    and self.fault_plan.take_corrupt()):
                self._flip_bit(blobs, req.rid)
                counters["sdc_injected"] += 1
                self.fault_plan.note("sdc_inject", round=round_no,
                                     rid=req.rid, slot=b)
            from ..models.paged import SwapBlobTag
            e.resume = _Resume(emitted=list(self._emitted[b]), blobs=blobs,
                               written=written, degraded=degrade,
                               checksums=sums,
                               tag=SwapBlobTag(replica=self.replica_id,
                                               dtype=str(self._pool_dtype),
                                               page=self.page))
            if degrade:
                e.degraded = True
                counters["degraded"] += 1
            counters["preempt_swap"] += 1
            counters["swap_out_bytes"] += nbytes
        elif self._emitted[b]:
            e.resume = _Resume(emitted=list(self._emitted[b]), blobs=None,
                               written=0, degraded=False)
            counters["preempt_reingest"] += 1
        else:
            e.resume = None         # mid-prefill: restart from the prompt
            counters["preempt_restart"] += 1
        mode = ("swap" if e.resume is not None
                and e.resume.blobs is not None else "reingest")
        if self.fault_plan is not None:
            self.fault_plan.note("preempt", round=round_no, rid=req.rid,
                                 slot=b, reason=reason, mode=mode)
        if self.journal is not None:
            self.journal.append("preempt", rid=req.rid,
                                replica=self.replica_id, round=round_no,
                                reason=reason, mode=mode)
        self.alloc.free(self._owned[b])
        self._owned[b] = []
        self._table[b, :] = self.scratch
        self._table_dirty = True
        self._req[b], self._entry[b] = None, None
        self._emitted[b], self._ingest[b] = [], []
        self._prog[b], self._resume_tok[b] = 0, None
        self.pos[b], self.lens[b] = self.max_len - 1, 0
        self.done[b], self.limit[b] = True, 0
        self.kv_levels[b], self.flag_pressure[b] = 0, 0
        self._spec_rows[b] = 0
        if self._use_pen:
            self._cnt[b] = 0
        e.not_before = max(e.not_before, round_no)
        self._pending.append(e)
        return caches

    # -- admission --------------------------------------------------------
    def _admit_one(self, e: _QEntry, b: int, pages: List[int],
                   round_no: int, caches, counters: dict):
        """Install entry ``e`` into free slot ``b`` with its admission
        pages, restoring resume state (swap-in or reingest plumbing).

        Swap-in is SDC-checked: the payload's CRC32s are recomputed and
        compared against the swap-out checksums first.  A mismatch never
        reaches the pool — the resume falls back to free-and-reingest
        (recompute), which needs exactly the pages already allocated here
        (``lens == prompt + emitted - 1`` is the engine invariant, so the
        swap and reingest page needs coincide) and reproduces the
        un-preempted run bit for bit."""
        req = e.req
        self._table[b, :len(pages)] = pages
        self._table_dirty = True
        self._owned[b] = pages
        self._req[b], self._entry[b] = req, e
        self._admit_round[b] = round_no
        self._resume_tok[b] = None
        k = 0
        if self.spec_k and not req.no_speculate:
            k = (self.spec_k if req.spec_k is None
                 else max(0, min(self.spec_k, req.spec_k)))
        self._spec_rows[b] = k
        self.kv_levels[b] = e.esc_level
        self.flag_pressure[b] = np.asarray(e.esc_pressure, np.int64)
        rs, e.resume = e.resume, None
        if rs is not None and rs.blobs is not None:
            # provenance gate before any pool write: a payload whose tag
            # mismatches this pool's (dtype, page) must never install
            from ..models.paged import check_blob_tag
            check_blob_tag(rs.tag, dtype=self._pool_dtype, page=self.page)
        if (rs is not None and rs.blobs is not None
                and rs.checksums is not None
                and _crc_blobs(rs.blobs) != rs.checksums):
            counters["sdc_detected"] += 1
            counters["sdc_reingest"] += 1
            if self.fault_plan is not None:
                self.fault_plan.note("sdc_detect", round=round_no,
                                     rid=req.rid, slot=b)
            rs.blobs, rs.checksums = None, None
        if rs is None:
            self._ingest[b] = list(req.tokens)
            self._prog[b] = 0
            self._emitted[b] = []
        elif rs.blobs is not None:
            caches = self._swap_in(caches, rs.blobs, pages)
            self._emitted[b] = list(rs.emitted)
            self._ingest[b] = []
            self._prog[b] = np.int32(req.prompt_len)
            self.tok[b, 0] = rs.emitted[-1]
            self.pos[b] = self.lens[b] = rs.written
            self.limit[b] = req.prompt_len + req.max_new - 1
            self.done[b] = False
            counters["resumed"] += 1
        else:
            self._ingest[b] = list(req.tokens) + list(rs.emitted[:-1])
            self._prog[b] = 0
            self._emitted[b] = list(rs.emitted)
            self._resume_tok[b] = rs.emitted[-1]
            counters["resumed"] += 1
        self._prompt_hist(b)
        if self.journal is not None:
            self.journal.append("admit", rid=req.rid,
                                replica=self.replica_id, round=round_no,
                                slot=b, resumed=rs is not None,
                                emitted=len(self._emitted[b]))
        return caches

    def _admission(self, round_no: int, caches, counters: dict):
        """One admission pass: visible entries in (effective priority,
        deadline, arrival, rid) order; a candidate that doesn't fit may
        preempt strictly-weaker residents (degrading/swapping them rather
        than dropping anything), else it is shed with backoff — never
        blocking the entries behind it."""
        admitted = 0
        vis = [e for e in self._pending if e.not_before <= round_no]
        vis.sort(key=lambda e: (
            -self._eff_pending(e, round_no),
            e.req.deadline if e.req.deadline is not None else _FAR,
            e.req.arrival, e.req.rid))
        for e in vis:
            req = e.req
            worst = self._num_pages(
                req.prompt_len + req.max_new + self.spec_k, self.page)
            need = self._pending_need(e)

            def fits():
                free_slots = [b for b in range(self.slots)
                              if self._req[b] is None]
                ok = (bool(free_slots)
                      and self._reserved_pages() + worst <= self.n_pages - 1
                      and self.alloc.n_free >= need)
                return free_slots[0] if ok else None

            b = fits()
            if b is None:
                eff = self._eff_pending(e, round_no)
                for v in self._victims_for(eff, round_no):
                    caches = self._preempt(v, round_no, caches, counters,
                                           reason="pressure")
                    b = fits()
                    if b is not None:
                        break
                if b is None:
                    # shed ONLY under resource pressure (pages short while
                    # a slot sits free): a backoff there keeps the loop
                    # live.  All-slots-busy is NOT pressure — the entry
                    # just waits for the burst's wave-exit to free a slot,
                    # uncapped bursts intact (the PR-5 steady state).
                    if self.shed and any(self._req[s] is None
                                         for s in range(self.slots)):
                        self._backoff(e, round_no, counters)
                    continue
            pages = self.alloc.try_alloc(need)
            if pages is None:       # raced an injected hold: treat as shed
                if self.shed:
                    self._backoff(e, round_no, counters)
                continue
            self._pending.remove(e)
            caches = self._admit_one(e, b, pages, round_no, caches, counters)
            admitted += 1
        return admitted, caches

    # -- finish -----------------------------------------------------------
    def _finish(self, b: int, round_no: int, results: dict) -> None:
        """Page recycling: the slot's pages go back to the allocator the
        round its request finishes; the table row falls back to scratch
        and the slot is immediately admissible.  Deadline accounting and
        the robustness trail land on the Finished record here."""
        req = self._req[b]
        e = self._entry[b]
        fin = Finished(
            rid=req.rid, prompt_len=req.prompt_len,
            tokens=list(self._emitted[b]),
            admit_round=int(self._admit_round[b]), finish_round=round_no,
            slot=b, preemptions=e.preemptions, sheds=e.sheds,
            degraded=e.degraded, deadline=req.deadline,
            deadline_miss=(req.deadline is not None
                           and round_no > req.deadline),
            escalated=int(self.kv_levels[b]))
        results[req.rid] = fin
        if self.journal is not None:
            self.journal.append(
                "finish", rid=req.rid, replica=self.replica_id,
                prompt_len=fin.prompt_len, toks=fin.tokens,
                admit_round=fin.admit_round, finish_round=fin.finish_round,
                slot=fin.slot, preemptions=fin.preemptions, sheds=fin.sheds,
                degraded=fin.degraded, deadline=fin.deadline,
                deadline_miss=fin.deadline_miss, escalated=fin.escalated)
        self.alloc.free(self._owned[b])
        self._owned[b] = []
        self._table[b, :] = self.scratch
        self._table_dirty = True
        self._req[b], self._entry[b] = None, None
        self._emitted[b], self._ingest[b] = [], []
        self._resume_tok[b] = None
        self.pos[b], self.lens[b] = self.max_len - 1, 0
        self.done[b], self.limit[b] = True, 0
        self.kv_levels[b], self.flag_pressure[b] = 0, 0
        self._spec_rows[b] = 0
        if self._use_pen:
            self._cnt[b] = 0

    # -- escalation -------------------------------------------------------
    def _maybe_escalate(self, active, round_no: int, caches, counters: dict):
        """Flag-pressure check after a burst: any live row whose OF or UF
        pressure crossed its threshold moves one rung up the ladder via a
        forced free-and-reingest (the saturated narrow-format bytes are
        exactly what the flags condemned — recompute, don't swap them
        back).  Refusable per request; deferred while the free list is
        shorter than the policy's ``min_free_pages`` (an escalating row
        re-prefills its whole history — the worst moment to fight
        admission for pages)."""
        esc = self.escalate
        plan = self.fault_plan
        for b in active:
            if self._req[b] is None or self.done[b]:
                continue                    # finished/evicted this round
            lvl = int(self.kv_levels[b])
            of, uf = (int(self.flag_pressure[b, 0]),
                      int(self.flag_pressure[b, 1]))
            if of < esc.of_threshold and uf < esc.uf_threshold:
                continue
            if lvl >= esc.top():
                continue                    # already at the widest rung
            e = self._entry[b]
            if self._req[b].no_escalate:
                if not e.esc_refused:
                    e.esc_refused = True
                    counters["esc_refused"] += 1
                continue
            if self.alloc.n_free < esc.min_free_pages:
                counters["esc_deferred"] += 1
                continue
            rid = self._req[b].rid
            caches = self._preempt(b, round_no, caches, counters,
                                   reason="escalate", force_reingest=True)
            e.esc_level = lvl + 1
            e.esc_pressure = (0, 0)
            counters["escalations"] += 1
            if plan is not None:
                plan.note("escalate", round=round_no, rid=rid, slot=b,
                          level=lvl + 1, of=of, uf=uf)
            if self.journal is not None:
                self.journal.append("escalate", rid=rid,
                                    replica=self.replica_id, round=round_no,
                                    level=lvl + 1)
        return caches

    # -- the serving state machine ----------------------------------------
    #
    # ``run`` = ``start`` + ``step`` until drained + ``finalize``.  The
    # split exists for the fleet host: ``ReplicatedEngine`` interleaves
    # replicas one ``step`` at a time, so a replica can die (or hang)
    # mid-run while its survivors keep stepping — ``evacuate``/``adopt``
    # then move the victim's in-flight requests over.
    def start(self, requests: Sequence[Request]) -> None:
        """Validate + enqueue ``requests`` and arm the run state.  With a
        non-empty journal attached (a restart), unfinished requests
        re-enter the queue seeded to resume from their last journaled
        token — the free-and-reingest path, so the recovery run's tokens
        are bit-identical to the run that never crashed — and finished
        ones are answered straight from their ``finish`` records."""
        jax = self._jax
        for r in requests:
            if r.prompt_len < 1 or r.max_new < 1:
                raise ValueError(f"request {r.rid}: empty prompt or budget")
            if r.prompt_len + r.max_new + self.spec_k > self.max_len:
                hint = (f" (+{self.spec_k} speculative lookahead: the "
                        f"verify chunk writes spec_k slots past the "
                        f"budget)" if self.spec_k else "")
                raise ValueError(
                    f"request {r.rid}: prompt {r.prompt_len} + budget "
                    f"{r.max_new}{hint} exceeds max_len {self.max_len}")
            worst = self._num_pages(r.prompt_len + r.max_new + self.spec_k,
                                    self.page)
            if worst > self.n_pages - 1:
                raise ValueError(
                    f"request {r.rid} can never fit the pool: needs "
                    f"{worst} pages, pool has {self.n_pages - 1} "
                    f"(+1 scratch)")
        self._requests = list(requests)
        self._results = {}
        self.alloc.reset_peak()
        plan = self.fault_plan
        if plan is not None:
            plan.reset()
        self._held, self._release_at = [], None
        self.reset_monitors()
        self._counters = {k: 0 for k in (
            "preemptions", "preempt_swap", "preempt_reingest",
            "preempt_restart", "resumed", "degraded", "swap_out_bytes",
            "shed_events", "poisoned_rounds", "nonfinite_prefill",
            "stragglers", "faults_exhaust", "faults_slow",
            "escalations", "esc_deferred", "esc_refused",
            "sdc_injected", "sdc_detected", "sdc_reingest",
            "spec_rounds", "spec_emitted",
            "migrated_in", "journal_replayed")}
        self._key = jax.random.key(self.seed)
        self._round_no = self._decode_rounds = 0
        self._occ_accum = self._bursts = 0
        jr = self.journal
        pend: List[_QEntry] = []
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            e = _QEntry(req=r, not_before=r.arrival)
            if jr is not None and jr.records:
                fr = jr.finish_record(r.rid)
                if fr is not None:
                    self._results[r.rid] = _finished_from_record(fr)
                    continue
                em = jr.emitted(r.rid)
                if em:
                    whole = (len(em) >= r.max_new
                             or (self.stop_token is not None
                                 and em[-1] == self.stop_token))
                    if whole:
                        # the crash fell between the final tokens record
                        # and its finish record: the stream is complete,
                        # only the completion fact is missing — recover
                        # it instead of re-serving a finished request
                        self._results[r.rid] = Finished(
                            rid=r.rid, prompt_len=r.prompt_len,
                            tokens=list(em), admit_round=0,
                            finish_round=0, slot=-1)
                        jr.append("finish", rid=r.rid,
                                  replica=self.replica_id,
                                  prompt_len=r.prompt_len, toks=list(em),
                                  recovered=True)
                        continue
                    e.resume = _Resume(emitted=list(em), blobs=None,
                                       written=0, degraded=False)
                    e.not_before = 0        # arrived before the crash
                    self._counters["journal_replayed"] += 1
                    jr.append("replay", rid=r.rid,
                              replica=self.replica_id, from_tok=len(em))
            pend.append(e)
        self._pending = pend

    def has_work(self) -> bool:
        """Queued or resident requests remain (the run-loop condition)."""
        return bool(self._pending
                    or any(r is not None for r in self._req))

    def _diag(self) -> dict:
        return {"round": self._round_no,
                "replica": self.replica_id,
                "pending": [(e.req.rid, e.not_before, e.sheds)
                            for e in self._pending],
                "resident": [r.rid for r in self._req if r is not None],
                "pool": self.alloc.stats(),
                "held_pages": len(self._held),
                "counters": dict(self._counters)}

    # -- migration (the fleet host's dead-replica API) --------------------
    def evacuate(self, *, readable: bool = True,
                 mode: str = "swap") -> List[_QEntry]:
        """Capture EVERY in-flight and queued request as portable queue
        entries.  Residents leave through the normal preemption capture:
        with the victim's device memory ``readable`` (a hang) and
        ``mode="swap"`` their live K/V pages travel as tagged swap blobs
        a survivor installs into its own pool; otherwise (a kill — pages
        unreachable — or ``mode="reingest"``) the continuation is the
        emitted-token list and the survivor recomputes K/V by
        free-and-reingest.  Either way the migrated request's remaining
        tokens are bit-identical to the unfailed run.  Queued entries
        drain as-is (their backoff clocks re-base on the receiver)."""
        force = (not readable) or mode != "swap"
        for b in range(self.slots):
            if self._req[b] is not None:
                self.caches = self._preempt(
                    b, self._round_no, self.caches, self._counters,
                    reason="migrate", force_reingest=force)
        out, self._pending = self._pending, []
        return out

    def adopt(self, entries: Sequence[_QEntry]) -> int:
        """Enqueue another replica's evacuated entries into THIS engine.
        Swap payloads are provenance-checked against the receiving pool
        first (``models.paged.check_blob_tag``): a foreign blob —
        dtype or page-size mismatch — raises ``ValueError`` instead of
        silently reinterpreting page bytes.  Adopted entries become
        admissible immediately on the receiver's round clock and then
        compose with its priority/deadline/backpressure scheduling like
        any preempted-and-requeued local request."""
        from ..models.paged import check_blob_tag
        n = 0
        for e in entries:
            if e.resume is not None and e.resume.blobs is not None:
                check_blob_tag(e.resume.tag, dtype=self._pool_dtype,
                               page=self.page)
            e.not_before = self._round_no
            self._pending.append(e)
            self._counters["migrated_in"] += 1
            if self.journal is not None:
                self.journal.append(
                    "migrate", rid=e.req.rid, to=self.replica_id,
                    mode=("swap" if e.resume is not None
                          and e.resume.blobs is not None else "reingest"),
                    emitted=(len(e.resume.emitted)
                             if e.resume is not None else 0))
            n += 1
        return n

    def step(self) -> bool:
        """ONE scheduler iteration: fault release -> admission -> prefill
        chunks -> at most one decode burst -> finish/escalate accounting.
        Returns ``has_work()`` — False once drained.  Raises
        ``ReplicaLostError`` at the burst dispatch when this replica's
        ``replica_fault`` kill is due (the simulated device loss)."""
        if not self.has_work():
            return False
        jnp, jax = self._jnp, self._jax
        plan = self.fault_plan
        counters = self._counters
        watchdog, monitor = self.watchdog, self.monitor
        key = self._key
        progress = 0

        # -- fault plan: release expired holds, fire due injections -------
        if self._held and self._round_no >= self._release_at:
            self.alloc.free(self._held)
            if plan is not None:
                plan.note("exhaust_release", round=self._round_no,
                          pages=len(self._held))
            self._held, self._release_at = [], None
        if plan is not None and not self._held:
            dur = plan.take_exhaustion(self._round_no)
            if dur is not None:
                grab = self.alloc.n_free
                self._held = self.alloc.alloc(grab) if grab else []
                self._release_at = self._round_no + max(1, dur)
                counters["faults_exhaust"] += 1
                plan.note("exhaust", round=self._round_no, pages=grab,
                          until=self._release_at)

        # -- admission: place queue entries (preempt/degrade/shed) --------
        admitted, self.caches = self._admission(
            self._round_no, self.caches, counters)
        progress += admitted

        # -- one prefill chunk per admitting slot, same-offset slots
        #    batched into one call (the t=0 admission wave especially)
        prefilling = [b for b in range(self.slots)
                      if self._req[b] is not None and self.done[b]]
        waves: Dict[int, List[int]] = {}
        for b in prefilling:
            waves.setdefault(int(self._prog[b]), []).append(b)
        for off, rows in sorted(waves.items()):
            m = len(rows)
            buf = np.zeros((m, self.chunk), np.int32)
            meta = np.zeros((3, m), np.int32)   # rows/chunk lens/levels
            meta[0] = rows
            for i, b in enumerate(rows):
                piece = self._ingest[b][off:off + self.chunk]
                buf[i, :len(piece)] = piece
                meta[1, i] = len(piece)
                meta[2, i] = self.kv_levels[b]
            if self.temperature > 0.0:
                key, sk = jax.random.split(key)
                self._key = key
            else:
                sk = key
            cnts = (jnp.asarray(self._cnt[rows]) if self._use_pen
                    else None)
            tok0, badp, self.caches, flp = self._chunk_fn(off, m)(
                self.params, self.caches, self._table_device(),
                jnp.asarray(buf), jnp.asarray(meta), cnts, sk)
            tok0, badp = np.asarray(tok0), np.asarray(badp)
            if self.escalate is not None:
                # prefill write flags feed the same per-slot pressure
                self.flag_pressure[rows] += np.asarray(flp, np.int64)
            progress += 1
            for i, b in enumerate(rows):
                req = self._req[b]
                self._prog[b] += int(meta[1, i])
                if int(self._prog[b]) != len(self._ingest[b]):
                    continue
                if badp[i]:
                    if plan is not None and plan.mask_poison:
                        counters["nonfinite_prefill"] += 1
                    else:
                        raise PoisonedLogitsError(
                            f"non-finite prefill logits for request "
                            f"{req.rid} (slot {b}, round "
                            f"{self._round_no})")
                if self._resume_tok[b] is not None:
                    # reingest resume: the re-fed tokens only rebuild
                    # K/V; generation continues from the last emitted
                    # token exactly where the un-preempted run was
                    self.tok[b, 0] = self._resume_tok[b]
                    self._resume_tok[b] = None
                    self.pos[b] = self.lens[b] = len(self._ingest[b])
                    self.limit[b] = req.prompt_len + req.max_new - 1
                    self.done[b] = False
                    continue
                t0 = int(tok0[i])
                self._emitted[b] = [t0]
                if self.journal is not None:
                    self.journal.append("tokens", rid=req.rid,
                                        replica=self.replica_id,
                                        toks=[t0])
                if self._use_pen:
                    self._cnt[b, t0 % self._cnt.shape[1]] += 1
                hit_stop = (self.stop_token is not None
                            and t0 == self.stop_token)
                if hit_stop or req.max_new == 1:
                    self._finish(b, self._round_no, self._results)
                    progress += 1
                else:
                    self.tok[b, 0] = t0
                    self.pos[b] = self.lens[b] = req.prompt_len
                    self.limit[b] = req.prompt_len + req.max_new - 1
                    self.done[b] = False

        # -- decode burst over every slot ---------------------------------
        active = [b for b in range(self.slots) if not self.done[b]]
        still_prefilling = any(
            self._req[b] is not None and self.done[b]
            for b in range(self.slots))
        n_max = 0
        if active:
            # admission wave: with a deep queue, let up to `admit_wave`
            # finishes accumulate before handing control back — halves
            # scheduler round-trips vs reacting to every single finish.
            # n_max is then capped near the wave-th soonest budget
            # finish so a lone early finisher never waits long.
            wave = (min(self.admit_wave, len(self._pending))
                    if self._pending else 0)
            if still_prefilling:
                # interleave: chunk, a few decode rounds, chunk, ... —
                # ongoing streams advance while a long prompt prefills
                n_max = self.prefill_rounds
            else:
                n_max = self.burst_cap
                if self._pending:
                    till = (min(e.not_before for e in self._pending)
                            - self._round_no)
                    if till > 0:
                        n_max = max(1, min(n_max, till))
                    rem = sorted(int(self.limit[b]) - int(self.pos[b])
                                 + 1 for b in active)
                    k = min(wave, len(rem)) - 1
                    n_max = max(1, min(n_max, rem[k] + 1))
            # page pressure: a failed lazy alloc preempts a weaker
            # resident; if none exists the row itself yields its slot
            look = self.spec_k
            for b in list(active):
                if b not in active:
                    continue
                # each speculative round advances up to spec_k+1
                # tokens and its verify chunk writes spec_k slots
                # past the accepted frontier (dead until accepted)
                tgt = min(int(self.pos[b]) + n_max * (look + 1) - 1
                          + look,
                          int(self.limit[b]) - 1 + look)
                while not self._ensure_pages(b, tgt):
                    vs = self._victims_for(
                        self._eff_resident(b, self._round_no),
                        self._round_no, exclude=(b,))
                    if not vs:
                        self.caches = self._preempt(
                            b, self._round_no, self.caches,
                            counters, reason="pages")
                        active.remove(b)
                        break
                    self.caches = self._preempt(
                        vs[0], self._round_no, self.caches,
                        counters, reason="pages")
                    if vs[0] in active:
                        active.remove(vs[0])
        if active:
            # the simulated device loss fires exactly here — after host
            # scheduling, at the burst dispatch, the boundary where a
            # real accelerator fault would surface
            if (self.replica_fault is not None
                    and self.replica_fault.take_kill(self.replica_id,
                                                     self._bursts)):
                raise ReplicaLostError(
                    f"replica {self.replica_id} lost at burst "
                    f"{self._bursts} (round {self._round_no}): "
                    f"simulated device failure",
                    replica=self.replica_id, burst=self._bursts)
            poison_rel = ovf_rel = -1
            if plan is not None:
                p = plan.next_poison(self._round_no,
                                     self._round_no + int(n_max))
                if p is not None:
                    poison_rel = p - self._round_no
                o = plan.next_overflow(self._round_no,
                                       self._round_no + int(n_max))
                if o is not None:
                    ovf_rel = o - self._round_no
            t_start = time.perf_counter()
            if plan is not None:
                stall = plan.take_slow(self._round_no)
                if stall > 0.0:
                    counters["faults_slow"] += 1
                    plan.note("slow", round=self._round_no,
                              seconds=stall)
                    time.sleep(stall)
            state = np.zeros((11 if self.spec_k else 10, self.slots),
                             np.int32)
            state[0, :] = self.tok[:, 0]
            state[1], state[2], state[3] = self.pos, self.lens, self.limit
            state[4] = self.done
            state[5, 0], state[6, 0] = n_max, wave
            state[7, 0] = poison_rel
            state[8] = self.kv_levels
            state[9, 0] = ovf_rel
            if self.spec_k:
                state[10] = self._spec_rows
            cnts = jnp.asarray(self._cnt) if self._use_pen else None
            res = self._burst(self.params, self.caches,
                              self._table_device(),
                              jnp.asarray(state), cnts, key)
            out, n, state_d, self.caches, key2, bad_d, fl_d = res[:7]
            n = int(n)                    # blocks on the burst
            new_state = np.array(state_d)
            if self.spec_k:
                # packed layout: row b's accepted tokens fill
                # out[b, :lens-growth]; download up to the widest row
                sp = np.asarray(res[7])
                counters["spec_rounds"] += int(sp[0])
                counters["spec_emitted"] += int(sp[1])
                w = int(max(1, (new_state[2] - self.lens).max()))
                outs = np.asarray(out[:, :w])
            else:
                outs = np.asarray(out[:, :n])  # only executed cols
            bad = np.asarray(bad_d)
            dt = time.perf_counter() - t_start
            if monitor.record(self._bursts, dt):
                counters["stragglers"] += 1
            if bad.sum():
                if plan is not None and plan.mask_poison:
                    counters["poisoned_rounds"] += int(bad.max())
                    plan.note("poison", round=self._round_no,
                              rows=np.nonzero(bad)[0].tolist())
                else:
                    raise PoisonedLogitsError(
                        f"non-finite decode logits at round "
                        f"{self._round_no} (rows "
                        f"{np.nonzero(bad)[0].tolist()}); no "
                        f"masking fault harness is active")
            self.tok = new_state[0][:, None].copy()
            self.pos = new_state[1]
            if self.temperature > 0.0:
                key = key2
                self._key = key
            total_ran = 0
            for b in active:
                # rounds this row actually ran = its live-length growth
                ran = int(new_state[2][b]) - int(self.lens[b])
                emitted = [int(t) for t in outs[b, :ran]]
                self._emitted[b].extend(emitted)
                if self.journal is not None and emitted:
                    # the per-burst delta is the crash-consistency
                    # quantum: at most one burst of tokens is ever lost,
                    # and greedy determinism regenerates it bit-exactly
                    self.journal.append("tokens", rid=self._req[b].rid,
                                        replica=self.replica_id,
                                        toks=emitted)
                if self._use_pen and emitted:
                    v = self._cnt.shape[1]
                    np.add.at(self._cnt[b],
                              np.asarray(emitted, np.int64) % v, 1)
                self._occ_accum += ran
                total_ran += ran
            if n > 0 and total_ran == 0:
                raise EngineStuckError(
                    f"decode burst executed {n} rounds without "
                    f"advancing any of {len(active)} live rows",
                    self._diag())
            if self.escalate is not None:
                self.flag_pressure += np.asarray(fl_d, np.int64)
                if plan is not None and 0 <= ovf_rel < n:
                    counters["faults_overflow"] = counters.get(
                        "faults_overflow", 0) + 1
                    plan.note("overflow",
                              round=self._round_no + ovf_rel,
                              scale=plan.overflow_scale)
            self.lens = new_state[2]
            self.done = new_state[3].astype(bool)
            self._round_no += n
            self._decode_rounds += n
            self._bursts += 1
            progress += n
            for b in active:
                if self.done[b]:
                    self._finish(b, self._round_no, self._results)
                    progress += 1
            if self.escalate is not None:
                self.caches = self._maybe_escalate(
                    active, self._round_no, self.caches, counters)
        elif still_prefilling:
            self._round_no += 1    # prefill-only round (no decoders yet)
        elif self._pending:
            # idle: jump to the next event — an arrival, a backoff
            # window expiring, or an injected exhaustion releasing
            nxt = [e.not_before for e in self._pending]
            if self._held:
                nxt.append(self._release_at)
            self._round_no = max(self._round_no + 1, min(nxt))
        watchdog.tick(progress > 0, self._diag)
        return self.has_work()

    def finalize(self):
        """Close out a drained (or abandoned) run: release fault-plan
        holds and assemble the stats dict (``self.caches`` is already
        current — it IS the donated burst carry, kept live step to step
        so a crashed run's restart never touches a donated buffer).
        Returns ``(results_by_rid, stats)`` — ``run`` orders the results
        itself; the fleet host merges the dicts across replicas instead
        (a victim's pre-death completions still count)."""
        if self._held:              # plan outlived the queue: tidy up
            self.alloc.free(self._held)
            self._held, self._release_at = [], None
        counters = self._counters
        dl = [f for f in self._results.values() if f.deadline is not None]
        misses = sum(1 for f in dl if f.deadline_miss)
        stats = {
            "rounds": self._round_no,
            "decode_rounds": self._decode_rounds,
            "bursts": self._bursts,
            "occupancy": (self._occ_accum
                          / (self.slots * self._decode_rounds)
                          if self._decode_rounds else 0.0),
            # request-KV pages only: the engine's always-live scratch page
            # (dead-write sink) is bookkeeping, not cache content
            "peak_live_pages": self.alloc.peak_live - 1,
            "n_pages": self.n_pages,
            "fixed_equiv_pages": self.slots * self.max_pages,
            "pages_live_end": self.alloc.n_live - 1,
            "deadline_total": len(dl),
            "deadline_misses": misses,
            "deadline_miss_rate": (misses / len(dl)) if dl else 0.0,
            "straggler_ewma_s": self.monitor.ewma,
            **counters,
        }
        if self.spec_k:
            lr = counters["spec_rounds"]
            stats["spec_k"] = self.spec_k
            # emitted / (live-row-rounds * chunk width): the bonus token
            # keeps every live row's per-round yield >= 1, so the rate
            # lives in (0, 1] whenever any speculative round ran
            stats["spec_accept_rate"] = (
                counters["spec_emitted"] / (lr * (self.spec_k + 1))
                if lr else 0.0)
        return dict(self._results), stats

    def run(self, requests: Sequence[Request]):
        """Serve ``requests`` to completion.  Returns ``(finished, stats)``
        with ``finished`` in input order and ``stats`` covering rounds,
        mean batch occupancy, the page-pool high-water mark, and the
        robustness counters (preempt/shed/degrade/deadline/fault)."""
        self.start(requests)
        while self.step():
            pass
        res, stats = self.finalize()
        return [res[r.rid] for r in requests], stats


class ReplicatedEngine:
    """Data-parallel engine replicas over a ``(data, model)`` serving mesh
    — or, with ``mesh=None, replicas=N``, a meshless fleet of ``N``
    unsharded replicas (the HA test topology).

    Each ``data`` row of the mesh becomes ONE ``ContinuousEngine`` running
    tensor-parallel attention over its own ``("model",)`` sub-mesh
    (``launch/mesh.py: replica_meshes``), with its OWN ``PageAllocator``
    over a disjoint page pool and its own block tables — replicas share
    no state and no collective, so the data axis is pure throughput.

    The request queue is partitioned host-side: arrivals round-robin over
    replicas in ``(arrival, rid)`` order, so each replica sees the same
    heavy-tail mix and admission waves split ``~1/dp`` per replica.
    ``run`` merges the ``Finished`` records back into input order and
    aggregates stats — counters sum, occupancy is decode-round-weighted,
    and the pool story is ``models.paged.aggregate_stats`` over the
    per-replica allocators (disjoint pools: totals are plain sums).

    The host loop INTERLEAVES replicas one scheduler step at a time
    (each replica owns its devices outright, so on real hardware the
    per-replica loops are embarrassingly parallel; time-slicing them
    here changes wall-clock on a simulated mesh, never tokens or
    accounting) — and that is what makes replica loss survivable
    mid-run:

      * every completed step is a HEARTBEAT; a ``ReplicaFaultPlan`` hang
        makes the victim stop stepping, and after ``hang_patience``
        consecutive missed beats the host declares it dead with device
        memory still readable — its residents evacuate as tagged swap
        blobs (``migrate="swap"``) or emitted-token reingest state;
      * a kill raises ``ReplicaLostError`` through the victim's burst
        dispatch — device memory is GONE, so evacuation always falls
        back to free-and-reingest (host-side emitted tokens survive);
      * evacuated entries are ``adopt``ed round-robin by the surviving
        replicas and finish there with token bits identical to the
        unfailed run; if NO replica survives, the loss re-raises for
        ``train.fault.run_with_restarts`` + the request journal.
    """

    def __init__(self, model, params, *, mesh=None, replicas=None,
                 migrate: str = "swap", hang_patience: int = 3, **kw):
        from .mesh import replica_meshes
        if migrate not in ("swap", "reingest"):
            raise ValueError(f"migrate must be swap|reingest, "
                             f"got {migrate!r}")
        subs = replica_meshes(mesh, replicas)
        self.mesh = mesh
        self.migrate = migrate
        self.hang_patience = max(1, hang_patience)
        self.replica_fault = kw.pop("replica_fault", None)
        self.journal = kw.pop("journal", None)
        self.engines = [ContinuousEngine(model, params, mesh=m,
                                         replica_id=i,
                                         replica_fault=self.replica_fault,
                                         journal=self.journal, **kw)
                        for i, m in enumerate(subs)]
        self._bound: Optional[List[Request]] = None
        self.heartbeats = [{"beats": 0, "missed": 0, "status": "live"}
                           for _ in self.engines]
        self._ha = {k: 0 for k in (
            "ha_kills", "ha_hangs", "ha_migrations",
            "ha_migrated_swap", "ha_migrated_reingest")}

    @property
    def allocators(self):
        return [e.alloc for e in self.engines]

    def reset_monitors(self) -> None:
        """The ``run_with_restarts`` contract, fanned out: every
        replica's watchdog + straggler monitor is rebuilt, and the
        fleet's heartbeat view starts fresh (a restarted fleet has no
        dead replicas — the fault plan decides whether one re-dies)."""
        for e in self.engines:
            e.reset_monitors()
        self.heartbeats = [{"beats": 0, "missed": 0, "status": "live"}
                           for _ in self.engines]
        self._ha = {k: 0 for k in self._ha}

    def bind(self, requests: Sequence[Request]) -> "ReplicatedEngine":
        """Stash a queue so ``run()`` needs no arguments — the shape
        ``run_with_restarts`` drives (its runner contract is a no-arg
        ``run``).  Returns self for factory one-liners."""
        self._bound = list(requests)
        return self

    def partition(self, requests: Sequence[Request]) -> List[List[Request]]:
        """Round-robin split in ``(arrival, rid)`` order — deterministic,
        and each replica's sub-queue preserves the arrival ordering the
        single-engine admission loop expects."""
        parts: List[List[Request]] = [[] for _ in self.engines]
        for i, r in enumerate(sorted(requests,
                                     key=lambda r: (r.arrival, r.rid))):
            parts[i % len(parts)].append(r)
        return parts

    # -- failure handling -------------------------------------------------
    def _survivors(self) -> List[int]:
        return [i for i, h in enumerate(self.heartbeats)
                if h["status"] == "live"]

    def _lose_replica(self, i: int, *, readable: bool, burst: int,
                      why: str) -> None:
        """Declare replica ``i`` dead and migrate its in-flight work.
        ``readable`` says whether the victim's device memory can still be
        swapped out (hang) or is gone (kill — evacuation re-ingests).
        Without survivors the loss re-raises for the restart supervisor;
        the journal then carries every already-emitted token."""
        self.heartbeats[i]["status"] = "dead"
        eng = self.engines[i]
        entries = eng.evacuate(readable=readable, mode=self.migrate)
        if self.journal is not None:
            self.journal.append("replica_lost", replica=i, why=why,
                               burst=burst, evacuated=len(entries))
        alive = self._survivors()
        if not alive:
            raise ReplicaLostError(
                f"replica {i} {why} at burst {burst} and no replica "
                f"survives to adopt its {len(entries)} requests — "
                f"restart and replay the journal",
                replica=i, burst=burst)
        for j, e in enumerate(entries):
            swap = e.resume is not None and e.resume.blobs is not None
            self.engines[alive[j % len(alive)]].adopt([e])
            self._ha["ha_migrations"] += 1
            self._ha["ha_migrated_swap" if swap
                     else "ha_migrated_reingest"] += 1

    # -- the fleet loop ---------------------------------------------------
    def run(self, requests: Optional[Sequence[Request]] = None):
        """Serve ``requests`` (or the ``bind``-ed queue) across all
        replicas, interleaved one step at a time.  Returns
        ``(finished, stats)`` with ``finished`` in input order;
        ``stats["replicas"]`` keeps each replica's own record,
        ``stats["pool"]`` the aggregated allocator view, and the
        ``ha_*`` fields + ``stats["heartbeats"]`` the fleet's
        fault-tolerance story."""
        from ..models.paged import aggregate_stats
        if requests is None:
            if self._bound is None:
                raise ValueError("run() needs requests (or bind() first)")
            requests = self._bound
        self.heartbeats = [{"beats": 0, "missed": 0, "status": "live"}
                           for _ in self.engines]
        self._ha = {k: 0 for k in self._ha}
        plan = self.replica_fault
        parts = self.partition(requests)
        for eng, part in zip(self.engines, parts):
            eng.start(part)
        while True:
            stepped = False
            for i, eng in enumerate(self.engines):
                hb = self.heartbeats[i]
                if hb["status"] == "dead" or not eng.has_work():
                    continue
                if plan is not None and plan.hang_due(i, eng._bursts):
                    # the victim stops responding: a missed beat per
                    # fleet sweep, then declared dead — device memory
                    # is still readable, so pages can migrate as blobs
                    hb["missed"] += 1
                    if hb["missed"] == 1:
                        self._ha["ha_hangs"] += 1
                    if hb["missed"] >= self.hang_patience:
                        self._lose_replica(i, readable=True,
                                           burst=eng._bursts, why="hung")
                    stepped = True      # the fleet is still making calls
                    continue
                try:
                    eng.step()
                    hb["beats"] += 1
                    stepped = True
                except ReplicaLostError as err:
                    self._ha["ha_kills"] += 1
                    self._lose_replica(i, readable=False,
                                       burst=err.burst, why="killed")
                    stepped = True
            work = [i for i in self._survivors()
                    if self.engines[i].has_work()]
            if not work:
                break
            if not stepped:     # defensive: nothing can advance
                raise EngineStuckError(
                    "replicated loop made no progress",
                    {"heartbeats": self.heartbeats,
                     "pending": [len(self.engines[i]._pending)
                                 for i in work]})
        results: Dict[int, Finished] = {}
        per = []
        for i, eng in enumerate(self.engines):
            res, st = eng.finalize()
            results.update(res)
            st["replica_status"] = self.heartbeats[i]["status"]
            per.append(st)
        dr = sum(s["decode_rounds"] for s in per)
        stats = {
            "replicas_n": len(self.engines),
            "rounds": max((s["rounds"] for s in per), default=0),
            "decode_rounds": dr,
            "bursts": sum(s["bursts"] for s in per),
            "occupancy": (sum(s["occupancy"] * s["decode_rounds"]
                              for s in per) / dr if dr else 0.0),
            "peak_live_pages": sum(s["peak_live_pages"] for s in per),
            "n_pages": sum(s["n_pages"] for s in per),
            "fixed_equiv_pages": sum(s["fixed_equiv_pages"] for s in per),
            "deadline_total": sum(s["deadline_total"] for s in per),
            "deadline_misses": sum(s["deadline_misses"] for s in per),
            "pool": aggregate_stats(self.allocators),
            "replicas": per,
            "heartbeats": [dict(h) for h in self.heartbeats],
            **self._ha,
        }
        dl = stats["deadline_total"]
        stats["deadline_miss_rate"] = (stats["deadline_misses"] / dl
                                       if dl else 0.0)
        if any("spec_accept_rate" in s for s in per):
            sr = sum(s.get("spec_rounds", 0) for s in per)
            se = sum(s.get("spec_emitted", 0) for s in per)
            k1 = max(s.get("spec_k", 0) for s in per) + 1
            stats["spec_rounds"], stats["spec_emitted"] = sr, se
            stats["spec_accept_rate"] = se / (sr * k1) if sr else 0.0
        for k in per[0] if per else ():
            if k not in stats and isinstance(per[0][k], (int, np.integer)):
                stats[k] = sum(s[k] for s in per)
        return [results[r.rid] for r in requests], stats
