"""Continuous-batching serving engine: admission, chunked prefill, bursts.

The serving-loop half of the repo's energy-proportionality story.  PR 1-4
made every LAYER of the stack length-proportional — per-row ``kv_len``
vectors prune each sequence's attention walk, the paged pool makes HBM
scale with live tokens, EOS freezing stops a finished row's outputs — but
the LOOP still paid batch-max cost everywhere: generation was a fixed-trip
scan that kept stepping EOS-frozen rows to ``max_new_tokens``, a finished
row's pages stayed live until the whole batch exited, and new requests
waited for a full batch teardown.  This module closes that gap:

  * **Admission** — a host-side loop over a request queue.  A finished
    row's pages go back to the ``PageAllocator`` the round it finishes
    (``decode_burst`` exits the compiled loop that round), and the freed
    slot is refilled from the queue mid-generation.  Admission reuses the
    traced per-row write-index/``kv_len``/block-table plumbing, so slot
    churn never retraces: ONE compiled burst program serves the whole run.
  * **Chunked prefill** — an admitted prompt is consumed in fixed-width
    chunks through the paged flash read path
    (``Model.prefill_chunk``/``flash_attention(block_table=)``), one chunk
    per round, interleaved with single-round decode bursts so ongoing
    streams are never stalled behind a long new prompt.
  * **Page accounting** — pages are allocated LAZILY (prompt pages at
    admission, one page per row as its length crosses a page boundary), so
    the allocator's ``peak_live`` high-water mark tracks the sum of live
    sequence lengths, not ``slots x max_len``.  Admission reserves each
    request's worst case (``num_pages(prompt + budget)``) against the pool
    so mid-generation allocation can never fail.

Dead-slot discipline (why idle/prefilling/finished slots are safe): every
row writes decode K/V only through its OWN table row, and a cache slot
becomes live for attention only AFTER the real token write to it — so
garbage writes (idle slots parked at ``max_len - 1``, frozen rows, pad
tails of prefill chunks) land either on the reserved scratch page or on
dead slots that real writes overwrite before any mask lets them be read.

The driver is deliberately host-side Python: admission and page churn
happen at burst boundaries, between compiled steps, never inside them —
the same boundary the ``PageAllocator`` already lives at.

``python -m repro.launch.serve --continuous`` drives this end to end.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One queued generation request.

    ``arrival`` is in DECODE ROUNDS (the engine's logical clock): the
    request becomes visible to admission once that many rounds have run —
    a deterministic stand-in for wall-clock arrival traces."""
    rid: int
    tokens: Sequence[int]          # prompt token ids (>= 1)
    max_new: int                   # generation budget incl. the first token
    arrival: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class Finished:
    """A served request: ``tokens`` holds the generated ids (first token
    included; a ``stop_token`` hit keeps the stop as the last element)."""
    rid: int
    prompt_len: int
    tokens: List[int]
    admit_round: int
    finish_round: int
    slot: int


def synthetic_trace(n_req: int, slots: int, prompt_len: int, gen: int,
                    vocab: int, seed: int = 2) -> List[Request]:
    """The deterministic mixed-length / mixed-budget / mixed-arrival
    workload of the continuous-vs-fixed A/B (benchmarks/serve_decode.py,
    ``launch/serve.py --continuous``).

    Shape (a chat-like heavy tail): every 8th request in the first 3/4 of
    the queue is LONG (budget ``gen``); the rest cycle short budgets
    (``gen/16``, ``gen/8``, ``gen/4``).  Prompt lengths cycle 1/4..4/4 of
    ``prompt_len``.  Arrivals: the first ``slots`` requests at round 0,
    then clumps of four every ``gen/16`` rounds — bursty traffic that
    keeps the admission queue fed.  Fixed batching pays ``gen`` rounds for
    every batch containing one long request; continuous pays each row only
    its own budget and backfills freed slots mid-generation."""
    rng = np.random.RandomState(seed)
    fr_len = (0.25, 0.5, 0.75, 1.0)
    shorts = (gen // 16, gen // 8, gen // 4)
    reqs = []
    for i in range(n_req):
        is_long = (i % 8 == 0) and i < (3 * n_req) // 4
        budget = gen if is_long else max(2, shorts[i % 3])
        plen = max(1, int(prompt_len * fr_len[i % 4]))
        arrival = (0 if i < slots
                   else ((i - slots) // 4 + 1) * max(2, gen // 16))
        reqs.append(Request(
            rid=i, tokens=rng.randint(0, vocab, size=plen).tolist(),
            max_new=budget, arrival=arrival))
    return reqs


class ContinuousEngine:
    """Continuous-batching scheduler over ``slots`` paged batch rows.

    The model must be paged (``cfg.paged_kv``; attention-mixer archs
    only).  Requests must satisfy ``prompt_len + max_new <= max_len`` and
    ``max_new >= 1``.  Greedy by default; ``temperature``/``top_k``/
    ``top_p`` enable sampling with one PRNG key threaded deterministically
    through every sampling site (same queue -> same tokens)."""

    def __init__(self, model, params, *, slots: int, max_len: int,
                 chunk: int = 32, n_pages: Optional[int] = None,
                 stop_token: Optional[int] = None, temperature: float = 0.0,
                 top_k: Optional[int] = None, top_p: Optional[float] = None,
                 seed: int = 0, burst_cap: int = 64,
                 prefill_rounds: int = 2, admit_wave: int = 2, mesh=None):
        import functools

        import jax
        import jax.numpy as jnp

        from ..models.paged import PageAllocator, num_pages
        from ..models.transformer import (caches_with_table, init_caches,
                                          sample_token)

        cfg = model.cfg
        if not cfg.paged_kv:
            raise ValueError("ContinuousEngine requires cfg.paged_kv "
                             "(admission allocates pages, not batch rows)")
        why = cfg.paged_unsupported_reason()
        if why is not None:
            raise ValueError(f"continuous batching is unsupported for "
                             f"{cfg.name}: {why} cannot page its cache")
        assert slots >= 1 and chunk >= 1 and burst_cap >= 1
        self.model, self.params, self.mesh = model, params, mesh
        self.slots, self.max_len, self.chunk = slots, max_len, chunk
        self.page = cfg.page_size
        self.max_pages = num_pages(max_len, self.page)
        self.n_pages = (slots * self.max_pages + 1 if n_pages is None
                        else n_pages)
        self.stop_token = stop_token
        self.temperature, self.top_k, self.top_p = temperature, top_k, top_p
        self.seed, self.burst_cap = seed, burst_cap
        self.prefill_rounds = prefill_rounds
        self.admit_wave = max(1, admit_wave)
        self._num_pages = num_pages
        self._jnp, self._jax = jnp, jax

        self.alloc = PageAllocator(self.n_pages)
        self.scratch = self.alloc.alloc(1)[0]      # dead-write sink, forever
        self._table = np.full((slots, self.max_pages), self.scratch,
                              np.int32)
        self._table_dev = jnp.asarray(self._table)
        self._table_dirty = False
        self.caches = init_caches(cfg, slots, max_len, model.policy,
                                  page_table=self._table,
                                  n_pages=self.n_pages)
        # per-slot host state (the scheduler's view; device state mirrors
        # it through the traced burst arguments)
        self.pos = np.full((slots,), max_len - 1, np.int32)
        self.lens = np.zeros((slots,), np.int32)
        self.done = np.ones((slots,), bool)
        self.limit = np.zeros((slots,), np.int32)
        self.tok = np.zeros((slots, 1), np.int32)
        self._req: List[Optional[Request]] = [None] * slots
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self._prog = np.zeros((slots,), np.int32)   # prefill progress
        self._emitted: List[List[int]] = [[] for _ in range(slots)]
        self._admit_round = np.zeros((slots,), np.int32)

        def burst(params, caches, table, state, key):
            # ONE packed [7, B] int32 upload carries the whole scheduler
            # state (tok, pos, lens, limit, done, n_max, watch) and the
            # table is installed inside the compiled region — per-burst
            # host->device traffic is 2 small transfers, independent of
            # model size
            caches = caches_with_table(caches, table)
            out, n, tok, caches, pos, lens, done, key = model.decode_burst(
                params, state[0][:, None], caches, state[1], state[2],
                state[4] != 0, state[3], max_len=max_len,
                out_width=burst_cap, n_max=state[5, 0],
                exit_on_finish=state[6, 0], stop_token=stop_token,
                temperature=temperature, top_k=top_k, top_p=top_p,
                key=key, mesh=mesh)
            return (out, n,
                    jnp.stack([tok[:, 0], pos, lens, done.astype(jnp.int32)]),
                    caches, key)

        # donate the caches operand: the page pools flow through every
        # burst/chunk as pure carries and the host never reuses the
        # pre-call object, so XLA aliases them in place instead of
        # holding two full pools across each dispatch
        self._burst = jax.jit(burst, donate_argnums=(1,))
        self._sample = functools.partial(
            sample_token, temperature=temperature, top_k=top_k, top_p=top_p)
        self._with_table = caches_with_table
        self._chunk_fns: Dict[tuple, object] = {}

    # -- helpers ----------------------------------------------------------
    def _chunk_fn(self, off: int, m: int):
        """Jitted prefill chunk for an ``m``-slot admission wave at static
        offset ``off`` (offsets step in multiples of ``self.chunk``, waves
        are at most ``slots`` wide, so few programs ever compile; slot
        indices, chunk lengths and tables are traced — admission never
        retraces).  Folds the wave's first-token sampling into the same
        dispatch: the returned [m] tokens are each row's sample off its
        last live chunk position (only meaningful for a row whose final
        chunk this is)."""
        fn = self._chunk_fns.get((off, m))
        if fn is None:
            model, sample, mesh = self.model, self._sample, self.mesh
            with_table = self._with_table

            def chunk_step(params, caches, table, t, meta, key):
                caches = with_table(caches, table)
                lg, caches = model.prefill_chunk(
                    params, t, caches, q_offset=off, row=meta[0],
                    chunk_lens=meta[1], mesh=mesh)
                return sample(lg[:, -1], key), caches

            fn = self._jax.jit(chunk_step, donate_argnums=(1,))
            self._chunk_fns[(off, m)] = fn
        return fn

    def _reserved_pages(self) -> int:
        """Worst-case pages of every admitted-but-unfinished request —
        the admission guard that makes lazy mid-burst allocation
        infallible."""
        return sum(self._num_pages(r.prompt_len + r.max_new, self.page)
                   for r in self._req if r is not None)

    def _ensure_pages(self, b: int, last_idx: int) -> None:
        """Lazily allocate slot ``b``'s pages covering token slots up to
        ``last_idx`` (inclusive) — the live-length-proportional part."""
        want = min(last_idx, self.max_len - 1) // self.page + 1
        while len(self._owned[b]) < want:
            (pid,) = self.alloc.alloc(1)
            self._table[b, len(self._owned[b])] = pid
            self._owned[b].append(pid)
            self._table_dirty = True

    def _table_device(self):
        """Device copy of the block table, re-uploaded only when the host
        table changed (admission, lazy page allocs, recycling)."""
        if self._table_dirty:
            self._table_dev = self._jnp.asarray(self._table)
            self._table_dirty = False
        return self._table_dev

    def _finish(self, b: int, round_no: int, results: dict) -> None:
        """Page recycling: the slot's pages go back to the allocator the
        round its request finishes; the table row falls back to scratch
        and the slot is immediately admissible."""
        req = self._req[b]
        results[req.rid] = Finished(
            rid=req.rid, prompt_len=req.prompt_len,
            tokens=list(self._emitted[b]),
            admit_round=int(self._admit_round[b]), finish_round=round_no,
            slot=b)
        self.alloc.free(self._owned[b])
        self._owned[b] = []
        self._table[b, :] = self.scratch
        self._table_dirty = True
        self._req[b] = None
        self._emitted[b] = []
        self.pos[b], self.lens[b] = self.max_len - 1, 0
        self.done[b], self.limit[b] = True, 0

    # -- the loop ---------------------------------------------------------
    def run(self, requests: Sequence[Request]):
        """Serve ``requests`` to completion.  Returns ``(finished, stats)``
        with ``finished`` in input order and ``stats`` covering rounds,
        mean batch occupancy and the page-pool high-water mark."""
        jnp, jax = self._jnp, self._jax
        for r in requests:
            if r.prompt_len < 1 or r.max_new < 1:
                raise ValueError(f"request {r.rid}: empty prompt or budget")
            if r.prompt_len + r.max_new > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {r.prompt_len} + budget "
                    f"{r.max_new} exceeds max_len {self.max_len}")
            if (self._num_pages(r.prompt_len + r.max_new, self.page)
                    > self.n_pages - 1):
                raise ValueError(
                    f"request {r.rid} can never fit the pool: needs "
                    f"{self._num_pages(r.prompt_len + r.max_new, self.page)}"
                    f" pages, pool has {self.n_pages - 1} (+1 scratch)")
        order = sorted(requests, key=lambda r: (r.arrival, r.rid))
        pending = deque(order)
        results: Dict[int, Finished] = {}
        self.alloc.reset_peak()
        key = jax.random.key(self.seed)
        caches = self.caches
        round_no = decode_rounds = occ_accum = bursts = 0

        while pending or any(r is not None for r in self._req):
            # -- admission: fill free slots from the queue ----------------
            for b in range(self.slots):
                if not pending or pending[0].arrival > round_no:
                    break
                if self._req[b] is not None:
                    continue
                req = pending[0]
                need = self._num_pages(req.prompt_len + req.max_new,
                                       self.page)
                if self._reserved_pages() + need > self.n_pages - 1:
                    break                       # stays queued; retry later
                pages = self.alloc.try_alloc(
                    self._num_pages(req.prompt_len, self.page))
                assert pages is not None  # reservation guard covers this
                self._table[b, :len(pages)] = pages
                self._table_dirty = True
                self._owned[b] = pages
                self._req[b] = req
                self._prog[b] = 0
                self._emitted[b] = []
                self._admit_round[b] = round_no
                pending.popleft()

            # -- one prefill chunk per admitting slot, same-offset slots
            #    batched into one call (the t=0 admission wave especially)
            prefilling = [b for b in range(self.slots)
                          if self._req[b] is not None and self.done[b]]
            waves: Dict[int, List[int]] = {}
            for b in prefilling:
                waves.setdefault(int(self._prog[b]), []).append(b)
            for off, rows in sorted(waves.items()):
                m = len(rows)
                buf = np.zeros((m, self.chunk), np.int32)
                meta = np.zeros((2, m), np.int32)       # rows / chunk lens
                meta[0] = rows
                for i, b in enumerate(rows):
                    piece = list(self._req[b].tokens[off:off + self.chunk])
                    buf[i, :len(piece)] = piece
                    meta[1, i] = len(piece)
                if self.temperature > 0.0:
                    key, sk = jax.random.split(key)
                else:
                    sk = key
                tok0, caches = self._chunk_fn(off, m)(
                    self.params, caches, self._table_device(),
                    jnp.asarray(buf), jnp.asarray(meta), sk)
                tok0 = np.asarray(tok0)
                for i, b in enumerate(rows):
                    req = self._req[b]
                    self._prog[b] += int(meta[1, i])
                    if int(self._prog[b]) != req.prompt_len:
                        continue
                    t0 = int(tok0[i])
                    self._emitted[b] = [t0]
                    hit_stop = (self.stop_token is not None
                                and t0 == self.stop_token)
                    if hit_stop or req.max_new == 1:
                        self._finish(b, round_no, results)
                    else:
                        self.tok[b, 0] = t0
                        self.pos[b] = self.lens[b] = req.prompt_len
                        self.limit[b] = req.prompt_len + req.max_new - 1
                        self.done[b] = False

            # -- decode burst over every slot -----------------------------
            active = [b for b in range(self.slots) if not self.done[b]]
            still_prefilling = any(
                self._req[b] is not None and self.done[b]
                for b in range(self.slots))
            if active:
                # admission wave: with a deep queue, let up to `admit_wave`
                # finishes accumulate before handing control back — halves
                # scheduler round-trips vs reacting to every single finish.
                # n_max is then capped near the wave-th soonest budget
                # finish so a lone early finisher never waits long.
                wave = min(self.admit_wave, len(pending)) if pending else 0
                if still_prefilling:
                    # interleave: chunk, a few decode rounds, chunk, ... —
                    # ongoing streams advance while a long prompt prefills
                    n_max = self.prefill_rounds
                else:
                    n_max = self.burst_cap
                    if pending:
                        till = pending[0].arrival - round_no
                        if till > 0:
                            n_max = max(1, min(n_max, till))
                        rem = sorted(int(self.limit[b]) - int(self.pos[b])
                                     + 1 for b in active)
                        k = min(wave, len(rem)) - 1
                        n_max = max(1, min(n_max, rem[k] + 1))
                for b in active:
                    self._ensure_pages(
                        b, min(int(self.pos[b]) + n_max - 1,
                               int(self.limit[b]) - 1))
                state = np.zeros((7, self.slots), np.int32)
                state[0, :] = self.tok[:, 0]
                state[1], state[2], state[3] = self.pos, self.lens, self.limit
                state[4] = self.done
                state[5, 0], state[6, 0] = n_max, wave
                out, n, state_d, caches, key2 = self._burst(
                    self.params, caches, self._table_device(),
                    jnp.asarray(state), key)
                n = int(n)                    # blocks on the burst
                outs = np.asarray(out[:, :n])  # download only executed cols
                new_state = np.array(state_d)
                self.tok = new_state[0][:, None].copy()
                self.pos = new_state[1]
                if self.temperature > 0.0:
                    key = key2
                for b in active:
                    # rounds this row actually ran = its live-length growth
                    ran = int(new_state[2][b]) - int(self.lens[b])
                    self._emitted[b].extend(int(t) for t in outs[b, :ran])
                    occ_accum += ran
                self.lens = new_state[2]
                self.done = new_state[3].astype(bool)
                round_no += n
                decode_rounds += n
                bursts += 1
                for b in active:
                    if self.done[b]:
                        self._finish(b, round_no, results)
            elif still_prefilling:
                round_no += 1       # prefill-only round (no decoders yet)
            elif pending:
                # idle: nothing active, next request hasn't arrived yet
                round_no = max(round_no + 1, pending[0].arrival)

        self.caches = caches
        stats = {
            "rounds": round_no,
            "decode_rounds": decode_rounds,
            "bursts": bursts,
            "occupancy": (occ_accum / (self.slots * decode_rounds)
                          if decode_rounds else 0.0),
            # request-KV pages only: the engine's always-live scratch page
            # (dead-write sink) is bookkeeping, not cache content
            "peak_live_pages": self.alloc.peak_live - 1,
            "n_pages": self.n_pages,
            "fixed_equiv_pages": self.slots * self.max_pages,
            "pages_live_end": self.alloc.n_live - 1,
        }
        return [results[r.rid] for r in requests], stats
