"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

On this CPU container it runs reduced configs end-to-end; on real TPU pods
the same entry point builds the production mesh and the full config (the
code path is identical — only ``--mesh`` changes).
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fpnew-case-study")
    ap.add_argument("--policy", default="tp_bf16")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", default=None,
                    help="fp8|fp16alt: compressed DP gradient sync")
    ap.add_argument("--mesh", choices=["none", "pod1", "pod2"],
                    default="none")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args(argv)

    if args.mesh != "none":
        from .mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "pod2")
    else:
        mesh = None

    from ..data.pipeline import DataConfig
    from ..models.registry import build_model
    from ..optim.optimizer import OptConfig
    from ..train.loop import LoopConfig, TrainLoop

    model = build_model(args.arch, policy=args.policy, reduced=args.reduced)
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps)
    data = DataConfig(vocab=model.cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    lc = LoopConfig(total_steps=args.steps,
                    log_every=max(args.steps // 20, 1),
                    ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                    compress_grads=args.compress_grads)
    loop = TrainLoop(model, opt, data, lc, mesh=mesh)
    log = loop.run()
    print(f"done: {len(log)} steps, final loss {log[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
