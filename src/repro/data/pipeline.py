"""Deterministic, shard-aware synthetic LM data pipeline.

Real multi-pod training pipelines must be (a) deterministic under restart
(a batch is a pure function of the step index), (b) host-sharded (each host
materializes only its slice), and (c) cheap.  This pipeline provides all
three, with a *learnable* token distribution so end-to-end examples show
real loss curves: each sequence is an arithmetic token progression
``t_{i+1} = (t_i + delta) mod V`` whose stride ``delta`` is sampled per
sequence — a transformer must infer the stride in-context, so loss drops
fast but not to zero; an LM that memorizes nothing stays at ~log(V).

Checkpoint/restart: state is just the step counter; ``batch_at(step)``
regenerates any batch bit-exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    max_stride: int = 16
    noise: float = 0.02          # fraction of corrupted positions
    frontend: Optional[str] = None       # "patch" | "audio" stubs
    n_frontend_tokens: int = 0
    d_model: int = 0


class SyntheticLMData:
    """Iterator over (tokens, labels[, frontend_embeds]) batches.

    ``host_index``/``host_count`` select this host's slice of the global
    batch — the multi-host analogue of tf.data shard()."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self.step = 0

    # -- deterministic batch construction ---------------------------------
    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(cfg.seed), step),
            self.host_index)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        b, s = self.local_batch, cfg.seq_len
        start = jax.random.randint(k1, (b, 1), 0, cfg.vocab)
        stride = jax.random.randint(k2, (b, 1), 1, cfg.max_stride + 1)
        idx = jnp.arange(s + 1)[None, :]
        seq = (start + stride * idx) % cfg.vocab
        if cfg.noise > 0:
            corrupt = jax.random.bernoulli(k3, cfg.noise, seq.shape)
            rand_tok = jax.random.randint(k4, seq.shape, 0, cfg.vocab)
            seq = jnp.where(corrupt, rand_tok, seq)
        seq = seq.astype(jnp.int32)
        batch = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if cfg.frontend == "patch":
            batch["frontend_embeds"] = jax.random.normal(
                k4, (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        elif cfg.frontend == "audio":
            batch["frontend_embeds"] = jax.random.normal(
                k4, (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        return batch

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # -- checkpointable state ----------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, state: dict):
        self.step = int(state["step"])
