"""gemma2-9b [dense]: alternating local/global attention, logit softcaps.

Assignment: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000
[arXiv:2408.00118; hf].  1:1 local(window 4096):global alternation,
attention-logit softcap 50, final-logit softcap 30, head_dim 256,
sandwich (pre+post) norms, embedding scaled by sqrt(d_model).
long_500k RUNS: decode against a long cache is O(S) and 50% of layers cap
at window 4096 (see DESIGN.md §Arch-applicability).
"""
from .base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="gqa", ffn="swiglu", window=4096,
                   attn_softcap=50.0, post_norms=True)
_GLOBAL = LayerSpec(mixer="gqa", ffn="swiglu", window=None,
                    attn_softcap=50.0, post_norms=True)

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000,
    pattern=(_LOCAL, _GLOBAL),
    logit_softcap=30.0, emb_scale=3584 ** 0.5,
    tie_embeddings=True,
    sub_quadratic=True,       # windowed majority; decode is O(S)
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        pattern=(LayerSpec(mixer="gqa", ffn="swiglu", window=16,
                           attn_softcap=50.0, post_norms=True),
                 LayerSpec(mixer="gqa", ffn="swiglu", attn_softcap=50.0,
                           post_norms=True)),
        logit_softcap=30.0, emb_scale=8.0, tie_embeddings=True,
    )
