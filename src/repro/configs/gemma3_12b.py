"""gemma3-12b [dense]: 5:1 local:global attention, 128k context.

Assignment: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-1b-pt lineage].  Pattern: 5 local (window 1024) + 1
global; qk-norm; head_dim 256; no attention softcap (gemma3 dropped it);
rope theta 1M on globals.  long_500k RUNS (windowed majority).
"""
from .base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="gqa", ffn="swiglu", window=1024, qk_norm=True,
                   post_norms=True)
_GLOBAL = LayerSpec(mixer="gqa", ffn="swiglu", qk_norm=True,
                    post_norms=True)

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    emb_scale=3840 ** 0.5, rope_theta=1e6,
    tie_embeddings=True,
    sub_quadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(LayerSpec(mixer="gqa", ffn="swiglu", window=16,
                           qk_norm=True, post_norms=True),
                 LayerSpec(mixer="gqa", ffn="swiglu", qk_norm=True,
                           post_norms=True)),
        emb_scale=8.0, tie_embeddings=True,
    )
