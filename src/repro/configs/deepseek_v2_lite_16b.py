"""deepseek-v2-lite-16b [moe]: MLA + fine-grained MoE.

Assignment: 27L d_model=2048 16H d_ff=1408 vocab=102400, MLA kv_lora=512,
2 shared + routed top-6 [arXiv:2405.04434; hf].  Config-source discrepancy
(recorded in DESIGN.md): the assignment line lists both "64e" and "160
routed"; hf:DeepSeek-V2-Lite has 64 routed experts — we follow the HF
config.  Layer 0 is dense (first_k_dense_replace=1), layers 1..26 are MoE.
"""
from ..models.moe import MoEConfig
from .base import LayerSpec, ModelConfig

_MLA = dict(mixer="mla")
_DENSE = LayerSpec(ffn="swiglu", **_MLA)
_MOE = LayerSpec(ffn="moe", **_MLA)

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=192,
    d_ff=10944,                       # dense layer-0 intermediate
    vocab=102400,
    prefix=(_DENSE,), pattern=(_MOE,),
    q_lora=None, kv_lora=512, nope_dim=128, rope_dim=64, v_head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    rope_theta=1e4, tie_embeddings=False,
    sub_quadratic=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=128, vocab=256,
        prefix=(_DENSE,), pattern=(_MOE,),
        q_lora=None, kv_lora=32, nope_dim=16, rope_dim=8, v_head_dim=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=2),
        tie_embeddings=False,
    )
