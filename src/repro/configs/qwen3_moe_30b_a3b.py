"""qwen3-moe-30b-a3b [moe]: 128 experts top-8, per-head q/k RMS norm.

Assignment: 48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936,
MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B; hf].  d_ff=768 is the per-expert
intermediate; no shared experts; head_dim 128.
"""
from ..models.moe import MoEConfig
from .base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="gqa", ffn="moe", qk_norm=True)

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936,
    pattern=(_L,),
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768, n_shared=0),
    rope_theta=1e6, tie_embeddings=False,
    sub_quadratic=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=256,
        pattern=(_L,),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=0),
        tie_embeddings=False,
    )
