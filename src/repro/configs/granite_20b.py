"""granite-20b [dense]: MQA code model.

Assignment: 52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf] — llama-arch per the assignment note; d_ff = 4*d
(non-gated gelu MLP, gpt-bigcode lineage).  MQA: a single shared KV head.
"""
from .base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="gqa", ffn="gelu")

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152,
    pattern=(_L,),
    tie_embeddings=True,
    sub_quadratic=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=256, vocab=256,
        pattern=(_L,), tie_embeddings=True,
    )
