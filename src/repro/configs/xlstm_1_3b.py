"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks, xLSTM[7:1].

Assignment: 48L d_model=2048 4H d_ff=0 vocab=50304 [arXiv:2405.04517].
Pattern: 7 mLSTM blocks (matrix memory, chunkwise-parallel) + 1 sLSTM
block (scalar memory, sequential scan) repeated 6x.  d_ff=0: the blocks
carry their own internal up/down projections (proj_factor 2 for mLSTM,
4/3 gated FFN tail for sLSTM).  Sub-quadratic: long_500k runs.
"""
from ..models.ssm import MLSTMConfig, SLSTMConfig
from .base import LayerSpec, ModelConfig

_M = LayerSpec(mixer="mlstm", ffn="none")
_S = LayerSpec(mixer="slstm", ffn="none")

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304,
    pattern=(_M, _M, _M, _M, _M, _M, _M, _S),
    mlstm=MLSTMConfig(d_model=2048, n_heads=4, proj_factor=2.0, chunk=256),
    slstm=SLSTMConfig(d_model=2048, n_heads=4),
    tie_embeddings=True,
    sub_quadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=0, vocab=256,
        pattern=(_M, _S),
        mlstm=MLSTMConfig(d_model=64, n_heads=2, proj_factor=2.0, chunk=16),
        slstm=SLSTMConfig(d_model=64, n_heads=2),
        tie_embeddings=True, sub_quadratic=True,
    )
