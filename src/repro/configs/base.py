"""Model configuration schema for all assigned architectures.

A :class:`ModelConfig` fully describes one architecture: its dimensions, its
layer *pattern* (the repeating unit that ``lax.scan`` iterates — keeping HLO
size O(1) in depth so 512-device dry-runs compile on one CPU), optional
prefix/suffix layers outside the scan, family-specific sub-configs (MLA,
MoE, Mamba2, mLSTM/sLSTM), an optional encoder (whisper), and an optional
modality-frontend stub (vlm/audio).

Every config exposes ``reduced()`` returning a small same-family config for
CPU smoke tests (the full config is exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..models.moe import MoEConfig
from ..models.ssm import Mamba2Config, MLSTMConfig, SLSTMConfig

__all__ = ["LayerSpec", "EncoderConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer's composition: a sequence mixer + a channel mixer (FFN)."""
    mixer: str = "gqa"          # gqa | mla | mamba2 | mlstm | slstm | shared_attn | none
    ffn: str = "swiglu"         # swiglu | gelu | moe | none
    window: Optional[int] = None        # sliding-window size (local attn)
    attn_softcap: Optional[float] = None
    qk_norm: bool = False
    use_rope: bool = True
    post_norms: bool = False            # gemma2-style sandwich norms
    cross_attn: bool = False            # whisper decoder


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder stack (bidirectional attention, gelu FFN)."""
    n_layers: int
    n_frames: int            # frontend sequence length (e.g. 1500)
    n_heads: int
    d_ff: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    prefix: Tuple[LayerSpec, ...] = ()     # unrolled before the scan
    suffix: Tuple[LayerSpec, ...] = ()     # unrolled after the scan
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    tie_embeddings: bool = True
    logit_softcap: Optional[float] = None
    emb_scale: Optional[float] = None      # gemma sqrt(d); minicpm scale_emb
    residual_scale: float = 1.0            # minicpm scale_depth/sqrt(L)
    mlp_bias: bool = False
    # MLA dims (deepseek / minicpm3)
    q_lora: Optional[int] = None
    kv_lora: int = 0
    nope_dim: int = 0
    rope_dim: int = 0
    v_head_dim: int = 0
    # family sub-configs
    moe: Optional[MoEConfig] = None
    mamba: Optional[Mamba2Config] = None
    mlstm: Optional[MLSTMConfig] = None
    slstm: Optional[SLSTMConfig] = None
    shared_block: Optional[LayerSpec] = None   # zamba2 shared attn+mlp
    encoder: Optional[EncoderConfig] = None    # whisper
    # modality frontend stubs (assignment: backbone only)
    frontend: Optional[str] = None             # "patch" | "audio"
    n_frontend_tokens: int = 0
    max_seq: int = 0         # learned positional table size (0 = rope only)
    sub_quadratic: bool = False  # eligible for long_500k
    attn_chunk: int = 512    # query-chunk size of the attention scan
    unroll_scan: bool = False  # unroll the layer scan (cost extraction only)
    # --- beyond-paper perf knobs (default off = paper-faithful baseline) ---
    windowed_slice: bool = False  # local attn: slice KV to the window
    decode_backend: str = "dense"  # "pallas": fused KV-dequant decode kernel;
    #                                "auto": pallas off-CPU, dense on CPU
    prefill_backend: str = "dense"  # "pallas": pruned-grid flash-attention
    #                                 kernel on prefill/train; "auto" as above
    paged_kv: bool = False  # paged KV cache: shared page pool + per-row
    #                         block table (attention-mixer archs only)
    page_size: int = 64     # tokens per KV page (= the decode kernel's KV
    #                         block when paged). 64 suits the CPU/interpret
    #                         demos; set >= 128 on real TPUs (lane alignment)
    ce_dtype: str = "fp32"        # "fp16alt": bf16 CE logits (half HBM)
    embed_sharding: str = "vocab"  # "replicated": no embed collectives
    remat_policy: str = "full"    # full | dots (save matmul outputs) | none
    narrow_partials: bool = False  # bf16 TP partial-sum all-reduces
    seq_parallel: bool = False    # shard residual seq dim over model
    dropout: float = 0.0

    # -- derived -------------------------------------------------------------
    @property
    def n_scanned(self) -> int:
        return self.n_layers - len(self.prefix) - len(self.suffix)

    @property
    def repeats(self) -> int:
        n, p = self.n_scanned, len(self.pattern)
        assert n % p == 0, (self.name, n, p)
        return n // p

    def layer_list(self) -> Tuple[LayerSpec, ...]:
        return self.prefix + self.pattern * self.repeats + self.suffix

    def paged_unsupported_reason(self) -> Optional[str]:
        """Why ``paged_kv`` cannot serve this arch (None = it can).  The
        single source of truth for the paged-support gate: Model.prefill
        raises on it and benchmarks skip on it.  Recurrent mixers and the
        MLA latent cache have no page axis yet, and the whisper
        cross-attention cache stays contiguous by design."""
        bad = sorted({s.mixer for s in self.layer_list()
                      if s.mixer not in ("gqa", "shared_attn", "none")})
        if bad:
            return "/".join(bad)
        if self.encoder is not None:
            return "cross-attention caches"
        return None

    def validate(self):
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name
        assert self.repeats >= 1
        for spec in self.layer_list():
            if spec.mixer == "mla":
                assert self.kv_lora and self.nope_dim and self.rope_dim
            if spec.ffn == "moe":
                assert self.moe is not None
            if spec.mixer == "mamba2":
                assert self.mamba is not None
            if spec.mixer == "mlstm":
                assert self.mlstm is not None
            if spec.mixer == "slstm":
                assert self.slstm is not None
            if spec.mixer == "shared_attn":
                assert self.shared_block is not None
        return self

    # -- parameter count (for roofline MODEL_FLOPS and docs) ------------------
    def param_counts(self) -> dict:
        """Returns three counts:
          total  — distinct parameters stored,
          active — distinct parameters touched per token (MoE: only the
                   routed top-k + shared experts; weight-shared blocks once),
          flops  — per-use parameter count for the 6·N·D FLOPs estimate
                   (weight-shared blocks counted once per invocation)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        active = emb

        def attn_params(spec):
            if spec.mixer == "gqa":
                qkv = d * self.n_heads * self.head_dim \
                    + 2 * d * self.n_kv_heads * self.head_dim \
                    + self.n_heads * self.head_dim * d
                return qkv
            if spec.mixer == "mla":
                qd = self.nope_dim + self.rope_dim
                p = d * self.kv_lora + d * self.rope_dim \
                    + self.kv_lora * self.n_heads * (self.nope_dim
                                                     + self.v_head_dim) \
                    + self.n_heads * self.v_head_dim * d
                if self.q_lora:
                    p += d * self.q_lora + self.q_lora * self.n_heads * qd
                else:
                    p += d * self.n_heads * qd
                return p
            if spec.mixer == "mamba2":
                m = self.mamba
                return d * (2 * m.d_inner + 2 * m.n_groups * m.d_state
                            + m.n_heads) + m.d_inner * d \
                    + m.d_conv * m.conv_dim
            if spec.mixer == "mlstm":
                ml = self.mlstm
                # headwise (block-diagonal) qkv: 3 * H * head_dim^2
                return d * 2 * ml.d_inner \
                    + 3 * ml.n_heads * ml.head_dim ** 2 \
                    + ml.d_inner * 2 * ml.n_heads + ml.d_inner * d \
                    + ml.d_conv * ml.d_inner
            if spec.mixer == "slstm":
                sl = self.slstm
                dff = int(sl.proj_factor * d)
                return 4 * d * d + 4 * d * sl.head_dim \
                    + d * 2 * dff + dff * d
            if spec.mixer == "shared_attn":
                sb = self.shared_block
                return d * self.n_heads * self.head_dim * 2 \
                    + 2 * d * self.n_kv_heads * self.head_dim \
                    + (3 * d * self.d_ff if sb.ffn == "swiglu"
                       else 2 * d * self.d_ff)
            return 0

        def ffn_params(spec):
            if spec.ffn == "swiglu":
                return 3 * d * self.d_ff
            if spec.ffn == "gelu":
                return 2 * d * self.d_ff + self.d_ff + d
            if spec.ffn == "moe":
                mc = self.moe
                routed = mc.n_experts * 3 * d * mc.d_expert
                shared = mc.n_shared * 3 * d * mc.d_expert
                act = mc.top_k * 3 * d * mc.d_expert + shared
                return routed + shared + d * mc.n_experts, act
            return 0

        flops = active
        shared_counted = False
        for spec in self.layer_list():
            a = attn_params(spec)
            f = ffn_params(spec)
            f_total, f_active = f if isinstance(f, tuple) else (f, f)
            if spec.mixer == "shared_attn":
                if not shared_counted:
                    total += a + f_total
                    active += a + f_active
                    shared_counted = True
                flops += a + f_active
            else:
                total += a + f_total
                active += a + f_active
                flops += a + f_active
        if self.encoder is not None:
            e = self.encoder
            per = 4 * (d * e.n_heads * (d // e.n_heads)) \
                + 2 * d * e.d_ff + e.d_ff + d
            total += e.n_layers * per
            active += e.n_layers * per
            flops += e.n_layers * per
        return {"total": total, "active": active, "flops": flops}
