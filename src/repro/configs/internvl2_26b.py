"""internvl2-26b [vlm]: InternViT + InternLM2-20B backbone.

Assignment: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf].  The ViT frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings that occupy the
first ``n_frontend_tokens`` positions of the sequence.
"""
from .base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="gqa", ffn="swiglu")

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=92553,
    pattern=(_L,),
    rope_theta=1e6, tie_embeddings=False,
    frontend="patch", n_frontend_tokens=256,
    sub_quadratic=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        pattern=(_L,), tie_embeddings=False,
        frontend="patch", n_frontend_tokens=8,
    )
