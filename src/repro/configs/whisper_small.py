"""whisper-small [audio]: encoder-decoder with conv frontend stub.

Assignment: 12L d_model=768 12H d_ff=3072 vocab=51865 [arXiv:2212.04356].
Enc-dec: 12 encoder layers (bidirectional, gelu) + 12 decoder layers
(causal self-attn + cross-attn + gelu).  The conv1d/log-mel frontend is a
STUB: ``input_specs()`` provides precomputed frame embeddings
[B, 1500, 768].  Learned positional embeddings (no rope); layernorm.
"""
from .base import EncoderConfig, LayerSpec, ModelConfig

_DEC = LayerSpec(mixer="gqa", ffn="gelu", use_rope=False, cross_attn=True)

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51865,
    pattern=(_DEC,),
    norm="layernorm", norm_eps=1e-5, tie_embeddings=True,
    encoder=EncoderConfig(n_layers=12, n_frames=1500, n_heads=12, d_ff=3072),
    frontend="audio",
    max_seq=65536,             # learned decoder positions (covers decode_32k)
    sub_quadratic=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        pattern=(_DEC,),
        norm="layernorm", norm_eps=1e-5, tie_embeddings=True,
        encoder=EncoderConfig(n_layers=2, n_frames=30, n_heads=4, d_ff=128),
        frontend="audio", max_seq=4096,
    )
