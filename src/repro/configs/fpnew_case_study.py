"""The paper's own configuration analogue: a ~110M-parameter dense LM used
for the end-to-end transprecision training example (examples/
transprecision_training.py) and the Table-III-style training ablation.

This is the workload on which we reproduce the paper's claim at training
scale: multiply in a narrow format, accumulate in fp32 (the expanding FMA),
and compare accuracy/energy against the all-fp32 baseline — Fig 10/11 and
Table III lifted from a dot-product kernel to LM training.
"""
from .base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="gqa", ffn="swiglu")

CONFIG = ModelConfig(
    name="fpnew-case-study", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=2048, vocab=32000,
    pattern=(_L,),
    tie_embeddings=True,
    sub_quadratic=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="fpnew-case-study-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        pattern=(_L,), tie_embeddings=True,
    )
