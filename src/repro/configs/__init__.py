"""Architecture configs (one module per assigned arch + the paper's own
case-study config).  Each module exports CONFIG (full, dry-run only) and
reduced() (small same-family config for CPU smoke tests)."""
