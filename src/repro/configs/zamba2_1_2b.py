"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.

Assignment: 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64 [arXiv:2411.15242; hf].  38 Mamba2 blocks; one SHARED
attention+MLP block (single weight set) invoked every 6 mamba blocks —
pattern = [5x mamba2, 1x shared_attn] x 6, + 2 trailing mamba blocks.
Sub-quadratic: long_500k runs (O(1) SSM state).
"""
from ..models.ssm import Mamba2Config
from .base import LayerSpec, ModelConfig

_M = LayerSpec(mixer="mamba2", ffn="none")
_SH = LayerSpec(mixer="shared_attn", ffn="swiglu", use_rope=True)

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000,
    pattern=(_M, _M, _M, _M, _M, _SH),
    suffix=(_M, _M),
    mamba=Mamba2Config(d_model=2048, d_state=64, head_dim=64, chunk=256),
    shared_block=_SH,
    tie_embeddings=True,
    sub_quadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        pattern=(_M, _SH),
        suffix=(_M, _M),
        mamba=Mamba2Config(d_model=64, d_state=16, head_dim=16, chunk=8),
        shared_block=_SH,
        tie_embeddings=True, sub_quadratic=True,
    )
