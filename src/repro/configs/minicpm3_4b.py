"""minicpm3-4b [dense]: MLA with q-LoRA + mu-parametrization scaling.

Assignment: 62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448
[hf:openbmb/MiniCPM3-4B; hf].  MLA: q_lora=768, kv_lora=256, nope=64,
rope=32, v_head=64.  muP scaling: scale_emb=12, residual scaled by
scale_depth/sqrt(L) = 1.4/sqrt(62).
"""
from .base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="mla", ffn="swiglu")

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=96,
    d_ff=6400, vocab=73448,
    pattern=(_L,),
    q_lora=768, kv_lora=256, nope_dim=64, rope_dim=32, v_head_dim=64,
    emb_scale=12.0, residual_scale=1.4 / (62 ** 0.5),
    tie_embeddings=True,
    sub_quadratic=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=128, vocab=256,
        pattern=(_L,),
        q_lora=32, kv_lora=32, nope_dim=16, rope_dim=8, v_head_dim=16,
        emb_scale=12.0, residual_scale=1.4 / (2 ** 0.5),
        tie_embeddings=True,
    )
