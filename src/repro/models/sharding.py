"""Sharding rules: parameter / cache / input PartitionSpecs for any arch.

Megatron-style tensor parallelism over the ``model`` mesh axis, data
parallelism over ``("pod", "data")`` (or whatever batch axes the launch
configures), with rank-agnostic name-based rules so the same table covers
plain, stacked-by-scan ([R, ...]) and expert ([E, ...]) parameters.

Every rule is divisibility-checked against the actual mesh: a dimension
that does not divide by the axis size falls back to replication (e.g.
xlstm's 8-wide gate projection on a 16-way model axis).
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..core.policy import PrecisionPolicy
from .transformer import Caches

# parameter-name -> role.  col = shard output (last) dim, row = shard input
# (second-to-last) dim, expert = shard dim -3, vocab = shard dim -2,
# rep = replicate.
_PARAM_RULES = {
    # embeddings
    "embed": "vocab", "lm_head": "col", "pos_embed": "rep", "pos": "rep",
    # attention / mla
    "wq": "col", "wk": "col", "wv": "col", "wo": "row",
    "w_q": "col", "w_dq": "col", "w_uq": "col", "w_dkv": "col",
    "w_kr": "col", "w_uk": "col", "w_uv": "col",
    # dense mlp
    "gate": "col", "up": "col", "down": "row",
    "b_up": "col1", "b_down": "rep",
    # moe (3D expert tensors)
    "router": "rep", "w_gate": "expert", "w_up": "expert", "w_down": "expert",
    # mamba2 / mlstm / slstm
    "in_proj": "col", "out_proj": "row", "conv_w": "col", "conv_b": "col1",
    "up_proj": "col", "down_proj": "row", "w_if": "col",
    # sLSTM: gates + recurrence fully replicated — ANY sharded dim in the
    # per-token scan body emits a collective every timestep (measured:
    # 24k tiny ARs = 57s of the step bound)
    "w_gates": "rep",
    "r_gates": "rep",  # sLSTM recurrence must be collective-free per token
    # mLSTM headwise projections: q/k replicated (local qk^T), v sharded
    # mLSTM inner tensors are model-replicated end-to-end: with 4 heads
    # and a chunked state scan, any model-axis sharding inside the mixer
    # forces per-chunk resharding (measured 0.84 GB/layer @ S=256 -> ~57s
    # of collective time); TP applies only to up/down projections.
    "wq_h": "rep", "wk_h": "rep", "wv_h": "rep",
    "A_log": "rep", "D": "rep", "dt_bias": "rep", "b_if": "rep",
    "b_gates": "rep",
    # norms
    "g": "rep", "b": "rep", "ln": "rep", "norm": "rep",
    "q_norm": "rep", "k_norm": "rep", "kv_norm": "rep",
}


def _spec_for_role(role: str, shape: Tuple[int, ...], model_axis: str,
                   model_size: int) -> P:
    rank = len(shape)

    def ok(dim_idx):
        return shape[dim_idx] % model_size == 0 and shape[dim_idx] > 0

    if role == "col" and rank >= 2 and ok(-1):
        return P(*([None] * (rank - 1) + [model_axis]))
    if role == "col1" and rank >= 1 and ok(-1):
        return P(*([None] * (rank - 1) + [model_axis]))
    if role == "row" and rank >= 2 and ok(-2):
        return P(*([None] * (rank - 2) + [model_axis, None]))
    if role == "expert" and rank >= 3 and ok(-3):
        return P(*([None] * (rank - 3) + [model_axis, None, None]))
    if role == "vocab" and rank >= 2 and ok(-2):
        return P(*([None] * (rank - 2) + [model_axis, None]))
    return P()


def param_specs(params, model_axis: str = "model", model_size: int = 16,
                overrides: Optional[dict] = None):
    """PartitionSpec pytree mirroring ``params`` (works on real arrays or
    ShapeDtypeStructs).  ``overrides``: name -> role replacements (e.g.
    {"embed": "rep"} for a replicated embedding table)."""
    rules = dict(_PARAM_RULES, **(overrides or {}))

    def visit(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        role = rules.get(name, "rep")
        spec = _spec_for_role(role, leaf.shape, model_axis, model_size)
        if role != "rep" and spec == P():
            # the divisibility fallback used to be silent — a 16-way mesh
            # quietly replicating a "sharded" tensor is a memory surprise
            warnings.warn(
                f"sharding: {name!r} {tuple(leaf.shape)} (role {role!r}) "
                f"does not divide the {model_size}-way {model_axis!r} axis "
                f"— replicated instead", stacklevel=3)
        return spec

    return jax.tree_util.tree_map_with_path(visit, params)


# ---------------------------------------------------------------------------
# caches and inputs
# ---------------------------------------------------------------------------
def batch_spec_axes(batch: int, batch_axes: Tuple[str, ...], mesh) -> Optional[Tuple[str, ...]]:
    """Batch sharding only when divisible (long_500k has batch 1)."""
    if not batch_axes:
        return None
    size = 1
    for a in batch_axes:
        size *= mesh.shape[a]
    return batch_axes if batch % size == 0 and batch >= size else None


def cache_specs(cfg: ModelConfig, caches, *, batch: int, mesh,
                batch_axes: Tuple[str, ...] = ("data",),
                model_axis: str = "model"):
    """Spec pytree for a Caches object.  Attention KV: shard heads over
    ``model`` when divisible, else shard the sequence dim (flash-decode
    style — GSPMD all-reduces the softmax statistics).  SSM states: shard
    heads/features.  Small normalizer/stabilizer states replicate."""
    msize = mesh.shape[model_axis]
    ba = batch_spec_axes(batch, batch_axes, mesh)

    def leaf_spec(field: str, shape, lead):
        body = shape[1 + len(lead):]

        def spec(*rest):
            return P(*lead, ba, *rest)

        def m(dim):
            return model_axis if body[dim] % msize == 0 else None

        if field in ("k_pool", "v_pool"):
            # paged pool [n_pages, Hkv, page, Dh] — NO batch dim: pages
            # replicate across data replicas (each engine replica owns a
            # whole pool), heads shard over model when divisible
            hkv = shape[len(lead) + 1]
            return P(*lead, None,
                     model_axis if hkv % msize == 0 else None, None, None)
        if field == "block_table":                   # [B, max_pages]
            # host-managed page indirection: replicated over model (every
            # shard dereferences the same table), batch-sharded like the
            # rows it indexes
            return P(*lead, ba, None)
        if field in ("k", "v"):                      # KVCache [B,Hkv,S,Dh]
            if body[0] % msize == 0:
                return spec(model_axis, None, None)
            return spec(None, m(1), None)
        if field in ("c_kv", "k_pe"):                # MLA latent [B,S,r]
            return spec(m(0), None)
        if field == "conv":                          # [B,K-1,conv_dim]
            return spec(None, m(1))
        if field == "ssm":                           # [B,H,P,N]
            return spec(m(0), None, None)
        if field == "c" and len(body) == 3:          # mLSTM C [B,H,dk,dv]
            return spec(None, None, m(2))
        return spec(*([None] * len(body)))           # nrm/m/h/slstm: replicate

    def walk(node, lead):
        if node is None:
            return None
        if isinstance(node, dict):
            return {k: walk(v, lead) for k, v in node.items()}
        if hasattr(node, "_fields"):                 # cache NamedTuples
            return type(node)(*[leaf_spec(f, getattr(node, f).shape, lead)
                                for f in node._fields])
        if isinstance(node, (tuple, list)):
            return tuple(walk(x, lead) for x in node)
        raise TypeError(type(node))

    return Caches(prefix=walk(caches.prefix, ()),
                  pattern=walk(caches.pattern, (None,)),
                  suffix=walk(caches.suffix, ()))


def input_specs_train(batch: int, mesh, batch_axes=("data",)):
    ba = batch_spec_axes(batch, batch_axes, mesh)
    return P(ba, None)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
