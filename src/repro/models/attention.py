"""Attention variants: GQA/MQA (with local windows, softcap, qk-norm) and
MLA (DeepSeek/MiniCPM latent attention), in train/prefill and decode forms.

All contractions run through core.ops.tp_einsum, i.e. under the FPnew
multi-format FMA contract (operands in src_fmt, f32 accumulation).  Softmax
statistics stay f32 (the paper keeps COMP in full precision).

Training/prefill uses a lax.scan over query chunks (online-softmax-free:
each chunk sees all keys, so memory is O(chunk * S) not O(S^2)) — the
pure-JAX twin of kernels/flash_attention.py, which is the TPU perf path.

Decode uses a KV cache: dense GQA caches k/v per head; MLA caches the
compressed latent + rope key only (the paper-style "storage format" win:
the latent cache is also quantizable via policy.kv_fmt).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import ops as tp
from ..core.formats import get_format
from .layers import (batch_axes, bspec, apply_rope, dense_init,
                     residual_spec, rmsnorm, shard, softcap)
from .paged import PagedKVCache, gather_paged_kv, paged_update_rows

NEG_INF = -1e30


def kv_store_dtype(policy):
    if policy.kv_fmt is not None and policy.mode == "native":
        return policy.kv_fmt.native_dtype
    return tp.storage_dtype(policy.param_fmt, policy.mode)


def kv_swap_dtype(fmt):
    """Host-side storage dtype for KV pages swapped out of the pool under
    a transprecision degrade format (serving-loop preemption): ``fmt`` is
    a format name or ``FPFormat`` with a native container (``fp8`` ->
    ``float8_e5m2``, 1 byte/value), so a degraded victim's swapped cache
    really is 2-4x smaller in host memory; swap-in widens back to the
    pool dtype.  When the pool itself already stores ``fmt`` (e.g. the
    ``tp_bf16_kv8`` policy), the round-trip is value-exact."""
    f = get_format(fmt)
    if f.native_dtype is None:
        raise ValueError(
            f"degrade format {f.name!r} has no native container dtype to "
            f"swap KV pages into (use fp8/bf16/fp16)")
    return f.native_dtype


def _is_vec(x) -> bool:
    """True for a per-sequence [B] vector (ragged batch), False for the
    scalar (python int / 0-d array) every row shares."""
    return getattr(x, "ndim", 0) >= 1 and not isinstance(x, (int, float))


def _len_rows(kv_len):
    """Normalize scalar-or-vector ``kv_len`` to a [1]-or-[B] int32 array —
    one broadcastable shape for every dense masking site below (a [1]
    array broadcasts over the batch exactly like the old scalar did)."""
    return jnp.reshape(jnp.asarray(kv_len, jnp.int32), (-1,))


def quantize_kv_rows(x, esc_fmts, levels):
    """Write-time per-row KV quantization for precision escalation.

    ``x`` [B, ...] is a freshly computed K or V tensor about to land in a
    shared f32 pool; ``levels`` [B] int32 picks each row's rung in the
    static ``esc_fmts`` ladder (narrow -> wide).  Every rung is snapped to
    its grid with the SATURATING cast (overflow clamps to ±max_normal
    instead of ±Inf — the stored value stays finite so attention never
    poisons, while the OF flag still fires and feeds the escalation
    pressure).  Returns ``(y, counts)`` with ``counts`` [B, 2] the per-row
    OF / UF flag totals of this write (FPnew fflags at the CONV stage,
    §II.B) — the select over rungs is traced, so changing a row's level
    never retraces."""
    from ..kernels.quant_common import quantize_flag_masks
    x = x.astype(jnp.float32)
    ys, ofs, ufs = [], [], []
    for fmt in esc_fmts:
        y, of, uf, _, _ = quantize_flag_masks(x, fmt, saturate=True)
        ys.append(y)
        ofs.append(of)
        ufs.append(uf)
    lvl = levels.reshape((-1,) + (1,) * (x.ndim - 1))
    y, of, uf = ys[-1], ofs[-1], ufs[-1]
    for i in range(len(esc_fmts) - 2, -1, -1):
        sel = lvl == i
        y = jnp.where(sel, ys[i], y)
        of = jnp.where(sel, ofs[i], of)
        uf = jnp.where(sel, ufs[i], uf)
    red = tuple(range(1, x.ndim))
    counts = jnp.stack([jnp.sum(of.astype(jnp.int32), axis=red),
                        jnp.sum(uf.astype(jnp.int32), axis=red)], axis=-1)
    return y, counts


def update_cache_rows(buf, new, pos, *, axis: int):
    """Write ``new`` into the cache ``buf`` at slot ``pos`` along ``axis``
    (both batch-leading).  A scalar ``pos`` writes one shared index (the
    uniform-batch fast path — identical to the old dynamic_update_slice);
    a per-row [B] vector writes each sequence at its OWN index (ragged
    decode: every row's cache grows at its own length)."""
    new = new.astype(buf.dtype)
    if not _is_vec(pos):
        start = [0] * buf.ndim
        start[axis] = pos
        return jax.lax.dynamic_update_slice(buf, new, tuple(start))

    def one(bb, nn, pp):
        start = [0] * bb.ndim
        start[axis - 1] = pp
        return jax.lax.dynamic_update_slice(bb, nn, tuple(start))

    return jax.vmap(one)(buf, new, pos)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def gqa_params(key, d_model, n_heads, n_kv_heads, head_dim, dtype,
               qk_norm: bool = False, out_bias: bool = False):
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), dtype)
        p["k_norm"] = jnp.zeros((head_dim,), dtype)
    return p


def _use_pallas_prefill(backend: str, q_offset=0) -> bool:
    """Route prefill/train attention through the pruned-grid Pallas kernel?
    ``q_offset`` must be a concrete int (it is a static kernel arg that
    shapes the block schedule); a traced offset falls back to dense."""
    if backend == "dense":
        return False
    from ..kernels.ops import resolve_backend
    return resolve_backend(backend) == "pallas" and isinstance(q_offset, int)


def _flash_attend(q, k, v, policy, *, causal, window, cap, q_offset=0,
                  kv_len=None):
    """q [B,H,S,Dh] vs k/v [B,Hkv,T,Dk/Dv] -> [B,H,S,Dv] via the pruned-grid
    Pallas flash-attention kernel (kernels/flash_attention.py): causal future
    blocks and blocks left of the sliding window are never visited, so the
    windowed-slice trick of ``_masked_softmax_attend`` is subsumed by the
    block schedule itself.  ``kv_len`` (scalar or per-sequence [B] vector)
    additionally prunes each row's KV walk at its own live length in-kernel
    (ragged prefill batches)."""
    from ..kernels import ops as kops
    return kops.flash_attention(q, k, v, kv_len=kv_len, policy=policy,
                                scale=q.shape[-1] ** -0.5, causal=causal,
                                window=window, softcap=cap, q_offset=q_offset)


def _flash_attend_paged(q, cache: PagedKVCache, policy, *, causal, window,
                        cap, q_offset, kv_len):
    """Prefill reads against a PAGED cache: q [B,H,S,Dh] against the page
    pools of ``cache`` through its block table — the flash kernel
    dereferences the table in its BlockSpec index maps
    (``kernels.ops.flash_attention(block_table=)``), with ``bk`` pinned to
    the page size (the page IS the KV block).  This is the chunked-prefill
    read path: ``q_offset`` is the chunk's start position in the row and
    ``kv_len`` the row's total live length (prefix + this chunk), so a
    continuation chunk attends every earlier chunk's K/V straight out of
    the pool, no contiguous view ever materialized."""
    from ..kernels import ops as kops
    return kops.flash_attention(q, cache.k_pool, cache.v_pool, kv_len=kv_len,
                                block_table=cache.block_table, policy=policy,
                                scale=q.shape[-1] ** -0.5, causal=causal,
                                window=window, softcap=cap, q_offset=q_offset)


def _masked_softmax_attend(q, k, v, policy, *, causal, window, cap,
                           q_offset, kv_len=None, chunk=512,
                           windowed_slice=False):
    """q [B,H,S,Dh] vs k/v [B,Hkv,T,Dh] -> [B,H,S,Dh]; scan over q chunks.

    ``windowed_slice`` (beyond-paper perf knob): for sliding-window layers,
    each query chunk attends only to the KV slice its window can reach —
    compute drops from O(S*T) to O(S*(window+chunk)).  The baseline
    computes full dense scores and masks (what the paper-faithful chunked
    schedule does).

    ``kv_len``: scalar (one live length for the batch) or a per-sequence
    [B] vector (ragged batch — each row masks keys past its OWN length)."""
    b, h, s, dh = q.shape
    _, hkv, t, _ = k.shape
    group = h // hkv
    scale = dh ** -0.5
    kv_len = _len_rows(t if kv_len is None else kv_len)    # [1] or [B]
    qg = q.reshape(b, hkv, group, s, dh)
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    qc = jnp.moveaxis(qg.reshape(b, hkv, group, n_chunks, chunk, dh), 3, 0)

    # KV slice width per chunk when window-sliced (128-aligned)
    use_slice = (windowed_slice and window is not None and causal
                 and q_offset == 0 and window + chunk < t)
    w_eff = min(-(-(window + chunk) // 128) * 128, t) if use_slice else t
    if use_slice:
        # broadcast KV to full heads ONCE, outside the chunk loop, so each
        # chunk's slice + einsums are collective-free on a head-sharded
        # layout (GQA's kv-head count rarely divides the model axis; the
        # baseline pays that reshard once per layer — paying it per chunk
        # would dominate, measured in §Perf iteration B_j1)
        kf = shard(jnp.repeat(k, group, axis=1), bspec("model", None, None))
        vf = shard(jnp.repeat(v, group, axis=1), bspec("model", None, None))
        qf = qc.reshape(n_chunks, b, h, chunk, dh)      # [nc,B,H,c,Dh]

    def attend_chunk(ci, qi):
        if use_slice:
            # qi: [B,H,c,Dh]; KV slice is local to every device
            start = jnp.clip(ci * chunk + chunk - w_eff, 0, t - w_eff)
            ks = jax.lax.dynamic_slice_in_dim(kf, start, w_eff, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(vf, start, w_eff, axis=2)
            k_idx = start + jnp.arange(w_eff)
            scores = tp.tp_einsum("bhcd,bhtd->bhct", qi, ks, policy,
                                  out_fmt="fp32") * scale
        else:
            ks, vs = k, v
            k_idx = jnp.arange(t)
            scores = tp.tp_einsum("bhgcd,bhtd->bhgct", qi, ks, policy,
                                  out_fmt="fp32") * scale
        scores = softcap(scores, cap)
        q_idx = q_offset + ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, k_idx.shape[0]), bool)
        if causal:
            mask = mask & (q_idx[:, None] >= k_idx[None, :])
        if window is not None:
            mask = mask & ((q_idx[:, None] - k_idx[None, :]) < window)
        # per-row live length ([1] broadcasts = the uniform case): combined
        # with the static masks at [B?, 1, (1,) chunk, t] rank
        lmask = k_idx[None, :] < kv_len[:, None]            # [1 or B, t]
        if use_slice:
            scores = jnp.where(mask[None, None]
                               & lmask[:, None, None, :], scores, NEG_INF)
        else:
            scores = jnp.where(mask[None, None, None]
                               & lmask[:, None, None, None, :],
                               scores, NEG_INF)
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - jnp.where(m <= NEG_INF / 2, 0.0, m))
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        if use_slice:
            return tp.tp_einsum("bhct,bhtd->bhcd", p, vs, policy,
                                out_fmt="fp32")
        return tp.tp_einsum("bhgct,bhtd->bhgcd", p, vs, policy,
                            out_fmt="fp32")

    dv = v.shape[-1]
    if use_slice:
        out = jax.lax.map(lambda args: attend_chunk(*args),
                          (jnp.arange(n_chunks), qf))
        out = jnp.moveaxis(out, 0, 2).reshape(b, h, n_chunks * chunk, dv)
        return out[..., :s, :]
    out = jax.lax.map(lambda args: attend_chunk(*args),
                      (jnp.arange(n_chunks), qc))
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, group, n_chunks * chunk, dv)
    return out[..., :s, :].reshape(b, h, s, dv)


class KVCache(NamedTuple):
    k: jnp.ndarray  # [B, Hkv, Smax, Dh]
    v: jnp.ndarray


# ---------------------------------------------------------------------------
# tensor-parallel head sharding (mesh "model" axis)
# ---------------------------------------------------------------------------
def _head_shard_size(mesh, n_heads, n_kv_heads, axis: str = "model"):
    """Tensor-parallel degree for head-sharded attention, or ``None`` for
    the single-device path: requires a mesh with a ``model`` axis of size
    > 1 that divides BOTH the query and KV head counts (every shard gets
    whole heads of each — GQA groups never straddle a shard)."""
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return None
    size = mesh.shape[axis]
    if size <= 1 or n_heads % size or n_kv_heads % size:
        return None
    return size


def _headshard_call(mesh, fn, q, head_ops=(), rep_ops=(),
                    axis: str = "model"):
    """Run ``fn(q, *head_ops, *rep_ops)`` under ``shard_map`` with the
    head axis (axis 1 of q and of every ``head_ops`` operand — q, K/V,
    caches and page pools all carry heads there) partitioned over the
    mesh ``axis``; ``rep_ops`` (block tables, kv_len vectors) are
    replicated.  Per-head attention outputs are independent, so the
    out-spec concatenation over heads is BIT-IDENTICAL to the unsharded
    call — the kernel bodies run unchanged on their head slice.

    Every traced operand must be passed explicitly (shard_map closures
    must not capture tracers); ``fn`` may capture only static
    configuration (policy, window, softcap, static q_offset...)."""
    from ..core.compat import shard_map_compat
    hs = P(None, axis, None, None)
    in_specs = (hs,) * (1 + len(head_ops)) + (P(),) * len(rep_ops)
    f = shard_map_compat(fn, mesh=mesh, in_specs=in_specs, out_specs=hs,
                         axis_names=set(mesh.axis_names))
    return f(q, *head_ops, *rep_ops)


def _row_parallel_wo(mesh, out, wo, policy, axis: str = "model"):
    """Row-parallel output projection: ``out`` [B, S, H*Dv] arrives
    head-major from the head-sharded attend (shard k owns the contiguous
    feature block of its heads), ``wo`` [H*Dv, D] is split over the same
    rows, and the partial products ``psum`` over ``axis``.  This is the
    bit-exactness boundary: per-head attend outputs are bitwise, the
    psum's reduction order is not — projections match the single-device
    path to fp32 allclose.

    Each shard's partial product stays fp32 through the psum; the
    policy's accumulate/output format snap is applied ONCE to the full
    sum (exactly where the single-device ``tp_einsum`` applies it) — a
    per-shard snap would quantize the partials themselves and drift by a
    whole output-format ulp instead of fp32 reduction-order noise."""
    from ..core.compat import shard_map_compat

    def body(o, w):
        return jax.lax.psum(
            tp.tp_einsum("bse,ed->bsd", o, w, policy, out_fmt="fp32"), axis)

    f = shard_map_compat(body, mesh=mesh,
                         in_specs=(P(None, None, axis), P(axis, None)),
                         out_specs=P(),
                         axis_names=set(mesh.axis_names))
    r = f(out, wo)
    pol = tp.get_policy(policy)
    mp = pol.matmul
    out_f = mp.resolved_out()
    if pol.mode == "native":
        return r.astype(out_f.native_dtype)
    if mp.acc_fmt.name != "fp32":
        r = tp.quantize_ste(r, mp.acc_fmt, pol.rounding)
    if out_f.name != "fp32":
        r = tp.quantize_ste(r, out_f, pol.rounding)
    return r


def gqa_attention(x, params, policy, *, n_heads, n_kv_heads, head_dim,
                  positions, causal=True, window=None, attn_softcap=None,
                  rope_theta=1e4, qk_norm=False, norm_eps=1e-6,
                  cache: Optional[KVCache] = None,
                  cache_pos: Optional[jnp.ndarray] = None,
                  kv_states=None, use_rope=True, chunk: int = 512,
                  windowed_slice: bool = False,
                  decode_backend: str = "dense",
                  prefill_backend: str = "dense",
                  kv_len=None, esc_fmts=None, kv_levels=None,
                  kv_scale=None, mesh=None, return_attend: bool = False,
                  verify: bool = False):
    """Returns (out [B,S,D], new_cache) — or (out, new_cache, kv_flags)
    when ``esc_fmts`` is given (the arity is static per trace).

    Train/prefill: cache None.  Decode: x is [B,1,D], cache holds Smax slots,
    cache_pos is the write index.  Cross-attention: kv_states provides
    encoder states (no cache update, no rope).

    Ragged batches: ``kv_len`` (scalar or per-sequence [B] vector) masks
    keys past each row's live length — in prefill it is the per-row prompt
    length; in decode it overrides the default ``cache_pos + s`` (EOS-frozen
    rows keep a fixed live length).  ``cache_pos`` may likewise be a [B]
    vector: each row's K/V is then written at its OWN cache index.

    Paged cache: ``cache`` may be a ``paged.PagedKVCache`` (shared page
    pools + per-row block table) instead of a contiguous ``KVCache``.
    Writes scatter through the table (``paged_update_rows``); reads —
    decode AND prefill — dereference it in the Pallas kernels' index maps
    (or gather, on the dense fallback).  Paged prefill is write-then-read:
    the chunk's K/V lands in the pool first and attention reads it back
    through the table, so a chunked continuation (``cache_pos`` = the
    chunk's start offset, ``kv_len`` = prefix + chunk live length) is the
    same code path as a fresh prompt.

    Escalation write path: ``esc_fmts`` (static tuple of FPFormat rungs,
    narrow -> wide) + ``kv_levels`` ([B] int32 per-row rung) route every
    self-attention cache write through ``quantize_kv_rows`` — K/V are
    snapped to each row's rung with the saturating cast before landing in
    the (f32) pool, and the per-row OF/UF write-flag counts come back as a
    third return value ``kv_flags`` [B, 2].  ``kv_scale`` (traced scalar,
    default off) multiplies K/V pre-quantization — the fault-injection
    hook that forces narrow-rung overflow on demand.

    Speculative verify: ``verify=True`` with ``s > 1`` and a cache is the
    multi-query verify read mode.  The chunk's K/V is written first
    (chunk-form writes are bit-identical to the step-form writes plain
    decode performs), then the s query positions FOLD INTO THE BATCH
    dimension — ``kv_len`` must be a [B, S] matrix of per-query live
    lengths (query i of row b attends ``kv_len[b, i]`` slots) — and the
    folded [B*S] pseudo-batch takes the EXACT decode attend path
    (``_decode_attend`` / ``_decode_attend_paged``, dense or Pallas).
    Decode attend is per-row independent, so each folded query's output
    is bitwise what a sequential decode step at that position would
    produce: speculative verification inherits bit-parity with plain
    decode by construction instead of by numerical accident.  Block
    tables are tiled per query (the pool is shared); contiguous caches
    are repeated along batch.

    Tensor parallelism: ``mesh`` with a ``model`` axis whose size divides
    both head counts runs every attend (dense AND Pallas, prefill AND
    decode, contiguous AND paged) under ``shard_map`` on its head slice —
    bit-identical per head to the single-device path — and the output
    projection row-parallel with a ``psum`` (fp32-allclose; see
    ``_row_parallel_wo``).  Cache writes stay outside the shard_map
    regions (the pool arrays carry their own shardings); block tables and
    ``kv_len`` are replicated.  An absent/size-1/indivisible axis falls
    back to the unsharded path.  ``return_attend=True`` (debug/test hook)
    returns the pre-projection per-head attend output [B, H, S, Dv]
    instead of the projected residual contribution.
    """
    b, s, d = x.shape
    q = tp.tp_einsum("bsd,de->bse", x, params["wq"], policy)
    q = q.reshape(b, s, n_heads, head_dim)
    kv_src = kv_states if kv_states is not None else x
    t = kv_src.shape[1]
    k = tp.tp_einsum("bsd,de->bse", kv_src, params["wk"], policy)
    v = tp.tp_einsum("bsd,de->bse", kv_src, params["wv"], policy)
    k = k.reshape(b, t, n_kv_heads, head_dim)
    v = v.reshape(b, t, n_kv_heads, head_dim)

    if qk_norm:
        q = rmsnorm(q, params["q_norm"], norm_eps)
        k = rmsnorm(k, params["k_norm"], norm_eps)
    if use_rope:
        kv_pos = positions if kv_states is None else jnp.arange(t)
        q = apply_rope(q.swapaxes(1, 2), positions, rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), kv_pos, rope_theta).swapaxes(1, 2)

    q = shard(q.swapaxes(1, 2), bspec("model", None, None))
    k = shard(k.swapaxes(1, 2), bspec("model", None, None))
    v = shard(v.swapaxes(1, 2), bspec("model", None, None))

    tp_size = _head_shard_size(mesh, n_heads, n_kv_heads)

    def _attend(fn, head_ops=(), rep_ops=(), q_op=None):
        qq = q if q_op is None else q_op
        if tp_size is None:
            return fn(qq, *head_ops, *rep_ops)
        return _headshard_call(mesh, fn, qq, head_ops, rep_ops)

    new_cache = None
    kv_flags = jnp.zeros((b, 2), jnp.int32)  # OF, UF write counts per row
    if kv_states is not None:
        # cross-attention: optionally persist the encoder K/V into the
        # cache (prefill), attend non-causally over all encoder states.
        if cache is not None:
            cdt = cache.k.dtype
            new_cache = KVCache(
                jax.lax.dynamic_update_slice(cache.k, k.astype(cdt),
                                             (0, 0, 0, 0)),
                jax.lax.dynamic_update_slice(cache.v, v.astype(cdt),
                                             (0, 0, 0, 0)))
        out = _attend(
            lambda q_, k_, v_: _masked_softmax_attend(
                q_, k_, v_, policy, causal=False, window=None,
                cap=attn_softcap, q_offset=0, chunk=chunk),
            head_ops=(k, v))
    elif cache is not None:
        paged = isinstance(cache, PagedKVCache)
        if esc_fmts is not None:
            if kv_scale is not None:
                k = k * kv_scale
                v = v * kv_scale
            k, kf = quantize_kv_rows(k, esc_fmts, kv_levels)
            v, vf = quantize_kv_rows(v, esc_fmts, kv_levels)
            kv_flags = kf + vf
        if paged:
            # paged cache: K/V scatter through the block table into the
            # shared page pool instead of a per-row contiguous strip
            new_cache = PagedKVCache(
                paged_update_rows(cache.k_pool, cache.block_table, k,
                                  cache_pos),
                paged_update_rows(cache.v_pool, cache.block_table, v,
                                  cache_pos),
                cache.block_table)
        else:
            ck = update_cache_rows(cache.k, k, cache_pos, axis=2)
            cv = update_cache_rows(cache.v, v, cache_pos, axis=2)
            new_cache = KVCache(ck, cv)
        if verify and s > 1:
            # speculative verify: fold the s chunk queries into the batch
            # dimension and take the exact decode read path — query i of
            # row b becomes pseudo-row b*s+i attending kv_len[b, i] slots
            # of row b's (just-updated) cache.  Decode attend is per-row
            # independent, so every folded query is bitwise identical to
            # the sequential decode step at its position; slots at or past
            # a query's kv_len (later chunk positions, rejected drafts)
            # are masked dead exactly as in plain decode.
            kvl = jnp.reshape(jnp.asarray(kv_len, jnp.int32), (b * s,))
            qv = q.swapaxes(1, 2).reshape(b * s, n_heads, head_dim)[
                :, :, None, :]
            if paged:
                bt = jnp.repeat(new_cache.block_table, s, axis=0)
                out = _attend(
                    lambda q_, kp, vp, bt_, lv: _decode_attend_paged(
                        q_, PagedKVCache(kp, vp, bt_), policy, kv_len=lv,
                        window=window, cap=attn_softcap,
                        backend=decode_backend),
                    head_ops=(new_cache.k_pool, new_cache.v_pool),
                    rep_ops=(bt, kvl), q_op=qv)
            else:
                ckr = jnp.repeat(ck, s, axis=0)
                cvr = jnp.repeat(cv, s, axis=0)
                out = _attend(
                    lambda q_, k_, v_, lv: _decode_attend(
                        q_, k_, v_, policy, kv_len=lv, window=window,
                        cap=attn_softcap, backend=decode_backend),
                    head_ops=(ckr, cvr), rep_ops=(kvl,), q_op=qv)
            out = out.reshape(b, s, n_heads, head_dim).swapaxes(1, 2)
        elif s > 1 and paged:
            # paged prefill attends THROUGH the pool just written
            # (write-then-read) instead of the freshly computed k/v: the
            # same read path a chunked continuation takes, so chunk
            # boundaries are invisible and decode later dereferences
            # exactly what prefill attended.  ``kv_len`` is each row's
            # TOTAL live length (prefix + this chunk's live tail);
            # ``cache_pos`` is the chunk's static query offset.  Pallas
            # keeps the indirection down to the kernel's index maps; the
            # dense fallback gathers the pool (pure data movement, so it
            # is bit-identical to attending the contiguous values).
            live = jnp.asarray(
                kv_len if kv_len is not None else cache_pos + s, jnp.int32)
            if _use_pallas_prefill(prefill_backend, cache_pos):
                out = _attend(
                    lambda q_, kp, vp, bt, lv: _flash_attend_paged(
                        q_, PagedKVCache(kp, vp, bt), policy, causal=causal,
                        window=window, cap=attn_softcap, q_offset=cache_pos,
                        kv_len=lv),
                    head_ops=(new_cache.k_pool, new_cache.v_pool),
                    rep_ops=(new_cache.block_table, live))
            else:
                out = _attend(
                    lambda q_, kp, vp, bt, lv: _masked_softmax_attend(
                        q_, gather_paged_kv(kp, bt), gather_paged_kv(vp, bt),
                        policy, causal=causal, window=window,
                        cap=attn_softcap, q_offset=cache_pos, chunk=chunk,
                        kv_len=lv, windowed_slice=windowed_slice),
                    head_ops=(new_cache.k_pool, new_cache.v_pool),
                    rep_ops=(new_cache.block_table, live))
        elif s > 1:
            # prefill: the prompt itself is the entire live cache content —
            # attend over the *current* k/v, not the cache buffer (kv_len
            # carries the per-row prompt lengths of a ragged batch).
            lv_ops = (() if kv_len is None
                      else (jnp.asarray(kv_len, jnp.int32),))
            if _use_pallas_prefill(prefill_backend, cache_pos):
                out = _attend(
                    lambda q_, k_, v_, *lv: _flash_attend(
                        q_, k_, v_, policy, causal=causal, window=window,
                        cap=attn_softcap, q_offset=cache_pos,
                        kv_len=lv[0] if lv else None),
                    head_ops=(k, v), rep_ops=lv_ops)
            else:
                out = _attend(
                    lambda q_, k_, v_, *lv: _masked_softmax_attend(
                        q_, k_, v_, policy, causal=causal, window=window,
                        cap=attn_softcap, q_offset=cache_pos, chunk=chunk,
                        kv_len=lv[0] if lv else None,
                        windowed_slice=windowed_slice),
                    head_ops=(k, v), rep_ops=lv_ops)
        else:
            if kv_len is None:
                kv_len = cache_pos + s     # [B] vector when cache_pos is one
            kvl = jnp.asarray(kv_len, jnp.int32)
            if paged:
                out = _attend(
                    lambda q_, kp, vp, bt, lv: _decode_attend_paged(
                        q_, PagedKVCache(kp, vp, bt), policy, kv_len=lv,
                        window=window, cap=attn_softcap,
                        backend=decode_backend),
                    head_ops=(new_cache.k_pool, new_cache.v_pool),
                    rep_ops=(new_cache.block_table, kvl))
            else:
                out = _attend(
                    lambda q_, k_, v_, lv: _decode_attend(
                        q_, k_, v_, policy, kv_len=lv, window=window,
                        cap=attn_softcap, backend=decode_backend),
                    head_ops=(ck, cv), rep_ops=(kvl,))
    else:
        lv_ops = (() if kv_len is None
                  else (jnp.asarray(kv_len, jnp.int32),))
        if _use_pallas_prefill(prefill_backend):
            out = _attend(
                lambda q_, k_, v_, *lv: _flash_attend(
                    q_, k_, v_, policy, causal=causal, window=window,
                    cap=attn_softcap, q_offset=0,
                    kv_len=lv[0] if lv else None),
                head_ops=(k, v), rep_ops=lv_ops)
        else:
            out = _attend(
                lambda q_, k_, v_, *lv: _masked_softmax_attend(
                    q_, k_, v_, policy, causal=causal, window=window,
                    cap=attn_softcap, q_offset=0, chunk=chunk,
                    kv_len=lv[0] if lv else None,
                    windowed_slice=windowed_slice),
                head_ops=(k, v), rep_ops=lv_ops)

    if return_attend:
        return out, new_cache

    out = out.swapaxes(1, 2).reshape(b, s, n_heads * head_dim)
    if tp_size is None:
        proj = tp.tp_einsum("bse,ed->bsd", out, params["wo"], policy)
    else:
        proj = _row_parallel_wo(mesh, out, params["wo"], policy)
    proj = shard(proj, residual_spec())
    if esc_fmts is not None:
        return proj, new_cache, kv_flags
    return proj, new_cache


def _decode_attend(q, ck, cv, policy, *, kv_len, window, cap,
                   backend: str = "dense"):
    """q [B,H,1,Dh] vs cache [B,Hkv,Smax,Dh].

    ``backend="pallas"`` routes through the fused decode-attention kernel
    (kernels/decode_attention.py): the cache stays in its narrow storage
    format until the in-kernel CONV->ADDMUL widening, and ``kv_len`` is a
    dynamic kernel input so scan-based generation never retraces.
    ``kv_len`` may be a per-sequence [B] vector (ragged batch): the kernel
    early-exits each row's KV loop at its own length; the dense path masks
    per row.  ``backend="auto"`` resolves via
    ``kernels.ops.resolve_backend`` (pallas off-CPU only — shared with the
    prefill path)."""
    if backend != "dense":
        from ..kernels import ops as kops
        if kops.resolve_backend(backend) == "pallas":
            return kops.decode_attention(q, ck, cv, kv_len=kv_len,
                                         policy=policy, window=window,
                                         softcap=cap)
    b, h, s, dh = q.shape
    _, hkv, smax, _ = ck.shape
    group = h // hkv
    qg = q.reshape(b, hkv, group * s, dh)
    scores = tp.tp_einsum("bhqd,bhtd->bhqt", qg, ck, policy,
                          out_fmt="fp32") * (dh ** -0.5)
    scores = softcap(scores, cap)
    idx = jnp.arange(smax)
    kvl = _len_rows(kv_len)[:, None]                    # [1 or B, 1]
    mask = idx[None, :] < kvl
    if window is not None:
        mask = mask & (idx[None, :] > kvl - 1 - window)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    # fully-masked rows (kv_len == 0, an empty ragged-batch slot): emit
    # zeros like the kernel's l == 0 store guard, not a uniform softmax
    # over dead cache slots
    p = p * jnp.any(mask, axis=-1).astype(p.dtype)[:, None, None, None]
    out = tp.tp_einsum("bhqt,bhtd->bhqd", p, cv, policy, out_fmt="fp32")
    return out.reshape(b, h, s, dh)


def _decode_attend_paged(q, cache: PagedKVCache, policy, *, kv_len, window,
                         cap, backend: str = "dense"):
    """Paged decode attention: q [B,H,1,Dh] against the page pools of
    ``cache`` through its block table.

    ``backend="pallas"`` keeps the indirection all the way down — the
    fused decode kernel's BlockSpec index maps dereference the table at
    DMA time, and no contiguous view is ever materialized (THE paged win:
    HBM traffic per row is its own page run).  The dense fallback gathers
    pages back into the contiguous layout first (pure data movement, so it
    is bit-identical to contiguous dense attention on the same values) —
    the CPU correctness path, not a serving path."""
    if backend != "dense":
        from ..kernels import ops as kops
        if kops.resolve_backend(backend) == "pallas":
            return kops.decode_attention(
                q, cache.k_pool, cache.v_pool, kv_len=kv_len,
                block_table=cache.block_table, policy=policy, window=window,
                softcap=cap)
    return _decode_attend(q, gather_paged_kv(cache.k_pool, cache.block_table),
                          gather_paged_kv(cache.v_pool, cache.block_table),
                          policy, kv_len=kv_len, window=window, cap=cap,
                          backend="dense")


def init_kv_cache(batch, n_kv_heads, max_len, head_dim, dtype):
    shape = (batch, n_kv_heads, max_len, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cross_attend_cached(x, params, cache: KVCache, policy, *, n_heads,
                        n_kv_heads, head_dim):
    """Decode-time cross-attention against fully-populated cached K/V
    (whisper decoder: the encoder states never change during decoding)."""
    b, s, d = x.shape
    q = tp.tp_einsum("bsd,de->bse", x, params["wq"], policy)
    q = q.reshape(b, s, n_heads, head_dim).swapaxes(1, 2)
    out = _decode_attend(q, cache.k, cache.v, policy,
                         kv_len=cache.k.shape[2], window=None, cap=None)
    out = out.swapaxes(1, 2).reshape(b, s, n_heads * head_dim)
    return tp.tp_einsum("bse,ed->bsd", out, params["wo"], policy)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------
class MLACache(NamedTuple):
    c_kv: jnp.ndarray   # [B, Smax, kv_lora]
    k_pe: jnp.ndarray   # [B, Smax, rope_dim]


def mla_params(key, d_model, n_heads, *, q_lora, kv_lora, nope_dim, rope_dim,
               v_head_dim, dtype):
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], d_model, kv_lora, dtype),
        "w_kr": dense_init(ks[1], d_model, rope_dim, dtype),
        "kv_norm": jnp.zeros((kv_lora,), dtype),
        "w_uk": dense_init(ks[2], kv_lora, n_heads * nope_dim, dtype),
        "w_uv": dense_init(ks[3], kv_lora, n_heads * v_head_dim, dtype),
        "wo": dense_init(ks[4], n_heads * v_head_dim, d_model, dtype),
    }
    if q_lora:
        p["w_dq"] = dense_init(ks[5], d_model, q_lora, dtype)
        p["q_norm"] = jnp.zeros((q_lora,), dtype)
        p["w_uq"] = dense_init(ks[6], q_lora, n_heads * (nope_dim + rope_dim),
                               dtype)
    else:
        p["w_q"] = dense_init(ks[5], d_model, n_heads * (nope_dim + rope_dim),
                              dtype)
    return p


def mla_attention(x, params, policy, *, n_heads, nope_dim, rope_dim,
                  v_head_dim, positions, rope_theta=1e4, norm_eps=1e-6,
                  cache: Optional[MLACache] = None,
                  cache_pos: Optional[jnp.ndarray] = None, chunk: int = 512,
                  prefill_backend: str = "dense", kv_len=None):
    """MLA with decoupled rope.  Prefill expands k/v; decode runs the
    absorbed form directly against the latent cache.  ``kv_len`` /
    ``cache_pos`` follow the gqa_attention ragged contract: scalar, or a
    per-sequence [B] vector (per-row length masking and per-row latent
    cache write indices)."""
    b, s, d = x.shape
    qd = nope_dim + rope_dim

    if "w_dq" in params:
        cq = tp.tp_einsum("bsd,dr->bsr", x, params["w_dq"], policy)
        cq = rmsnorm(cq, params["q_norm"], norm_eps)
        q = tp.tp_einsum("bsr,re->bse", cq, params["w_uq"], policy)
    else:
        q = tp.tp_einsum("bsd,de->bse", x, params["w_q"], policy)
    q = q.reshape(b, s, n_heads, qd)
    q_nope, q_pe = q[..., :nope_dim], q[..., nope_dim:]
    q_pe = apply_rope(q_pe.swapaxes(1, 2), positions, rope_theta).swapaxes(1, 2)

    c_kv = tp.tp_einsum("bsd,dr->bsr", x, params["w_dkv"], policy)
    c_kv = rmsnorm(c_kv, params["kv_norm"], norm_eps)
    k_pe = tp.tp_einsum("bsd,dr->bsr", x, params["w_kr"], policy)
    k_pe = apply_rope(k_pe[:, :, None], positions, rope_theta)[:, :, 0]

    scale = (nope_dim + rope_dim) ** -0.5

    new_cache = None
    if cache is not None:
        cc = update_cache_rows(cache.c_kv, c_kv, cache_pos, axis=1)
        cp = update_cache_rows(cache.k_pe, k_pe, cache_pos, axis=1)
        new_cache = MLACache(cc, cp)
    if cache is not None and s == 1:
        if kv_len is None:
            kv_len = cache_pos + s
        # absorbed decode: q_nope -> latent space via W_uk
        cc, cp = new_cache
        kv_lora = cc.shape[-1]
        w_uk = params["w_uk"].reshape(kv_lora, n_heads, nope_dim)
        q_lat = tp.tp_einsum("bshn,rhn->bshr", q_nope, w_uk, policy)
        smax = cc.shape[1]
        scores = (tp.tp_einsum("bshr,btr->bhst", q_lat, cc, policy,
                               out_fmt="fp32")
                  + tp.tp_einsum("bshr,btr->bhst", q_pe, cp, policy,
                                 out_fmt="fp32")) * scale
        mask = jnp.arange(smax)[None, :] < _len_rows(kv_len)[:, None]
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        # kv_len == 0 rows: zeros, not uniform weights over dead slots
        p = p * jnp.any(mask, axis=-1).astype(p.dtype)[:, None, None, None]
        o_lat = tp.tp_einsum("bhst,btr->bshr", p, cc, policy, out_fmt="fp32")
        w_uv = params["w_uv"].reshape(kv_lora, n_heads, v_head_dim)
        out = tp.tp_einsum("bshr,rhv->bshv", o_lat, w_uv, policy)
    else:
        # train / prefill (cache written above if present): expanded form
        k_nope = tp.tp_einsum("bsr,re->bse", c_kv, params["w_uk"], policy)
        k_nope = k_nope.reshape(b, s, n_heads, nope_dim)
        v = tp.tp_einsum("bsr,re->bse", c_kv, params["w_uv"], policy)
        v = v.reshape(b, s, n_heads, v_head_dim)
        k_pe_b = jnp.broadcast_to(k_pe[:, :, None], (b, s, n_heads, rope_dim))
        qq = jnp.concatenate([q_nope, q_pe], axis=-1).swapaxes(1, 2)
        kk = jnp.concatenate([k_nope, k_pe_b], axis=-1).swapaxes(1, 2)
        vv = v.swapaxes(1, 2)
        qq = shard(qq, bspec("model", None, None))
        kk = shard(kk, bspec("model", None, None))
        vv = shard(vv, bspec("model", None, None))
        if _use_pallas_prefill(prefill_backend):
            # the kernel supports Dv != Dqk directly (expanded MLA prefill)
            out = _flash_attend(qq, kk, vv, policy, causal=True, window=None,
                                cap=None, q_offset=0, kv_len=kv_len)
        else:
            # _masked_softmax_attend scales by qd**-0.5 internally == MLA
            out = _masked_softmax_attend(qq, kk, vv, policy, causal=True,
                                         window=None, cap=None, q_offset=0,
                                         chunk=chunk, kv_len=kv_len)
        out = out.swapaxes(1, 2)

    out = out.reshape(b, s, n_heads * v_head_dim)
    proj = tp.tp_einsum("bse,ed->bsd", out, params["wo"], policy)
    return shard(proj, residual_spec()), new_cache


def init_mla_cache(batch, max_len, kv_lora, rope_dim, dtype):
    return MLACache(jnp.zeros((batch, max_len, kv_lora), dtype),
                    jnp.zeros((batch, max_len, rope_dim), dtype))
