"""Common policy-aware layers: norms, MLPs, rotary embeddings, embedding
tables.  Every matmul routes through core.ops.tp_einsum so the active
PrecisionPolicy (FPnew's per-op-group format configuration) applies
uniformly across all ten architectures.

Sharding convention (Megatron-style, GSPMD-propagated):
  activations [B, S, D]   -> P(BATCH_AXES, None, None)
  col-parallel weights    -> P(None, "model")
  row-parallel weights    -> P("model", None)
  embeddings [V, D]       -> P("model", None)   (vocab-sharded)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import ops as tp
from ..core.policy import PrecisionPolicy, get_policy
from jax.sharding import PartitionSpec as P

# data-parallel mesh axes for the current launch; the train/serve step
# factories set this from the mesh before tracing (("pod","data") on the
# multi-pod mesh, ("data",) on a single pod, () on a single device).
_BATCH_AXES = ("data",)
_SEQ_PARALLEL = False


def set_batch_axes(axes):
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes)


def set_seq_parallel(enable: bool):
    global _SEQ_PARALLEL
    _SEQ_PARALLEL = bool(enable)


def residual_spec() -> P:
    """Sharding of the [B, S, D] residual stream: sequence-parallel mode
    shards S over the model axis (GSPMD turns the row-parallel all-reduce
    into reduce-scatter + all-gather and runs norms on S/TP shards)."""
    return P(_BATCH_AXES, "model" if _SEQ_PARALLEL else None, None)


def batch_axes():
    return _BATCH_AXES


def shard(x, spec):
    """Sharding hint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


def bspec(*rest) -> P:
    """P(batch_axes, *rest) — activation sharding helper."""
    return P(_BATCH_AXES, *rest)


def param_dtype(policy: PrecisionPolicy):
    return tp.storage_dtype(policy.param_fmt, policy.mode)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in, d_out, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype):
    # d^-1/2 keeps tied-unembedding logits at unit scale
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * d ** -0.5).astype(dtype)


# ---------------------------------------------------------------------------
# norms (always f32 — FPnew keeps COMP/normalization paths in full precision)
# ---------------------------------------------------------------------------
def rmsnorm(x, gamma, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., S, D] (D even), positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu(x, w_gate, w_up, w_down, policy):
    """SwiGLU MLP: the ADDMUL group runs under the multi-format FMA policy,
    the activation under the DIVSQRT/elementwise policy."""
    g = tp.tp_einsum("bsd,df->bsf", x, w_gate, policy)
    u = tp.tp_einsum("bsd,df->bsf", x, w_up, policy)
    h = tp.tp_elementwise("silu", g, policy=policy) * u
    h = shard(h, bspec(None, "model"))
    out = tp.tp_einsum("bsf,fd->bsd", h, w_down, policy)
    return shard(out, residual_spec())


def gelu_mlp(x, w_up, b_up, w_down, b_down, policy):
    h = tp.tp_einsum("bsd,df->bsf", x, w_up, policy) + b_up
    h = tp.tp_elementwise("gelu", h, policy=policy)
    h = shard(h, bspec(None, "model"))
    out = tp.tp_einsum("bsf,fd->bsd", h, w_down, policy) + b_down
    return shard(out, residual_spec())


def mlp_params(key, d, f, dtype, kind="swiglu"):
    ks = jax.random.split(key, 4)
    if kind == "swiglu":
        return {"gate": dense_init(ks[0], d, f, dtype),
                "up": dense_init(ks[1], d, f, dtype),
                "down": dense_init(ks[2], f, d, dtype)}
    return {"up": dense_init(ks[0], d, f, dtype),
            "b_up": jnp.zeros((f,), dtype),
            "down": dense_init(ks[1], f, d, dtype),
            "b_down": jnp.zeros((d,), dtype)}


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    xf = x.astype(jnp.float32)
    return (cap * jnp.tanh(xf / cap)).astype(x.dtype)
