"""Architecture registry: ``--arch <id>`` -> (ModelConfig, Model)."""
from __future__ import annotations

import importlib

from ..core.policy import PrecisionPolicy, get_policy
from .transformer import Model

ARCHS = (
    "internvl2_26b", "deepseek_v2_lite_16b", "qwen3_moe_30b_a3b",
    "whisper_small", "xlstm_1_3b", "granite_20b", "gemma2_9b",
    "minicpm3_4b", "gemma3_12b", "zamba2_1_2b",
)

# external ids (assignment spelling) -> module names
ALIASES = {
    "internvl2-26b": "internvl2_26b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-small": "whisper_small",
    "xlstm-1.3b": "xlstm_1_3b",
    "granite-20b": "granite_20b",
    "gemma2-9b": "gemma2_9b",
    "minicpm3-4b": "minicpm3_4b",
    "gemma3-12b": "gemma3_12b",
    "zamba2-1.2b": "zamba2_1_2b",
    "fpnew-case-study": "fpnew_case_study",
}


def canonical(arch: str) -> str:
    return ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str, reduced: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    cfg = mod.CONFIG
    if reduced:
        cfg = mod.reduced()
    return cfg.validate()


def build_model(arch: str, policy="tp_bf16", reduced: bool = False) -> Model:
    cfg = get_config(arch, reduced=reduced)
    return Model(cfg=cfg, policy=get_policy(policy))
