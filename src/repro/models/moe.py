"""Mixture-of-Experts with production expert parallelism.

Dispatch is the TPU-idiomatic *sort-based capacity* scheme (no [T, E, C]
one-hot tensors):

  1. router top-k -> flat (token, slot) -> expert assignments,
  2. stable argsort by expert, per-expert rank via run-starts,
  3. scatter into an [E, C, D] buffer — with the default drop-free
     capacity (``capacity_factor=None``) every assignment fits, so
     per-token outputs are batch-composition-invariant (serving needs
     this: chunked verify must equal sequential decode bitwise); an
     explicit finite factor restores training-style over-capacity drops,
  4. expert-parallel all_to_all over the ``model`` mesh axis (each data row
     exchanges expert slabs within itself; expert weights are sharded over
     ``model`` and replicated over ``data`` like every other weight),
  5. batched expert SwiGLU ([E_loc, M*C, D] x [E_loc, D, F]),
  6. reverse all_to_all, gather back, gate-weighted combine, unsort.

The same core runs without collectives when ``ep_axis`` is None (single
device smoke tests); the EP path is wrapped in shard_map by the caller.

Transprecision: expert matmuls follow the multi-format FMA policy; the
router runs in f32 (FPnew keeps the COMP group full-precision) — exactly
the per-op-group format split of paper §II.B.2.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import ops as tp
from .layers import batch_axes, bspec, dense_init, residual_spec, shard


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    # None = drop-free dispatch (capacity >= n_tokens, so no expert can
    # overflow and no token is ever dropped).  Serving REQUIRES drop-free:
    # capacity scales with the total token count, so with a finite factor a
    # token's keep/drop decision depends on what else is in the batch — and
    # then chunked verify (B*(k+1) pseudo-rows) diverges from sequential
    # decode (B rows).  Set an explicit factor only for training-style
    # load-balancing experiments.
    capacity_factor: float | None = None
    router_norm_topk: bool = True   # normalize top-k gates to sum to 1


def moe_params(key, d_model, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_expert
    p = {
        "router": dense_init(ks[0], d_model, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d_model, f), jnp.float32)
                   * d_model ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, f), jnp.float32)
                 * d_model ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d_model), jnp.float32)
                   * f ** -0.5).astype(dtype),
    }
    if cfg.n_shared:
        fs = cfg.n_shared * f
        km = jax.random.split(ks[4], 3)
        p["shared"] = {"gate": dense_init(km[0], d_model, fs, dtype),
                       "up": dense_init(km[1], d_model, fs, dtype),
                       "down": dense_init(km[2], fs, d_model, dtype)}
    return p


def _capacity(n_tokens: int, cfg: MoEConfig) -> int:
    if cfg.capacity_factor is None:
        # Drop-free: each token assigns an expert at most once, so one
        # expert receives at most n_tokens rows.  cap >= n_tokens makes
        # per-token outputs independent of batch composition (bitwise).
        return max(8, -(-n_tokens // 8) * 8)
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _expert_ffn(buf, w_gate, w_up, w_down, policy):
    """buf [E, C, D] -> [E, C, D] batched SwiGLU."""
    g = tp.tp_einsum("ecd,edf->ecf", buf, w_gate, policy)
    u = tp.tp_einsum("ecd,edf->ecf", buf, w_up, policy)
    h = tp.tp_elementwise("silu", g, policy=policy) * u
    return tp.tp_einsum("ecf,efd->ecd", h, w_down, policy)


def moe_core(x_flat, params, cfg: MoEConfig, policy, *,
             ep_axis: Optional[str] = None, ep_size: int = 1):
    """x_flat [T, D] -> (y [T, D], aux_loss scalar).

    When ``ep_axis`` is set, this runs *inside shard_map*: experts arrive
    sharded [E_loc, ...] and tokens are the per-device shard; all_to_all
    exchanges expert slabs across ``ep_axis``.
    """
    t, d = x_flat.shape
    e_total = cfg.n_experts
    e_loc = params["w_gate"].shape[0]     # == e_total/ep_size under EP
    k = cfg.top_k
    cap = _capacity(t, cfg)

    # --- routing (f32; COMP group) ---------------------------------------
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)              # [T, k]
    if cfg.router_norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style)
    me = probs.mean(axis=0)                            # mean prob per expert
    onehot_top1 = jax.nn.one_hot(idx[:, 0], e_total)
    ce = onehot_top1.mean(axis=0)                      # dispatch fraction
    aux = e_total * jnp.sum(me * ce)

    # --- sort-based dispatch ----------------------------------------------
    flat_e = idx.reshape(-1)                           # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(e_total), side="left")
    rank = jnp.arange(t * k) - first[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e_total * cap)
    src_tok = order // k
    buf = jnp.zeros((e_total * cap + 1, d), x_flat.dtype)
    buf = buf.at[slot].set(x_flat[src_tok], mode="drop",
                           unique_indices=True)
    buf = buf[:-1].reshape(e_total, cap, d)

    # --- EP exchange -------------------------------------------------------
    # all_to_all(split=0, concat=0, tiled=False) swaps the leading
    # destination-shard axis for a source-shard axis in place.
    if ep_axis is not None and ep_size > 1:
        # [E, C, D] -> [M(dest), E_loc, C, D] -> a2a -> [M(src), E_loc, C, D]
        buf = buf.reshape(ep_size, e_loc, cap, d)
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        # -> [E_loc, M(src), C, D] -> [E_loc, M*C, D]
        buf = buf.swapaxes(0, 1).reshape(e_loc, ep_size * cap, d)

    out = _expert_ffn(buf, params["w_gate"], params["w_up"],
                      params["w_down"], policy)

    if ep_axis is not None and ep_size > 1:
        # [E_loc, M(src), C, D] -> [M(src=dest now), E_loc, C, D] -> a2a
        out = out.reshape(e_loc, ep_size, cap, d).swapaxes(0, 1)
        out = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        # [M(expert-shard), E_loc, C, D] == [E, C, D] in expert-major order
        out = out.reshape(e_total * cap, d)
    else:
        out = out.reshape(e_total * cap, d)

    # --- combine ------------------------------------------------------------
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)
    gathered = out[slot]                               # [T*k, D] (sorted order)
    unsort = jnp.argsort(order, stable=True)
    gathered = gathered[unsort].reshape(t, k, d)
    y = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                   gates.astype(jnp.float32)).astype(x_flat.dtype)
    return y, aux


def moe_block(x, params, cfg: MoEConfig, policy, *, mesh=None,
              ep_axis: Optional[str] = "model"):
    """x [B, S, D] -> (y, aux).  Uses shard_map EP when a mesh with the
    ``ep_axis`` is provided (production path); plain local dispatch
    otherwise (tests / single device)."""
    b, s, d = x.shape
    y_shared = None
    if cfg.n_shared:
        from .layers import swiglu
        y_shared = swiglu(x, params["shared"]["gate"], params["shared"]["up"],
                          params["shared"]["down"], policy)

    xf = x.reshape(b * s, d)
    routed = {k: v for k, v in params.items() if k != "shared"}

    # under an explicit mesh the specs may only name ITS axes: a serving
    # replica's ("model",) sub-mesh has no "data" axis to batch-shard over
    ba = tuple(a for a in batch_axes()
               if mesh is None or a in mesh.axis_names)
    if mesh is not None and ep_axis in mesh.axis_names and \
            mesh.shape[ep_axis] > 1:
        ep = mesh.shape[ep_axis]
        from ..core.compat import shard_map_compat as shard_map
        espec = P(ep_axis)
        pspec = {"router": P(), "w_gate": espec, "w_up": espec,
                 "w_down": espec}

        def body(xb, pb):
            yb, auxb = moe_core(xb, pb, cfg, policy,
                                ep_axis=ep_axis, ep_size=ep)
            return yb, auxb.reshape((1,) * max(len(ba), 1))

        y, aux = shard_map(
            body, mesh=mesh,
            in_specs=(P(ba if ba else None), pspec),
            out_specs=(P(ba), P(*ba) if ba else P()),
            check_vma=False,
        )(xf, routed)
        aux = aux.mean()
    else:
        y, aux = moe_core(xf, routed, cfg, policy)

    y = y.reshape(b, s, d)
    y = shard(y, residual_spec())
    if y_shared is not None:
        y = y + y_shared
    return y, aux
