"""Paged KV cache: a shared block pool + per-sequence block tables.

The memory-side analogue of the repo's compute-proportionality story: the
ragged-serving PRs made attention *work* scale with each sequence's own
length, but every sequence still OWNED a contiguous ``[Smax]`` KV buffer —
HBM paid at batch-max.  This module replaces the contiguous buffer with
indirection:

  * ``k_pool`` / ``v_pool`` — one shared pool of fixed-size pages,
    ``[n_pages, Hkv, page, Dh]`` (a page holds ``page`` tokens of K or V
    for every KV head; the trailing ``[page, Dh]`` tile per head is what
    the Pallas kernels' BlockSpecs load).
  * ``block_table`` — ``[B, max_pages]`` int32: row ``b``'s logical token
    block ``j`` lives in physical page ``block_table[b, j]``.  Tables are
    *traced* values: differing tables (new admissions, shared prefixes)
    reuse one compiled program, exactly like the per-row ``kv_lens``.

Rows that share a prompt prefix can point table entries at the SAME page
(prefix sharing — the pool stores the prefix once); a finished row's pages
return to the allocator for the next admission (continuous batching).  The
allocator is deliberately host-side Python: page churn happens at the
serving-loop boundary, between compiled steps, never inside them.

Layout note: pages are head-major (``[n_pages, Hkv, page, Dh]``) so a
zero-copy reshape to ``[n_pages * Hkv, page, Dh]`` gives each (page, head)
pair its own flat pool slot — kernels/ops.py expands a ``[B, max_pages]``
table to flat per-head page ids (``table * Hkv + head``) the same way it
expands ``kv_len`` vectors, and the kernels' scalar-prefetch index maps
dereference those flat ids directly.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class PagedKVCache(NamedTuple):
    """One attention layer's paged cache (drop-in for ``KVCache``)."""
    k_pool: jnp.ndarray       # [n_pages, Hkv, page, Dh]
    v_pool: jnp.ndarray       # [n_pages, Hkv, page, Dh]
    block_table: jnp.ndarray  # [B, max_pages] int32 (physical page ids)

    @property
    def page_size(self) -> int:
        return self.k_pool.shape[2]


def num_pages(max_len: int, page: int) -> int:
    """Pages needed to hold ``max_len`` tokens (the table width)."""
    return -(-max_len // page)


def identity_block_table(batch: int, max_pages: int) -> np.ndarray:
    """The unshared layout as a table: row ``b`` owns pages
    ``[b * max_pages, (b + 1) * max_pages)`` — contiguous-by-another-name,
    through the same indirection every other table uses."""
    return np.arange(batch * max_pages, dtype=np.int32).reshape(
        batch, max_pages)


def init_paged_kv_cache(batch: int, n_kv_heads: int, max_len: int, page: int,
                        head_dim: int, dtype, *, block_table=None,
                        n_pages: Optional[int] = None) -> PagedKVCache:
    """Zero pools + a block table.  ``block_table=None`` builds the identity
    (unshared) table; a caller-supplied table (allocator output, shared
    prefixes) is adopted as-is.  ``n_pages`` sizes the pool — default
    ``batch * max_pages``, the unshared worst case, so a shared table simply
    leaves pool tail pages unused (pool size is static under jit; the
    allocator's live-page count is the host-side memory story)."""
    mp = num_pages(max_len, page)
    if block_table is None:
        block_table = identity_block_table(batch, mp)
    block_table = jnp.asarray(block_table, jnp.int32)
    assert block_table.shape == (batch, mp), (block_table.shape, batch, mp)
    n_pages = batch * mp if n_pages is None else n_pages
    shape = (n_pages, n_kv_heads, page, head_dim)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                        block_table)


def paged_update_rows(pool, table, new, pos):
    """Write ``new`` [B, Hkv, S, Dh] into ``pool`` [n_pages, Hkv, page, Dh]
    at token positions ``pos .. pos + S`` per row, dereferenced through
    ``table`` [B, max_pages] — the paged twin of
    ``attention.update_cache_rows``.  ``pos`` is a scalar (uniform batch)
    or a per-row [B] vector (ragged decode), exactly like the contiguous
    writer.  Rows aliasing the same page (shared prefixes) must write
    identical values there (prefill over a common prompt does); decode
    writes land past the shared run, in private pages."""
    n, hkv, page, dh = pool.shape
    b, _, s, _ = new.shape
    pos = jnp.broadcast_to(
        jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))
    t_idx = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B, S]
    blk = jnp.take_along_axis(table, t_idx // page, axis=1)         # [B, S]
    off = t_idx % page
    vals = new.swapaxes(1, 2).reshape(b * s, hkv, dh).astype(pool.dtype)
    return pool.at[blk.reshape(-1), :, off.reshape(-1)].set(vals)


def gather_paged_kv(pool, table):
    """Materialize the contiguous view: [n_pages, Hkv, page, Dh] gathered
    through [B, max_pages] -> [B, Hkv, max_pages * page, Dh].  The dense
    (non-Pallas) attention fallback — pure data movement, so paged dense
    attention is bit-identical to the contiguous path; the Pallas kernels
    skip this gather entirely and dereference the table in their BlockSpec
    index maps."""
    b, mp = table.shape
    n, hkv, page, dh = pool.shape
    g = jnp.take(pool, table.reshape(-1), axis=0)    # [B*mp, Hkv, page, Dh]
    g = g.reshape(b, mp, hkv, page, dh).transpose(0, 2, 1, 3, 4)
    return g.reshape(b, hkv, mp * page, dh)


# ---------------------------------------------------------------------------
# host-side page allocator (serving-loop boundary; never traced)
# ---------------------------------------------------------------------------
class PageAllocator:
    """Refcounted free-list over ``n_pages`` physical pages.

    ``alloc(n)`` hands out ``n`` pages (refcount 1); ``share(ids)`` adds a
    reference per page (prefix sharing: several rows' tables point at one
    page); ``free(ids)`` drops one reference per page and returns pages to
    the free list when their last reference dies (a finished row leaving a
    continuous batch).  Freed pages are handed out again LIFO — warm reuse.
    Raises ``MemoryError`` when the pool is exhausted (admission control's
    signal to stop packing rows); ``try_alloc`` is the non-raising admission
    probe.

    Continuous-batching hooks: every mid-generation ``alloc``/``free`` keeps
    ``peak_live`` — the pool's high-water mark — so a serving loop can prove
    its steady-state occupancy tracks the *sum of live sequence lengths*
    rather than ``batch x max_len`` (``stats()`` snapshots the counters;
    ``reset_peak()`` restarts the watermark, e.g. after warmup).

    Misuse (double free, share of a dead page) raises ``ValueError`` — a
    first-class error, not an ``assert``: a preemption batch frees many
    rows' page lists in one sweep, and a bookkeeping bug there must
    surface identically under ``python -O``.  ``free`` returns the number
    of pages actually RELEASED to the free list (a shared page whose
    refcount is still positive stays live), which is what a preempting
    scheduler must add back to its fit arithmetic — the refcount, not the
    length of the freed list, decides how many pages a victim donates."""

    def __init__(self, n_pages: int):
        assert n_pages > 0, n_pages
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._refs: dict = {}
        self.peak_live = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: want {n}, have {len(self._free)} "
                f"of {self.n_pages} free")
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._refs[i] = 1
        self.peak_live = max(self.peak_live, self.n_live)
        return ids

    def try_alloc(self, n: int) -> Optional[List[int]]:
        """``alloc`` that returns None instead of raising — the admission
        loop's probe: a request that doesn't fit simply stays queued."""
        if n > len(self._free):
            return None
        return self.alloc(n)

    def reset_peak(self) -> None:
        self.peak_live = self.n_live

    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "n_live": self.n_live,
                "n_free": self.n_free, "peak_live": self.peak_live}

    def refcount(self, pid: int) -> int:
        return self._refs.get(pid, 0)

    def share(self, ids: Sequence[int]) -> List[int]:
        for i in ids:
            if self._refs.get(i, 0) <= 0:
                raise ValueError(f"share of dead page {i}")
            self._refs[i] += 1
        return list(ids)

    def free(self, ids: Sequence[int]) -> int:
        """Drop one reference per id; returns how many pages were actually
        released (last reference died).  Raises ``ValueError`` on a double
        free — including a duplicate id inside ONE call whose references
        ran out mid-batch (the share-then-preempt footgun)."""
        released = 0
        for i in ids:
            if self._refs.get(i, 0) <= 0:
                raise ValueError(f"double free of page {i}")
            self._refs[i] -= 1
            if self._refs[i] == 0:
                del self._refs[i]
                self._free.append(i)
                released += 1
        return released


class SwapBlobTag(NamedTuple):
    """Provenance tag on a swapped-out page payload: which replica's pool
    it came from, the pool's container dtype, and the page size.  A blob
    is plain host numpy — nothing in its bytes says what pool layout
    produced it — so migration between replicas re-derives compatibility
    from the tag instead of silently reinterpreting bytes: same dtype +
    page size means the receiving pool can install the pages verbatim
    (disjoint pools of one fleet always match); anything else is a
    foreign blob and swap-in must refuse it."""
    replica: int
    dtype: str
    page: int


def check_blob_tag(tag: Optional[SwapBlobTag], *, dtype, page: int) -> None:
    """Reject a swap-in whose payload tag mismatches the receiving pool.

    ``tag=None`` (a pre-tagging payload, or an intra-engine resume that
    never left its pool) is accepted — the tag exists to guard CROSS-pool
    installs.  A replica-id mismatch alone is fine: migrating a payload
    to a survivor replica is the point.  Dtype or page-size mismatch
    raises ``ValueError`` — widening fp8 pages into an fp16 pool or
    re-chunking 8-token pages as 16-token pages would silently corrupt
    every migrated token."""
    if tag is None:
        return
    want_dt, want_pg = str(np.dtype(dtype)), int(page)
    got_dt, got_pg = str(np.dtype(tag.dtype)), int(tag.page)
    if got_dt != want_dt or got_pg != want_pg:
        raise ValueError(
            f"foreign swap blob refused: payload from replica "
            f"{tag.replica} is ({got_dt}, page={got_pg}) but the receiving "
            f"pool is ({want_dt}, page={want_pg}) — migrating it would "
            f"reinterpret page bytes; re-ingest the request instead")


def aggregate_stats(allocators: Sequence[PageAllocator]) -> dict:
    """Fleet-level pool stats across per-replica allocators (data-parallel
    serving: each engine replica owns a DISJOINT pool, so the totals are
    plain sums — ``peak_live`` sums because replica peaks are peaks of
    independent pools, not a max over a shared one)."""
    agg = {"n_pages": 0, "n_live": 0, "n_free": 0, "peak_live": 0}
    per = []
    for a in allocators:
        s = a.stats()
        per.append(s)
        for k in agg:
            agg[k] += s[k]
    agg["replicas"] = per
    return agg


def build_tables(alloc: PageAllocator, batch: int, max_pages: int,
                 *, shared_pages: int = 0) -> np.ndarray:
    """Allocate one ``[batch, max_pages]`` table.  The first
    ``shared_pages`` entries of every row alias ONE page run (allocated
    once, then ``share``d into rows 1..B-1) — the common-prompt prefix;
    the rest are private per row.  Only pages FULLY covered by the common
    prefix may be shared: the first partial block is written differently
    per row once decoding diverges, so callers pass
    ``shared_pages = common_prefix_len // page_size``."""
    table = np.zeros((batch, max_pages), np.int32)
    prefix = alloc.alloc(shared_pages) if shared_pages else []
    for b in range(batch):
        run = list(prefix) if b == 0 else alloc.share(prefix)
        run += alloc.alloc(max_pages - shared_pages)
        table[b] = run
    return table
