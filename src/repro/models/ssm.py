"""State-space / recurrent mixers: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

These are the sub-quadratic architectures of the assignment (zamba2-1.2b,
xlstm-1.3b).  Transprecision mapping (paper §II.B.2): all projections run
under the multi-format FMA policy (ADDMUL group); the recurrent *state* is
the accumulation destination of an expanding FMA and therefore stays in
``acc_fmt`` (f32) — exactly the paper's ``dst_fmt`` contract — while gates
and normalizers (COMP group) are computed in f32.

Each mixer ships three forms:
  *_chunked : chunkwise-parallel over the sequence (training / prefill),
              lax.scan over chunks so HLO size is O(1) in sequence length.
  *_step    : single-token recurrence against a carried state (decode).
  init_*_cache : the decode-state pytree.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import ops as tp
from .layers import bspec, dense_init, residual_spec, rmsnorm, shard

F32 = jnp.float32

# cost-extraction hook: fully unroll the sLSTM time scan so XLA's
# cost_analysis sees every step (trip-N while bodies are counted once)
_UNROLL_TIME = False


def set_unroll_time(enable: bool) -> None:
    global _UNROLL_TIME
    _UNROLL_TIME = bool(enable)


# ---------------------------------------------------------------------------
# Mamba2 — chunked SSD (zamba2 backbone)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


class Mamba2Cache(NamedTuple):
    conv: jnp.ndarray   # [B, d_conv-1, conv_dim] rolling conv window
    ssm: jnp.ndarray    # [B, H, head_dim, d_state] f32 state


def mamba2_params(key, cfg: Mamba2Config, dtype):
    ks = jax.random.split(key, 4)
    di, cd, h = cfg.d_inner, cfg.conv_dim, cfg.n_heads
    # in_proj emits [z (di), xBC (cd), dt (h)]
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * di
                              + 2 * cfg.n_groups * cfg.d_state + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, cd), F32)
                   * cfg.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((cd,), dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=F32)),   # A = -exp(A_log)
        "D": jnp.ones((h,), F32),
        "dt_bias": jnp.zeros((h,), F32),
        "norm": jnp.zeros((di,), dtype),     # gated RMSNorm before out_proj
        "out_proj": dense_init(ks[2], di, cfg.d_model, dtype),
    }


def _split_zxbcdt(zxbcdt, cfg: Mamba2Config):
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time.  xbc [B,S,C]; w [K,C]; state
    [B,K-1,C] holds the trailing window of the previous segment."""
    k = w.shape[0]
    pad = (jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
           if state is None else state.astype(xbc.dtype))
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i].astype(F32)
              for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(out + b.astype(F32)), new_state


def _segsum(x):
    """log-space segment sums: out[..., i, j] = sum_{j < k <= i} x[..., k].
    Lower-triangular; -inf above the diagonal."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]       # sum_(j, i]
    idx = jnp.arange(q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_mix(x, params, cfg: Mamba2Config, policy, *,
               cache: Optional[Mamba2Cache] = None):
    """x [B,S,D] -> (y [B,S,D], new_cache or None).

    Chunked SSD: scan over S/chunk chunks carrying the [B,H,P,N] state.
    When ``cache`` is given (decode, S small) the same code path runs with
    the cached conv window / ssm state as the initial carry.
    """
    b, s, d = x.shape
    h, p, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    zxbcdt = tp.tp_einsum("bsd,de->bse", x, params["in_proj"], policy,
                          out_fmt="fp32")
    z, xbc, dt = _split_zxbcdt(zxbcdt, cfg)
    conv_state = cache.conv if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xin = xbc[..., :cfg.d_inner].reshape(b, s, h, p)
    B_ = xbc[..., cfg.d_inner:cfg.d_inner + g * n].reshape(b, s, g, n)
    C_ = xbc[..., cfg.d_inner + g * n:].reshape(b, s, g, n)
    # broadcast groups to heads
    rep = h // g
    Bh = jnp.repeat(B_, rep, axis=2)                  # [B,S,H,N]
    Ch = jnp.repeat(C_, rep, axis=2)
    A = -jnp.exp(params["A_log"])                     # [H], negative
    dt = jax.nn.softplus(dt + params["dt_bias"])      # [B,S,H]

    xin = shard(xin, bspec(None, "model", None))
    q = min(cfg.chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    # chunked layout [B, nc, q, ...] -> scan over nc
    xc = xin.reshape(b, nc, q, h, p)
    Bc = Bh.reshape(b, nc, q, h, n)
    Cc = Ch.reshape(b, nc, q, h, n)
    dtc = dt.reshape(b, nc, q, h)

    init = (cache.ssm.astype(F32) if cache is not None
            else jnp.zeros((b, h, p, n), F32))

    def chunk_step(state, inp):
        xq, bq, cq, dq = inp                          # [B,q,H,*]
        da = dq * A                                   # [B,q,H] log-decay
        da_t = da.transpose(0, 2, 1)                  # [B,H,q]
        L = jnp.exp(_segsum(da_t))                    # [B,H,q,q]
        # intra-chunk: Y[i] = sum_j<=i (C_i . B_j) L_ij dt_j x_j
        cb = tp.tp_einsum("bihn,bjhn->bhij", cq, bq, policy, out_fmt="fp32")
        w = cb * L * dq.transpose(0, 2, 1)[:, :, None, :]
        y_intra = tp.tp_einsum("bhij,bjhp->bihp", w, xq, policy,
                               out_fmt="fp32")
        # inter-chunk: contribution of the carried state
        cumda = jnp.cumsum(da_t, axis=-1)             # [B,H,q]
        y_inter = tp.tp_einsum("bihn,bhpn->bihp", cq, state, policy,
                               out_fmt="fp32")
        y = y_intra + y_inter * jnp.exp(cumda).transpose(0, 2, 1)[..., None]
        # state update: S' = exp(sum da) S + sum_j exp(sum_{k>j} da) dt_j x_j B_j^T
        total = cumda[..., -1]                        # [B,H]
        decay_j = jnp.exp(total[..., None] - cumda)   # [B,H,q]
        wx = xq * (dq * decay_j.transpose(0, 2, 1))[..., None]
        new_state = (state * jnp.exp(total)[..., None, None]
                     + tp.tp_einsum("bjhp,bjhn->bhpn", wx, bq, policy,
                                    out_fmt="fp32"))
        return new_state, y

    xs = (xc.transpose(1, 0, 2, 3, 4), Bc.transpose(1, 0, 2, 3, 4),
          Cc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3))
    final_state, ys = jax.lax.scan(chunk_step, init, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * q, h, p)[:, :s]
    y = y + xc.reshape(b, nc * q, h, p)[:, :s] * params["D"][:, None]
    y = y.reshape(b, s, cfg.d_inner)
    # gated RMSNorm (Mamba2 norm_before_gate=False): norm(y * silu(z))
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = tp.tp_einsum("bse,ed->bsd", y, params["out_proj"], policy)
    new_cache = Mamba2Cache(new_conv.astype(
        cache.conv.dtype if cache is not None else jnp.bfloat16),
        final_state) if cache is not None else None
    return shard(out, residual_spec()), new_cache


def init_mamba2_cache(batch, cfg: Mamba2Config, dtype):
    return Mamba2Cache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
        ssm=jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), F32))


# ---------------------------------------------------------------------------
# mLSTM — matrix-memory LSTM, chunkwise parallel (xLSTM)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    n_heads: int = 4
    proj_factor: float = 2.0
    d_conv: int = 4
    chunk: int = 128
    # beyond-paper: materialize the intra-chunk [q, q] gate/weight tensors
    # in bf16 (log-space stabilizers stay f32) — halves the dominant HBM
    # term of the chunkwise mLSTM
    narrow_intra: bool = False

    @property
    def d_inner(self) -> int:
        return int(self.proj_factor * self.d_model)

    @property
    def head_dim(self) -> int:
        assert self.d_inner % self.n_heads == 0
        return self.d_inner // self.n_heads


class MLSTMCache(NamedTuple):
    conv: jnp.ndarray    # [B, d_conv-1, d_inner]
    c: jnp.ndarray       # [B, H, dk, dv] matrix memory (f32)
    nrm: jnp.ndarray     # [B, H, dk] normalizer (f32)
    m: jnp.ndarray       # [B, H] log-stabilizer (f32)


def mlstm_params(key, cfg: MLSTMConfig, dtype):
    ks = jax.random.split(key, 8)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "up_proj": dense_init(ks[0], d, 2 * di, dtype),    # x branch + z gate
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di), F32)
                   * cfg.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        # headwise (block-diagonal) projections, as in the xLSTM release.
        # q/k are REPLICATED across the model axis (small; keeps the
        # intra-chunk qk^T contraction collective-free — §Perf iteration),
        # v stays column-sharded.
        "wq_h": (jax.random.normal(ks[2], (h, cfg.head_dim, cfg.head_dim),
                                   F32) * cfg.head_dim ** -0.5).astype(dtype),
        "wk_h": (jax.random.normal(ks[3], (h, cfg.head_dim, cfg.head_dim),
                                   F32) * cfg.head_dim ** -0.5).astype(dtype),
        "wv_h": (jax.random.normal(ks[4], (h, cfg.head_dim, cfg.head_dim),
                                   F32) * cfg.head_dim ** -0.5).astype(dtype),
        "w_if": dense_init(ks[5], di, 2 * h, dtype),       # i/f gate heads
        "b_if": jnp.concatenate([jnp.zeros((h,), F32),
                                 jnp.linspace(3.0, 6.0, h)]).astype(F32),
        "ln": jnp.zeros((di,), dtype),                     # per-head out norm
        "down_proj": dense_init(ks[6], di, d, dtype),
    }


def mlstm_mix(x, params, cfg: MLSTMConfig, policy, *,
              cache: Optional[MLSTMCache] = None):
    """Chunkwise-parallel mLSTM with log-space gate stabilization.

    Within a chunk, attention-like weights W_ij = exp(F_i - F_j + logi_j - m)
    give the intra-chunk term; the inter-chunk term reads the carried matrix
    memory C.  All state math in f32 (the expanding-FMA destination)."""
    b, s, d = x.shape
    h, dk = cfg.n_heads, cfg.head_dim
    act_fmt = "fp16alt" if cfg.narrow_intra else "fp32"
    up = tp.tp_einsum("bsd,de->bse", x, params["up_proj"], policy,
                      out_fmt=act_fmt)
    xb, z = up[..., :cfg.d_inner], up[..., cfg.d_inner:]
    conv_state = cache.conv if cache is not None else None
    xc, new_conv = _causal_conv(xb, params["conv_w"], params["conv_b"],
                                conv_state)
    xc = xc.astype(up.dtype)
    xch = xc.reshape(b, s, h, dk)
    xbh = xb.reshape(b, s, h, dk)
    q = tp.tp_einsum("bshe,hef->bshf", xch, params["wq_h"], policy,
                     out_fmt=act_fmt)
    k = tp.tp_einsum("bshe,hef->bshf", xch, params["wk_h"], policy,
                     out_fmt=act_fmt) * dk ** -0.5
    v = tp.tp_einsum("bshe,hef->bshf", xbh, params["wv_h"], policy,
                     out_fmt=act_fmt)
    gates = (tp.tp_einsum("bse,eg->bsg", xb, params["w_if"], policy,
                          out_fmt="fp32") + params["b_if"])
    logi = gates[..., :h]                             # [B,S,H] log input gate
    logf = jax.nn.log_sigmoid(gates[..., h:])         # log forget gate

    # inner chunk tensors are batch-sharded ONLY (model-replicated): any
    # model sharding here reshards every chunk of the state scan
    q = shard(q, bspec(None, None, None))
    k = shard(k, bspec(None, None, None))
    v = shard(v, bspec(None, None, None))

    qq = min(cfg.chunk, s)
    nc = -(-s // qq)
    pad = nc * qq - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(t, extra):
        return t.reshape((b, nc, qq) + extra).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(extra))))

    qc, kc, vc = (to_chunks(t, (h, dk)) for t in (q, k, v))
    ic = to_chunks(logi, (h,))
    fc = to_chunks(logf, (h,))

    if cache is not None:
        init = (cache.c.astype(F32), cache.nrm.astype(F32),
                cache.m.astype(F32))
    else:
        init = (jnp.zeros((b, h, dk, dk), F32), jnp.zeros((b, h, dk), F32),
                jnp.full((b, h), -1e30, F32))

    def chunk_step(carry, inp):
        C, nrm, m = carry
        qi, ki, vi, li, fi = inp                      # [B,q,H,*], [B,q,H]
        fT = fi.transpose(0, 2, 1)                    # [B,H,q]
        lT = li.transpose(0, 2, 1)
        F_cum = jnp.cumsum(fT, axis=-1)               # [B,H,q] sum_{k<=i} logf
        # intra log-weights D_ij = F_i - F_j + logi_j  (j <= i)
        D = _segsum(fT) + lT[:, :, None, :]           # [B,H,q,q]
        # stabilizers
        m_intra = jnp.max(D, axis=-1)                 # [B,H,q]
        m_inter = F_cum + m[..., None]                # [B,H,q]
        m_i = jnp.maximum(m_intra, m_inter)
        intra_dt = jnp.bfloat16 if cfg.narrow_intra else F32
        W = jnp.exp(D - m_i[..., None]).astype(intra_dt)  # [B,H,q,q]
        qk = tp.tp_einsum("bihe,bjhe->bhij", qi, ki, policy,
                          out_fmt="fp16alt" if cfg.narrow_intra else "fp32")
        wq_ = (W * qk).astype(intra_dt)
        h_intra = tp.tp_einsum("bhij,bjhe->bihe", wq_, vi, policy,
                               out_fmt="fp32")
        inter_scale = jnp.exp(m_inter - m_i)          # [B,H,q]
        h_inter = tp.tp_einsum("bihe,bhef->bihf", qi, C, policy,
                               out_fmt="fp32") * inter_scale.transpose(
                                   0, 2, 1)[..., None]
        # normalizer: n_i = sum_j W_ij (q_i . k_j-dir) ... per xLSTM:
        # n = max(|sum_j w_ij|, exp(-m)) with w = W @ (q.k) row sums
        n_intra = jnp.sum(wq_.astype(F32), axis=-1)   # [B,H,q]
        n_inter = tp.tp_einsum("bihe,bhe->bhi", qi, nrm, policy,
                               out_fmt="fp32") * inter_scale
        n_i = n_intra + n_inter                       # [B,H,q]
        denom = jnp.maximum(jnp.abs(n_i), jnp.exp(-m_i))
        h_out = (h_intra + h_inter) / denom.transpose(0, 2, 1)[..., None]
        # carry update
        F_tot = F_cum[..., -1]                        # [B,H]
        m_new = jnp.maximum(F_tot + m, jnp.max(lT + (F_tot[..., None] - F_cum),
                                               axis=-1))
        kv_scale = jnp.exp(lT + F_tot[..., None] - F_cum - m_new[..., None])
        kw = ki * kv_scale.transpose(0, 2, 1)[..., None]
        C_new = (C * jnp.exp(F_tot + m - m_new)[..., None, None]
                 + tp.tp_einsum("bjhe,bjhf->bhef", kw, vi, policy,
                                out_fmt="fp32"))
        nrm_new = (nrm * jnp.exp(F_tot + m - m_new)[..., None]
                   + jnp.sum(kw, axis=1))
        return (C_new, nrm_new, m_new), h_out

    (C_f, n_f, m_f), ys = jax.lax.scan(chunk_step, init, (qc, kc, vc, ic, fc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * qq, h, dk)[:, :s]
    y = y.reshape(b, s, cfg.d_inner)
    y = rmsnorm(y, params["ln"])
    y = y * jax.nn.silu(z)                            # output gate branch
    out = tp.tp_einsum("bse,ed->bsd", y, params["down_proj"], policy)
    new_cache = (MLSTMCache(new_conv.astype(cache.conv.dtype), C_f, n_f, m_f)
                 if cache is not None else None)
    return shard(out, residual_spec()), new_cache


def init_mlstm_cache(batch, cfg: MLSTMConfig, dtype):
    h, dk = cfg.n_heads, cfg.head_dim
    return MLSTMCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        c=jnp.zeros((batch, h, dk, dk), F32),
        nrm=jnp.zeros((batch, h, dk), F32),
        m=jnp.full((batch, h), -1e30, F32))


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory LSTM with exponential gating (xLSTM)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    n_heads: int = 4
    proj_factor: float = 4.0 / 3.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


class SLSTMCache(NamedTuple):
    c: jnp.ndarray    # [B, D] cell
    nrm: jnp.ndarray  # [B, D] normalizer
    m: jnp.ndarray    # [B, D] stabilizer
    h: jnp.ndarray    # [B, D] hidden (recurrent input)


def slstm_params(key, cfg: SLSTMConfig, dtype):
    ks = jax.random.split(key, 4)
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    dff = int(cfg.proj_factor * d)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),     # i,f,z,o from x
        # block-diagonal recurrent matrix, one [dh, dh] block per head
        "r_gates": (jax.random.normal(ks[1], (4, h, dh, dh), F32)
                    * dh ** -0.5).astype(dtype),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,), F32), jnp.linspace(3.0, 6.0, d),
             jnp.zeros((2 * d,), F32)]).astype(F32),
        "ln": jnp.zeros((d,), dtype),
        "up": dense_init(ks[2], d, 2 * dff, dtype),        # gated FFN after
        "down": dense_init(ks[3], dff, d, dtype),
    }


def slstm_mix(x, params, cfg: SLSTMConfig, policy, *,
              cache: Optional[SLSTMCache] = None):
    """Sequential scan over time (the sLSTM's memory mixing is inherently
    recurrent — paper DIVSQRT-style latency/throughput trade, kept exact)."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    gx = tp.tp_einsum("bsd,dg->bsg", x, params["w_gates"], policy,
                      out_fmt="fp32") + params["b_gates"]
    if cache is not None:
        init = (cache.c.astype(F32), cache.nrm.astype(F32),
                cache.m.astype(F32), cache.h.astype(F32))
    else:
        zeros = jnp.zeros((b, d), F32)
        init = (zeros, zeros, jnp.full((b, d), -1e30, F32), zeros)
    r = params["r_gates"].astype(F32)

    def step(carry, g_t):
        c, nrm, m, h_prev = carry
        hp = h_prev.reshape(b, h, dh)
        rec = jnp.einsum("bhe,ghef->bghf", hp, r).reshape(b, 4 * d)
        g = g_t + rec
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(gf + m, gi)
        i_ = jnp.exp(gi - m_new)
        f_ = jnp.exp(gf + m - m_new)
        c_new = f_ * c + i_ * jnp.tanh(gz)
        n_new = jnp.maximum(f_ * nrm + i_, jnp.exp(-m_new))
        h_new = jax.nn.sigmoid(go) * c_new / n_new
        return (c_new, n_new, m_new, h_new), h_new

    (c_f, n_f, m_f, h_f), ys = jax.lax.scan(step, init,
                                            gx.transpose(1, 0, 2),
                                            unroll=True if _UNROLL_TIME
                                            else 1)
    y = ys.transpose(1, 0, 2)                         # [B,S,D]
    y = rmsnorm(y, params["ln"])
    # gated FFN tail (part of the sLSTM block in xLSTM)
    uu = tp.tp_einsum("bsd,df->bsf", y, params["up"], policy)
    dff = uu.shape[-1] // 2
    y = tp.tp_elementwise("gelu", uu[..., :dff], policy=policy) \
        * uu[..., dff:]
    out = tp.tp_einsum("bsf,fd->bsd", y, params["down"], policy)
    new_cache = (SLSTMCache(c_f, n_f, m_f, h_f) if cache is not None
                 else None)
    return shard(out, residual_spec()), new_cache


def init_slstm_cache(batch, cfg: SLSTMConfig, dtype):
    d = cfg.d_model
    zeros = jnp.zeros((batch, d), F32)
    return SLSTMCache(zeros, zeros, jnp.full((batch, d), -1e30, F32), zeros)
