"""Unified model composition for all assigned architectures.

A model is: embedding (+ optional modality-frontend stub) -> a stack of
layers described by ``cfg.prefix + cfg.pattern * repeats + cfg.suffix``
(the pattern part runs under ``lax.scan`` with stacked weights, keeping HLO
size O(1) in depth) -> final norm -> (tied) unembedding with chunked
cross-entropy.

Three entry points per model (the shapes of the assignment):
  ``forward_train``  — [B, S] tokens -> scalar loss (train_4k)
  ``prefill``        — [B, S] tokens -> (last-token logits, caches)  (prefill_32k)
  ``decode_step``    — one token + caches -> (logits, caches)  (decode_32k/long_500k)

plus the continuous-batching steps driven by ``launch/engine.py``:
  ``prefill_chunk``  — one prompt chunk into existing paged caches
  ``decode_round``   — one decode round over every batch slot
  ``decode_burst``   — a ``while_loop`` of rounds, exiting on any finish
and ``generate(loop="while")``, the early-exit single-shot form (with
repetition/presence penalties riding the carry — ``apply_penalties``).

Transprecision: every matmul routes through core.ops under the active
PrecisionPolicy; caches store in ``policy.kv_fmt``; softmax/norm/router
stay f32 (FPnew's COMP group).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import EncoderConfig, LayerSpec, ModelConfig
from ..core import ops as tp
from ..core.policy import PrecisionPolicy, get_policy
from . import attention as attn
from . import moe as moe_mod
from . import paged
from . import ssm
from .layers import (batch_axes, bspec, dense_init, embed_init, gelu_mlp,
                     layernorm, mlp_params, param_dtype, residual_spec,
                     rmsnorm, shard, softcap, swiglu)

F32 = jnp.float32

#: embeddings/unembeddings are padded to a multiple of this so the vocab
#: dimension shards evenly over any production model axis (16) and stays
#: MXU-lane aligned (128) — standard practice (MaxText etc.); the pad tail
#: is masked to -inf in logits and never trained or sampled.
VOCAB_PAD = 256


def padded_vocab(vocab: int) -> int:
    return -(-vocab // VOCAB_PAD) * VOCAB_PAD


def sample_token(lg, key, *, temperature: float = 0.0,
                 top_k: Optional[int] = None,
                 top_p: Optional[float] = None):
    """One sampling step: logits [B, V] -> token ids [B] (int32).

    ``temperature <= 0`` is greedy argmax — bit-identical to the
    pre-sampling decode path (``key`` is ignored, so XLA dead-code-
    eliminates the PRNG plumbing).  Otherwise: temperature scaling, then
    optional top-k truncation, then optional nucleus (top-p) truncation —
    the smallest prefix of the sorted distribution whose mass reaches
    ``top_p`` is kept (always >= 1 token) — then a categorical draw.
    Truncated logits go to a large negative (not -inf: the vocab pad tail
    is already masked at -1e30 and stays unsampleable)."""
    lg = lg.astype(F32)
    if temperature is None or temperature <= 0.0:
        return jnp.argmax(lg, -1).astype(jnp.int32)
    lg = lg / temperature
    if top_k is not None and top_k > 0:
        kth = jax.lax.top_k(lg, min(top_k, lg.shape[-1]))[0][..., -1:]
        lg = jnp.where(lg < kth, -1e30, lg)
    if top_p is not None and top_p < 1.0:
        srt = jnp.sort(lg, axis=-1)[..., ::-1]
        prob = jax.nn.softmax(srt, axis=-1)
        exclusive_mass = jnp.cumsum(prob, axis=-1) - prob
        keep = exclusive_mass < top_p           # first token always kept
        kth = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
        lg = jnp.where(lg < kth, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def apply_penalties(lg, counts, *, repetition_penalty: Optional[float] = None,
                    presence_penalty: Optional[float] = None):
    """Repetition/presence penalties on logits [B, V] from per-row token
    counts [B, V] (prompt + everything emitted so far).

    ``repetition_penalty`` (HF semantics, > 1 discourages): seen tokens'
    logits are divided by the penalty when positive, multiplied when
    negative.  ``presence_penalty`` (OpenAI semantics, > 0 discourages): a
    flat subtraction for every seen token.  Both key off *presence*
    (count > 0), are applied to the raw logits BEFORE temperature/top-k/
    top-p, and leave unseen tokens untouched — ``None``/neutral knobs are
    static, so the default graph carries no count state at all."""
    lg = lg.astype(F32)
    seen = counts > 0
    if repetition_penalty is not None and repetition_penalty != 1.0:
        rp = jnp.asarray(repetition_penalty, F32)
        lg = jnp.where(seen, jnp.where(lg > 0, lg / rp, lg * rp), lg)
    if presence_penalty is not None and presence_penalty != 0.0:
        lg = lg - jnp.asarray(presence_penalty, F32) * seen.astype(F32)
    return lg


def token_counts(tokens, vocab: int, prompt_lens=None):
    """Per-row token histogram [B, vocab] int32 of a (right-padded) prompt
    [B, S] — the count state penalties start from.  ``prompt_lens`` masks
    each row's pad tail out of the histogram (pad slots are not 'seen')."""
    b, s = tokens.shape
    live = jnp.ones((b, s), jnp.int32)
    if prompt_lens is not None:
        live = (jnp.arange(s)[None, :]
                < jnp.reshape(jnp.asarray(prompt_lens, jnp.int32),
                              (-1, 1))).astype(jnp.int32)
    cnt = jnp.zeros((b, vocab), jnp.int32)
    return cnt.at[jnp.arange(b)[:, None], tokens].add(live)


def _bump_counts(cnt, tok):
    """counts [B, V] += 1 at each row's emitted token [B, 1]."""
    b = cnt.shape[0]
    return cnt.at[jnp.arange(b), tok[:, 0]].add(1)


def sanitize_logits(lg):
    """Non-finite logits guard: NaN/Inf entries go to the same large
    negative the vocab pad tail uses (unsampleable), and each poisoned row
    is flagged.  Returns ``(clean [..., V], bad [...])`` — ``bad`` is True
    where ANY entry of the row was non-finite.  On finite logits the mask
    is a no-op, so guarded and unguarded sampling stay bit-identical; on a
    fully-poisoned row every logit collapses to the floor and argmax
    deterministically picks token 0 — callers decide whether that flag is
    fatal (fail fast) or counted (fault-harness mask-and-flag)."""
    lg = lg.astype(F32)
    finite = jnp.isfinite(lg)
    return jnp.where(finite, lg, -1e30), ~jnp.all(finite, axis=-1)


def _is_paged_leaf(x) -> bool:
    return isinstance(x, paged.PagedKVCache)


def _caches_table_view(caches: "Caches", rows):
    """View of paged ``caches`` whose block tables hold only the batch
    slots ``rows`` (a traced [] or [m] int32 — an admission wave): pools
    are shared, so subset-row prefill writes scatter into the full pool
    while reads see only those rows' pages.  Stacked pattern caches
    gather along their batch axis (second-to-last of the
    [R, B, max_pages] table)."""
    rows = jnp.atleast_1d(jnp.asarray(rows, jnp.int32))
    def one(c):
        if not _is_paged_leaf(c):
            return c
        tbl = jnp.take(c.block_table, rows, axis=c.block_table.ndim - 2)
        return paged.PagedKVCache(c.k_pool, c.v_pool, tbl)
    return jax.tree.map(one, caches, is_leaf=_is_paged_leaf)


def _caches_adopt_tables(new: "Caches", orig: "Caches"):
    """Updated pools from ``new``, block tables from ``orig`` (undo a
    row view after a single-row prefill chunk)."""
    def two(n, o):
        if not _is_paged_leaf(n):
            return n
        return paged.PagedKVCache(n.k_pool, n.v_pool, o.block_table)
    return jax.tree.map(two, new, orig, is_leaf=_is_paged_leaf)


def caches_with_table(caches: "Caches", table):
    """Swap a fresh [B, max_pages] block table into every paged layer
    cache (stacked pattern caches broadcast it over their repeat axis) —
    the serving loop's admission/recycling hook.  Tables are traced
    values, so swapping between compiled steps never retraces."""
    table = jnp.asarray(table, jnp.int32)
    def one(c):
        if not _is_paged_leaf(c):
            return c
        return paged.PagedKVCache(c.k_pool, c.v_pool,
                                  jnp.broadcast_to(table,
                                                   c.block_table.shape))
    return jax.tree.map(one, caches, is_leaf=_is_paged_leaf)


def _norm(x, p, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layernorm(x, p["g"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["g"], cfg.norm_eps)


def _norm_params(cfg: ModelConfig, dtype):
    p = {"g": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------
def init_layer(key, spec: LayerSpec, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    p: dict = {"norm1": _norm_params(cfg, dtype)}
    if spec.mixer == "gqa":
        p["attn"] = attn.gqa_params(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, dtype,
                                    qk_norm=spec.qk_norm)
    elif spec.mixer == "mla":
        p["attn"] = attn.mla_params(
            ks[0], cfg.d_model, cfg.n_heads, q_lora=cfg.q_lora,
            kv_lora=cfg.kv_lora, nope_dim=cfg.nope_dim,
            rope_dim=cfg.rope_dim, v_head_dim=cfg.v_head_dim, dtype=dtype)
    elif spec.mixer == "mamba2":
        p["attn"] = ssm.mamba2_params(ks[0], cfg.mamba, dtype)
    elif spec.mixer == "mlstm":
        p["attn"] = ssm.mlstm_params(ks[0], cfg.mlstm, dtype)
    elif spec.mixer == "slstm":
        p["attn"] = ssm.slstm_params(ks[0], cfg.slstm, dtype)
    elif spec.mixer in ("shared_attn", "none"):
        pass  # shared params live at top level / no mixer
    else:
        raise ValueError(spec.mixer)

    if spec.cross_attn:
        p["xattn"] = attn.gqa_params(ks[1], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, dtype)
        p["norm_x"] = _norm_params(cfg, dtype)

    if spec.ffn in ("swiglu", "gelu"):
        p["mlp"] = mlp_params(ks[2], cfg.d_model, cfg.d_ff, dtype,
                              kind=spec.ffn if spec.ffn == "swiglu" else "gelu")
        p["norm2"] = _norm_params(cfg, dtype)
    elif spec.ffn == "moe":
        p["mlp"] = moe_mod.moe_params(ks[2], cfg.d_model, cfg.moe, dtype)
        p["norm2"] = _norm_params(cfg, dtype)
    if spec.post_norms:
        p["post1"] = _norm_params(cfg, dtype)
        if spec.ffn != "none":
            p["post2"] = _norm_params(cfg, dtype)
    return p


def init_shared_block(key, cfg: ModelConfig, dtype):
    """zamba2: one attention+MLP block whose weights are reused at every
    shared_attn position."""
    sb = cfg.shared_block
    ks = jax.random.split(key, 2)
    p = {"norm1": _norm_params(cfg, dtype),
         "attn": attn.gqa_params(ks[0], cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim, dtype),
         "norm2": _norm_params(cfg, dtype),
         "mlp": mlp_params(ks[1], cfg.d_model, cfg.d_ff, dtype,
                           kind=sb.ffn if sb.ffn == "swiglu" else "gelu")}
    return p


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     max_len: int, policy: PrecisionPolicy,
                     page_table=None, n_pages: Optional[int] = None):
    kv_dtype = attn.kv_store_dtype(policy)
    c: dict = {}
    if spec.mixer in ("gqa", "shared_attn"):
        if cfg.paged_kv:
            c["kv"] = paged.init_paged_kv_cache(
                batch, cfg.n_kv_heads, max_len, cfg.page_size, cfg.head_dim,
                kv_dtype, block_table=page_table, n_pages=n_pages)
        else:
            c["kv"] = attn.init_kv_cache(batch, cfg.n_kv_heads, max_len,
                                         cfg.head_dim, kv_dtype)
    elif spec.mixer == "mla":
        c["kv"] = attn.init_mla_cache(batch, max_len, cfg.kv_lora,
                                      cfg.rope_dim, kv_dtype)
    elif spec.mixer == "mamba2":
        c["kv"] = ssm.init_mamba2_cache(batch, cfg.mamba, kv_dtype)
    elif spec.mixer == "mlstm":
        c["kv"] = ssm.init_mlstm_cache(batch, cfg.mlstm, kv_dtype)
    elif spec.mixer == "slstm":
        c["kv"] = ssm.init_slstm_cache(batch, cfg.slstm, kv_dtype)
    if spec.cross_attn:
        enc_len = cfg.encoder.n_frames
        c["xkv"] = attn.init_kv_cache(batch, cfg.n_kv_heads, enc_len,
                                      cfg.head_dim, kv_dtype)
    return c


class Caches(NamedTuple):
    prefix: Tuple
    pattern: Any          # stacked [R, ...] pytree
    suffix: Tuple


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                policy: PrecisionPolicy, page_table=None,
                n_pages: Optional[int] = None) -> Caches:
    """``page_table`` / ``n_pages`` (paged mode): every attention layer's
    ``PagedKVCache`` adopts the SAME [B, max_pages] table (allocation is
    symmetric across layers — each layer's pool grows identically), each
    with its own page pool.  ``None`` builds the identity (unshared)
    table."""
    mk = lambda spec: init_layer_cache(spec, cfg, batch, max_len, policy,
                                       page_table=page_table,
                                       n_pages=n_pages)
    pattern_one = tuple(mk(s) for s in cfg.pattern)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.repeats,) + x.shape),
        pattern_one)
    return Caches(prefix=tuple(mk(s) for s in cfg.prefix),
                  pattern=stacked,
                  suffix=tuple(mk(s) for s in cfg.suffix))


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------
def apply_layer(x, p, spec: LayerSpec, cfg: ModelConfig,
                policy: PrecisionPolicy, *, positions, mesh=None,
                cache=None, cache_pos=None, enc_states=None,
                shared_params=None, decode: bool = False, kv_len=None,
                esc_fmts=None, kv_levels=None, kv_scale=None,
                verify: bool = False):
    """Returns (x, new_cache, aux_loss) — with a fourth element
    ``kv_flags`` [B, 2] (per-row OF/UF write-flag counts) when
    ``esc_fmts`` is given (escalation write path; GQA mixers only, other
    mixers contribute zeros).  ``kv_len``/``cache_pos`` may be
    per-sequence [B] vectors (ragged batches) — attention mixers mask and
    write per row; SSM mixers have no length axis and ignore them."""
    aux = jnp.zeros((), F32)
    new_cache: dict = {}
    kv_flags = None
    rs = cfg.residual_scale

    ap = shared_params if spec.mixer == "shared_attn" else p
    h = _norm(x, ap["norm1"], cfg)
    kv_cache = cache.get("kv") if cache else None

    if spec.mixer in ("gqa", "shared_attn"):
        esc_kw = ({} if esc_fmts is None else
                  dict(esc_fmts=esc_fmts, kv_levels=kv_levels,
                       kv_scale=kv_scale))
        r = attn.gqa_attention(
            h, ap["attn"], policy, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            positions=positions, causal=True, window=spec.window,
            attn_softcap=spec.attn_softcap, rope_theta=cfg.rope_theta,
            qk_norm=spec.qk_norm, norm_eps=cfg.norm_eps,
            cache=kv_cache, cache_pos=cache_pos, use_rope=spec.use_rope,
            chunk=cfg.attn_chunk, windowed_slice=cfg.windowed_slice,
            decode_backend=cfg.decode_backend,
            prefill_backend=cfg.prefill_backend, kv_len=kv_len, mesh=mesh,
            verify=verify, **esc_kw)
        if esc_fmts is not None:
            mix, nc, kv_flags = r
        else:
            mix, nc = r
    elif spec.mixer == "mla":
        mix, nc = attn.mla_attention(
            h, ap["attn"], policy, n_heads=cfg.n_heads, nope_dim=cfg.nope_dim,
            rope_dim=cfg.rope_dim, v_head_dim=cfg.v_head_dim,
            positions=positions, rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps, cache=kv_cache, cache_pos=cache_pos,
            chunk=cfg.attn_chunk, prefill_backend=cfg.prefill_backend,
            kv_len=kv_len)
    elif spec.mixer == "mamba2":
        mix, nc = ssm.mamba2_mix(h, ap["attn"], cfg.mamba, policy,
                                 cache=kv_cache)
    elif spec.mixer == "mlstm":
        mix, nc = ssm.mlstm_mix(h, ap["attn"], cfg.mlstm, policy,
                                cache=kv_cache)
    elif spec.mixer == "slstm":
        mix, nc = ssm.slstm_mix(h, ap["attn"], cfg.slstm, policy,
                                cache=kv_cache)
    elif spec.mixer == "none":
        mix, nc = jnp.zeros_like(x), None
    else:
        raise ValueError(spec.mixer)

    if nc is not None:
        new_cache["kv"] = nc
    if spec.post_norms:
        mix = _norm(mix, p["post1"], cfg)
    x = x + rs * mix

    if spec.cross_attn:
        hx = _norm(x, p["norm_x"], cfg)
        if enc_states is not None:
            # prefill / train: compute cross K/V from encoder states
            mixx, xkv = attn.gqa_attention(
                hx, p["xattn"], policy, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                positions=positions, causal=False, use_rope=False,
                kv_states=enc_states,
                cache=cache.get("xkv") if cache else None, cache_pos=0,
                mesh=mesh)
        else:
            # decode: attend against the cached cross K/V
            mixx = attn.cross_attend_cached(
                hx, p["xattn"], cache["xkv"], policy, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim)
            xkv = cache["xkv"]
        if cache is not None:
            new_cache["xkv"] = xkv if xkv is not None else cache["xkv"]
        x = x + rs * mixx

    if spec.ffn != "none":
        fp = shared_params if spec.mixer == "shared_attn" else p
        h2 = _norm(x, fp["norm2"], cfg)
        if spec.ffn == "swiglu" or (spec.mixer == "shared_attn"
                                    and cfg.shared_block.ffn == "swiglu"):
            f = swiglu(h2, fp["mlp"]["gate"], fp["mlp"]["up"],
                       fp["mlp"]["down"], policy)
        elif spec.ffn == "gelu":
            f = gelu_mlp(h2, fp["mlp"]["up"], fp["mlp"]["b_up"],
                         fp["mlp"]["down"], fp["mlp"]["b_down"], policy)
        elif spec.ffn == "moe":
            f, aux = moe_mod.moe_block(h2, fp["mlp"], cfg.moe, policy,
                                       mesh=mesh)
        else:
            raise ValueError(spec.ffn)
        if spec.post_norms:
            f = _norm(f, p["post2"], cfg)
        x = x + rs * f
    if esc_fmts is not None:
        if kv_flags is None:
            kv_flags = jnp.zeros((x.shape[0], 2), jnp.int32)
        return x, (new_cache if new_cache else None), aux, kv_flags
    return x, (new_cache if new_cache else None), aux


# ---------------------------------------------------------------------------
# whisper-style encoder
# ---------------------------------------------------------------------------
def init_encoder(key, cfg: ModelConfig, dtype):
    e = cfg.encoder
    ks = jax.random.split(key, e.n_layers + 2)
    head_dim = cfg.d_model // e.n_heads
    layers = []
    for i in range(e.n_layers):
        kk = jax.random.split(ks[i], 2)
        layers.append({
            "norm1": _norm_params(cfg, dtype),
            "attn": attn.gqa_params(kk[0], cfg.d_model, e.n_heads,
                                    e.n_heads, head_dim, dtype),
            "norm2": _norm_params(cfg, dtype),
            "mlp": mlp_params(kk[1], cfg.d_model, e.d_ff, dtype, kind="gelu"),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {"layers": stacked,
            "pos": (jax.random.normal(ks[-1], (e.n_frames, cfg.d_model), F32)
                    * 0.01).astype(dtype),
            "norm_f": _norm_params(cfg, dtype)}


def encode(frame_embeds, enc_params, cfg: ModelConfig,
           policy: PrecisionPolicy):
    e = cfg.encoder
    head_dim = cfg.d_model // e.n_heads
    x = frame_embeds + enc_params["pos"].astype(frame_embeds.dtype)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        a, _ = attn.gqa_attention(
            _norm(h, lp["norm1"], cfg), lp["attn"], policy,
            n_heads=e.n_heads, n_kv_heads=e.n_heads, head_dim=head_dim,
            positions=positions, causal=False, use_rope=False)
        h = h + a
        f = gelu_mlp(_norm(h, lp["norm2"], cfg), lp["mlp"]["up"],
                     lp["mlp"]["b_up"], lp["mlp"]["down"],
                     lp["mlp"]["b_down"], policy)
        return h + f, None

    x, _ = jax.lax.scan(body, x, enc_params["layers"],
                        unroll=True if cfg.unroll_scan else 1)
    return _norm(x, enc_params["norm_f"], cfg)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    policy: PrecisionPolicy

    def with_cfg(self, **overrides) -> "Model":
        """Copy of this model with config fields replaced (e.g.
        ``model.with_cfg(decode_backend="pallas")``)."""
        return dataclasses.replace(
            self, cfg=dataclasses.replace(self.cfg, **overrides))

    # -- init ------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = param_dtype(self.policy)
        n_keys = len(cfg.prefix) + len(cfg.suffix) + cfg.repeats * len(
            cfg.pattern) + 4
        ks = list(jax.random.split(key, n_keys))
        vpad = padded_vocab(cfg.vocab)
        params: dict = {
            "embed": embed_init(ks.pop(), vpad, cfg.d_model, dtype),
            "norm_f": _norm_params(cfg, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks.pop(), cfg.d_model, vpad,
                                           dtype)
        if cfg.max_seq:
            params["pos_embed"] = (jax.random.normal(
                ks.pop(), (cfg.max_seq, cfg.d_model), F32) * 0.01).astype(dtype)
        params["prefix"] = tuple(
            init_layer(ks.pop(), s, cfg, dtype) for s in cfg.prefix)
        params["suffix"] = tuple(
            init_layer(ks.pop(), s, cfg, dtype) for s in cfg.suffix)
        # stacked pattern params [R, ...]
        groups = []
        for _ in range(cfg.repeats):
            groups.append(tuple(init_layer(ks.pop(), s, cfg, dtype)
                                for s in cfg.pattern))
        params["pattern"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
        if cfg.shared_block is not None:
            params["shared"] = init_shared_block(ks.pop(), cfg, dtype)
        if cfg.encoder is not None:
            params["encoder"] = init_encoder(ks.pop(), cfg, dtype)
        return params

    # -- embedding / unembedding ------------------------------------------
    def embed(self, params, tokens, frontend_embeds=None, *, pos_offset=0):
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.emb_scale:
            x = (x.astype(F32) * cfg.emb_scale).astype(x.dtype)
        if cfg.frontend == "patch" and frontend_embeds is not None:
            # VLM stub: patch embeddings occupy the first K positions
            x = jax.lax.dynamic_update_slice(
                x, frontend_embeds.astype(x.dtype), (0, 0, 0))
        if cfg.max_seq:
            s = tokens.shape[1]
            if getattr(pos_offset, "ndim", 0) == 2:
                # speculative verify chunk: per-row, per-position offsets
                pe = params["pos_embed"][pos_offset]            # [B, S, d]
            elif getattr(pos_offset, "ndim", 0) >= 1:
                # ragged decode: each row reads its own learned position
                pe = params["pos_embed"][pos_offset][:, None]   # [B, 1, d]
            else:
                pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"],
                                                  pos_offset, s, 0)
            x = x + pe.astype(x.dtype)
        return shard(x, residual_spec() if tokens.shape[1] > 1
                     else bspec(None, None))

    @property
    def vocab_out(self) -> int:
        """Logits width (padded vocab)."""
        return padded_vocab(self.cfg.vocab)

    def logits(self, params, x, policy=None):
        cfg = self.cfg
        policy = policy or self.policy
        w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        spec_str = "bsd,vd->bsv" if cfg.tie_embeddings else "bsd,dv->bsv"
        out_fmt = "fp16alt" if cfg.ce_dtype == "fp16alt" else "fp32"
        lg = tp.tp_einsum(spec_str, x, w, policy, out_fmt=out_fmt)
        lg = softcap(lg, cfg.logit_softcap)
        vpad = padded_vocab(cfg.vocab)
        if vpad != cfg.vocab:  # mask the pad tail (never predicted)
            lg = jnp.where(jnp.arange(vpad) < cfg.vocab, lg, -1e30)
        return shard(lg, bspec(None, "model"))

    # -- stacks ------------------------------------------------------------
    def _run_stack(self, params, x, *, positions, mesh=None, caches=None,
                   cache_pos=None, enc_states=None, remat: bool = False,
                   decode: bool = False, kv_len=None, esc_fmts=None,
                   kv_levels=None, kv_scale=None, verify: bool = False):
        cfg = self.cfg
        shared = params.get("shared")
        esc = esc_fmts is not None
        aux_total = jnp.zeros((), F32)
        flags_total = (jnp.zeros((x.shape[0], 2), jnp.int32) if esc
                       else None)
        new_prefix, new_suffix = [], []

        def run_one(x, p, c, spec):
            return apply_layer(x, p, spec, cfg, self.policy,
                               positions=positions, mesh=mesh, cache=c,
                               cache_pos=cache_pos, enc_states=enc_states,
                               shared_params=shared, decode=decode,
                               kv_len=kv_len, esc_fmts=esc_fmts,
                               kv_levels=kv_levels, kv_scale=kv_scale,
                               verify=verify)

        for i, spec in enumerate(cfg.prefix):
            c = caches.prefix[i] if caches else None
            r = run_one(x, params["prefix"][i], c, spec)
            x, nc, aux = r[:3]
            new_prefix.append(nc)
            aux_total += aux
            if esc:
                flags_total += r[3]

        def group_body(carry, xs):
            if esc:
                h, aux_acc, fl_acc = carry
            else:
                h, aux_acc = carry
            gp, gc = xs
            new_gc = []
            for j, spec in enumerate(cfg.pattern):
                c = gc[j] if gc is not None else None
                r = run_one(h, gp[j], c, spec)
                h, nc, aux = r[:3]
                new_gc.append(nc)
                if esc:
                    fl_acc = fl_acc + r[3]
            carry = ((h, aux_acc + aux, fl_acc) if esc
                     else (h, aux_acc + aux))
            return carry, (tuple(new_gc) if caches is not None else None)

        if remat and cfg.remat_policy == "full":
            body = jax.checkpoint(group_body)
        elif remat and cfg.remat_policy == "dots":
            body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:  # "none" or remat=False: save everything
            body = group_body
        pat_caches = caches.pattern if caches is not None else None
        carry0 = ((x, aux_total, flags_total) if esc
                  else (x, aux_total))
        fc, new_pat = jax.lax.scan(
            body, carry0, (params["pattern"], pat_caches),
            unroll=True if cfg.unroll_scan else 1)
        if esc:
            x, aux_total, flags_total = fc
        else:
            x, aux_total = fc

        for i, spec in enumerate(cfg.suffix):
            c = caches.suffix[i] if caches else None
            r = run_one(x, params["suffix"][i], c, spec)
            x, nc, aux = r[:3]
            new_suffix.append(nc)
            aux_total += aux
            if esc:
                flags_total += r[3]

        new_caches = (Caches(tuple(new_prefix), new_pat, tuple(new_suffix))
                      if caches is not None else None)
        if esc:
            return x, new_caches, aux_total, flags_total
        return x, new_caches, aux_total

    # -- entry points -------------------------------------------------------
    def forward_train(self, params, tokens, labels, *, frontend_embeds=None,
                      mesh=None, remat: bool = True, aux_coef: float = 0.01,
                      loss_chunk: int = 1024):
        """[B,S] -> scalar LM loss (+ MoE aux)."""
        cfg = self.cfg
        enc_states = None
        if cfg.encoder is not None:
            enc_states = encode(frontend_embeds, params["encoder"], cfg,
                                self.policy)
        x = self.embed(params, tokens,
                       frontend_embeds if cfg.frontend == "patch" else None)
        positions = jnp.arange(tokens.shape[1])
        x, _, aux = self._run_stack(params, x, positions=positions, mesh=mesh,
                                    enc_states=enc_states, remat=remat)
        x = _norm(x, params["norm_f"], cfg)
        loss = self.chunked_ce(params, x, labels, chunk=loss_chunk)
        return loss + aux_coef * aux

    def chunked_ce(self, params, x, labels, *, chunk: int = 1024):
        """Cross-entropy without materializing [B,S,V]: scan over S-chunks."""
        cfg = self.cfg
        b, s, d = x.shape
        chunk = min(chunk, s)
        nchunks = -(-s // chunk)
        pad = nchunks * chunk - s
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        xc = jnp.moveaxis(x.reshape(b, nchunks, chunk, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, nchunks, chunk), 1, 0)

        def chunk_loss(carry, xs):
            xi, li = xs
            # [B,c,V]; bf16 under ce_dtype=fp16alt (stats below stay f32)
            lg = self.logits(params, xi).astype(F32)
            lse = jax.nn.logsumexp(lg, axis=-1)
            mask = li >= 0
            li_safe = jnp.maximum(li, 0)
            gold = jnp.take_along_axis(lg, li_safe[..., None],
                                       axis=-1)[..., 0]
            nll = jnp.where(mask, lse - gold, 0.0)
            return (carry[0] + nll.sum(), carry[1] + mask.sum()), None

        (tot, cnt), _ = jax.lax.scan(
            chunk_loss, (jnp.zeros((), F32), jnp.zeros((), jnp.int32)),
            (xc, lc))
        return tot / jnp.maximum(cnt, 1)

    def prefill(self, params, tokens, *, max_len: int, frontend_embeds=None,
                mesh=None, prompt_lens=None, page_table=None,
                n_pages: Optional[int] = None):
        """Consume a prompt, build caches sized ``max_len``.

        ``prompt_lens`` ([B] int32) serves a RAGGED batch: ``tokens`` is
        right-padded to a shared width, each row's live prompt is its first
        ``prompt_lens[b]`` tokens.  Attention masks keys past each row's
        own length (the Pallas prefill kernel early-outs there — work
        proportional to the row's length), pad-slot K/V lands in cache
        slots the per-row decode ``kv_len`` keeps dead, and the returned
        logits are each row's LAST LIVE position's (not the pad tail's).

        Paged KV (``cfg.paged_kv``): caches become page pools + block
        tables (``models.paged``).  ``page_table`` ([B, max_pages] int32,
        a traced value — default: the identity/unshared table) lets rows
        alias pages, e.g. a shared prompt prefix stored once; aliasing
        rows must write identical values into shared pages, which a common
        prefix does by construction.  ``n_pages`` sizes the pools (static;
        default ``B * max_pages``, the unshared worst case).  Attention-
        mixer archs only: recurrent state has no page axis, and the
        whisper cross-attention cache stays contiguous by design.
        """
        cfg = self.cfg
        if cfg.paged_kv:
            why = cfg.paged_unsupported_reason()
            if why is not None:
                raise ValueError(
                    f"paged_kv is unsupported for {cfg.name}: {why} cannot "
                    f"page a contiguous-state cache (attention archs only)")
        elif page_table is not None:
            raise ValueError("page_table given but cfg.paged_kv is off")
        if prompt_lens is not None:
            # recurrent mixers have no length axis to mask: pad embeddings
            # would enter the state scan and silently corrupt every later
            # decode step — refuse rather than return padding-dependent
            # output (attention archs only, until SSM prefill masks inputs)
            ssm = sorted({s.mixer for s in cfg.layer_list()
                          if s.mixer in ("mamba2", "mlstm", "slstm")})
            if ssm:
                raise ValueError(
                    f"prompt_lens (ragged serving) is unsupported for "
                    f"{cfg.name}: {'/'.join(ssm)} mixers cannot mask pad "
                    f"tokens out of their recurrent state")
        enc_states = None
        if cfg.encoder is not None:
            enc_states = encode(frontend_embeds, params["encoder"], cfg,
                                self.policy)
        caches = init_caches(cfg, tokens.shape[0], max_len, self.policy,
                             page_table=page_table, n_pages=n_pages)
        x = self.embed(params, tokens,
                       frontend_embeds if cfg.frontend == "patch" else None)
        positions = jnp.arange(tokens.shape[1])
        x, caches, _ = self._run_stack(params, x, positions=positions,
                                       mesh=mesh, caches=caches, cache_pos=0,
                                       enc_states=enc_states,
                                       kv_len=prompt_lens)
        x = _norm(x, params["norm_f"], cfg)
        if prompt_lens is None:
            xl = x[:, -1:]
        else:
            last = (jnp.asarray(prompt_lens, jnp.int32) - 1)[:, None, None]
            xl = jnp.take_along_axis(x, last, axis=1)     # [B, 1, d]
        lg = self.logits(params, xl).astype(F32)
        return lg, caches

    def generate(self, params, tokens, *, gen_len: int,
                 max_len: Optional[int] = None, frontend_embeds=None,
                 mesh=None, return_logits: bool = False,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 top_p: Optional[float] = None, key=None,
                 prompt_lens=None, stop_token: Optional[int] = None,
                 page_table=None, n_pages: Optional[int] = None,
                 repetition_penalty: Optional[float] = None,
                 presence_penalty: Optional[float] = None,
                 loop: str = "scan", return_trips: bool = False,
                 guard_nonfinite: bool = False):
        """Prefill + decode of ``gen_len`` tokens as ONE compiled program:
        the decode loop is a ``lax.scan`` over ``decode_step``, so the whole
        generation costs a single dispatch instead of one per token (the
        per-step Python loop pays XLA dispatch + argument flattening ~every
        token; see benchmarks/serve_decode.py).

        The cache write index and the attention ``kv_len`` are traced scan
        carries — decode_step (and the Pallas decode kernel, which takes
        ``kv_len`` as a dynamic input) compile exactly once.

        Sampling: ``temperature > 0`` enables temperature / top-k / top-p
        sampling (``sample_token``) with the PRNG ``key`` threaded through
        the scan carry (split once per step).  The default ``temperature=0``
        is greedy argmax, bit-identical to the pre-sampling path — the
        sampling knobs are static, so the greedy graph carries no PRNG
        state at all.

        Ragged serving: ``prompt_lens`` ([B] int32) says row ``b``'s live
        prompt is ``tokens[b, :prompt_lens[b]]`` (right-padded batch).  The
        write index becomes a per-row vector — each row decodes from its
        own length, and the Pallas kernels prune each row's KV walk there.
        Differing length vectors reuse one compiled program (they are
        traced values).

        Paged KV: under ``cfg.paged_kv`` the caches riding the scan carry
        are page pools + block tables (see ``prefill``; ``page_table`` /
        ``n_pages`` pass through).  Decode-step writes scatter through the
        table and decode attention dereferences it — the write index /
        ``kv_len`` plumbing below is IDENTICAL either way, and since
        tables are traced, page churn between calls never retraces.

        EOS early-exit: with ``stop_token`` set, a per-row ``done`` mask
        rides the scan carry.  A finished row's outputs are frozen to
        ``stop_token``, and its live attention length is frozen at the
        step it finished — subsequent steps' K/V writes land in cache slots
        past that length, which every attention mask treats as dead, so the
        live cache is effectively frozen too (SSM-mixer layers in hybrid
        archs keep updating their recurrent state; their outputs are
        discarded the same way).

        Penalties: ``repetition_penalty`` / ``presence_penalty``
        (``apply_penalties``) discount tokens already seen — a per-row
        count histogram (prompt + emitted tokens, pad slots excluded)
        rides the loop carry and is applied to the raw logits before
        temperature / top-k / top-p at every step, composing with greedy
        (penalized argmax) and EOS freezing alike.  The default (both
        ``None``) carries no count state — greedy stays bit-identical.

        ``loop="while"`` swaps the fixed-trip scan for a
        ``jax.lax.while_loop`` over the SAME step body: with
        ``stop_token`` set, the loop exits the round ALL rows are done
        instead of stepping EOS-frozen rows to ``gen_len`` (trip count
        capped at ``gen_len - 1`` either way) — tokens are bit-identical
        to the scan form (unexecuted tail slots are pre-frozen to
        ``stop_token``), and per-step logits match for every round that
        actually ran (the tail of ``logits`` is zeros after an early
        exit).  ``return_trips`` appends the executed decode-round count
        to the return (``gen_len - 1`` for the scan form).

        ``guard_nonfinite=True`` routes every sampling site (prefill
        last-token logits included) through ``sanitize_logits`` and
        appends a per-row [B] int32 count of guarded steps to the return —
        the caller's fail-fast hook (raise when any count is nonzero) or
        the fault harness's mask-and-flag accounting.  Finite logits are
        untouched, so guarded greedy decoding stays bit-identical; the
        default carries no guard state at all.

        Returns ``(gen_tokens [B, gen_len], logits)`` where ``logits`` is
        ``[B, gen_len, V]`` (prefill last-token logits followed by each
        step's) when ``return_logits`` else None; ``return_trips`` appends
        the executed decode-round count, ``guard_nonfinite`` appends the
        per-row guard counts (in that order).
        """
        if loop not in ("scan", "while"):
            raise ValueError(f"loop must be scan|while, got {loop!r}")
        b, prompt_len = tokens.shape
        max_len = max_len if max_len is not None else prompt_len + gen_len
        do_sample = temperature is not None and temperature > 0.0
        use_stop = stop_token is not None
        use_pen = ((repetition_penalty is not None
                    and repetition_penalty != 1.0)
                   or (presence_penalty is not None
                       and presence_penalty != 0.0))
        pick = functools.partial(sample_token, temperature=temperature,
                                 top_k=top_k, top_p=top_p)
        pen = functools.partial(apply_penalties,
                                repetition_penalty=repetition_penalty,
                                presence_penalty=presence_penalty)
        lg0, caches = self.prefill(params, tokens, max_len=max_len,
                                   frontend_embeds=frontend_embeds,
                                   mesh=mesh, prompt_lens=prompt_lens,
                                   page_table=page_table, n_pages=n_pages)
        cnt0 = (token_counts(tokens, self.vocab_out, prompt_lens)
                if use_pen else None)
        guard = guard_nonfinite
        lg0v = lg0[:, -1]
        bad0 = None
        if guard:
            lg0v, bad0 = sanitize_logits(lg0v)
        lg0p = pen(lg0v, cnt0) if use_pen else lg0v
        if do_sample:
            key = jax.random.key(0) if key is None else key
            key, k0 = jax.random.split(key)
            tok0 = pick(lg0p, k0)[:, None]
        else:
            tok0 = jnp.argmax(lg0p, -1).astype(jnp.int32)[:, None]

        # per-row write index when ragged, the shared scalar otherwise —
        # it ALWAYS advances (done rows write into dead slots, see above)
        pos0 = (jnp.asarray(prompt_lens, jnp.int32) if prompt_lens is not None
                else jnp.asarray(prompt_len, jnp.int32))
        if use_stop:
            done0 = tok0[:, 0] == stop_token
            tok0 = jnp.where(done0[:, None], stop_token, tok0)
        if use_pen:
            cnt0 = _bump_counts(cnt0, tok0)

        def body(carry, _):
            tok, c, pos = carry[:3]
            rest = list(carry[3:])
            lens = done = ky = cnt = None
            if use_stop:
                lens, done = rest.pop(0), rest.pop(0)
            if use_pen:
                cnt = rest.pop(0)
            if do_sample:
                ky, step_key = jax.random.split(rest.pop(0))
            bad_acc = rest.pop(0) if guard else None
            # a done row's live window stays at the length it finished with
            attend = jnp.where(done, lens, pos + 1) if use_stop else None
            lg, c = self.decode_step(params, tok, c, pos, mesh=mesh,
                                     kv_len=attend)
            lgv = lg[:, -1]
            bad = None
            if guard:
                lgv, bad = sanitize_logits(lgv)
            lgp = pen(lgv, cnt) if use_pen else lgv
            if do_sample:
                nxt = pick(lgp, step_key)[:, None]
            else:
                nxt = jnp.argmax(lgp, -1).astype(jnp.int32)[:, None]
            nc = [None, c, pos + 1]
            if use_stop:
                nxt = jnp.where(done[:, None], stop_token, nxt)
                nc += [jnp.where(done, lens, pos + 1), done
                       | (nxt[:, 0] == stop_token)]
            nc[0] = nxt
            if use_pen:
                nc.append(_bump_counts(cnt, nxt))
            if do_sample:
                nc.append(ky)
            if guard:
                live_bad = (bad & ~done) if use_stop else bad
                nc.append(bad_acc + live_bad.astype(jnp.int32))
            ys = (nxt[:, 0], lg[:, 0]) if return_logits else (nxt[:, 0],)
            return tuple(nc), ys

        init = [tok0, caches, pos0]
        if use_stop:
            # live length entering the first step: the prompt only (tok0's
            # K/V is written by that step); broadcast for uniform batches
            init += [jnp.broadcast_to(pos0, (b,)), done0]
        if use_pen:
            init.append(cnt0)
        if do_sample:
            init.append(key)
        if guard:
            init.append(bad0.astype(jnp.int32))

        if loop == "while":
            return self._generate_while(tuple(init), body, tok0, lg0,
                                        gen_len, use_stop=use_stop,
                                        stop_token=stop_token,
                                        return_logits=return_logits,
                                        return_trips=return_trips,
                                        return_bad=guard)
        fc, ys = jax.lax.scan(body, tuple(init), None, length=gen_len - 1)
        gen = jnp.concatenate([tok0, ys[0].swapaxes(0, 1)], axis=1)
        lgs = (jnp.concatenate([lg0, jnp.moveaxis(ys[1], 0, 1)], axis=1)
               if return_logits else None)
        out = (gen, lgs)
        if return_trips:
            out += (jnp.asarray(gen_len - 1, jnp.int32),)
        if guard:
            out += (fc[-1],)
        return out

    def _generate_while(self, init, body, tok0, lg0, gen_len: int, *,
                        use_stop, stop_token, return_logits, return_trips,
                        return_bad: bool = False):
        """``generate``'s early-exit form: a ``lax.while_loop`` over the
        SAME scan step body (bit-parity by construction), exiting the
        round every row is done.  The token buffer is pre-frozen to
        ``stop_token``, so unexecuted rounds emit exactly what the scan's
        frozen rows would have."""
        b = tok0.shape[0]
        pad = stop_token if use_stop else 0
        out0 = jnp.full((b, gen_len), pad, jnp.int32).at[:, 0].set(tok0[:, 0])
        head = [jnp.zeros((), jnp.int32), out0]
        if return_logits:
            head.append(jnp.zeros((b, gen_len, lg0.shape[-1]), F32)
                        .at[:, 0].set(lg0[:, -1]))
        n_head = len(head)
        done_idx = n_head + 4                       # (tok, caches, pos, lens, done)

        def cond(c):
            more = c[0] < gen_len - 1
            if use_stop:
                more = more & ~jnp.all(c[done_idx])
            return more

        def wbody(c):
            i = c[0]
            nc, ys = body(tuple(c[n_head:]), None)
            out = jax.lax.dynamic_update_slice(c[1], ys[0][:, None],
                                               (jnp.zeros((), jnp.int32),
                                                i + 1))
            head = [i + 1, out]
            if return_logits:
                head.append(jax.lax.dynamic_update_slice(
                    c[2], ys[1][:, None].astype(F32),
                    (jnp.zeros((), jnp.int32), i + 1,
                     jnp.zeros((), jnp.int32))))
            return tuple(head) + nc

        fin = jax.lax.while_loop(cond, wbody, tuple(head) + init)
        gen, trips = fin[1], fin[0]
        lgs = fin[2] if return_logits else None
        out = (gen, lgs)
        if return_trips:
            out += (trips,)
        if return_bad:
            out += (fin[-1],)     # guard counts ride last in the carry
        return out

    def decode_step(self, params, token, caches: Caches, pos, *, mesh=None,
                    kv_len=None, esc_fmts=None, kv_levels=None,
                    kv_scale=None):
        """One decode step: token [B,1], pos scalar -> (logits [B,1,V],
        caches).  ``pos`` may be a per-sequence [B] vector (ragged batch):
        each row writes its K/V at — and takes its rope position from — its
        OWN index.  ``kv_len`` overrides the attended live length
        (scalar-or-vector; default ``pos + 1``) so EOS-frozen rows keep
        writing into dead cache slots without growing their live window.

        ``esc_fmts``/``kv_levels``/``kv_scale`` (escalation write path, see
        ``attention.quantize_kv_rows``) append the per-row OF/UF write-flag
        counts ``kv_flags`` [B, 2] to the return."""
        cfg = self.cfg
        x = self.embed(params, token, pos_offset=pos if cfg.max_seq else 0)
        if getattr(pos, "ndim", 0) >= 1:
            positions = pos[:, None, None]     # broadcastable to [B, H, 1]
        else:
            positions = pos + jnp.arange(1)
        r = self._run_stack(params, x, positions=positions,
                            mesh=mesh, caches=caches,
                            cache_pos=pos, decode=True,
                            kv_len=kv_len, esc_fmts=esc_fmts,
                            kv_levels=kv_levels, kv_scale=kv_scale)
        x, caches = r[0], r[1]
        x = _norm(x, params["norm_f"], cfg)
        lg = self.logits(params, x).astype(F32)
        if esc_fmts is not None:
            return lg, caches, r[3]
        return lg, caches

    # -- continuous-batching steps (launch/engine.py drives these) ---------
    def prefill_chunk(self, params, tokens, caches: Caches, *,
                      q_offset: int, row=None, chunk_lens=None, mesh=None,
                      esc_fmts=None, kv_levels=None):
        """Consume ONE prompt chunk into EXISTING caches — the chunked-
        prefill half of continuous batching (paged archs only: the chunk
        must read every EARLIER chunk's K/V back through the page pool,
        which is exactly the paged prefill read path).

        ``tokens`` [b, C]: the chunk, right-padded to a fixed width C so
        chunk calls share compiled programs.  ``q_offset``: the chunk's
        start position in the row — a STATIC int (it shapes the Pallas
        block schedule); schedulers step it in multiples of C, so at most
        ``max_prompt / C`` programs ever compile.  ``chunk_lens`` [b]: live
        tokens within this chunk (pad-tail K/V lands in dead slots that
        later real writes overwrite before they can ever be read).

        ``row``: traced [] or [m] int32 batch-slot indices — serve a
        SUBSET of a wider serving batch (an admission wave while other
        slots keep decoding; ``tokens``/``chunk_lens`` are then [m, C] /
        [m]): block tables are gathered to those rows, writes scatter
        into the SHARED pool through each row's own table entries, and
        the returned caches carry the original full-width tables.  Being
        traced, slot indices never retrace across admission events.

        Returns ``(logits [b, 1, V], caches)`` — each row's logits at its
        last live chunk position (the final chunk's logits seed the first
        generated token).  ``esc_fmts`` + ``kv_levels`` ([b] int32 rungs
        aligned to ``tokens`` rows — the caller gathers per-slot levels to
        the wave) route the chunk's cache writes through the escalation
        quantizer and append the per-row OF/UF flag counts [b, 2] to the
        return — a reingested row re-prefills AT its escalated rung."""
        cfg = self.cfg
        if not cfg.paged_kv:
            raise ValueError(
                "prefill_chunk requires cfg.paged_kv: a continuation chunk "
                "reads the prefix through the page pool (contiguous prefill "
                "attends only its own fresh K/V)")
        why = cfg.paged_unsupported_reason()
        if why is not None:
            raise ValueError(
                f"prefill_chunk is unsupported for {cfg.name}: {why} cannot "
                f"page a contiguous-state cache (attention archs only)")
        b, s = tokens.shape
        run = _caches_table_view(caches, row) if row is not None else caches
        x = self.embed(params, tokens, pos_offset=q_offset)
        positions = q_offset + jnp.arange(s)
        live = jnp.reshape(jnp.asarray(
            s if chunk_lens is None else chunk_lens, jnp.int32), (-1,))
        r = self._run_stack(params, x, positions=positions,
                            mesh=mesh, caches=run,
                            cache_pos=q_offset,
                            kv_len=q_offset + live,
                            esc_fmts=esc_fmts, kv_levels=kv_levels)
        x, run = r[0], r[1]
        x = _norm(x, params["norm_f"], cfg)
        last = (jnp.maximum(jnp.broadcast_to(live, (b,)), 1) - 1)[:, None,
                                                                  None]
        lg = self.logits(params, jnp.take_along_axis(x, last,
                                                     axis=1)).astype(F32)
        if row is not None:
            run = _caches_adopt_tables(run, caches)
        if esc_fmts is not None:
            return lg, run, r[3]
        return lg, run

    def decode_round(self, params, tok, caches: Caches, pos, *, lens, done,
                     stop_token: Optional[int] = None,
                     temperature: float = 0.0, top_k: Optional[int] = None,
                     top_p: Optional[float] = None, key=None, mesh=None,
                     counts=None, repetition_penalty: Optional[float] = None,
                     presence_penalty: Optional[float] = None,
                     poison=None, guard: bool = False, esc_fmts=None,
                     kv_levels=None, kv_scale=None):
        """ONE decode round over every batch slot of a continuous batch:
        ``decode_step`` at per-row write index ``pos``, attending each
        row's live window (``lens`` for done/idle rows, ``pos + 1`` for
        running ones), then sampling.  Done rows emit ``stop_token`` and
        keep writing into dead slots; idle slots (``lens == 0``) attend
        nothing and emit garbage the scheduler ignores.  All row state is
        traced — admission, page recycling and EOS churn between rounds
        never retrace.

        ``counts`` [B, V] + ``repetition_penalty``/``presence_penalty``
        apply the same seen-token discounts as ``generate`` (raw logits,
        before temperature/top-k/top-p); the caller owns count upkeep.
        ``poison`` (traced bool, fault injection) overwrites the round's
        logits with NaN; ``guard=True`` routes sampling through
        ``sanitize_logits`` — bit-identical on finite logits — and appends
        the per-row ``bad`` flag to the return.  ``esc_fmts``/``kv_levels``
        /``kv_scale`` (escalation write path) append the per-row OF/UF
        write-flag counts [B, 2].  Returns ``(next_tok [B,1], logits,
        caches, key[, bad][, kv_flags])``; the SCHEDULER owns
        pos/lens/done advancement (see decode_burst for the compiled
        multi-round form)."""
        attend = jnp.where(done, lens, pos + 1)
        r = self.decode_step(params, tok, caches, pos, mesh=mesh,
                             kv_len=attend, esc_fmts=esc_fmts,
                             kv_levels=kv_levels, kv_scale=kv_scale)
        lg, caches = r[0], r[1]
        kv_flags = r[2] if esc_fmts is not None else None
        lgv = lg[:, -1]
        if poison is not None:
            lgv = jnp.where(jnp.asarray(poison), jnp.nan, lgv)
        bad = None
        if guard:
            lgv, bad = sanitize_logits(lgv)
        if counts is not None:
            lgv = apply_penalties(lgv, counts,
                                  repetition_penalty=repetition_penalty,
                                  presence_penalty=presence_penalty)
        if temperature is not None and temperature > 0.0:
            key, sk = jax.random.split(jax.random.key(0)
                                       if key is None else key)
            nxt = sample_token(lgv, sk, temperature=temperature,
                               top_k=top_k, top_p=top_p)[:, None]
        else:
            nxt = jnp.argmax(lgv, -1).astype(jnp.int32)[:, None]
        if stop_token is not None:
            nxt = jnp.where(done[:, None], stop_token, nxt)
        ret = (nxt, lg, caches, key)
        if guard:
            ret += (bad,)
        if esc_fmts is not None:
            ret += (kv_flags,)
        return ret

    def decode_burst(self, params, tok, caches: Caches, pos, lens, done,
                     limit, *, max_len: int, out_width: int, n_max,
                     exit_on_finish, stop_token: Optional[int] = None,
                     temperature: float = 0.0, top_k: Optional[int] = None,
                     top_p: Optional[float] = None, key=None, mesh=None,
                     counts=None, repetition_penalty: Optional[float] = None,
                     presence_penalty: Optional[float] = None,
                     poison_at=None, guard: bool = False, esc_fmts=None,
                     kv_levels=None, ovf_at=None, ovf_scale=None):
        """Up to ``n_max`` continuous-batching decode rounds as ONE
        compiled ``lax.while_loop`` — the engine's steady-state dispatch
        cost amortizes like the scan path's.

        Per-row carry: write index ``pos``, live length ``lens``, ``done``
        mask, and ``limit`` (the pos at which a row has emitted its whole
        budget: ``prompt_len + budget - 1``).  A row finishes when it
        emits ``stop_token`` or reaches its limit; its outputs freeze and
        its later writes land in dead slots (write index clamped inside
        ``max_len``).  The loop exits when every row is done, after
        ``n_max`` rounds (both always on), or — when ``exit_on_finish``
        (a TRACED int) is ``k > 0`` — the round the k-th running row
        finishes since burst entry, handing control back to the host
        scheduler so finished rows' pages can be freed and queued
        requests admitted that round (``k = 1``: react to every finish;
        ``k = 2``: batch admissions in waves, halving scheduler
        round-trips; ``0``: run to ``n_max``/all-done).  ``n_max``,
        ``exit_on_finish`` and all row state are traced: bursts of any
        shape share one compiled program.

        Robustness hooks (launch/engine.py): ``counts`` [B, V] rides the
        carry and applies ``repetition_penalty``/``presence_penalty`` at
        every round exactly like ``generate``'s count carry (the caller
        seeds the histogram and re-syncs it between bursts);
        ``poison_at`` (traced int, ``-1`` = never) NaN-poisons that
        relative round's logits — deterministic fault injection;
        ``guard=True`` masks non-finite logits before sampling and counts
        each live row's poisoned rounds.

        Numerical-health hooks: ``esc_fmts`` + ``kv_levels`` ([B] int32,
        constant within a burst — the host escalates between bursts) route
        every round's cache writes through the escalation quantizer; the
        per-row OF/UF write-flag counts accumulate in the carry (rounds a
        row is done contribute zero — same attribution rule as ``bad``)
        and ride back as ``kv_flags`` [B, 2].  ``ovf_at`` (traced int,
        ``-1`` = never) + ``ovf_scale`` multiply that relative round's K/V
        pre-quantization — deterministic overflow injection, the write-side
        twin of ``poison_at``.

        Returns ``(out [B, out_width], n_steps, tok, caches, pos, lens,
        done, key[, bad][, counts][, kv_flags])`` — ``out[:, :n_steps]``
        holds each round's emitted token per row (rows already done emit
        ``stop_token``/pad); ``bad`` [B] int32 (when ``guard``) counts
        rounds a live row's logits went non-finite; ``counts`` (when
        penalties are active) is the advanced histogram."""
        b = tok.shape[0]
        do_sample = temperature is not None and temperature > 0.0
        if do_sample and key is None:
            key = jax.random.key(0)
        use_pen = counts is not None and (
            (repetition_penalty is not None and repetition_penalty != 1.0)
            or (presence_penalty is not None and presence_penalty != 0.0))
        done0 = done
        pad = stop_token if stop_token is not None else -1
        out0 = jnp.full((b, out_width), pad, jnp.int32)
        n_max = jnp.asarray(n_max, jnp.int32)
        zero = jnp.zeros((), jnp.int32)
        poison_at = (None if poison_at is None
                     else jnp.asarray(poison_at, jnp.int32))
        esc = esc_fmts is not None
        ovf_at = None if ovf_at is None else jnp.asarray(ovf_at, jnp.int32)

        wave = jnp.asarray(exit_on_finish, jnp.int32)

        def cond(c):
            i, done = c[0], c[6]
            more = (i < n_max) & ~jnp.all(done)
            newly = jnp.sum((done & ~done0).astype(jnp.int32))
            return more & ((wave == 0) | (newly < wave))

        def body(c):
            i, out, tok, caches, pos, lens, done = c[:7]
            extra = list(c[7:])
            cnt = extra.pop(0) if use_pen else None
            badc = extra.pop(0) if guard else None
            flacc = extra.pop(0) if esc else None
            scale = (jnp.where(i == ovf_at, ovf_scale, 1.0)
                     if ovf_at is not None else None)
            r = self.decode_round(
                params, tok, caches, pos, lens=lens, done=done,
                stop_token=stop_token, temperature=temperature,
                top_k=top_k, top_p=top_p,
                key=extra.pop(0) if do_sample else None, mesh=mesh,
                counts=cnt if use_pen else None,
                repetition_penalty=repetition_penalty,
                presence_penalty=presence_penalty,
                poison=(i == poison_at) if poison_at is not None else None,
                guard=guard, esc_fmts=esc_fmts, kv_levels=kv_levels,
                kv_scale=scale)
            nxt, _, caches, ky = r[:4]
            out = jax.lax.dynamic_update_slice(out, nxt, (zero, i))
            fin = done | (pos + 1 >= limit)
            if stop_token is not None:
                fin = fin | (nxt[:, 0] == stop_token)
            new_pos = jnp.where(done, pos,
                                jnp.minimum(pos + 1, max_len - 1))
            new_lens = jnp.where(done, lens, pos + 1)
            nc = (i + 1, out, nxt, caches, new_pos, new_lens, fin)
            if use_pen:
                nc += (_bump_counts(cnt, nxt),)
            if guard:
                # attribute poisoned rounds to rows live entering the round
                nc += (badc + (r[4] & ~done).astype(jnp.int32),)
            if esc:
                fl = r[4 + (1 if guard else 0)]
                nc += (flacc + fl * (~done).astype(jnp.int32)[:, None],)
            return nc + ((ky,) if do_sample else ())

        init = (zero, out0, tok, caches, pos, lens, done)
        if use_pen:
            init += (counts,)
        if guard:
            init += (jnp.zeros((b,), jnp.int32),)
        if esc:
            init += (jnp.zeros((b, 2), jnp.int32),)
        if do_sample:
            init += (key,)
        fin = jax.lax.while_loop(cond, body, init)
        n, out, tok, caches, pos, lens, done = fin[:7]
        extra = list(fin[7:])
        cnt_out = extra.pop(0) if use_pen else None
        bad_out = extra.pop(0) if guard else None
        fl_out = extra.pop(0) if esc else None
        ret = (out, n, tok, caches, pos, lens, done,
               extra.pop(0) if do_sample else key)
        if guard:
            ret += (bad_out,)
        if use_pen:
            ret += (cnt_out,)
        if esc:
            ret += (fl_out,)
        return ret

    # -- speculative decoding (draft k cheap, verify once, accept prefix) --
    def speculate_check(self):
        """Raise unless this arch supports speculative decoding: the
        verify read folds chunk queries through the decode attend path,
        which exists for GQA-family mixers only (recurrent state cannot
        roll back rejected tokens, and the MLA latent cache has no
        multi-query verify read yet)."""
        cfg = self.cfg
        bad = sorted({s.mixer for s in cfg.layer_list()
                      if s.mixer not in ("gqa", "shared_attn", "none")})
        if bad:
            raise ValueError(
                f"speculative decoding is unsupported for {cfg.name}: "
                f"{'/'.join(bad)} mixers cannot roll back rejected tokens")
        if cfg.encoder is not None or any(s.cross_attn
                                          for s in cfg.layer_list()):
            raise ValueError(
                f"speculative decoding is unsupported for {cfg.name}: "
                f"cross-attention decode has no verify read path")

    def draft_view(self, params, caches, draft_repeats,
                   draft_policy=None):
        """Layer-skip draft submodel: the SAME weights truncated to the
        first ``draft_repeats`` pattern groups (prefix/suffix layers kept
        — they are few and cheap), optionally under a narrower
        ``draft_policy`` for the matmuls.  Returns ``(model, params,
        caches)`` views; the stacked pattern leaves are sliced ``[:r]``,
        so the draft SHARES the target's cache pools for the layers it
        runs — its writes are discarded by the caller (verify rewrites
        every drafted position at every layer with target-precision
        values before any accepted read)."""
        cfg = self.cfg
        r = cfg.repeats if draft_repeats is None else draft_repeats
        r = max(0, min(int(r), cfg.repeats))
        dm = self
        dp, dc = params, caches
        if r < cfg.repeats:
            n_layers = (len(cfg.prefix) + len(cfg.suffix)
                        + r * len(cfg.pattern))
            dm = self.with_cfg(n_layers=n_layers)
            dp = dict(params)
            dp["pattern"] = jax.tree.map(lambda x: x[:r], params["pattern"])
            if caches is not None:
                dc = Caches(caches.prefix,
                            jax.tree.map(lambda x: x[:r], caches.pattern),
                            caches.suffix)
        if draft_policy is not None:
            dm = dataclasses.replace(dm, policy=draft_policy)
        return dm, dp, dc

    def verify_chunk(self, params, tokens, caches: Caches, pos, *,
                     kv_len, mesh=None, esc_fmts=None, kv_levels=None,
                     kv_scale=None):
        """Score a [B, S] candidate chunk at target precision through the
        DECODE read path — the speculative verify call.

        ``pos`` [B] (or scalar) is each row's write index for the chunk's
        first token; the chunk's K/V lands at ``pos .. pos+S-1`` (the same
        bytes S sequential decode steps would write), and ``kv_len``
        [B, S] gives each query position's live attend length (running
        rows: ``pos + i + 1``; EOS-frozen rows: their frozen length).
        Queries fold into the batch dimension inside attention
        (``gqa_attention(verify=True)``), so ``logits[:, i]`` is BITWISE
        the logits a plain ``decode_step`` would emit after consuming
        ``tokens[:, :i+1]`` — parity by construction, not by tolerance.
        Returns ``(logits [B, S, V], caches[, kv_flags])``."""
        cfg = self.cfg
        b, s = tokens.shape
        posv = jnp.broadcast_to(
            jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))
        offs = posv[:, None] + jnp.arange(s, dtype=jnp.int32)   # [B, S]
        x = self.embed(params, tokens, pos_offset=offs if cfg.max_seq else 0)
        r = self._run_stack(params, x, positions=offs[:, None, :],
                            mesh=mesh, caches=caches, cache_pos=posv,
                            decode=True, verify=True,
                            kv_len=jnp.broadcast_to(
                                jnp.asarray(kv_len, jnp.int32), (b, s)),
                            esc_fmts=esc_fmts, kv_levels=kv_levels,
                            kv_scale=kv_scale)
        x, caches = r[0], r[1]
        x = _norm(x, params["norm_f"], cfg)
        lg = self.logits(params, x).astype(F32)
        if esc_fmts is not None:
            return lg, caches, r[3]
        return lg, caches

    def speculate_step(self, params, tok, caches: Caches, pos, *, lens,
                       done, limit, spec_k: int, draft_repeats=None,
                       k_rows=None, stop_token: Optional[int] = None,
                       mesh=None, guard: bool = False, esc_fmts=None,
                       kv_levels=None, kv_scale=None, poison=None,
                       draft_policy=None, _draft_fn=None):
        """ONE speculative round: draft ``spec_k`` tokens with the cheap
        pass, verify the whole chunk at target precision, accept the
        longest matching prefix plus the verify model's own next token.

        Greedy only — acceptance compares draft proposals against the
        verify argmax, so every accepted token (and the bonus token) is
        exactly what sequential greedy decode would have emitted; a wrong
        draft can only LOWER the accept count, never change the stream.
        Rollback is free: rejected positions sit at/past each row's new
        ``lens``, which every attention mask treats as dead, and the next
        round's chunk write covers them before they could become live.

        ``k_rows`` [B] (optional) caps each row's accepted DRAFTS
        (``0`` = that row runs plain single-token decode inside the
        speculative batch); EOS clamps acceptance at the first emitted
        ``stop_token``; ``limit`` clamps it at the row's budget.
        ``_draft_fn(tok, pos) -> [B, spec_k]`` overrides the draft pass —
        the fault/test hook for adversarial (e.g. never-matching) drafts.

        Returns ``(g [B, spec_k+1], n [B], tok, pos, lens, done,
        caches[, bad][, kv_flags])`` — ``g[:, :n[b]]`` are row b's
        emitted tokens this round (``n == 0`` for rows already done),
        ``bad`` [B] flags rows whose ACCEPTED logits went non-finite."""
        b = tok.shape[0]
        k1 = spec_k + 1
        pos = jnp.broadcast_to(
            jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))
        if _draft_fn is not None:
            drafts = jnp.asarray(_draft_fn(tok, pos), jnp.int32)
        elif spec_k == 0:
            drafts = jnp.zeros((b, 0), jnp.int32)
        else:
            dm, dp, dc = self.draft_view(params, caches, draft_repeats,
                                         draft_policy)

            def dstep(carry, _):
                dtok, dcc, dpos = carry
                attend = jnp.where(done, lens, dpos + 1)
                dlg, dcc = dm.decode_step(dp, dtok, dcc, dpos, mesh=mesh,
                                          kv_len=attend)
                nxt = jnp.argmax(dlg[:, -1], -1).astype(jnp.int32)[:, None]
                return (nxt, dcc, dpos + 1), nxt[:, 0]

            # draft writes ride dcc within the round (step i attends its
            # own earlier proposals) and are then DISCARDED: verify
            # rewrites pos..pos+k at every layer below
            _, dseq = jax.lax.scan(dstep, (tok, dc, pos), None,
                                   length=spec_k)
            drafts = dseq.swapaxes(0, 1)                       # [B, k]
        chunk = jnp.concatenate([tok, drafts], axis=1)         # [B, k+1]
        offs = pos[:, None] + jnp.arange(k1, dtype=jnp.int32)
        attend = jnp.where(done[:, None], lens[:, None], offs + 1)
        r = self.verify_chunk(params, chunk, caches, pos, kv_len=attend,
                              mesh=mesh, esc_fmts=esc_fmts,
                              kv_levels=kv_levels, kv_scale=kv_scale)
        lg, caches = r[0], r[1]
        kv_flags = r[2] if esc_fmts is not None else None
        if poison is not None:
            lg = jnp.where(jnp.asarray(poison), jnp.nan, lg)
        badm = None
        if guard:
            lg, badm = sanitize_logits(lg)                     # bad [B, k+1]
        g = jnp.argmax(lg, -1).astype(jnp.int32)               # [B, k+1]
        if spec_k:
            m = jnp.sum(jnp.cumprod(
                (drafts == g[:, :-1]).astype(jnp.int32), axis=1), axis=1)
        else:
            m = jnp.zeros((b,), jnp.int32)
        if k_rows is not None:
            m = jnp.minimum(m, jnp.asarray(k_rows, jnp.int32))
        n = m + 1
        if stop_token is not None:
            is_stop = g == stop_token
            fs = jnp.where(jnp.any(is_stop, 1),
                           jnp.argmax(is_stop, 1), k1).astype(jnp.int32)
            n = jnp.minimum(n, fs + 1)
        n = jnp.minimum(n, jnp.maximum(limit - pos, 1))
        n = jnp.where(done, 0, n).astype(jnp.int32)
        lastix = jnp.maximum(n - 1, 0)[:, None]
        last = jnp.take_along_axis(g, lastix, axis=1)
        new_tok = jnp.where(done[:, None], tok, last)
        new_pos = pos + n
        new_lens = jnp.where(done, lens, new_pos)
        new_done = done | (new_pos >= limit)
        if stop_token is not None:
            stopped = jnp.take_along_axis(g == stop_token, lastix,
                                          axis=1)[:, 0]
            new_done = new_done | (~done & stopped)
        ret = (g, n, new_tok, new_pos, new_lens, new_done, caches)
        if guard:
            # attribute non-finite logits to rows whose ACCEPTED positions
            # were sanitized (rejected drafts never reach the stream)
            acc = jnp.arange(k1)[None, :] < n[:, None]
            ret += (jnp.any(badm & acc, axis=1),)
        if esc_fmts is not None:
            ret += (kv_flags,)
        return ret

    def speculate_decode(self, params, tokens, *, gen_len: int,
                         spec_k: int, draft_repeats=None,
                         max_len: Optional[int] = None, prompt_lens=None,
                         stop_token: Optional[int] = None, page_table=None,
                         n_pages: Optional[int] = None, mesh=None,
                         draft_policy=None, _draft_fn=None,
                         return_stats: bool = False):
        """Speculative analog of greedy ``generate``: prefill, then a
        ``while_loop`` of ``speculate_step`` rounds, each emitting 1 to
        ``spec_k + 1`` tokens per row.  The emitted stream is bit-identical
        to ``generate(..., temperature=0)`` — same prompts, same
        ``stop_token`` freezing, same per-row budgets — regardless of how
        good or bad the draft is (accepted tokens are always the verify
        model's own argmax chain).

        ``max_len`` must leave ``spec_k`` slots of lookahead headroom past
        ``prompt + gen_len``: every round writes a full ``spec_k + 1``-wide
        chunk, and a clamped ``dynamic_update_slice`` near the cache edge
        would SHIFT the write window onto live slots.  ``return_stats``
        appends ``(rounds, emitted)`` int32 scalars (accept rate =
        ``emitted / (rounds * (spec_k + 1))`` over live-row rounds)."""
        self.speculate_check()
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        b, prompt_len = tokens.shape
        k1 = spec_k + 1
        need = prompt_len + gen_len + spec_k
        max_len = need if max_len is None else max_len
        if max_len < need:
            raise ValueError(
                f"speculative decoding needs max_len >= prompt + gen_len + "
                f"spec_k = {need} (draft lookahead headroom; a clamped "
                f"chunk write would corrupt live slots), got {max_len}")
        lg0, caches = self.prefill(params, tokens, max_len=max_len,
                                   mesh=mesh, prompt_lens=prompt_lens,
                                   page_table=page_table, n_pages=n_pages)
        tok0 = jnp.argmax(lg0[:, -1], -1).astype(jnp.int32)[:, None]
        pos0 = jnp.broadcast_to(jnp.reshape(jnp.asarray(
            prompt_lens if prompt_lens is not None else prompt_len,
            jnp.int32), (-1,)), (b,))
        limit = pos0 + gen_len - 1
        done0 = jnp.zeros((b,), bool) if stop_token is None else (
            tok0[:, 0] == stop_token)
        if stop_token is not None:
            tok0 = jnp.where(done0[:, None], stop_token, tok0)
        done0 = done0 | (pos0 >= limit)        # gen_len == 1: prefill only
        pad = stop_token if stop_token is not None else 0
        out0 = jnp.full((b, gen_len + k1), pad,
                        jnp.int32).at[:, 0].set(tok0[:, 0])
        rows = jnp.arange(b)[:, None]
        arange_k = jnp.arange(k1, dtype=jnp.int32)

        def cond(c):
            return ~jnp.all(c[6])

        def body(c):
            out, ec, tok, caches, pos, lens, done, rounds, emitted = c
            g, n, tok, pos, lens, done, caches = self.speculate_step(
                params, tok, caches, pos, lens=lens, done=done,
                limit=limit, spec_k=spec_k, draft_repeats=draft_repeats,
                stop_token=stop_token, mesh=mesh,
                draft_policy=draft_policy, _draft_fn=_draft_fn)
            valid = arange_k[None, :] < n[:, None]
            sidx = jnp.where(valid, ec[:, None] + arange_k[None, :],
                             gen_len + arange_k[None, :])
            out = out.at[rows, sidx].set(jnp.where(valid, g, pad))
            return (out, ec + n, tok, caches, pos, lens, done,
                    rounds + 1, emitted + jnp.sum(n))

        init = (out0, jnp.ones((b,), jnp.int32), tok0, caches, pos0,
                pos0, done0, jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32))
        fin = jax.lax.while_loop(cond, body, init)
        gen = fin[0][:, :gen_len]
        if return_stats:
            return gen, fin[7], fin[8]
        return gen

    def speculate_burst(self, params, tok, caches: Caches, pos, lens,
                        done, limit, *, spec_k: int, draft_repeats=None,
                        k_rows=None, max_len: int, out_width: int, n_max,
                        exit_on_finish, stop_token: Optional[int] = None,
                        key=None, mesh=None, guard: bool = False,
                        esc_fmts=None, kv_levels=None, poison_at=None,
                        ovf_at=None, ovf_scale=None, draft_policy=None,
                        _draft_fn=None):
        """Speculative twin of ``decode_burst``: up to ``n_max``
        ``speculate_step`` rounds as ONE compiled ``while_loop``, each
        emitting a VARIABLE number of tokens per row.  Unlike the plain
        burst's one-column-per-round layout, ``out[b]`` holds row b's
        accepted tokens PACKED contiguously — exactly ``new_lens[b] -
        old_lens[b]`` of them, so the engine's existing lens-growth
        accounting consumes the buffer unchanged.  The loop additionally
        exits when another full chunk might not fit ``out_width``.

        Greedy only (acceptance is defined against the verify argmax);
        the ``key`` passes through untouched for signature compatibility.
        ``k_rows`` [B] is the per-request draft cap (``0`` =
        ``no_speculate`` rows, which still verify their single next token
        — same batch, same compiled program, plain-decode results).
        Hooks mirror ``decode_burst``: ``poison_at``/``guard`` (NaN
        rounds + sanitize accounting), ``esc_fmts``/``kv_levels`` +
        ``ovf_at``/``ovf_scale`` (escalation writes; flags attribute the
        whole verify chunk to the row).  Returns ``(out [B, out_width],
        n_rounds, tok, caches, pos, lens, done, key[, bad][, kv_flags],
        stats [2])`` with ``stats = (live_row_rounds, emitted)``."""
        b = tok.shape[0]
        k1 = spec_k + 1
        done0 = done
        pad = stop_token if stop_token is not None else -1
        out0 = jnp.full((b, out_width + k1), pad, jnp.int32)
        n_max = jnp.asarray(n_max, jnp.int32)
        wave = jnp.asarray(exit_on_finish, jnp.int32)
        poison_at = (None if poison_at is None
                     else jnp.asarray(poison_at, jnp.int32))
        ovf_at = None if ovf_at is None else jnp.asarray(ovf_at, jnp.int32)
        esc = esc_fmts is not None
        rows = jnp.arange(b)[:, None]
        arange_k = jnp.arange(k1, dtype=jnp.int32)

        def cond(c):
            i, ec, done = c[0], c[2], c[7]
            more = (i < n_max) & ~jnp.all(done)
            newly = jnp.sum((done & ~done0).astype(jnp.int32))
            fits = jnp.max(jnp.where(done, 0, ec)) + k1 <= out_width
            return more & fits & ((wave == 0) | (newly < wave))

        def body(c):
            i, out, ec, tok, caches, pos, lens, done, stats = c[:9]
            extra = list(c[9:])
            badc = extra.pop(0) if guard else None
            flacc = extra.pop(0) if esc else None
            scale = (jnp.where(i == ovf_at, ovf_scale, 1.0)
                     if ovf_at is not None else None)
            r = self.speculate_step(
                params, tok, caches, pos, lens=lens, done=done,
                limit=limit, spec_k=spec_k, draft_repeats=draft_repeats,
                k_rows=k_rows, stop_token=stop_token, mesh=mesh,
                guard=guard, esc_fmts=esc_fmts, kv_levels=kv_levels,
                kv_scale=scale,
                poison=(i == poison_at) if poison_at is not None else None,
                draft_policy=draft_policy, _draft_fn=_draft_fn)
            g, n, tok, pos, new_lens, new_done, caches = r[:7]
            valid = arange_k[None, :] < n[:, None]
            sidx = jnp.where(valid, ec[:, None] + arange_k[None, :],
                             out_width + arange_k[None, :])
            out = out.at[rows, sidx].set(jnp.where(valid, g, pad))
            live = (~done).astype(jnp.int32)
            stats = stats + jnp.stack([jnp.sum(live), jnp.sum(n)])
            nc = (i + 1, out, ec + n, tok, caches, pos, new_lens,
                  new_done, stats)
            if guard:
                nc += (badc + (r[7] & ~done).astype(jnp.int32),)
            if esc:
                fl = r[7 + (1 if guard else 0)]
                nc += (flacc + fl * (~done).astype(jnp.int32)[:, None],)
            return nc

        init = (jnp.zeros((), jnp.int32), out0, jnp.zeros((b,), jnp.int32),
                tok, caches, pos, lens, done,
                jnp.zeros((2,), jnp.int32))
        if guard:
            init += (jnp.zeros((b,), jnp.int32),)
        if esc:
            init += (jnp.zeros((b, 2), jnp.int32),)
        fin = jax.lax.while_loop(cond, body, init)
        n, out, _, tok, caches, pos, lens, done, stats = fin[:9]
        extra = list(fin[9:])
        ret = (out[:, :out_width], n, tok, caches, pos, lens, done, key)
        if guard:
            ret += (extra.pop(0),)
        if esc:
            ret += (extra.pop(0),)
        return ret + (stats,)
