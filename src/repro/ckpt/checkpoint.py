"""Atomic, async, mesh-elastic checkpointing.

Production requirements covered:
  * atomicity — write to a temp dir, fsync, then ``os.replace`` (a crashed
    save can never corrupt the latest checkpoint),
  * keep-N retention with monotonically increasing step dirs,
  * async save — serialization happens on a background thread while
    training continues; the next save (or close) joins it,
  * mesh-elastic restore — leaves are stored host-side as numpy with their
    tree paths; ``restore_pytree`` re-places them under ANY sharding pytree
    (restore a 512-chip checkpoint onto 256 chips or a different mesh
    shape), which is the fault-tolerance path after losing a pod slice.

Storage is flattened-path .npz + a structure descriptor — no external
checkpoint library, as the substrate must be self-contained.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return paths, leaves, treedef


def save_pytree(path: str, tree, extra: Optional[dict] = None):
    """Synchronous atomic save of one pytree + json-able extras."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    paths, leaves, _ = _flatten(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    # bfloat16 & friends round-trip via raw bytes + dtype tag
    arrays, dtypes = {}, {}
    for i, a in enumerate(host):
        name = f"leaf_{i}"
        dtypes[name] = str(a.dtype)
        arrays[name] = (a.view(np.uint8) if a.dtype.kind == "V"
                        or str(a.dtype) not in np.sctypeDict else a)
        if str(a.dtype) not in np.sctypeDict:  # ml_dtypes etc.
            arrays[name] = a.view(np.uint16 if a.dtype.itemsize == 2
                                  else np.uint8)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"paths": paths, "dtypes": dtypes, "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def restore_pytree(path: str, like, shardings=None):
    """Restore into the structure of ``like``; optionally device_put each
    leaf under the matching sharding (mesh-elastic)."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    _, like_leaves, treedef = _flatten(like)
    assert len(like_leaves) == len(meta["paths"]), (
        f"checkpoint has {len(meta['paths'])} leaves, target structure "
        f"expects {len(like_leaves)}")
    out = []
    import ml_dtypes
    for i, ref in enumerate(like_leaves):
        a = data[f"leaf_{i}"]
        want = np.dtype(meta["dtypes"][f"leaf_{i}"]) \
            if meta["dtypes"][f"leaf_{i}"] in np.sctypeDict \
            else np.dtype(getattr(ml_dtypes, meta["dtypes"][f"leaf_{i}"]))
        if a.dtype != want:
            a = a.view(want)
        out.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, meta["extra"]


class CheckpointManager:
    """keep-N retention + async saves + latest-step discovery."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def _step_dirs(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                try:
                    out.append((int(d.split("_")[1]), d))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    def path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None,
             sync: bool = False):
        self.wait()
        # materialize on host *before* returning so training can mutate
        # device buffers freely
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save_pytree(self.path(step), host_tree,
                        {**(extra or {}), "step": step})
            for s, d in self._step_dirs()[:-self.keep]:
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

        if sync:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def restore_latest(self, like, shardings=None):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None, None
        tree, extra = restore_pytree(self.path(step), like, shardings)
        return step, tree, extra
