"""FP format definitions — the software analogue of FPnew's parametric format slices.

FPnew (paper §II.A.1) supports any format following IEEE 754-2008 binary
encoding principles, parameterized by (exponent bits, mantissa bits).  We
mirror that exactly: an :class:`FPFormat` is a frozen descriptor carrying the
derived IEEE constants; :data:`REGISTRY` ships the paper's five formats plus
a few extras used by beyond-paper experiments (e4m3, tf32).

Formats that have a native JAX dtype expose it via ``native_dtype`` so the
framework can run in *native* mode (real bf16/fp8 arrays in the HLO — what a
TPU would execute) as well as *emulate* mode (grid-quantized f32 arrays with
bit-exact paper semantics, validated against ml_dtypes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import ml_dtypes
import numpy as np

__all__ = [
    "FPFormat", "REGISTRY", "get_format",
    "FP64", "FP32", "FP16", "FP16ALT", "FP8",
    "FP8_E4M3", "TF32",
]


@dataclasses.dataclass(frozen=True)
class FPFormat:
    """An IEEE-754-style binary format with ``e_bits`` exponent and
    ``m_bits`` explicit mantissa bits (plus sign).  Paper Fig. 1."""

    name: str
    e_bits: int
    m_bits: int
    # numpy dtype implementing this format natively, if one exists
    native: Optional[np.dtype] = None

    def __post_init__(self):
        if self.e_bits < 2 or self.m_bits < 1:
            raise ValueError(
                f"format {self.name}: need >=2 exponent and >=1 mantissa bits"
            )

    # -- derived IEEE constants ------------------------------------------------
    @property
    def width(self) -> int:
        return 1 + self.e_bits + self.m_bits

    @property
    def bias(self) -> int:
        return (1 << (self.e_bits - 1)) - 1

    @property
    def emax(self) -> int:
        return self.bias

    @property
    def emin(self) -> int:
        return 1 - self.bias

    @property
    def precision(self) -> int:
        """Significand precision incl. hidden bit."""
        return self.m_bits + 1

    @property
    def max_normal(self) -> float:
        return float((2.0 - 2.0 ** (-self.m_bits)) * 2.0 ** self.emax)

    @property
    def min_normal(self) -> float:
        return float(2.0 ** self.emin)

    @property
    def min_subnormal(self) -> float:
        return float(2.0 ** (self.emin - self.m_bits))

    @property
    def eps(self) -> float:
        return float(2.0 ** (-self.m_bits))

    # -- container / native dtype handling -------------------------------------
    @property
    def native_dtype(self):
        """jnp dtype natively implementing this format, or None."""
        return None if self.native is None else jnp.dtype(self.native)

    def fits_in_f32(self) -> bool:
        return self.e_bits <= 8 and self.m_bits <= 23

    def container_dtype(self):
        """Narrowest standard float dtype whose grid is a superset of ours,
        with enough precision for innocuous double rounding
        (p_container >= 2*p + 2, Figueroa)."""
        if self.fits_in_f32() and 24 >= 2 * self.precision + 2:
            return jnp.float32
        return jnp.float64

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.name}({self.e_bits},{self.m_bits})"


# ---------------------------------------------------------------------------
# The paper's five formats (§III.A.1) + beyond-paper extras.
# ---------------------------------------------------------------------------
FP64 = FPFormat("fp64", 11, 52, native=np.dtype(np.float64))
FP32 = FPFormat("fp32", 8, 23, native=np.dtype(np.float32))
FP16 = FPFormat("fp16", 5, 10, native=np.dtype(np.float16))
#: paper's binary16alt == bfloat16 encoding, full IEEE semantics
FP16ALT = FPFormat("fp16alt", 8, 7, native=np.dtype(ml_dtypes.bfloat16))
#: paper's custom quarter-precision minifloat (5, 2) == float8_e5m2
FP8 = FPFormat("fp8", 5, 2, native=np.dtype(ml_dtypes.float8_e5m2))

# beyond-paper formats exercising the arbitrary-(e,m) machinery
FP8_E4M3 = FPFormat("fp8_e4m3", 4, 3, native=None)  # IEEE-style e4m3 (with inf)
TF32 = FPFormat("tf32", 8, 10, native=None)
FP6_E3M2 = FPFormat("fp6_e3m2", 3, 2, native=None)

REGISTRY = {
    f.name: f
    for f in (FP64, FP32, FP16, FP16ALT, FP8, FP8_E4M3, TF32, FP6_E3M2)
}
# aliases
REGISTRY["bf16"] = FP16ALT
REGISTRY["bfloat16"] = FP16ALT
REGISTRY["float32"] = FP32
REGISTRY["float16"] = FP16


def get_format(fmt) -> FPFormat:
    """Coerce a name / FPFormat / (e,m) tuple to an FPFormat."""
    if isinstance(fmt, FPFormat):
        return fmt
    if isinstance(fmt, str):
        try:
            return REGISTRY[fmt]
        except KeyError:
            raise KeyError(f"unknown FP format {fmt!r}; known: {sorted(REGISTRY)}")
    if isinstance(fmt, (tuple, list)) and len(fmt) == 2:
        e, m = fmt
        return FPFormat(f"fp_e{e}m{m}", e, m)
    raise TypeError(f"cannot interpret {fmt!r} as FP format")
