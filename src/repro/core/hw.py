"""Target-hardware constants used by the roofline analysis and dry-run.

Target is a TPU v5e-class chip.  Peak MXU throughput scales with format
width (the TPU analogue of FPnew's SIMD lane packing, paper §II.B.3:
k = w_fpu / w_f lanes).
"""
from __future__ import annotations

from .formats import get_format

# per-chip peaks
PEAK_FLOPS_BF16 = 197e12          # bf16/fp16 MXU peak, FLOP/s
PEAK_FLOPS_BY_FMT = {
    "fp32": PEAK_FLOPS_BF16 / 2,  # fp32 via passes of the bf16 MXU
    "fp16": PEAK_FLOPS_BF16,
    "fp16alt": PEAK_FLOPS_BF16,
    "fp8": PEAK_FLOPS_BF16 * 2,   # width-proportional lane packing
    "fp64": PEAK_FLOPS_BF16 / 8,  # no native fp64; software emulated
}
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link (~)
ICI_LINKS = 4                     # 2D torus: 4 links/chip (v5e)
DCN_BW = 25e9                     # bytes/s per host across pods (multi-pod axis)
HBM_PER_CHIP = 16 * 2**30         # 16 GiB


def peak_flops(fmt) -> float:
    return PEAK_FLOPS_BY_FMT[get_format(fmt).name]
