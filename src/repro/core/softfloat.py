"""Bit-exact software emulation of arbitrary IEEE-754-style formats.

This is the numerical heart of the FPnew reproduction: every functional unit
in the paper (FMA, add, mul, conversions) produces results *as if* computed
in the target format with a single rounding.  We emulate that by snapping
container values (f32, or f64 under x64) onto the target format's grid with
correct handling of

  * all five IEEE rounding modes (RNE, RTZ, RDN, RUP, RMM) + stochastic,
  * gradual underflow (subnormals),
  * overflow to +/-inf (or saturation, as a non-IEEE option),
  * signed zeros, inf and NaN propagation.

Implementation note (hardware adaptation): XLA:CPU — like the TPU vector
unit — flushes container-subnormal operands/results to zero in FP arithmetic
(FTZ/DAZ).  FPnew explicitly supports gradual underflow (§II.A.1), so the
rounding is done entirely in *integer* bit arithmetic on the container's bit
pattern, which is immune to FTZ and naturally exact across the
subnormal/normal boundary (mantissa rounding carries propagate into the
exponent field, the classic trick used by hardware rounding stages).

Double rounding through the container is innocuous because every supported
(container, target) pair satisfies p_container >= 2*p_target + 2 (Figueroa);
tests/test_softfloat.py verifies bit-exactness against ml_dtypes for the
formats that have native implementations.

All functions are pure jnp and jit/vmap-compatible; core/ops.py exposes a
straight-through-estimator variant for training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .formats import FPFormat, get_format

__all__ = ["quantize", "quantize_with_flags", "ROUNDING_MODES", "FLAG_NAMES"]

# IEEE-754 status flags raised by a conversion (FPnew §II.B exposes these as
# RISC-V fflags).  DZ cannot fire on a cast, so the telemetry tuple is:
#   OF  overflow   — |x| rounded beyond the target's max normal
#   UF  underflow  — tiny (below min normal, before rounding) AND inexact
#   NX  inexact    — the snapped value differs from the input
#   NV  invalid    — the input was NaN (we flag all NaN traffic, not just
#                    signaling NaNs: a NaN reaching a cast means upstream
#                    arithmetic already went invalid)
FLAG_NAMES = ("of", "uf", "nx", "nv")

ROUNDING_MODES = ("rne", "rtz", "rdn", "rup", "rmm", "stochastic")

# container descriptors: (uint dtype, mantissa bits, exponent bias, exp mask)
_CONTAINERS = {
    jnp.dtype(jnp.float32): (jnp.uint32, 23, 127, 0xFF),
    jnp.dtype(jnp.float64): (jnp.uint64, 52, 1023, 0x7FF),
}


def _round_signed(r, mode: str, u):
    """Round an exactly-representable signed ratio ``r`` to an integer-valued
    float per ``mode``.  ``u`` is uniform [0,1) noise for stochastic mode."""
    if mode == "rne":
        return jnp.round(r)  # round-half-to-even
    if mode == "rtz":
        return jnp.trunc(r)
    if mode == "rdn":
        return jnp.floor(r)
    if mode == "rup":
        return jnp.ceil(r)
    if mode == "rmm":
        return jnp.sign(r) * jnp.floor(jnp.abs(r) + 0.5)
    if mode == "stochastic":
        return jnp.floor(r + u)
    raise ValueError(f"unknown rounding mode {mode!r}; known: {ROUNDING_MODES}")


def _quantize_core(x, *, fmt: FPFormat, mode: str, saturate: bool, key):
    """Shared rounding core: returns the snapped value plus the four
    per-element IEEE flag masks (OF, UF, NX, NV bool arrays)."""
    cdt = jnp.dtype(x.dtype)
    udt, cm, cbias, emask = _CONTAINERS[cdt]
    m, emin, emax = fmt.m_bits, fmt.emin, fmt.emax
    s = cm - m  # constant mantissa-bit shift (valid at/above target emin)

    if mode == "stochastic":
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        kbits, kunif = jax.random.split(key)
    else:
        kbits = kunif = None

    bits = jax.lax.bitcast_convert_type(x, udt)
    sign = bits & (jnp.asarray(1, udt) << (cm + len(bin(emask)) - 2))
    absbits = bits ^ sign
    pos = sign == 0
    one = jnp.asarray(1, udt)

    # ---- path 1: round-to-m-mantissa-bits in integer bit space -------------
    # Valid wherever the target grid has m fractional significand bits below
    # the leading bit — i.e. everywhere except the target-subnormal region
    # (and if the target's emin coincides with the container's, even there,
    # since that region is a single uniform-spacing container binade).
    # Mantissa carries propagate into the exponent field, which is exactly
    # the correct IEEE behaviour (1.11..1 rounding up to 2.0).
    if mode == "rne":
        tie_odd = (absbits >> s) & one
        addend = (one << (s - 1)) - one + tie_odd if s > 0 else jnp.zeros_like(bits)
    elif mode == "rmm":
        addend = jnp.full_like(bits, 1 << (s - 1)) if s > 0 else jnp.zeros_like(bits)
    elif mode == "rtz":
        addend = jnp.zeros_like(bits)
    elif mode == "rdn":  # toward -inf: away-from-zero for negatives
        addend = jnp.where(pos, 0, (1 << s) - 1).astype(udt)
    elif mode == "rup":  # toward +inf: away-from-zero for positives
        addend = jnp.where(pos, (1 << s) - 1, 0).astype(udt)
    elif mode == "stochastic":
        u = jax.random.bits(kbits, x.shape, udt)
        addend = u & jnp.asarray((1 << s) - 1, udt)
    else:
        raise ValueError(f"unknown rounding mode {mode!r}; known: {ROUNDING_MODES}")
    rounded = ((absbits + addend) >> s) << s

    # ---- path 2: fixed-point rounding for the target-subnormal region ------
    # Only needed when the target's subnormal range sits strictly above the
    # container's (fp16/fp8/... inside f32).  There the grid spacing is the
    # constant 2^(emin-m) across several container binades, so we round
    # k = x / 2^(emin-m) in FP — all quantities are container-normal, hence
    # exact and immune to FTZ.
    if emin > 1 - cbias:
        inv_q = jnp.asarray(2.0 ** (m - emin), cdt)   # exact power of two
        qq = jnp.asarray(2.0 ** (emin - m), cdt)
        uu = (jax.random.uniform(kunif, x.shape, cdt)
              if mode == "stochastic" else None)
        k = _round_signed(x * inv_q, mode, uu)
        fx_mag = jnp.abs(k) * qq  # sign-correct magnitude (|k| has it already)
        fx_bits = jax.lax.bitcast_convert_type(fx_mag, udt)
        subnormal_rgn = (absbits >> cm).astype(jnp.int32) - cbias < emin
        rounded = jnp.where(subnormal_rgn, fx_bits, rounded)
        # Container-subnormal inputs: XLA CPU (like the TPU VPU) applies
        # DAZ to FP operands, so the x*inv_q above sees 0.  Every such
        # input is < 2^(1-cbias) <= half the target's min subnormal
        # (guaranteed by container selection), so the correct rounding is
        # known in closed form: 0, except away-from-zero directed modes
        # which give one min-subnormal step.  Pure integer — DAZ-immune.
        csub = (absbits != 0) & (absbits < (one << cm))
        min_sub_bits = jnp.asarray((emin - m + cbias) << cm, udt)
        if mode == "rup":
            csub_val = jnp.where(pos, min_sub_bits, 0).astype(udt)
        elif mode == "rdn":
            csub_val = jnp.where(pos, 0, min_sub_bits).astype(udt)
        else:
            csub_val = jnp.zeros_like(bits)
        rounded = jnp.where(csub, csub_val, rounded)

    # ---- overflow: compare against target max_normal in container bits -----
    max_bits = jnp.asarray(
        ((emax + cbias) << cm) | (((1 << m) - 1) << (cm - m)), udt)
    inf_bits = jnp.asarray(emask << cm, udt)
    over = rounded > max_bits
    if saturate:
        ovf_val = jnp.full_like(bits, max_bits)
    elif mode in ("rne", "rmm", "stochastic"):
        ovf_val = jnp.full_like(bits, inf_bits)
    elif mode == "rtz":
        ovf_val = jnp.full_like(bits, max_bits)
    elif mode == "rdn":
        ovf_val = jnp.where(pos, max_bits, inf_bits)
    else:  # rup
        ovf_val = jnp.where(pos, inf_bits, max_bits)
    rounded = jnp.where(over, ovf_val, rounded)

    # ---- IEEE status flags (before specials overwrite ``rounded``) ---------
    special = absbits >= inf_bits
    nv = absbits > inf_bits                      # NaN input
    of = over & ~special
    nx = (rounded != absbits) & ~special         # OF implies NX, per IEEE
    # tininess detected before rounding: nonzero magnitude below min normal
    tiny = (absbits != 0) & (
        (absbits >> cm).astype(jnp.int32) - cbias < emin)
    uf = tiny & nx

    # specials: container inf/NaN propagate untouched
    rounded = jnp.where(special, absbits, rounded)

    return jax.lax.bitcast_convert_type(sign | rounded, cdt), of, uf, nx, nv


@functools.partial(jax.jit, static_argnames=("fmt", "mode", "saturate"))
def _quantize_bits(x, *, fmt: FPFormat, mode: str, saturate: bool, key):
    return _quantize_core(x, fmt=fmt, mode=mode, saturate=saturate, key=key)[0]


@functools.partial(jax.jit, static_argnames=("fmt", "mode", "saturate"))
def _quantize_bits_flags(x, *, fmt: FPFormat, mode: str, saturate: bool, key):
    y, of, uf, nx, nv = _quantize_core(x, fmt=fmt, mode=mode,
                                       saturate=saturate, key=key)
    return y, {"of": of, "uf": uf, "nx": nx, "nv": nv}


def quantize(x, fmt, mode: str = "rne", *, saturate: bool = False,
             key: Optional[jax.Array] = None):
    """Snap ``x`` onto the grid of ``fmt`` with one correct rounding.

    Returns an array in the *container* dtype (f32, or f64 when the target
    needs it and x64 is enabled) whose values are exactly representable in
    ``fmt``.  This models FPnew's CONV block (§II.B.4) and is the primitive
    from which multi-format FMA semantics are built.
    """
    fmt = get_format(fmt)
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        x = x.astype(jnp.float32)
    # identity fast-path: target grid is a superset of the *input's* grid
    xinfo = jnp.finfo(x.dtype)
    if fmt.e_bits >= xinfo.nexp and fmt.m_bits >= xinfo.nmant:
        return x
    cdt = fmt.container_dtype()
    if cdt == jnp.float64 and not jax.config.read("jax_enable_x64"):
        if fmt.e_bits >= 11 and fmt.m_bits >= 23:
            # target at least as wide as f32: identity on f32 data
            return x.astype(jnp.float32)
        raise ValueError(
            f"format {fmt} needs an f64 container; enable jax_enable_x64")
    xin = x.astype(cdt)
    # identity fast-path: target grid is a superset of the container grid
    if fmt.e_bits >= jnp.finfo(cdt).nexp and fmt.m_bits >= jnp.finfo(cdt).nmant:
        return xin
    return _quantize_bits(xin, fmt=fmt, mode=mode, saturate=saturate, key=key)


def quantize_with_flags(x, fmt, mode: str = "rne", *, saturate: bool = False,
                        key: Optional[jax.Array] = None):
    """:func:`quantize` plus the IEEE status flags the conversion raises.

    Returns ``(y, flags)`` where ``flags`` is a dict of per-element bool
    masks keyed by :data:`FLAG_NAMES` (``of``/``uf``/``nx``/``nv``).  This
    is the software analog of FPnew's fflags output (§II.B): the signal a
    transprecision runtime consumes to learn that a narrow format is
    failing the workload *at the source*, instead of discovering the Inf
    three matmuls later.  ``saturate=True`` additionally clamps overflow
    to ±max_normal (finite, degraded) instead of ±Inf — OF still fires.

    Exact conversions (identity fast-paths) raise no OF/UF/NX; NV still
    reports NaN inputs so poisoned traffic stays visible.
    """
    fmt = get_format(fmt)
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.floating):
        x = x.astype(jnp.float32)

    def _exact(y):
        no = jnp.zeros(y.shape, jnp.bool_)
        return y, {"of": no, "uf": no, "nx": no, "nv": jnp.isnan(y)}

    xinfo = jnp.finfo(x.dtype)
    if fmt.e_bits >= xinfo.nexp and fmt.m_bits >= xinfo.nmant:
        return _exact(x)
    cdt = fmt.container_dtype()
    if cdt == jnp.float64 and not jax.config.read("jax_enable_x64"):
        if fmt.e_bits >= 11 and fmt.m_bits >= 23:
            return _exact(x.astype(jnp.float32))
        raise ValueError(
            f"format {fmt} needs an f64 container; enable jax_enable_x64")
    xin = x.astype(cdt)
    if fmt.e_bits >= jnp.finfo(cdt).nexp and fmt.m_bits >= jnp.finfo(cdt).nmant:
        return _exact(xin)
    return _quantize_bits_flags(xin, fmt=fmt, mode=mode, saturate=saturate,
                                key=key)
