"""Energy model calibrated to the paper's GF22FDX silicon measurements.

The paper evaluates FPnew purely on energy, throughput and silicon
efficiency.  This module encodes those measurements as an analytical model:

  * :data:`FMA_PJ_PER_FLOP` — Table IV, measured pJ/flop of the FMA per
    format, scalar and SIMD (whole-FPU energy at 0.8 V, 923 MHz, 22FDX).
  * :data:`OP_ENERGY_PJ` — Fig 7 per-instruction energies (FMA anchor values
    are exact from Table IV; mul/add/comparison anchors estimated from the
    bar chart, chained with the relative gains quoted in §IV.B.3b).
  * :class:`DVFSModel` — Fig 8's voltage/frequency scaling, an alpha-power
    CV²f + leakage model fitted to the published (perf, efficiency) extremes.
  * :class:`CoreModel` — Ariane/RI5CY core-level overheads (Fig 9,
    §IV.A.2) used by the Table III case-study reproduction.
  * :func:`step_energy` — maps a compiled train-step's HLO cost analysis
    (flops, bytes, collective bytes) onto a cluster-scale energy estimate
    with per-format energy proportionality — the paper's
    energy-proportionality thesis applied at datacenter scale.

All constants are *measured values transcribed from the paper* unless marked
``estimated``; benchmarks/ reproduce the paper's tables from this model and
report deviations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from .formats import get_format

# ---------------------------------------------------------------------------
# Table IV — measured energy per flop (pJ), whole TP-FPU, 0.8 V, 923 MHz.
# FMA = 2 flops.  keys: (format, simd?)
# ---------------------------------------------------------------------------
FMA_PJ_PER_FLOP: Dict[tuple, float] = {
    ("fp64", False): 13.36,
    ("fp32", False): 4.72,
    ("fp16", False): 2.48,
    ("fp16alt", False): 2.18,
    ("fp8", False): 1.27,
    ("fp32", True): 5.01,
    ("fp16", True): 2.01,
    ("fp16alt", True): 1.72,
    ("fp8", True): 0.80,
}

#: Table IV — throughput in FMA-ops/cycle (SIMD lane counts) and latency.
FMA_LANES = {("fp64", False): 1, ("fp32", False): 1, ("fp16", False): 1,
             ("fp16alt", False): 1, ("fp8", False): 1,
             ("fp32", True): 2, ("fp16", True): 4, ("fp16alt", True): 4,
             ("fp8", True): 8}
FMA_LATENCY = {"fp64": 4, "fp32": 3, "fp16": 3, "fp16alt": 3, "fp8": 2}

NOMINAL_FREQ_HZ = 923e6      # measured nominal (0.8 V, 25C)
NOMINAL_VDD = 0.8

# ---------------------------------------------------------------------------
# Fig 7 — per-instruction FPU energy (pJ).  FMA values derived exactly from
# Table IV (pJ/flop * 2 flops [* lanes for SIMD]); mul/add/cmp anchors are
# estimated from the figure and chained with the quoted relative gains:
#   mul:  65/47/52/47 % cheaper per next-smaller format (from FP64)
#   add:  53/47/57/47 %
#   cmp:  38/34/35/22 %
# ---------------------------------------------------------------------------
def _chain(anchor: float, gains) -> list:
    vals = [anchor]
    for g in gains:
        vals.append(vals[-1] * (1.0 - g))
    return vals


_FMTS = ["fp64", "fp32", "fp16", "fp16alt", "fp8"]
# NB: gains for fp16alt are quoted w.r.t. fp32 (the "next larger" format),
# not w.r.t. fp16 — build fp16 and fp16alt both from fp32.
def _chain_tree(anchor, g32, g16, g16a, g8):
    v64 = anchor
    v32 = v64 * (1 - g32)
    v16 = v32 * (1 - g16)
    v16a = v32 * (1 - g16a)
    v8 = v16 * (1 - g8)
    return {"fp64": v64, "fp32": v32, "fp16": v16, "fp16alt": v16a, "fp8": v8}


OP_ENERGY_PJ = {
    # scalar FMA (exact, Table IV)
    ("fma", False): {f: FMA_PJ_PER_FLOP[(f, False)] * 2 for f in _FMTS},
    # SIMD FMA per instruction (pJ/flop * 2 * lanes)
    ("fma", True): {f: FMA_PJ_PER_FLOP[(f, True)] * 2 * FMA_LANES[(f, True)]
                    for f in _FMTS if (f, True) in FMA_PJ_PER_FLOP},
    # scalar mul/add/cmp (anchor estimated from Fig 7 bar chart)
    ("mul", False): _chain_tree(19.5, 0.65, 0.47, 0.52, 0.47),   # estimated
    ("add", False): _chain_tree(11.0, 0.53, 0.47, 0.57, 0.47),   # estimated
    ("cmp", False): _chain_tree(2.9, 0.38, 0.34, 0.35, 0.22),    # estimated
}

# Scalar FP-FP conversion energies, §IV.B.3b: 7.0 pJ for fp64<->fp32; the
# halved-width chain is 30 % / 35 % cheaper per step.
CONV_SCALAR_PJ = {("fp64", "fp32"): 7.0,
                  ("fp32", "fp16"): 7.0 * 0.70,
                  ("fp16", "fp8"): 7.0 * 0.70 * 0.65}
#: vectorial casts per instruction, §IV.B.3b ("2.2 pJ to 4.9 pJ per datum")
CONV_VEC_PJ = {("fp32", "fp16"): 4.9, ("fp16", "fp8"): 4.9 * 0.905}
#: cast-and-pack of two scalars: ~1.3x one scalar conversion (§IV.B.3b)
CASTPACK_FACTOR = 1.3


def conv_energy_pj(src, dst, simd: bool = False) -> float:
    s, d = get_format(src).name, get_format(dst).name
    s, d = ("fp16" if s == "fp16alt" else s), ("fp16" if d == "fp16alt" else d)
    table = CONV_VEC_PJ if simd else CONV_SCALAR_PJ
    key = (s, d) if (s, d) in table else (d, s)
    if key in table:
        return table[key]
    # multi-step conversions: sum the chain (worst case estimate)
    order = ["fp64", "fp32", "fp16", "fp8"]
    i, j = sorted((order.index(s), order.index(d)))
    return sum(table.get((order[k], order[k + 1]),
                         list(table.values())[0]) for k in range(i, j))


def fma_energy_pj(fmt, simd: bool = False) -> float:
    """Per-instruction FMA energy (whole FPU), Table IV exact."""
    f = get_format(fmt).name
    per_flop = FMA_PJ_PER_FLOP[(f, simd)]
    lanes = FMA_LANES[(f, simd)]
    return per_flop * 2 * lanes


def fma_perf_gflops(fmt, simd: bool = False,
                    freq_hz: float = NOMINAL_FREQ_HZ) -> float:
    """Table IV performance column: 2 flops * lanes * f."""
    return 2 * FMA_LANES[(get_format(fmt).name, simd)] * freq_hz / 1e9


def fma_efficiency_gflops_w(fmt, simd: bool = False) -> float:
    """Table IV efficiency column: 1e3/pJ-per-flop = Gflop/sW."""
    return 1000.0 / FMA_PJ_PER_FLOP[(get_format(fmt).name, simd)]


# ---------------------------------------------------------------------------
# Fig 8 — DVFS model.  f_max(V) linear through the two published frequency
# points; per-op energy = dynamic (V^2-scaled, anchored so that the TOTAL at
# 0.8 V equals the measured pJ/flop) + leakage/op (leakage power / flop
# rate).  Published anchors:
#   0.8 V  -> 923 MHz,  FP64 FMA eff 74.83 Gflop/sW
#   1.2 V  -> 1585 MHz  (3.17 Gflop/s FP64 peak perf)
#   ~0.45 V -> peak eff 178 Gflop/sW FP64; 2.95 Tflop/sW FP8 SIMD
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DVFSModel:
    v_t: float = 0.2423        # from the two (V, f) anchors
    f_slope_hz_per_v: float = 1.655e9  # f_max(V) = slope * (V - v_t)
    leak_w_at_08: float = 3.5e-3
    leak_exp: float = 1.5      # leakage ~ (V/0.8)^exp (FD-SOI, weak body bias)

    def f_max(self, v: float) -> float:
        return max(self.f_slope_hz_per_v * (v - self.v_t), 1e6)

    def perf_gflops(self, v: float, lanes: int = 1) -> float:
        return 2 * lanes * self.f_max(v) / 1e9

    def efficiency_gflops_w(self, v: float, lanes: int = 1,
                            pj_per_flop_nominal: float = 13.36) -> float:
        flop_rate = 2 * lanes * self.f_max(v)
        leak_per_flop_08 = self.leak_w_at_08 / (2 * lanes *
                                                self.f_max(NOMINAL_VDD))
        e_dyn0 = (pj_per_flop_nominal * 1e-12 - leak_per_flop_08)
        e_dyn = e_dyn0 * (v / NOMINAL_VDD) ** 2
        e_leak = (self.leak_w_at_08 * (v / 0.8) ** self.leak_exp) / flop_rate
        return 1e-9 / (e_dyn + e_leak)


# ---------------------------------------------------------------------------
# Core-level model (Ariane, Fig 9): during an FP64 FMA the FPU is 39 % of
# core energy -> ~41.8 pJ/instruction of non-FPU core overhead, amortized
# over SIMD lanes for vector instructions.
# ---------------------------------------------------------------------------
ARIANE_CORE_OVERHEAD_PJ = 26.7 / 0.39 - 26.7  # = 41.77 pJ / instruction

# ---------------------------------------------------------------------------
# RI5CY merged-slice energies (pJ/op) for the Table III case study.
# The RI5CY TP-FPU uses MERGED ADDMUL and CONV slices (Table I): narrow
# formats reuse the fp32-wide datapath, so fp16 ops cost nearly as much as
# fp32 ops (the very effect that makes variant c of Fig 11 a net LOSS) and
# conversions are cheap.  fma_fp32 = 3.9 pJ is the paper's measured value
# (§IV.A.2); the others are fitted once against Table III's published
# relative energies and kept fixed.
# ---------------------------------------------------------------------------
RI5CY_MERGED_PJ = {
    "fma_fp32": 3.9,      # measured, §IV.A.2
    "fma_fp16": 3.3,      # merged slice: ~85% of fp32
    "fmacex": 3.5,        # fp16 mul + fp32 acc in the merged FMA
    "mul_fp16": 4.6,      # merged multiplier, fp16 operands (Table III's c)
    "add_fp32": 2.6,
    "cvt": 0.8,           # merged CONV, 32-bit datapath
    "vfmul_fp16": 9.5,    # 2-lane SIMD mul in the merged slice
}
RI5CY_CORE_PJ = {
    "overhead_per_instr": 1.9,   # decode/regfile/pipeline
    "load_extra": 0.4,           # lh/lw datapath cost in-core
    "mem_extra": 2.0,            # system-level memory access adder
    "background_per_instr": 12.7,  # SoC static+clock per cycle (system)
}


@dataclasses.dataclass(frozen=True)
class CoreModel:
    """Per-instruction core+system energy: E = n_instr * (overhead + fpu_op).

    Used by the Table III case-study reproduction (RI5CY-class core);
    overheads are fitted there against the published relative energies.
    """
    core_overhead_pj: float = 3.3      # non-FPU core energy / instruction
    mem_pj: float = 4.0                # extra energy of a load/store at system level
    fpu_scale: float = 1.0             # RI5CY FPU energy scale vs Ariane table

    def instr_energy(self, kind: str, fmt: str, simd: bool = False,
                     system: bool = False) -> float:
        base = self.core_overhead_pj
        if kind in ("lh", "lw", "load", "store"):
            return base + (self.mem_pj if system else 0.0)
        if kind == "fma":
            e = fma_energy_pj(fmt, simd)
        elif kind in ("mul", "add", "cmp"):
            e = OP_ENERGY_PJ[(kind, False)][get_format(fmt).name]
            if simd:
                lanes = FMA_LANES[(get_format(fmt).name, True)]
                e = e * lanes * 0.85  # SIMD amortization, Fig 7 right
        elif kind == "cvt":
            e = conv_energy_pj("fp32", fmt, simd)
        elif kind == "castpack":
            e = conv_energy_pj("fp32", fmt, False) * CASTPACK_FACTOR
        else:
            raise KeyError(kind)
        return base + self.fpu_scale * e


# ---------------------------------------------------------------------------
# Cluster-scale energy (beyond paper): apply the measured per-format energy
# proportionality to a compiled step's HLO cost terms.  Scaled from 22FDX
# FPU measurements to a v5e-class chip by anchoring bf16 at the public
# ~0.6 pJ/flop system-level figure and keeping the paper's *ratios*.
# ---------------------------------------------------------------------------
TPU_PJ_PER_FLOP = {
    "fp32": 0.6 * (5.01 / 1.72),
    "fp16alt": 0.6,
    "fp16": 0.6 * (2.01 / 1.72),
    "fp8": 0.6 * (0.80 / 1.72),
}
TPU_PJ_PER_HBM_BYTE = 1.3      # DRAM access energy, public estimates
TPU_PJ_PER_ICI_BYTE = 0.7


def step_energy_joules(flops_by_fmt: Dict[str, float], hbm_bytes: float,
                       ici_bytes: float = 0.0) -> float:
    e = sum(TPU_PJ_PER_FLOP[get_format(f).name] * n
            for f, n in flops_by_fmt.items())
    e += TPU_PJ_PER_HBM_BYTE * hbm_bytes + TPU_PJ_PER_ICI_BYTE * ici_bytes
    return e * 1e-12
