"""Precision policies — the software analogue of FPnew's per-op-group
format configuration (paper §II.B.2, Tables I/II).

FPnew routes every operation through one of four *operation group blocks*
(ADDMUL / DIVSQRT / COMP / CONV), and each block is configured per format as
a parallel or merged slice.  In a JAX training/serving framework the same
partition of work exists:

  ADDMUL  -> matmuls / FMAs          (MXU)       -> :class:`MatmulPolicy`
  DIVSQRT -> elementwise transcendentals (VPU)   -> ``elem_fmt`` (+ fast mode)
  COMP    -> comparisons, masking, argmax        -> ``comp_fmt``
  CONV    -> dtype conversions, quantization     -> ``rounding`` mode

plus framework-level format choices the paper's ISA extension exposes to
software: parameter storage, gradient communication, KV-cache storage, and
optimizer state formats.

Two execution modes:

  ``native``  — tensors really carry the narrow dtype (bf16 / fp16 / fp8
                arrays in the HLO).  This is what runs on the TPU and what
                the dry-run/roofline measures.
  ``emulate`` — tensors are f32 arrays snapped to the target grid via
                core.softfloat (bit-exact paper semantics; used for
                numerics validation and formats with no native dtype).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .formats import FPFormat, get_format

__all__ = ["MatmulPolicy", "PrecisionPolicy", "EscalationPolicy",
           "get_policy", "PRESETS"]


@dataclasses.dataclass(frozen=True)
class MatmulPolicy:
    """Multi-format FMA configuration: ``dst fma(src, src, dst)`` (§II.B.4).

    ``src_fmt``: operand/multiply format; ``acc_fmt``: accumulation format
    (the FMA's dst); ``out_fmt``: storage format of the result (CONV on the
    way out; None = keep acc).
    """
    src_fmt: FPFormat
    acc_fmt: FPFormat
    out_fmt: Optional[FPFormat] = None

    def resolved_out(self) -> FPFormat:
        return self.out_fmt or self.acc_fmt


def _f(x):
    return get_format(x) if x is not None else None


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    name: str
    mode: str = "native"                      # "native" | "emulate"
    matmul: MatmulPolicy = None               # ADDMUL group
    elem_fmt: FPFormat = None                 # DIVSQRT-ish group (VPU)
    comp_fmt: FPFormat = None                 # COMP group
    rounding: str = "rne"                     # CONV group rounding
    param_fmt: FPFormat = None                # parameter storage
    grad_comm_fmt: Optional[FPFormat] = None  # gradient all-reduce format
    kv_fmt: Optional[FPFormat] = None         # KV-cache storage
    opt_m_fmt: Optional[FPFormat] = None      # optimizer 1st-moment storage
    opt_v_fmt: Optional[FPFormat] = None      # optimizer 2nd-moment storage
    master_fmt: FPFormat = None               # master weights / updates
    stochastic_grad_round: bool = False       # SR when quantizing grads
    # beyond-paper: matmul partial sums carried (and all-reduced) in the
    # OUTPUT format instead of acc_fmt — halves tensor-parallel activation
    # all-reduce bytes (the paper's narrow-wire insight; local tile
    # accumulation inside the MXU stays f32)
    narrow_partials: bool = False

    def __post_init__(self):
        # allow string/None-friendly construction
        object.__setattr__(self, "matmul", self.matmul or MatmulPolicy(
            get_format("fp32"), get_format("fp32")))
        for field in ("elem_fmt", "comp_fmt", "param_fmt", "master_fmt"):
            v = getattr(self, field)
            object.__setattr__(self, field, _f(v) or get_format("fp32"))
        for field in ("grad_comm_fmt", "kv_fmt", "opt_m_fmt", "opt_v_fmt"):
            object.__setattr__(self, field, _f(getattr(self, field)))
        if self.mode not in ("native", "emulate"):
            raise ValueError(f"mode must be native|emulate, got {self.mode}")
        if self.mode == "native":
            for fmt in (self.matmul.src_fmt, self.param_fmt):
                if fmt.native_dtype is None:
                    raise ValueError(
                        f"policy {self.name}: format {fmt} has no native dtype; "
                        f"use mode='emulate'")

    def replace(self, **kw) -> "PrecisionPolicy":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class EscalationPolicy:
    """Flag-driven KV-precision escalation — the inverse of graceful
    degradation, steered by the IEEE exception telemetry of the write-side
    CONV stage (FPnew's fflags, §II.B; SmallFloat format selection in the
    ultra-low-power platform paper).

    A serving row starts at ``ladder[0]`` (narrowest).  Its accumulated
    write-time OF / UF counts are per-request *pressure*; when either
    crosses its threshold and the row is not yet at the top of the ladder,
    the scheduler escalates the row one rung — recomputing its K/V at the
    wider format via the free-and-reingest path, since the cached
    narrow-format values (saturated on overflow) are exactly what the
    telemetry says is damaged.  Escalation is refusable per request
    (``Request.no_escalate``) and budgeted against page pressure: it is
    deferred while the pool's free list is shorter than
    ``min_free_pages`` (an escalating row re-prefills its whole history,
    the worst possible moment to fight admission for pages).

    Every rung must fit the f32 pool container exactly (the engine stores
    rung-snapped values in a shared f32 pool, selected per row at write
    time — mixed formats in one pool, no repage on escalation).
    ``uf_threshold`` defaults effectively off: underflow is high-rate /
    low-harm telemetry, overflow is what poisons logits.
    """
    ladder: tuple = ("fp8", "fp16", "fp16alt")
    of_threshold: int = 8
    uf_threshold: int = 1 << 30
    min_free_pages: int = 0

    def __post_init__(self):
        if len(self.ladder) < 2:
            raise ValueError("escalation ladder needs >= 2 rungs")
        if self.of_threshold < 1 or self.uf_threshold < 1:
            raise ValueError("escalation thresholds must be >= 1")
        for name in self.ladder:
            fmt = get_format(name)
            if fmt.e_bits > 8 or fmt.m_bits > 23:
                raise ValueError(
                    f"ladder rung {name!r} does not fit an f32 container")

    @property
    def formats(self) -> tuple:
        return tuple(get_format(n) for n in self.ladder)

    def top(self) -> int:
        return len(self.ladder) - 1


def _mk(name, src, acc, out=None, **kw) -> PrecisionPolicy:
    return PrecisionPolicy(
        name=name,
        matmul=MatmulPolicy(get_format(src), get_format(acc), _f(out)),
        **kw)


PRESETS = {
    # The paper's FP32 baseline (Fig 11b): everything single-precision.
    "fp32": _mk("fp32", "fp32", "fp32", param_fmt="fp32", elem_fmt="fp32"),
    # Paper-faithful transprecision: FP16 storage/multiply, FP32 accumulate —
    # the expanding FMA of Fig 10c / Fig 11e, applied to every matmul.
    "tp_fp16": _mk("tp_fp16", "fp16", "fp32", out="fp16",
                   param_fmt="fp16", elem_fmt="fp32", kv_fmt="fp16"),
    # Same with bfloat16 (paper's FP16alt): the TPU-native expanding FMA.
    "tp_bf16": _mk("tp_bf16", "fp16alt", "fp32", out="fp16alt",
                   param_fmt="fp16alt", elem_fmt="fp32", kv_fmt="fp16alt"),
    # FP8 operands, FP32 accumulate (paper's minifloat, §III.A.1).
    "tp_fp8": _mk("tp_fp8", "fp8", "fp32", out="fp16alt",
                  param_fmt="fp16alt", elem_fmt="fp32", kv_fmt="fp8"),
    # tp_bf16 with an fp8 KV cache (the paper's storage-format knob on the
    # dominant serving memory term).
    "tp_bf16_kv8": _mk("tp_bf16_kv8", "fp16alt", "fp32", out="fp16alt",
                       param_fmt="fp16alt", elem_fmt="fp32", kv_fmt="fp8"),
    # Beyond-paper production policy: bf16 compute + fp8 gradient
    # all-reduce with stochastic rounding + fp8 KV cache + bf16 moments.
    "prod_tp": _mk("prod_tp", "fp16alt", "fp32", out="fp16alt",
                   param_fmt="fp16alt", elem_fmt="fp32",
                   grad_comm_fmt="fp8", kv_fmt="fp8",
                   opt_m_fmt="fp16alt", opt_v_fmt="fp16alt",
                   stochastic_grad_round=True),
    # Emulated variants (bit-exact grids on f32 containers) for validation.
    "em_fp16": _mk("em_fp16", "fp16", "fp32", out="fp16", mode="emulate",
                   param_fmt="fp16", elem_fmt="fp32"),
    "em_fp8": _mk("em_fp8", "fp8", "fp32", out="fp16", mode="emulate",
                  param_fmt="fp16", elem_fmt="fp32"),
}


def get_policy(p) -> PrecisionPolicy:
    if isinstance(p, PrecisionPolicy):
        return p
    try:
        return PRESETS[p]
    except KeyError:
        raise KeyError(f"unknown policy {p!r}; known: {sorted(PRESETS)}")
