"""jax cross-version shims.

The framework targets the current jax API surface but must also run on the
0.4.x line (this container ships 0.4.37).  Keep every version branch in
this leaf module — call sites stay clean and the suite exercises one
definition.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map_compat"]


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names=None,
                     check_vma: bool = False):
    """``jax.shard_map`` across jax versions.

    jax >= 0.5 exposes ``jax.shard_map(..., axis_names=, check_vma=)``;
    0.4.x has ``jax.experimental.shard_map.shard_map(..., check_rep=,
    auto=)``.  ``axis_names`` (the axes that are manual inside the body;
    all others stay auto) is translated to the old ``auto=`` complement.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kw["auto"] = auto
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, **kw)
