"""Transprecision operations — FPnew's functional units as JAX ops.

Every op takes a :class:`PrecisionPolicy` and computes with the paper's
multi-format semantics:

  * ``tp_fma``     — expanding FMA ``dst fma(src a, src b, dst c)`` with a
                     single rounding into dst (paper §II.B.4, Fig 11e).
  * ``tp_matmul``/``tp_einsum`` — the same contract lifted to contractions:
                     operands in ``src_fmt``, accumulation in ``acc_fmt``
                     (MXU semantics), result stored in ``out_fmt``.
  * ``cast_and_pack`` — convert two scalar streams and pack them as vector
                     elements (paper §III.A.2c).
  * ``tp_cast``    — CONV block: format conversion with any rounding mode.
  * ``quantize_ste`` — straight-through-estimator quantization for training.

In ``native`` mode the ops emit real narrow dtypes (what a TPU executes and
what the roofline measures); in ``emulate`` mode they snap f32 containers to
the target grid bit-exactly (what the numerics tests validate).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from . import softfloat
from .formats import FPFormat, get_format
from .policy import MatmulPolicy, PrecisionPolicy, get_policy

__all__ = [
    "tp_cast", "quantize_ste", "tp_fma", "tp_matmul", "tp_einsum",
    "cast_and_pack", "tp_elementwise", "storage_dtype", "set_mixed_dot",
]

# Emit true mixed-precision dots (bf16 x bf16 -> f32, the MXU's native
# expanding FMA) in the HLO.  XLA:CPU can *compile* these but its thunk
# runtime cannot execute every layout, so execution paths on CPU default to
# upcasting operands first (bit-identical results — narrow->f32 casts are
# exact).  The dry-run (lower/compile only) enables this so the lowered HLO
# and its cost analysis match what a TPU would run.
_MIXED_DOT = False


def set_mixed_dot(enable: bool) -> None:
    global _MIXED_DOT
    _MIXED_DOT = enable


def storage_dtype(fmt, mode: str):
    """dtype used to store values of ``fmt`` under the given mode."""
    fmt = get_format(fmt)
    if mode == "native":
        assert fmt.native_dtype is not None, f"{fmt} has no native dtype"
        return fmt.native_dtype
    return fmt.container_dtype() if fmt.container_dtype() == jnp.float32 else jnp.float32


def tp_cast(x, fmt, policy=None, *, rounding: Optional[str] = None,
            key=None, saturate: bool = False):
    """CONV block: convert ``x`` to ``fmt`` under the policy's mode."""
    fmt = get_format(fmt)
    policy = get_policy(policy) if policy is not None else None
    mode = policy.mode if policy is not None else "native"
    rounding = rounding or (policy.rounding if policy is not None else "rne")
    if mode == "native":
        if rounding == "stochastic":
            # stochastic rounding has no native lowering — emulate the grid
            # then bitcast down (values are exactly representable)
            q = softfloat.quantize(jnp.asarray(x, jnp.float32), fmt,
                                   "stochastic", key=key, saturate=saturate)
            return q.astype(fmt.native_dtype)
        return jnp.asarray(x).astype(fmt.native_dtype)
    return softfloat.quantize(x, fmt, rounding, key=key, saturate=saturate)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def quantize_ste(x, fmt, rounding="rne"):
    """Quantize to ``fmt``'s grid with a straight-through gradient."""
    return softfloat.quantize(x, fmt, rounding)


def _ste_fwd(x, fmt, rounding):
    return softfloat.quantize(x, fmt, rounding), None


def _ste_bwd(fmt, rounding, _, g):
    return (g,)


quantize_ste.defvjp(_ste_fwd, _ste_bwd)


def tp_fma(a, b, c, policy, *, key=None):
    """Expanding FMA: multiply ``a*b`` in ``src_fmt`` (exact product),
    accumulate with ``c`` in ``acc_fmt`` with a single rounding.

    Emulation exactness: products of two src_fmt values are exactly
    representable in the f32 container whenever 2*p_src <= 24, which holds
    for all of the paper's sub-32-bit formats; the one rounding then happens
    in the quantize to acc_fmt (innocuous double rounding per Figueroa).
    """
    policy = get_policy(policy)
    mp = policy.matmul
    if policy.mode == "native":
        sa = a.astype(mp.src_fmt.native_dtype)
        sb = b.astype(mp.src_fmt.native_dtype)
        acc_dt = storage_dtype(mp.acc_fmt, "native")
        return (sa.astype(acc_dt) * sb.astype(acc_dt)
                + c.astype(acc_dt)).astype(acc_dt)
    qa = softfloat.quantize(a, mp.src_fmt, policy.rounding, key=key)
    qb = softfloat.quantize(b, mp.src_fmt, policy.rounding, key=key)
    prod = qa * qb  # exact in container
    return softfloat.quantize(prod + c, mp.acc_fmt, policy.rounding, key=key)


def tp_einsum(spec: str, a, b, policy, *, out_fmt=None, use_ste: bool = True,
              precision=None):
    """Contraction with multi-format FMA semantics.

    native : operands cast to src_fmt's dtype, dot with
             ``preferred_element_type`` = acc dtype (MXU expanding FMA),
             output cast to out_fmt.
    emulate: operands snapped to src_fmt grid (STE for training), f32
             accumulation (the acc grid for acc_fmt==fp32), output snapped.
    """
    policy = get_policy(policy)
    mp = policy.matmul
    out = get_format(out_fmt) if out_fmt is not None else mp.resolved_out()
    if policy.mode == "native":
        sa = a.astype(mp.src_fmt.native_dtype)
        sb = b.astype(mp.src_fmt.native_dtype)
        acc_dt = storage_dtype(mp.acc_fmt, "native")
        if policy.narrow_partials and out.width < mp.acc_fmt.width \
                and out.native_dtype is not None:
            # emit the dot with a narrow output element type: XLA's
            # cross-shard partial-sum all-reduce then runs in the narrow
            # format (per-tile MXU accumulation is still f32)
            acc_dt = out.native_dtype
        if _MIXED_DOT:
            r = jnp.einsum(spec, sa, sb, preferred_element_type=acc_dt,
                           precision=precision)
        else:
            r = jnp.einsum(spec, sa.astype(acc_dt), sb.astype(acc_dt),
                           precision=precision)
        return r.astype(out.native_dtype)
    q = quantize_ste if use_ste else (lambda x, f, r: softfloat.quantize(x, f, r))
    qa = q(a, mp.src_fmt, policy.rounding)
    qb = q(b, mp.src_fmt, policy.rounding)
    r = jnp.einsum(spec, qa, qb, preferred_element_type=jnp.float32,
                   precision=precision)
    # accumulate grid: f32 container accumulation == acc_fmt when acc is
    # fp32; narrower acc grids get a final snap (chunkwise-rounded model)
    if mp.acc_fmt.name != "fp32":
        r = q(r, mp.acc_fmt, policy.rounding)
    if out.name != "fp32":
        r = q(r, out, policy.rounding)
    return r


def tp_matmul(a, b, policy, *, out_fmt=None, use_pallas: bool = False,
              **kw):
    """2D+ matmul ``a @ b`` under the policy; optionally via the Pallas
    tp_matmul kernel (perf path)."""
    if use_pallas:
        from ..kernels import ops as kops
        return kops.tp_matmul(a, b, policy=get_policy(policy),
                              out_fmt=out_fmt, **kw)
    return tp_einsum("...ij,jk->...ik", a, b, policy, out_fmt=out_fmt, **kw)


def cast_and_pack(a, b, fmt, policy=None, *, axis: int = -1):
    """Paper §III.A.2c: convert two scalar operand streams to ``fmt`` and
    pack them as interleaved elements of the destination vector along
    ``axis``: ``out[.., 2i, ..] = a[.., i, ..]`` and ``out[.., 2i+1, ..] =
    b[.., i, ..]``, so ``out.shape[axis] == 2 * a.shape[axis]``."""
    fmt = get_format(fmt)
    qa = tp_cast(a, fmt, policy)
    qb = tp_cast(b, fmt, policy)
    axis = axis % qa.ndim
    stacked = jnp.stack([qa, qb], axis=axis + 1)
    shape = list(qa.shape)
    shape[axis] *= 2
    return stacked.reshape(shape)


# -- DIVSQRT / elementwise group --------------------------------------------
_ELEM_FNS = {
    "exp": jnp.exp, "log": jnp.log, "rsqrt": jax.lax.rsqrt,
    "sqrt": jnp.sqrt, "div": lambda a, b: a / b, "recip": lambda a: 1.0 / a,
    "tanh": jnp.tanh, "silu": jax.nn.silu, "gelu": jax.nn.gelu,
    "sigmoid": jax.nn.sigmoid,
}


def tp_elementwise(fn: str, *args, policy, out_fmt=None):
    """DIVSQRT-group op computed in ``elem_fmt`` (paper's iterative unit has
    a per-format precision knob; here the knob is the compute format)."""
    policy = get_policy(policy)
    ef = policy.elem_fmt
    if policy.mode == "native":
        cdt = storage_dtype(ef, "native")
        r = _ELEM_FNS[fn](*[jnp.asarray(x).astype(cdt) for x in args])
        if out_fmt is not None:
            r = r.astype(get_format(out_fmt).native_dtype)
        return r
    qargs = [softfloat.quantize(x, ef, policy.rounding) for x in args]
    r = softfloat.quantize(_ELEM_FNS[fn](*qargs), ef, policy.rounding)
    if out_fmt is not None:
        r = softfloat.quantize(r, out_fmt, policy.rounding)
    return r
