"""repro.core — FPnew's transprecision architecture as a JAX numerics layer."""
from .formats import (FPFormat, REGISTRY, get_format,
                      FP64, FP32, FP16, FP16ALT, FP8, FP8_E4M3, TF32)
from .softfloat import quantize, ROUNDING_MODES
from .policy import MatmulPolicy, PrecisionPolicy, get_policy, PRESETS
from .ops import (tp_cast, quantize_ste, tp_fma, tp_matmul, tp_einsum,
                  cast_and_pack, tp_elementwise, storage_dtype)
from . import energy, hw

__all__ = [
    "FPFormat", "REGISTRY", "get_format",
    "FP64", "FP32", "FP16", "FP16ALT", "FP8", "FP8_E4M3", "TF32",
    "quantize", "ROUNDING_MODES",
    "MatmulPolicy", "PrecisionPolicy", "get_policy", "PRESETS",
    "tp_cast", "quantize_ste", "tp_fma", "tp_matmul", "tp_einsum",
    "cast_and_pack", "tp_elementwise", "storage_dtype",
    "energy", "hw",
]
