"""Pallas TPU kernels for FPnew's performance-critical compute paths.

Each kernel ships three pieces (framework convention):
  <name>.py — pl.pallas_call + BlockSpec VMEM tiling,
  ops.py    — jit'd public wrapper (padding, policy plumbing),
  ref.py    — pure-jnp oracle with identical format semantics.
Validated in interpret mode on CPU; compiled on TPU via interpret=False.
"""
from . import autotune, ops, ref
from .ops import (tp_matmul, tp_quantize, cast_and_pack, flash_attention,
                  decode_attention, dotp_ex)
