"""Pallas TPU kernel: fused quantize / cast-and-pack (FPnew CONV block).

Converts one or two f32 streams onto an arbitrary (e, m) grid — RNE or
stochastic — and packs them into the destination vector, mirroring the
paper's vectorial conversions and cast-and-pack instructions (§III.A.2b/c).
Stochastic rounding consumes a caller-supplied uint32 random-bits operand
(deterministic, reproducible — the framework threads PRNG keys, the kernel
stays pure).

Grid: 1D over row blocks; each block is an (rows, 128)-aligned VMEM tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.formats import FPFormat, get_format
from .quant_common import quantize_bits as _quant_bits


def _quant_kernel(x_ref, r_ref, o_ref, *, fmt, stochastic, out_dtype):
    q = _quant_bits(x_ref[...], r_ref[...], fmt, stochastic)
    o_ref[...] = q.astype(out_dtype)


def _pack_kernel(a_ref, b_ref, r_ref, o_ref, *, fmt, stochastic, out_dtype):
    qa = _quant_bits(a_ref[...], r_ref[...], fmt, stochastic)
    qb = _quant_bits(b_ref[...], ~r_ref[...], fmt, stochastic)
    rows, cols = qa.shape
    packed = jnp.stack([qa, qb], axis=-1).reshape(rows, 2 * cols)
    o_ref[...] = packed.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "fmt_name", "stochastic", "block_rows", "out_dtype", "interpret"))
def tp_quantize_pallas(x, rbits=None, *, fmt_name: str, stochastic=False,
                       block_rows: int = 256, out_dtype=jnp.float32,
                       interpret: bool = True):
    """Quantize a 2D f32 array onto fmt's grid. rbits: uint32, same shape."""
    fmt = get_format(fmt_name)
    rows, cols = x.shape
    assert rows % block_rows == 0 and cols % 128 == 0, x.shape
    if rbits is None:
        rbits = jnp.zeros(x.shape, jnp.uint32)
    return pl.pallas_call(
        functools.partial(_quant_kernel, fmt=fmt, stochastic=stochastic,
                          out_dtype=out_dtype),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        interpret=interpret,
    )(x, rbits)


@functools.partial(jax.jit, static_argnames=(
    "fmt_name", "stochastic", "block_rows", "out_dtype", "interpret"))
def cast_and_pack_pallas(a, b, rbits=None, *, fmt_name: str,
                         stochastic=False, block_rows: int = 256,
                         out_dtype=jnp.float32, interpret: bool = True):
    """Fused cast-and-pack: quantize two f32 streams and interleave them as
    vector elements (paper §III.A.2c).  Output has 2x the columns."""
    fmt = get_format(fmt_name)
    rows, cols = a.shape
    assert a.shape == b.shape
    assert rows % block_rows == 0 and cols % 128 == 0, a.shape
    if rbits is None:
        rbits = jnp.zeros(a.shape, jnp.uint32)
    return pl.pallas_call(
        functools.partial(_pack_kernel, fmt=fmt, stochastic=stochastic,
                          out_dtype=out_dtype),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((block_rows, 2 * cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 2 * cols), out_dtype),
        interpret=interpret,
    )(a, b, rbits)
