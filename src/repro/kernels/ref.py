"""Pure-jnp oracles for every Pallas kernel (the paper-semantics references).

Each function mirrors one kernel's contract exactly — same formats, same
masking, same accumulation dtype — but written as straight jnp so tests can
assert_allclose kernels against them over shape/dtype sweeps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import softfloat
from ..core.formats import get_format

NEG_INF = -1e30

N_FLAG_CH = 4   # flag-count channel order: OF, UF, NX, NV


def _per_row_lens(kv_len, bh, default):
    """Normalize a scalar-or-vector ``kv_len`` to a length-``bh`` numpy int
    vector (one live length per flattened head row) — the oracle twin of the
    kernels' SMEM length normalization.  ``None`` means ``default``."""
    import numpy as np
    if kv_len is None:
        kv_len = default
    lens = np.asarray(kv_len, np.int64).reshape(-1)
    assert lens.shape[0] in (1, bh), (lens.shape, bh)
    return np.broadcast_to(lens, (bh,))


def tp_matmul_ref(a, b, *, out_dtype=jnp.float32, quant_fmt_name=None,
                  bk=None):
    """Expanding-FMA matmul oracle: optional fp-grid operand snap (FTZ like
    the kernel), f32 accumulation, out_dtype store.

    ``bk`` fixes the K-blocking schedule: partial products are summed per
    K-block in order, exactly like the kernel's VMEM accumulator.  The
    summation schedule is part of the op's numerical contract (the paper's
    FMA units likewise specify their accumulation order); with matching
    ``bk`` the oracle is bit-exact against the kernel."""
    if quant_fmt_name is not None:
        fmt = get_format(quant_fmt_name)
        a = _ftz(softfloat.quantize(a.astype(jnp.float32), fmt), fmt)
        b = _ftz(softfloat.quantize(b.astype(jnp.float32), fmt), fmt)
    # operands stay in their source dtype (the MXU contract); only the
    # accumulator is f32 — identical to the kernel's dot_general.
    k = a.shape[-1]
    dot = lambda x, y: jax.lax.dot_general(
        x, y, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    if bk is None or bk >= k:
        r = dot(a, b)
    else:
        assert k % bk == 0, (k, bk)
        r = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
        for kk in range(0, k, bk):  # sequential K-block accumulation
            r = r + dot(a[:, kk:kk + bk], b[kk:kk + bk, :])
    return r.astype(out_dtype)


def _ftz(x, fmt):
    return jnp.where(jnp.abs(x) < fmt.min_normal, jnp.sign(x) * 0.0, x)


def _snap(x, fmt, src_dtype):
    """Oracle twin of quant_common.widen: emulate-mode f32 containers are
    RNE-snapped (with FTZ) onto the storage grid, then cast to the compute
    dtype.  Shared by every attention oracle in this module."""
    if fmt is not None and x.dtype == jnp.float32:
        x = _ftz(softfloat.quantize(x, fmt), fmt)
    return x.astype(src_dtype)


def tp_quantize_ref(x, *, fmt_name, out_dtype=jnp.float32):
    fmt = get_format(fmt_name)
    q = _ftz(softfloat.quantize(x.astype(jnp.float32), fmt), fmt)
    return q.astype(out_dtype)


def cast_and_pack_ref(a, b, *, fmt_name, out_dtype=jnp.float32):
    qa = tp_quantize_ref(a, fmt_name=fmt_name, out_dtype=out_dtype)
    qb = tp_quantize_ref(b, fmt_name=fmt_name, out_dtype=out_dtype)
    r, c = qa.shape
    return jnp.stack([qa, qb], axis=-1).reshape(r, 2 * c)


def flash_attention_ref(q, k, v, *, group: int = 1, scale: float = 1.0,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        kv_len: Optional[int] = None, q_offset: int = 0,
                        src_fmt_name: Optional[str] = None,
                        src_dtype=jnp.bfloat16, out_dtype=jnp.float32,
                        bq: Optional[int] = None, bk: Optional[int] = None):
    """Flash-attention oracle with identical format contract to the kernel.

    ``bq``/``bk`` fix the online-softmax blocking schedule: the oracle then
    walks the SAME pruned block schedule as the kernel
    (``flash_attention.block_schedule``) with the same per-block rescaling
    ops, making it bit-exact against ``flash_attention_pallas`` in interpret
    mode — the prefill analogue of ``decode_attention_ref``'s ``bk``.  With
    ``bq=bk=None`` it is the plain dense-softmax reference (one global max,
    one sum — tolerance comparisons only).

    ``src_fmt_name`` mirrors the kernel's emulate-mode RNE operand snap
    (f32 containers); ``q_offset`` shifts query positions for the causal /
    window masks.  q: [BH, Sq, D]; k: [BKV, Skv, D]; v: [BKV, Skv, Dv].

    ``kv_len`` may be a scalar (every row shares one length) or a per-row
    length-BH vector (ragged batches — the per-sequence oracle; expand a
    [B] sequence-length vector by the head count like ops.py does).
    """
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    kv_len = _per_row_lens(kv_len, bh, skv)
    if bq is not None or bk is not None:
        assert bq is not None and bk is not None, (bq, bk)
        return _flash_blocked_ref(
            q, k, v, group=group, scale=scale, causal=causal, window=window,
            softcap=softcap, kv_len=kv_len, q_offset=q_offset,
            src_fmt_name=src_fmt_name, src_dtype=src_dtype,
            out_dtype=out_dtype, bq=bq, bk=bk)

    fmt = get_format(src_fmt_name) if src_fmt_name else None
    snap = lambda x: _snap(x, fmt, src_dtype)

    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", snap(q), snap(kk),
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_idx = q_offset + jnp.arange(sq)[:, None]
    k_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    # per-row live length: [BH, 1, Skv] against the static [Sq, Skv] masks
    mask = mask[None] & (k_idx[None] < jnp.asarray(kv_len)[:, None, None])
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("hqk,hkd->hqd", snap(p).astype(jnp.float32),
                   vv.astype(jnp.float32), preferred_element_type=jnp.float32)
    return (o / jnp.where(l == 0.0, 1.0, l)).astype(out_dtype)


def _flash_block_update(qb, kb, vb, acc, m, l, q_base, k_base, kvl, *,
                        scale, causal, window, softcap, src_fmt_name,
                        src_dtype):
    """One online-softmax block step — the exact op sequence of
    ``flash_attention._attn_kernel``'s work block.  MUST run jitted: the
    rescale updates are mul+add chains that XLA:CPU contracts into FMAs
    (single rounding) inside any compiled computation — eager op-by-op
    dispatch rounds twice and is one ulp off.  The jitted form matches the
    kernel (whose body is always compiled, interpret mode included)."""
    from .decode_attention import softcap_scores

    fmt = get_format(src_fmt_name) if src_fmt_name else None
    snap = lambda x: _snap(x, fmt, src_dtype)
    bq, bk = qb.shape[0], kb.shape[0]
    s = jax.lax.dot_general(snap(qb), snap(kb), (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap_scores(s, softcap)
    q_idx = q_base + jnp.arange(bq)[:, None]
    k_idx = k_base + jnp.arange(bk)[None, :]
    mask = k_idx < kvl
    if causal:
        mask = mask & (q_idx >= k_idx)
    if window is not None:
        mask = mask & ((q_idx - k_idx) < window)
    s = jnp.where(mask, s, NEG_INF)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m, m_cur)
    p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(jnp.where(m_new <= NEG_INF / 2, 0.0, m - m_new))
    l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(snap(p), snap(vb), (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc = acc * alpha + pv
    return acc, m_new, l


def _flash_blocked_ref(q, k, v, *, group, scale, causal, window, softcap,
                       kv_len, q_offset, src_fmt_name, src_dtype, out_dtype,
                       bq, bk):
    """Blocked online-softmax walk over the kernel's pruned schedule —
    elementary-op-for-op the same updates as ``_attn_kernel``, so the
    result is bitwise identical in interpret mode.  ``kv_len`` is the
    per-row length vector from ``_per_row_lens``: each row early-outs at
    its OWN length, the oracle twin of the kernel's per-row ``pl.when``."""
    from .flash_attention import block_schedule

    bh, sq, d = q.shape
    dv = v.shape[-1]
    qi, ki, ff, lf = block_schedule(sq, k.shape[1], bq, bk, causal=causal,
                                    window=window, q_offset=q_offset)
    upd = jax.jit(functools.partial(
        _flash_block_update, scale=scale, causal=causal, window=window,
        softcap=softcap, src_fmt_name=src_fmt_name, src_dtype=src_dtype))
    out = []
    for h in range(bh):
        hk = h // group
        kvl = int(kv_len[h])
        rows = {}
        for step in range(len(qi)):
            iq, ik = int(qi[step]), int(ki[step])
            if ff[step]:
                acc = jnp.zeros((bq, dv), jnp.float32)
                m = jnp.full((bq, 1), NEG_INF, jnp.float32)
                l = jnp.zeros((bq, 1), jnp.float32)
            if ik * bk < kvl:   # the kernel's dynamic pl.when early-out
                acc, m, l = upd(q[h, iq * bq:(iq + 1) * bq],
                                k[hk, ik * bk:(ik + 1) * bk],
                                v[hk, ik * bk:(ik + 1) * bk],
                                acc, m, l,
                                jnp.int32(q_offset + iq * bq),
                                jnp.int32(ik * bk), jnp.int32(kvl))
            if lf[step]:
                rows[iq] = (acc /
                            jnp.where(l == 0.0, 1.0, l)).astype(out_dtype)
        out.append(jnp.concatenate([rows[iq] for iq in sorted(rows)], axis=0))
    return jnp.stack(out)


def decode_attention_ref(q, k, v, *, kv_len, scale: float = 1.0,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         kv_fmt_name: Optional[str] = None,
                         q_fmt_name: Optional[str] = None,
                         src_dtype=jnp.float32, out_dtype=jnp.float32,
                         bk: Optional[int] = None):
    """Dense single-query decode-attention oracle with the decode kernel's
    exact format contract: in-container RNE snap of KV (and optionally q)
    onto the storage grid, src-format multiplies, f32 accumulation, exact
    global softmax max, single store cast.

    ``bk`` fixes the KV-blocking schedule of the numerator/denominator
    accumulation (and the score dot shapes), exactly like tp_matmul_ref's
    K-blocking — with matching ``bk`` the oracle is bit-exact against
    decode_attention_pallas in interpret mode; with ``bk=None`` it is the
    plain dense path (one block).

    q: [BHkv, G, D]; k, v: [BHkv, Smax, D]; kv_len: int (or 0-d array)
    shared by every row, or a per-row length-BHkv vector (ragged batches —
    each row's KV blocks past its own length are skipped, mirroring the
    kernel's per-row early-exit).
    """
    bh, g, d = q.shape
    _, smax, _ = k.shape
    bk = smax if bk is None else bk
    assert smax % bk == 0, (smax, bk)
    kv_len = _per_row_lens(kv_len, bh, smax)

    snap = lambda x, fmt_name: _snap(
        x, get_format(fmt_name) if fmt_name else None, src_dtype)
    qs = snap(q, q_fmt_name)
    ks = snap(k, kv_fmt_name)
    vs = snap(v, kv_fmt_name)
    dot_qk = lambda qi, ki: jax.lax.dot_general(
        qi, ki, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    dot_pv = lambda pi, vi: jax.lax.dot_general(
        pi, vi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    out = []
    for h in range(bh):
        kvl = int(kv_len[h])
        if kvl <= 0:           # empty row: the kernel's l == 0 store guard
            out.append(jnp.zeros((g, d), out_dtype))
            continue
        blocks = []
        for kk in range(0, smax, bk):
            if kk >= kvl:      # the kernel's per-row early-exit (exact)
                continue
            s = dot_qk(qs[h], ks[h, kk:kk + bk]) * scale
            if softcap is not None:
                from .decode_attention import softcap_scores
                s = softcap_scores(s, softcap)
            k_idx = kk + jnp.arange(bk)[None, :]
            mask = k_idx < kvl
            if window is not None:
                mask = mask & (k_idx > kvl - 1 - window)
            blocks.append((kk, jnp.where(mask, s, NEG_INF), mask))
        m = jnp.max(jnp.concatenate([s for _, s, _ in blocks], axis=-1),
                    axis=-1, keepdims=True)
        m = jnp.where(m <= NEG_INF / 2, 0.0, m)
        acc = jnp.zeros((g, d), jnp.float32)
        l = jnp.zeros((g, 1), jnp.float32)
        for kk, s, mask in blocks:
            p = jnp.where(mask, jnp.exp(s - m), 0.0)
            l = l + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc + dot_pv(p.astype(src_dtype), vs[h, kk:kk + bk])
        out.append((acc / jnp.where(l == 0.0, 1.0, l)).astype(out_dtype))
    return jnp.stack(out)


def paged_gather(pool, table):
    """Materialize a paged KV layout back into per-row contiguous strips:
    ``pool`` [n_pages, page, D] gathered through ``table`` [rows, nk] ->
    [rows, nk * page, D].  Pure data movement (no arithmetic), so oracles
    built on it are exact references for the paged kernels: the kernel
    dereferences the table at DMA time, the oracle dereferences it up
    front, and both then run the identical blocked walk."""
    rows, nk = table.shape
    n_pages, page, d = pool.shape
    g = jnp.take(pool, jnp.asarray(table).reshape(-1), axis=0)
    return g.reshape(rows, nk * page, d)


def decode_attention_paged_ref(q, k_pool, v_pool, block_table, *, kv_len,
                               **kw):
    """Paged decode-attention oracle: gather pages to the contiguous view,
    then run ``decode_attention_ref`` with ``bk`` pinned to the page size
    (the paged kernel's block IS the page, so the blocked accumulation
    schedule — part of the numerical contract — matches and the result is
    bit-exact against ``decode_attention_pallas(..., block_table=)``,
    partial tail pages included via the usual ``kv_len`` masking).

    q: [BHkv, G, D]; k_pool/v_pool: [n_pages, page, D];
    block_table: [BHkv, nk] flat per-head page ids."""
    page = k_pool.shape[1]
    return decode_attention_ref(q, paged_gather(k_pool, block_table),
                                paged_gather(v_pool, block_table),
                                kv_len=kv_len, bk=page, **kw)


def flash_attention_paged_ref(q, k_pool, v_pool, block_table, *, bq,
                              kv_len=None, **kw):
    """Paged flash-attention oracle: gather, then the blocked online-softmax
    walk with ``bk`` pinned to the page size — bit-exact against
    ``flash_attention_pallas(..., block_table=)`` (same pruned schedule,
    same per-block update ops, same operand values).

    q: [BH, Sq, D]; k_pool: [n_pages, page, D]; v_pool: [n_pages, page,
    Dv]; block_table: [BKV, nk] per-KV-row page ids (BH = BKV * group)."""
    page = k_pool.shape[1]
    return flash_attention_ref(q, paged_gather(k_pool, block_table),
                               paged_gather(v_pool, block_table),
                               kv_len=kv_len, bq=bq, bk=page, **kw)


def _flag_masks_ref(x, fmt):
    """Oracle twin of ``quant_common.widen_with_flags``'s masks, derived
    independently from the softfloat oracle: the non-saturating snap's Inf
    marks OF, the FTZ'd snap's value change marks NX, tininess below min
    normal plus NX marks UF, NaN input marks NV.  Native narrow storage
    (fmt None / non-f32 input): OF := stored ±Inf, NV := stored NaN,
    UF/NX := False."""
    if fmt is not None and x.dtype == jnp.float32:
        y_ieee = softfloat.quantize(x, fmt)       # overflow -> ±Inf
        y = _ftz(y_ieee, fmt)
        nv = jnp.isnan(x)
        of = jnp.isinf(y_ieee) & ~jnp.isinf(x) & ~nv
        nx = (y != x) & ~nv
        uf = (x != 0) & (jnp.abs(x) < fmt.min_normal) & nx
        return of, uf, nx, nv
    z = jnp.zeros(x.shape, bool)
    return jnp.isinf(x), z, z, jnp.isnan(x)


def _mask_counts(masks, live):
    return jnp.stack([jnp.sum((f & live).astype(jnp.int32),
                              axis=tuple(range(1, f.ndim)))
                      for f in masks], axis=-1)


def decode_flag_counts_ref(q, k, v, *, kv_len,
                           kv_fmt_name: Optional[str] = None,
                           q_fmt_name: Optional[str] = None):
    """Per-row IEEE flag-count oracle of ``decode_attention_pallas(...,
    debug_flags=True)`` summed over KV blocks: int32 [BHkv, 4] in OF, UF,
    NX, NV order.  Each live K/V element (position < that row's kv_len)
    counts once; Q counts once per row with live length > 0; dead/padded
    slots contribute zero.  Layouts as in :func:`decode_attention_ref`."""
    bh, g, d = q.shape
    smax = k.shape[1]
    kv_len = jnp.asarray(_per_row_lens(kv_len, bh, smax), jnp.int32)
    kfmt = get_format(kv_fmt_name) if kv_fmt_name else None
    qfmt = get_format(q_fmt_name) if q_fmt_name else None
    live = (jnp.arange(smax)[None, :, None]
            < kv_len[:, None, None])                       # [BH, Smax, 1]
    cnt = (_mask_counts(_flag_masks_ref(k, kfmt), live)
           + _mask_counts(_flag_masks_ref(v, kfmt), live))
    qc = _mask_counts(_flag_masks_ref(q, qfmt), jnp.ones((bh, 1, 1), bool))
    return cnt + jnp.where((kv_len > 0)[:, None], qc, 0)


def decode_flag_counts_paged_ref(q, k_pool, v_pool, block_table, *, kv_len,
                                 **kw):
    """Paged twin: gather pages to the contiguous view first (the count is
    schedule-free — a position is live iff it is < kv_len)."""
    return decode_flag_counts_ref(q, paged_gather(k_pool, block_table),
                                  paged_gather(v_pool, block_table),
                                  kv_len=kv_len, **kw)


def flash_flag_counts_ref(q, k, v, *, group: int = 1, kv_len=None,
                          causal: bool = True,
                          window: Optional[int] = None, q_offset: int = 0,
                          src_fmt_name: Optional[str] = None,
                          bq: int = 128, bk: int = 128):
    """Per-row flag-count oracle of ``flash_attention_pallas(...,
    debug_flags=True)`` summed over steps: int32 [BH, 4].  Walks the SAME
    pruned ``block_schedule`` with the kernel's per-VISIT semantics — a KV
    block seen by several query blocks is charged at each visit, the Q
    tile once per query block at its first scheduled step, early-out steps
    (block start >= that row's kv_len) charge nothing."""
    from .flash_attention import block_schedule

    bh, sq, d = q.shape
    skv = k.shape[1]
    kv_len = _per_row_lens(kv_len, bh, skv)
    fmt = get_format(src_fmt_name) if src_fmt_name else None
    qi, ki, ff, lf = block_schedule(sq, skv, bq, bk, causal=causal,
                                    window=window, q_offset=q_offset)
    kmask = _flag_masks_ref(k, fmt)
    vmask = _flag_masks_ref(v, fmt)
    qmask = _flag_masks_ref(q, fmt)
    pos = jnp.arange(skv)[:, None]                          # [Skv, 1]
    out = []
    for h in range(bh):
        hk = h // group
        kvl = int(kv_len[h])
        cnt = jnp.zeros((N_FLAG_CH,), jnp.int32)
        for step in range(len(qi)):
            iq, ik = int(qi[step]), int(ki[step])
            if ik * bk >= kvl:
                continue
            live = pos[ik * bk:(ik + 1) * bk] < kvl
            cnt = cnt + _mask_counts(
                [f[hk, ik * bk:(ik + 1) * bk][None] for f in kmask],
                live[None])[0]
            cnt = cnt + _mask_counts(
                [f[hk, ik * bk:(ik + 1) * bk][None] for f in vmask],
                live[None])[0]
            if ff[step]:
                cnt = cnt + _mask_counts(
                    [f[h, iq * bq:(iq + 1) * bq][None] for f in qmask],
                    jnp.ones((1, 1, 1), bool))[0]
        out.append(cnt)
    return jnp.stack(out)


def flash_flag_counts_paged_ref(q, k_pool, v_pool, block_table, *, bq,
                                kv_len=None, **kw):
    """Paged twin of :func:`flash_flag_counts_ref` (bk pinned to the page
    size, like the paged output oracles)."""
    page = k_pool.shape[1]
    return flash_flag_counts_ref(q, paged_gather(k_pool, block_table),
                                 paged_gather(v_pool, block_table),
                                 kv_len=kv_len, bq=bq, bk=page, **kw)


def dotp_ex_ref(a, b, *, src_dtype=jnp.float16):
    """Expanding dot product oracle (f32 accumulate of exact products)."""
    prod = (a.astype(src_dtype).astype(jnp.float32)
            * b.astype(src_dtype).astype(jnp.float32))
    return jnp.sum(prod)


def dotp_sequential_ref(a, b, *, src_fmt="fp16", acc_fmt="fp32"):
    """Bit-exact *sequential* oracle of the paper's fmacex loop (Fig 11e):
    acc_{i+1} = round_acc(acc_i + a_i * b_i), products exact."""
    src, acc = get_format(src_fmt), get_format(acc_fmt)
    qa = softfloat.quantize(a, src)
    qb = softfloat.quantize(b, src)

    def step(acc_v, ab):
        s = softfloat.quantize(acc_v + ab[0] * ab[1], acc)
        return s, ()

    out, _ = jax.lax.scan(step, jnp.float32(0.0), (qa, qb))
    return out
