"""Shared in-kernel quantization primitives (FPnew CONV block).

Integer-space rounding of f32 containers onto an arbitrary (e, m) grid —
the bit-twiddling core used by every Pallas kernel that fuses a format
conversion into its datapath: tp_quant (standalone CONV), tp_matmul
(CONV->ADDMUL operand snap), and decode_attention (CONV->ADDMUL dequant of
the narrow KV cache inside the attention loop).

Hoisted here so kernels share one bit-exact implementation; the pure-jnp
oracle is ``softfloat.quantize`` + FTZ (see kernels/ref.py), and
tests/test_kernels.py pins the two against each other.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.formats import FPFormat


def quantize_bits(x, rbits, fmt: FPFormat, stochastic: bool,
                  saturate: bool = False):
    """Integer-space rounding onto fmt's grid (normals; FTZ below min normal,
    matching the MXU input stage; softfloat.quantize keeps the gradual-
    underflow oracle).

    ``rbits`` is a uint32 array of x's shape supplying the stochastic
    addend; ignored (may be None) when ``stochastic`` is False.
    ``saturate=True`` clamps overflow to ±max_normal instead of ±Inf (the
    non-IEEE saturating CONV mode: a finite, degraded value instead of an
    Inf that poisons every downstream FMA).
    """
    m, emax, emin = fmt.m_bits, fmt.emax, fmt.emin
    s = 23 - m
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = bits & jnp.uint32(0x80000000)
    mag = bits ^ sign
    if stochastic:
        addend = rbits & jnp.uint32((1 << s) - 1)
    else:
        tie = (mag >> s) & jnp.uint32(1)
        addend = (jnp.uint32(1) << (s - 1)) - jnp.uint32(1) + tie
    special = mag >= jnp.uint32(0xFF << 23)
    rmag = ((mag + addend) >> s) << s
    max_bits = jnp.uint32(((emax + 127) << 23) | (((1 << m) - 1) << s))
    ovf = max_bits if saturate else jnp.uint32(0xFF << 23)
    rmag = jnp.where(rmag > max_bits, ovf, rmag)
    # FTZ below min normal, except the RNE subnormal-boundary band
    # [min_normal*(1-2^-(m+1)), min_normal) which rounds up to min_normal
    # on the true IEEE grid (deterministic mode only; stochastic keeps the
    # plain flush — the bias is confined to that half-ulp band).
    min_bits = jnp.uint32((emin + 127) << 23)
    if stochastic:
        rmag = jnp.where(rmag < min_bits, jnp.uint32(0), rmag)
    else:
        # boundary = 2^(emin-1) * (2 - 2^-m) = min_normal * (1 - 2^-(m+1))
        boundary = jnp.uint32(((emin - 1 + 127) << 23)
                              | (((1 << m) - 1) << (23 - m)))
        rmag = jnp.where(rmag < min_bits,
                         jnp.where(mag >= boundary, min_bits, jnp.uint32(0)),
                         rmag)
    rmag = jnp.where(special, mag, rmag)
    return jax.lax.bitcast_convert_type(sign | rmag, jnp.float32)


def quantize_rne_bits(x, fmt: FPFormat, saturate: bool = False):
    """RNE grid snap of an f32 array onto ``fmt`` (no randomness operand) —
    the in-kernel dequant step for narrow formats stored in f32 containers."""
    return quantize_bits(x, None, fmt, stochastic=False, saturate=saturate)


def quantize_flag_masks(x, fmt: FPFormat, saturate: bool = False):
    """RNE grid snap plus the IEEE status flags it raises (FPnew's fflags,
    §II.B, FTZ flavor): ``(y, of, uf, nx, nv)`` with per-element bool masks.

    OF: |x| rounded beyond max normal (raised in BOTH overflow modes —
    saturation changes the value written, not the telemetry).  UF: nonzero
    |x| below min normal AND inexact (FTZ makes every flush inexact, so a
    target-exact subnormal still reports the damage).  NX: y != x.  NV:
    x is NaN.  Specials (Inf in, NaN in) pass through and raise only NV.
    """
    m, emax, emin = fmt.m_bits, fmt.emax, fmt.emin
    s = 23 - m
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = bits & jnp.uint32(0x80000000)
    mag = bits ^ sign
    tie = (mag >> s) & jnp.uint32(1)
    addend = (jnp.uint32(1) << (s - 1)) - jnp.uint32(1) + tie
    special = mag >= jnp.uint32(0xFF << 23)
    nv = mag > jnp.uint32(0xFF << 23)
    rmag = ((mag + addend) >> s) << s
    max_bits = jnp.uint32(((emax + 127) << 23) | (((1 << m) - 1) << s))
    over = rmag > max_bits
    ovf = max_bits if saturate else jnp.uint32(0xFF << 23)
    rmag = jnp.where(over, ovf, rmag)
    min_bits = jnp.uint32((emin + 127) << 23)
    boundary = jnp.uint32(((emin - 1 + 127) << 23)
                          | (((1 << m) - 1) << (23 - m)))
    rmag = jnp.where(rmag < min_bits,
                     jnp.where(mag >= boundary, min_bits, jnp.uint32(0)),
                     rmag)
    of = over & ~special
    nx = (rmag != mag) & ~special
    tiny = (mag != jnp.uint32(0)) & (mag < min_bits)
    uf = tiny & nx
    rmag = jnp.where(special, mag, rmag)
    return jax.lax.bitcast_convert_type(sign | rmag, jnp.float32), of, uf, nx, nv


def widen(x, fmt, src_dtype):
    """CONV stage: storage format -> compute format at the FMA input.
    Native narrow dtypes widen exactly; f32 containers RNE-snap onto the
    storage grid first (emulated narrow storage).  Shared by the decode-
    and prefill-attention kernels."""
    if fmt is not None and x.dtype == jnp.float32:
        x = quantize_rne_bits(x, fmt)
    return x.astype(src_dtype)


def widen_with_flags(x, fmt, src_dtype):
    """:func:`widen` plus the flag masks the CONV stage raises:
    ``(y, of, uf, nx, nv)``.

    Emulated narrow storage (f32 container + fmt) reports the full set
    from the in-kernel grid snap.  Native narrow storage widens exactly,
    so the snap-time flags are gone — what remains observable is the
    damage already stored in the cache: OF := stored ±Inf, NV := stored
    NaN, UF/NX := False.  Telemetry consumers must read the two modes
    accordingly (docs/KERNELS.md)."""
    if fmt is not None and x.dtype == jnp.float32:
        y, of, uf, nx, nv = quantize_flag_masks(x, fmt)
        return y.astype(src_dtype), of, uf, nx, nv
    y = x.astype(src_dtype)
    none = jnp.zeros(x.shape, jnp.bool_)
    return y, jnp.isinf(x), none, none, jnp.isnan(x)
