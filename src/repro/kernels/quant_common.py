"""Shared in-kernel quantization primitives (FPnew CONV block).

Integer-space rounding of f32 containers onto an arbitrary (e, m) grid —
the bit-twiddling core used by every Pallas kernel that fuses a format
conversion into its datapath: tp_quant (standalone CONV), tp_matmul
(CONV->ADDMUL operand snap), and decode_attention (CONV->ADDMUL dequant of
the narrow KV cache inside the attention loop).

Hoisted here so kernels share one bit-exact implementation; the pure-jnp
oracle is ``softfloat.quantize`` + FTZ (see kernels/ref.py), and
tests/test_kernels.py pins the two against each other.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.formats import FPFormat


def quantize_bits(x, rbits, fmt: FPFormat, stochastic: bool):
    """Integer-space rounding onto fmt's grid (normals; FTZ below min normal,
    matching the MXU input stage; softfloat.quantize keeps the gradual-
    underflow oracle).

    ``rbits`` is a uint32 array of x's shape supplying the stochastic
    addend; ignored (may be None) when ``stochastic`` is False.
    """
    m, emax, emin = fmt.m_bits, fmt.emax, fmt.emin
    s = 23 - m
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = bits & jnp.uint32(0x80000000)
    mag = bits ^ sign
    if stochastic:
        addend = rbits & jnp.uint32((1 << s) - 1)
    else:
        tie = (mag >> s) & jnp.uint32(1)
        addend = (jnp.uint32(1) << (s - 1)) - jnp.uint32(1) + tie
    special = mag >= jnp.uint32(0xFF << 23)
    rmag = ((mag + addend) >> s) << s
    max_bits = jnp.uint32(((emax + 127) << 23) | (((1 << m) - 1) << s))
    rmag = jnp.where(rmag > max_bits, jnp.uint32(0xFF << 23), rmag)
    # FTZ below min normal, except the RNE subnormal-boundary band
    # [min_normal*(1-2^-(m+1)), min_normal) which rounds up to min_normal
    # on the true IEEE grid (deterministic mode only; stochastic keeps the
    # plain flush — the bias is confined to that half-ulp band).
    min_bits = jnp.uint32((emin + 127) << 23)
    if stochastic:
        rmag = jnp.where(rmag < min_bits, jnp.uint32(0), rmag)
    else:
        # boundary = 2^(emin-1) * (2 - 2^-m) = min_normal * (1 - 2^-(m+1))
        boundary = jnp.uint32(((emin - 1 + 127) << 23)
                              | (((1 << m) - 1) << (23 - m)))
        rmag = jnp.where(rmag < min_bits,
                         jnp.where(mag >= boundary, min_bits, jnp.uint32(0)),
                         rmag)
    rmag = jnp.where(special, mag, rmag)
    return jax.lax.bitcast_convert_type(sign | rmag, jnp.float32)


def quantize_rne_bits(x, fmt: FPFormat):
    """RNE grid snap of an f32 array onto ``fmt`` (no randomness operand) —
    the in-kernel dequant step for narrow formats stored in f32 containers."""
    return quantize_bits(x, None, fmt, stochastic=False)


def widen(x, fmt, src_dtype):
    """CONV stage: storage format -> compute format at the FMA input.
    Native narrow dtypes widen exactly; f32 containers RNE-snap onto the
    storage grid first (emulated narrow storage).  Shared by the decode-
    and prefill-attention kernels."""
    if fmt is not None and x.dtype == jnp.float32:
        x = quantize_rne_bits(x, fmt)
    return x.astype(src_dtype)
