"""Pallas TPU kernel: fused single-query decode attention over a quantized
KV cache — the serving-path instantiation of FPnew's CONV->ADDMUL fusion.

FPnew's headline energy-proportionality result comes from keeping narrow
formats *on the wire* and fusing the format conversion (CONV block) into the
FMA datapath (ADDMUL block) so values are widened exactly once, at the
multiplier input (paper §II.B.4, the expanding multi-format FMA
``dst fma(src a, src b, dst c)``).  This kernel applies that contract to the
hottest serving loop — decode attention against a long KV cache:

  stage           FPnew block   what happens here
  -----------     -----------   ------------------------------------------
  KV dequant      CONV          cache lines enter in their *storage* format
                                (native bf16/fp16/fp8 dtype, or an f32
                                container holding values on the ``kv_fmt``
                                grid); they are RNE-snapped / widened
                                in-kernel, per VMEM tile — never
                                materialized wide in HBM.
  q·K^T           ADDMUL        src-format multiplies, f32 accumulation
                                (the expanding FMA; MXU semantics).
  softmax stats   COMP          max / exp / sum stay f32 (the paper keeps
                                COMP in full precision).
  p·V             ADDMUL        src-format multiplies, f32 accumulation.
  store           CONV          single cast to ``out_dtype`` on the way out.

Layout: q [BHkv, G, D] (the G = n_heads/n_kv_heads query heads that share
one KV head), k/v [BHkv, Smax, D] cache buffers, kv_len a *dynamic* per-row
[BHkv, 1] vector (SMEM) masking dead cache slots — it changes every decode
step, so it must not trigger a retrace inside the ``lax.scan`` generation
loop.  Each row's KV-block loop early-exits at its OWN length (``pl.when``
on ``j * bk < kv_len[row]``): a ragged serving batch pays per-sequence
work, not the longest sequence's grid — the work-level analogue of FPnew's
per-operand precision proportionality.  A uniform batch passes the same
scalar in every row and behaves exactly as before.

Schedule: grid (BHkv, 2, Smax/bk), kv innermost, two passes over the KV
blocks.  Pass 0 computes the exact global score max; pass 1 recomputes
scores (flash-style recompute) and accumulates the numerator / denominator
blockwise in f32 VMEM scratch.  Unlike online-softmax rescaling, the
two-pass schedule is *bit-exact* against the dense reference
(ref.decode_attention_ref with matching ``bk``): the max is exact, and the
blockwise f32 sums are part of the op's numerical contract, exactly like
tp_matmul's K-blocking.  The cost is streaming K twice (V's block index is
pinned during the max pass, so V streams once) — for single-query decode
the score pass is a thin [G, bk] strip, so the extra traffic is the K
reload, not a 2x compute or bandwidth bill.

Paged KV (``block_table``): instead of each row owning a contiguous
``[Smax, D]`` cache strip, K/V live in a shared page pool ``[n_pages, bk,
D]`` and a per-row table maps the row's logical block ``j`` to a physical
page.  Only the BlockSpec index maps change — ``(h, j, 0)`` becomes
``(bt[h, j], 0, 0)`` — dereferenced at DMA-issue time from the
scalar-prefetch table, so the kernel body (and therefore the numerics) is
IDENTICAL to the contiguous layout: paged output is bit-exact against the
contiguous kernel and the ``bk``-blocked oracle whenever the gathered
pages hold the same values.  Rows may alias pages (prefix sharing) and the
table is a traced value (page churn never retraces).  ``kv_len`` keeps
masking exactly as before, so partial tail pages need no special casing.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import get_format
from .quant_common import widen as _widen
from .quant_common import widen_with_flags as _widen_flags

NEG_INF = -1e30

# flag-counter channel order (docs/KERNELS.md): OF, UF, NX, NV
N_FLAGS = 4


def _flag_counts(x_ref, fmt, src_dtype, live):
    """Per-tile OF/UF/NX/NV counts of one CONV site, masked by ``live``
    (liveness along the leading tile axis — dead/padded slots contribute
    zero).  Returns an int32 [4] vector."""
    _, of, uf, nx, nv = _widen_flags(x_ref, fmt, src_dtype)
    return jnp.stack([jnp.sum((f & live).astype(jnp.int32))
                      for f in (of, uf, nx, nv)])


def softcap_scores(s, cap: float):
    """Attention-logit soft-capping via exp: ``cap * tanh(s / cap)`` with
    ``tanh(x) = 1 - 2/(exp(2x) + 1)``.  Written out this way (instead of
    ``jnp.tanh``) because XLA expands tanh into a polynomial whose FMA
    contraction depends on the surrounding fusion context — the exp form
    uses only context-stable ops, so kernel and oracle stay bit-identical.
    Shared by decode_attention_pallas and ref.decode_attention_ref."""
    e = jnp.exp(s * (2.0 / cap))
    return cap * (1.0 - 2.0 / (e + 1.0))


def _decode_kernel(len_ref, *args, nk: int, bk: int, paged: bool,
                   scale: float, window: Optional[int],
                   softcap: Optional[float], kv_fmt, q_fmt, src_dtype,
                   out_dtype, debug_visits: bool, debug_flags: bool):
    if paged:
        args = args[1:]            # bt_ref: consumed by the index maps only
    q_ref, k_ref, v_ref, o_ref, *rest = args
    visits_ref = flags_ref = None
    if debug_visits:
        visits_ref, rest = rest[0], rest[1:]
    if debug_flags:
        flags_ref, rest = rest[0], rest[1:]
    m_ref, acc_ref, l_ref = rest
    ip = pl.program_id(1)          # 0 = max pass, 1 = accumulate pass
    j = pl.program_id(2)           # kv block
    kvl = len_ref[pl.program_id(0)]   # this row's own live length

    @pl.when((ip == 0) & (j == 0))
    def _init_max():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    @pl.when((ip == 1) & (j == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        l_ref[...] = jnp.zeros_like(l_ref)

    # per-row early-exit: the whole KV block lies past this row's length.
    # Skipping is exact — a fully-masked block contributes max = NEG_INF
    # (no-op under jnp.maximum) in pass 0 and p = 0 in pass 1.
    active = j * bk < kvl

    @pl.when(active)
    def _work():
        q = _widen(q_ref[0], q_fmt, src_dtype)          # (G, D)
        k = _widen(k_ref[0], kv_fmt, src_dtype)         # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap is not None:
            s = softcap_scores(s, softcap)

        g = s.shape[0]
        k_idx = j * bk + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        mask = k_idx < kvl
        if window is not None:
            mask &= k_idx > kvl - 1 - window
        s = jnp.where(mask, s, NEG_INF)

        @pl.when(ip == 0)
        def _max_pass():
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_ref[...] = jnp.maximum(m_ref[...],
                                     jnp.broadcast_to(m_cur, m_ref.shape))

        @pl.when(ip == 1)
        def _acc_pass():
            m = m_ref[:, :1]
            # guard fully-masked rows (m == NEG_INF): keep exp arg finite
            p = jnp.exp(s - jnp.where(m <= NEG_INF / 2, 0.0, m))
            p = jnp.where(mask, p, 0.0)
            l_ref[...] = l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
            v = _widen(v_ref[0], kv_fmt, src_dtype)
            acc_ref[...] = acc_ref[...] + jax.lax.dot_general(
                p.astype(src_dtype), v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

    # the store must run even when this row's last blocks were early-outs
    @pl.when((ip == 1) & (j == nk - 1))
    def _store():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] /
                    jnp.where(l == 0.0, 1.0, l)).astype(out_dtype)

    if debug_visits:
        visits_ref[0, 0] = active.astype(jnp.int32)
    if debug_flags:
        # Flag accumulation mirrors debug_visits: both passes write the same
        # (h, j) cell and the accumulate pass (ip == 1) writes last, when
        # v_ref maps to block j's true page (it is pinned during the max
        # pass) — so the surviving value counts each K/V tile exactly once
        # per row.  Q's CONV site is charged to the j == 0 cell.  Slots at
        # or past this row's kv_len are masked out and early-out blocks
        # write zeros: dead/padded cache slots contribute nothing.
        live = (j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
                ) < kvl
        cnts = (_flag_counts(k_ref[0], kv_fmt, src_dtype, live)
                + _flag_counts(v_ref[0], kv_fmt, src_dtype, live))
        qc = _flag_counts(q_ref[0], q_fmt, src_dtype,
                          jnp.ones((1, 1), jnp.bool_))
        cnts = cnts + jnp.where(j == 0, qc, 0)
        flags_ref[0, 0, :] = jnp.where(active, cnts, 0)


@functools.partial(jax.jit, static_argnames=(
    "bk", "scale", "window", "softcap", "kv_fmt_name", "q_fmt_name",
    "src_dtype", "out_dtype", "interpret", "debug_visits", "debug_flags"))
def decode_attention_pallas(q, k, v, kv_len, block_table=None, *,
                            bk: int = 128,
                            scale: float = 1.0,
                            window: Optional[int] = None,
                            softcap: Optional[float] = None,
                            kv_fmt_name: Optional[str] = None,
                            q_fmt_name: Optional[str] = None,
                            src_dtype=jnp.bfloat16,
                            out_dtype=jnp.float32,
                            interpret: bool = True,
                            debug_visits: bool = False,
                            debug_flags: bool = False):
    """q: [BHkv, G, D]; k, v: [BHkv, Smax, D]; kv_len: int32 live cache
    length(s) — a traced value, not a static.  A [1, 1] (or scalar) length
    is broadcast to every row; a per-row [BHkv, 1] (or [BHkv]) vector gives
    each row its own length and its KV-block loop early-exits there (ragged
    serving batches; ops.py expands per-sequence [B] lengths by the KV-head
    count).

    Paged layout (``block_table`` [BHkv, nk] int32, also traced): k/v are
    instead shared page POOLS [n_pages, bk, D] and row ``h``'s logical
    block ``j`` lives in physical page ``block_table[h, j]`` — only the
    BlockSpec index maps change, the kernel body (hence the numerics) is
    identical, and the logical cache capacity is ``nk * bk``.  ops.py
    expands a per-sequence [B, max_pages] table to these flat per-head
    page ids.

    Smax % bk == 0 (the ops.py wrapper pads; padded slots have
    ``k_idx >= kv_len`` and are masked).  ``kv_fmt_name`` / ``q_fmt_name``
    request the in-kernel RNE grid snap for f32-container (emulated narrow)
    storage; native narrow dtypes are widened exactly without it.  With
    ``debug_visits`` the kernel also returns an int32 [BHkv, Smax/bk] array
    flagging, per row, which KV blocks did work (early-outs write 0).

    With ``debug_flags`` the kernel additionally returns an int32
    [BHkv, Smax/bk, 4] array of per-(row, KV-block) IEEE flag counts in
    channel order OF, UF, NX, NV — the fflags its CONV sites raise
    (docs/KERNELS.md).  Each live K and V element is counted once per row,
    Q once per row in the j == 0 cell; slots at or past ``kv_len`` and
    early-out blocks contribute zero.  Extra outputs are appended in
    (visits, flags) order when both are requested.
    """
    bh, g, d = q.shape
    paged = block_table is not None
    if paged:
        n_pages, page, dk = k.shape
        assert page == bk, (k.shape, bk)
        assert block_table.shape[0] == bh, (block_table.shape, bh)
        nk = block_table.shape[1]
    else:
        bkv, smax, dk = k.shape
        assert bh == bkv, (q.shape, k.shape)
        assert smax % bk == 0, (k.shape, bk)
        nk = smax // bk
    assert d == dk, (q.shape, k.shape)
    kvl = jnp.reshape(jnp.asarray(kv_len, jnp.int32), (-1,))
    assert kvl.shape[0] in (1, bh), (kvl.shape, bh)
    kvl = jnp.broadcast_to(kvl, (bh,))

    kern = functools.partial(
        _decode_kernel, nk=nk, bk=bk, paged=paged, scale=scale,
        window=window, softcap=softcap,
        kv_fmt=get_format(kv_fmt_name) if kv_fmt_name else None,
        q_fmt=get_format(q_fmt_name) if q_fmt_name else None,
        src_dtype=src_dtype, out_dtype=out_dtype, debug_visits=debug_visits,
        debug_flags=debug_flags)
    # scalar-prefetch args (kvl, and the page table when paged) are SMEM
    # tables the index maps may read at DMA-issue time; index maps take
    # (grid ids..., *scalar refs).
    if paged:
        scalars = (kvl, jnp.asarray(block_table, jnp.int32))
        k_map = lambda h, p, j, kvl, bt: (bt[h, j], 0, 0)
        # V is only read in the accumulate pass (p == 1): pin its page to
        # the row's first during the max pass so consecutive grid steps hit
        # the same tile and Mosaic skips the copy — V streams from HBM
        # once, K twice (the cost stated in the module docstring).
        v_map = lambda h, p, j, kvl, bt: (bt[h, j * p], 0, 0)
        fixed = lambda h, p, j, kvl, bt: (h, 0, 0)
        vis = lambda h, p, j, kvl, bt: (h, j)
        flg = lambda h, p, j, kvl, bt: (h, j, 0)
    else:
        scalars = (kvl,)
        k_map = lambda h, p, j, kvl: (h, j, 0)
        v_map = lambda h, p, j, kvl: (h, j * p, 0)   # pinned as above
        fixed = lambda h, p, j, kvl: (h, 0, 0)
        vis = lambda h, p, j, kvl: (h, j)
        flg = lambda h, p, j, kvl: (h, j, 0)
    out_shape = [jax.ShapeDtypeStruct((bh, g, d), out_dtype)]
    out_specs = [pl.BlockSpec((1, g, d), fixed)]
    if debug_visits:
        # both passes write the same (h, j) cell with the same value
        out_shape.append(jax.ShapeDtypeStruct((bh, nk), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1), vis))
    if debug_flags:
        # the accumulate pass's write survives (correct V page; see kernel)
        out_shape.append(jax.ShapeDtypeStruct((bh, nk, N_FLAGS), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1, N_FLAGS), flg))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(bh, 2, nk),
        in_specs=[
            pl.BlockSpec((1, g, d), fixed),
            pl.BlockSpec((1, bk, d), k_map),
            pl.BlockSpec((1, bk, d), v_map),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),   # running max
            pltpu.VMEM((g, d), jnp.float32),     # output accumulator
            pltpu.VMEM((g, 128), jnp.float32),   # softmax denominator
        ])
    out = pl.pallas_call(
        kern, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(*scalars, q, k, v)
    return tuple(out) if (debug_visits or debug_flags) else out[0]
