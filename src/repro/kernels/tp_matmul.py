"""Pallas TPU kernel: multi-format (expanding-FMA) tiled matmul.

The TPU-native instantiation of FPnew's merged multi-format FMA slice
(paper §II.B.4): operands enter in ``src_fmt`` (bf16 / fp16 / fp8 / grid-
quantized f32), products are accumulated in an f32 VMEM scratch accumulator
(the MXU's native expanding FMA), and the result is cast to ``out_fmt`` on
the way out — fusing FPnew's CONV block into the ADDMUL datapath so the
narrow result never round-trips through HBM in wide form.

Tiling: grid (M/bm, N/bn, K/bk) with K innermost; the f32 accumulator lives
in VMEM scratch across the K steps of one (i, j) tile.  Block shapes default
to MXU-aligned (128, 512, 128) and must keep
bm*bk + bk*bn (operands, src width) + bm*bn*4 (acc) within VMEM.

An optional *fused operand quantization* snaps f32 operands onto an
arbitrary (e, m) grid inside the kernel with the same integer-rounding
stage hardware uses — this is the beyond-paper CONV+ADDMUL fusion used in
§Perf. Validated against ref.py in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import FPFormat, get_format
from .quant_common import quantize_rne_bits as _quantize_rne_bits

DEFAULT_BLOCK = (128, 512, 128)  # (bm, bk, bn)


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int,
               quant_fmt: Optional[FPFormat], out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if quant_fmt is not None:  # fused CONV->ADDMUL operand quantization
        a = _quantize_rne_bits(a.astype(jnp.float32), quant_fmt)
        b = _quantize_rne_bits(b.astype(jnp.float32), quant_fmt)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block", "out_dtype", "quant_fmt_name", "interpret"))
def tp_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                     block=DEFAULT_BLOCK,
                     out_dtype=jnp.float32,
                     quant_fmt_name: Optional[str] = None,
                     interpret: bool = True) -> jnp.ndarray:
    """``a [M,K] @ b [K,N]`` with f32 accumulation and ``out_dtype`` store.

    M, K, N must be multiples of the block shape (the ops.py wrapper pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = block
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (a.shape, b.shape, block)
    nk = k // bk
    quant_fmt = get_format(quant_fmt_name) if quant_fmt_name else None

    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk, quant_fmt=quant_fmt,
                          out_dtype=out_dtype),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
