"""Block-shape autotuner for the Pallas kernels.

The right VMEM tiling for a kernel depends on the operand shapes, the dtype
(narrower formats fit bigger tiles — the FPnew resource argument, §III.B),
and the backend.  Hardcoded defaults leave performance on the table, so this
module times candidate block shapes on the live backend and memoizes the
winner in a JSON cache keyed by (op, shape, dtype, backend):

  * ``best_block(op, shape, dtype)`` — the default block picker used by
    kernels/ops.py: returns the memoized winner if one exists, else the
    static heuristic (so the cold path costs one dict lookup, never a
    timing run).
  * ``autotune_matmul / autotune_attention / autotune_decode`` — run the
    actual sweep for one shape and persist the winner.
  * CLI: ``python -m repro.kernels.autotune --op matmul --shape 512x1024x512``

The cache file lives at ``$REPRO_AUTOTUNE_CACHE`` (default
``~/.cache/repro/autotune.json``); entries from different backends never
collide, so a cache warmed on TPU is inert on CPU and vice versa.  The
repo additionally SHIPS a pre-warmed cache (``kernels/pretuned.json``,
``$REPRO_PRETUNED_CACHE`` to override) holding swept winners for the
shipped arch configs' common shapes — loaded AFTER the user cache, so a
locally-tuned winner always beats the shipped one, and only for entries
whose recorded jax version matches the running install (a stale shipped
entry silently falls back to the heuristic, same as any other version
mismatch).  Entries
are additionally keyed by the jax version that timed them — a jax upgrade
changes Mosaic/XLA codegen, so pre-upgrade winners silently invalidate and
``best_block`` falls back to the heuristic until re-tuned.  Legacy
(pre-versioning) cache files load fine: their entries are adopted once
under the running jax version (they were timed on the install that wrote
them) and re-persisted in the keyed form on the next ``record``.

Ragged workloads: batch/sequence-length dimensions are canonicalized to
power-of-two buckets in the cache key (``_bucket_shape``) — the feature
dims (head dim, matmul K/N) that are architecturally fixed stay exact.
Without bucketing, a ragged serving mix would mint one JSON entry per
distinct prompt-length combination; with it, every length in (64, 128]
shares one winner, which kernels/ops.py clamps to the live shape anyway.
Bucketed keys carry the ``v2|`` version prefix (a key-format bump): v1
entries (exact shapes) migrate on load by re-bucketing — first entry per
bucket wins — so existing caches keep resolving.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "best_block", "lookup", "record", "candidates", "default_block",
    "autotune_matmul", "autotune_attention", "autotune_decode",
    "pretuned_path",
]

_MEM: Dict[str, List[int]] = {}     # in-process cache (file mirror + new wins)
_FILE_LOADED = False


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


#: per-op axes whose sizes vary with batch/prompt length (bucketed in keys);
#: the remaining axes are architectural constants and stay exact.
_BUCKET_AXES = {"matmul": (0,), "attn": (0, 1), "decode_attn": (1,)}


def _pow2_bucket(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length() if n > 0 else 0


def _bucket_shape(op: str, shape: Sequence[int]) -> Tuple[int, ...]:
    """Canonicalize length-like dims to their next power of two so ragged
    workloads (one shape per prompt-length mix) share cache entries."""
    axes = _BUCKET_AXES.get(op, ())
    return tuple(_pow2_bucket(int(s)) if i in axes else int(s)
                 for i, s in enumerate(shape))


def _key(op: str, shape: Sequence[int], dtype, backend: Optional[str] = None
         ) -> str:
    backend = backend or jax.default_backend()
    shape = _bucket_shape(op, shape)
    return f"v2|{op}|{'x'.join(str(int(s)) for s in shape)}|" \
           f"{jnp.dtype(dtype).name}|{backend}|jax-{jax.__version__}"


def _migrate_key(k: str) -> Optional[str]:
    """Bring one on-disk key to the current (v2, bucketed) format.

    v2 keys pass through; v1 keys — 4-field pre-jax-versioning and 5-field
    jax-versioned, both with exact shapes — are re-bucketed (4-field ones
    additionally adopt the running jax version, as before).  Anything else
    is skipped, not fatal."""
    parts = k.split("|")
    if parts[0] == "v2" and len(parts) == 6:
        return k
    if len(parts) == 4:                   # op|shape|dtype|backend
        parts.append(f"jax-{jax.__version__}")
    if len(parts) != 5:
        return None
    try:
        shape = _bucket_shape(parts[0], [int(x) for x in parts[1].split("x")])
    except ValueError:
        return None
    parts[1] = "x".join(str(s) for s in shape)
    return "|".join(["v2"] + parts)


def pretuned_path() -> str:
    return os.environ.get(
        "REPRO_PRETUNED_CACHE",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "pretuned.json"))


def _load_pretuned() -> None:
    """Adopt the shipped warm cache.  Called after the user's disk cache
    (``setdefault``: local winners beat shipped ones).  Only v2 entries
    whose key carries the RUNNING jax version are adopted — a pretuned
    file generated under another jax is a silent no-op (heuristic
    fallback), because codegen changed under the timed winners."""
    try:
        with open(pretuned_path()) as f:
            ship = json.load(f)
    except (OSError, ValueError):
        return
    if not isinstance(ship, dict):
        return
    tag = f"jax-{jax.__version__}"
    for k, v in ship.get("entries", {}).items():
        try:
            block = [int(x) for x in v]
        except (TypeError, ValueError):
            continue
        parts = k.split("|")
        if parts[0] != "v2" or len(parts) != 6 or parts[5] != tag:
            continue                     # stale version / malformed: skip
        _MEM.setdefault(k, block)


def _load_file() -> None:
    global _FILE_LOADED
    if _FILE_LOADED:
        return
    _FILE_LOADED = True
    path = cache_path()
    try:
        with open(path) as f:
            disk = json.load(f)
    except (OSError, ValueError):
        disk = {}
    for k, v in disk.items():
        try:
            block = [int(x) for x in v]
        except (TypeError, ValueError):
            continue                     # unknown entry shape: skip, don't die
        k = _migrate_key(k)
        if k is not None:                # first entry per bucket wins
            _MEM.setdefault(k, block)
    _load_pretuned()


def reset(clear_env_cache: bool = False) -> None:
    """Drop the in-process cache (tests; or after pointing
    $REPRO_AUTOTUNE_CACHE somewhere else)."""
    global _FILE_LOADED
    _MEM.clear()
    _FILE_LOADED = False
    if clear_env_cache:
        try:
            os.remove(cache_path())
        except OSError:
            pass


def lookup(op: str, shape: Sequence[int], dtype,
           backend: Optional[str] = None) -> Optional[Tuple[int, ...]]:
    _load_file()
    v = _MEM.get(_key(op, shape, dtype, backend))
    return tuple(v) if v is not None else None


def record(op: str, shape: Sequence[int], dtype, block: Sequence[int],
           backend: Optional[str] = None, persist: bool = True) -> None:
    _load_file()
    _MEM[_key(op, shape, dtype, backend)] = [int(x) for x in block]
    if persist:
        path = cache_path()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(_MEM, f, indent=2, sort_keys=True)


# ---------------------------------------------------------------------------
# heuristics + candidate grids
# ---------------------------------------------------------------------------
def _mult_128(x: int) -> int:
    return -(-int(x) // 128) * 128


def default_block(op: str, shape: Sequence[int]) -> Tuple[int, ...]:
    """The static fallbacks (what ops.py hardcoded before the autotuner)."""
    if op == "matmul":
        m, k, n = shape
        return (min(128, max(8, m)), max(128, min(512, k)),
                max(128, min(128, n)))
    if op == "attn":                 # (sq, skv, d) -> (bq, bk)
        sq, skv, _ = shape
        return (min(128, max(8, sq)), min(128, max(128, skv)))
    if op == "decode_attn":          # (g, smax, d) -> (bk,)
        _, smax, _ = shape
        return (min(512, _mult_128(max(smax, 1))),)
    raise ValueError(op)


def candidates(op: str, shape: Sequence[int]) -> List[Tuple[int, ...]]:
    """Legal candidate tilings for one op/shape (deduped, heuristic first so
    ties keep the old default)."""
    out = [default_block(op, shape)]
    if op == "matmul":
        m, k, n = shape
        for bm in (32, 64, 128, 256):
            for bk in (128, 256, 512):
                for bn in (128, 256):
                    c = (min(bm, max(8, m)), max(128, min(bk, _mult_128(k))),
                         max(128, min(bn, _mult_128(n))))
                    if c not in out:
                        out.append(c)
    elif op == "attn":
        sq, skv, _ = shape
        for bq in (32, 64, 128, 256):
            for bk in (128, 256, 512):
                c = (min(bq, max(8, sq)), max(128, min(bk, _mult_128(skv))))
                if c not in out:
                    out.append(c)
    elif op == "decode_attn":
        _, smax, _ = shape
        for bk in (128, 256, 512, 1024):
            c = (max(128, min(bk, _mult_128(max(smax, 1)))),)
            if c not in out:
                out.append(c)
    else:
        raise ValueError(op)
    return out


def best_block(op: str, shape: Sequence[int], dtype,
               backend: Optional[str] = None) -> Tuple[int, ...]:
    """Default block picker for kernels/ops.py: memoized winner, else the
    static heuristic.  Never times anything."""
    return lookup(op, shape, dtype, backend) or default_block(op, shape)


# ---------------------------------------------------------------------------
# timing sweeps
# ---------------------------------------------------------------------------
def _time_one(fn: Callable[[], jax.Array], repeats: int = 3) -> float:
    jax.block_until_ready(fn())            # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _sweep(op: str, shape: Sequence[int], dtype, make_fn, *,
           repeats: int = 3, persist: bool = True, verbose: bool = False
           ) -> Tuple[Tuple[int, ...], Dict[Tuple[int, ...], float]]:
    timings: Dict[Tuple[int, ...], float] = {}
    for block in candidates(op, shape):
        try:
            timings[block] = _time_one(make_fn(block), repeats)
        except Exception as e:           # illegal tiling for this backend
            if verbose:
                print(f"  {op} {block}: skipped ({type(e).__name__})")
            continue
        if verbose:
            print(f"  {op} {block}: {timings[block] * 1e3:.3f} ms")
    assert timings, f"no legal candidate for {op} {shape}"
    winner = min(timings, key=timings.get)
    record(op, shape, dtype, winner, persist=persist)
    return winner, timings


def _resolve_interpret(interpret) -> bool:
    """None -> interpret on CPU, compiled elsewhere.  Winners are keyed by
    backend, so a sweep must time what that backend will actually run —
    timing the interpreter on TPU would memoize garbage under the tpu key."""
    return jax.default_backend() == "cpu" if interpret is None else interpret


def autotune_matmul(m: int, k: int, n: int, dtype=jnp.float32, *,
                    interpret: Optional[bool] = None, repeats: int = 3,
                    persist: bool = True, verbose: bool = False):
    from . import ops as kops
    interpret = _resolve_interpret(interpret)
    a = jax.random.normal(jax.random.key(0), (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.key(1), (k, n), jnp.float32).astype(dtype)
    mk = lambda blk: functools.partial(kops.tp_matmul, a, b, block=blk,
                                       interpret=interpret)
    return _sweep("matmul", (m, k, n), dtype, mk, repeats=repeats,
                  persist=persist, verbose=verbose)


def autotune_attention(sq: int, skv: int, d: int, heads: int = 4,
                       dtype=jnp.float32, *, interpret: Optional[bool] = None,
                       repeats: int = 3, persist: bool = True,
                       verbose: bool = False):
    from . import ops as kops
    interpret = _resolve_interpret(interpret)
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, heads, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (1, heads, skv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (1, heads, skv, d), jnp.float32).astype(dtype)
    mk = lambda blk: functools.partial(kops.flash_attention, q, k, v,
                                       bq=blk[0], bk=blk[1],
                                       interpret=interpret)
    return _sweep("attn", (sq, skv, d), dtype, mk, repeats=repeats,
                  persist=persist, verbose=verbose)


def autotune_decode(group: int, smax: int, d: int, heads: int = 4,
                    dtype=jnp.float32, *, interpret: Optional[bool] = None,
                    repeats: int = 3, persist: bool = True,
                    verbose: bool = False):
    from . import ops as kops
    interpret = _resolve_interpret(interpret)
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, heads * group, 1, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, heads, smax, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, heads, smax, d), jnp.float32)
    g_pad = max(8, group)
    mk = lambda blk: functools.partial(
        kops.decode_attention, q.astype(dtype), k.astype(dtype),
        v.astype(dtype), kv_len=smax, bk=blk[0], interpret=interpret)
    return _sweep("decode_attn", (g_pad, smax, d), dtype, mk,
                  repeats=repeats, persist=persist, verbose=verbose)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--op", choices=("matmul", "attn", "decode_attn"),
                    required=True)
    ap.add_argument("--shape", required=True,
                    help="matmul: MxKxN; attn: SQxSKVxD; decode_attn: GxSMAXxD")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    try:
        dims = tuple(int(x) for x in args.shape.lower().split("x"))
    except ValueError:
        ap.error(f"--shape wants AxBxC integers, got {args.shape!r}")
    if len(dims) != 3:
        ap.error(f"--shape wants exactly 3 'x'-separated dims, "
                 f"got {args.shape!r}")
    dtype = jnp.dtype(args.dtype)
    fn = {"matmul": autotune_matmul, "attn": autotune_attention,
          "decode_attn": autotune_decode}[args.op]
    winner, timings = fn(*dims, dtype=dtype, repeats=args.repeats,
                         verbose=True)
    print(f"winner for {args.op} {args.shape} [{dtype}] on "
          f"{jax.default_backend()}: {winner} "
          f"({timings[winner] * 1e3:.3f} ms) -> {cache_path()}")


if __name__ == "__main__":
    main()
