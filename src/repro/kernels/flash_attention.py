"""Pallas TPU kernel: transprecision flash attention.

Attention is the framework's dominant non-GEMM compute hot-spot; this kernel
applies FPnew's multi-format FMA contract to both attention contractions:
QK^T and PV multiply in ``src_fmt`` (bf16/fp16/fp8), while the online-softmax
statistics (running max / denominator) and the output accumulator stay in
f32 — the expanding-FMA pattern of paper §II.B.4 at the kernel level.

Features: GQA head mapping, causal masking, sliding-window (local) masking,
attention-logit soft-capping (gemma-2/3), per-block VMEM tiling.

Layout: q [BH, Sq, D], k/v [BKV, Skv, D] (heads pre-flattened by ops.py).
Grid (BH, Sq/bq, Skv/bk), kv innermost; scratch: acc (bq, D) f32, running
max m and denominator l as (bq, 128) replicated lanes (TPU-friendly 2D).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 nk: int, bq: int, bk: int, scale: float, causal: bool,
                 window: Optional[int], softcap: Optional[float],
                 kv_len: int, src_dtype, out_dtype):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(src_dtype)           # (bq, D)
    k = k_ref[0].astype(src_dtype)           # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(1)
    q_idx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_idx < kv_len
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                     # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new == NEG_INF): keep exp argument finite
    p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(jnp.where(m_new <= NEG_INF / 2, 0.0, m_prev - m_new))

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(src_dtype)
    pv = jax.lax.dot_general(p.astype(src_dtype), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ik == nk - 1)
    def _store():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] /
                    jnp.where(l == 0.0, 1.0, l)).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "group", "bq", "bk", "scale", "causal", "window", "softcap", "kv_len",
    "src_dtype", "out_dtype", "interpret"))
def flash_attention_pallas(q, k, v, *, group: int = 1, bq: int = 128,
                           bk: int = 128, scale: float = 1.0,
                           causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           kv_len: Optional[int] = None,
                           src_dtype=jnp.bfloat16,
                           out_dtype=jnp.float32,
                           interpret: bool = True):
    """q: [BH, Sq, D]; k, v: [BKV, Skv, D] with BH = BKV * group.

    Sq % bq == 0 and Skv % bk == 0 (ops.py pads); ``kv_len`` masks padding.
    """
    bh, sq, d = q.shape
    bkv, skv, dk = k.shape
    assert d == dk and bh == bkv * group, (q.shape, k.shape, group)
    assert sq % bq == 0 and skv % bk == 0, (q.shape, k.shape, bq, bk)
    kv_len = skv if kv_len is None else kv_len
    nk = skv // bk

    kern = functools.partial(
        _attn_kernel, nk=nk, bq=bq, bk=bk, scale=scale, causal=causal,
        window=window, softcap=softcap, kv_len=kv_len,
        src_dtype=src_dtype, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(bh, sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
