"""Pallas TPU kernel: pruned-grid transprecision flash attention (prefill).

Attention is the framework's dominant non-GEMM compute hot-spot; this kernel
applies FPnew's multi-format FMA contract to both attention contractions:
QK^T and PV multiply in ``src_fmt`` (bf16/fp16/fp8), while the online-softmax
statistics (running max / denominator) and the output accumulator stay in
f32 — the expanding-FMA pattern of paper §II.B.4 at the kernel level.

Energy proportionality at the schedule level (§II.B.4): the grid visits ONLY
the KV blocks a query block can actually see.  ``block_schedule`` computes
the active ``(iq, ik)`` pairs host-side — causal future blocks and blocks
left of a sliding window never appear in the grid at all — and the flattened
schedule is fed to the kernel as scalar-prefetch tables that drive the block
index maps (splash-attention style).  Causal ``sq == skv`` prefill thus runs
~half the dense grid's block visits, and a window layer O(window / skv) of
them.  ``kv_len`` is a *dynamic* kernel input (SMEM scalar-prefetch, like
the decode kernel): distinct prompt lengths reuse one compiled kernel, and
blocks entirely past ``kv_len`` early-out via ``pl.when`` at run time.

Ragged batches: ``kv_len`` generalizes to a per-row *vector* — one int32
SMEM entry per flattened head row (ops.py expands a [B] sequence-length
vector by the head count).  The early-out and the in-block mask both read
``kvl_ref[program_id(0)]``, so each sequence's KV walk stops at its OWN
length: a short row in a ragged batch does work proportional to its own
``kv_len``, not the batch max (``debug_visits`` is per-row, [BH, n_steps],
and proves it).  The length vector is a traced value — differing ragged
batches share one compiled kernel, exactly like the scalar case.

Paged KV (``block_table``): K/V may arrive as shared page pools instead of
per-row contiguous strips — a per-row table in the scalar-prefetch set maps
each logical KV block to a physical page, and ONLY the K/V BlockSpec index
maps change (``(h // g, ki[s], 0)`` becomes ``(bt[h // g, ki[s]], 0, 0)``).
The kernel body is untouched, so paged output is bit-exact against the
contiguous kernel on the same values; tables are traced (page churn and
prefix re-sharing never retrace).  This is the continued-prefill read path
against a paged cache (decoding's twin lives in decode_attention.py).

Features: GQA head mapping, causal masking, sliding-window (local) masking,
attention-logit soft-capping (gemma-2/3), V head dim != QK head dim (MLA
expanded prefill), optional in-kernel RNE operand snap for emulate-mode
policies, per-block VMEM tiling, optional block-visit instrumentation.

Layout: q [BH, Sq, D], k [BKV, Skv, D], v [BKV, Skv, Dv] (heads
pre-flattened by ops.py).  Grid (BH, n_steps) over the pruned schedule;
scratch: acc (bq, Dv) f32, running max m and denominator l as (bq, 128)
replicated lanes (TPU-friendly 2D).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import get_format
from .decode_attention import N_FLAGS, _flag_counts, softcap_scores
from .quant_common import widen as _widen

NEG_INF = -1e30


def block_schedule(sq: int, skv: int, bq: int, bk: int, *, causal: bool,
                   window: Optional[int], q_offset: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """The pruned grid: active ``(iq, ik)`` block pairs, host-side.

    Returns int32 arrays ``(qi, ki, first, last)`` of equal length — for
    each grid step, the query-block index, the KV-block index, and flags
    marking the first / last KV block of that query block's run (scratch
    init / output store points).  A KV block is scheduled iff some query row
    in the block can attend to some key in it under the *static* masks:

      causal  — key blocks past the last query row of the block are dropped
                (``ik * bk > q_offset + (iq+1)*bq - 1``),
      window  — key blocks entirely left of the earliest reachable key
                (``q_offset + iq*bq - window + 1``) are dropped.

    The dynamic ``kv_len`` bound cannot shrink the grid (it is a traced
    value) — the kernel ``pl.when``-skips those blocks at run time instead.
    Every query block keeps >= 1 step so its output is always stored.
    """
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    nq, nk = sq // bq, skv // bk
    qi, ki, first, last = [], [], [], []
    for iq in range(nq):
        k_hi = nk - 1
        if causal:
            k_hi = min(k_hi, (q_offset + (iq + 1) * bq - 1) // bk)
        k_lo = 0
        if window is not None:
            k_lo = max(0, (q_offset + iq * bq - window + 1) // bk)
        k_lo = min(k_lo, k_hi)   # degenerate: keep one step for the store
        for ik in range(k_lo, k_hi + 1):
            qi.append(iq)
            ki.append(ik)
            first.append(1 if ik == k_lo else 0)
            last.append(1 if ik == k_hi else 0)
    mk = lambda a: np.asarray(a, np.int32)
    return mk(qi), mk(ki), mk(first), mk(last)


def _attn_kernel(kvl_ref, qi_ref, ki_ref, ff_ref, lf_ref, *args, bq: int,
                 bk: int, paged: bool, scale: float, causal: bool,
                 window: Optional[int], softcap: Optional[float],
                 q_offset: int, src_fmt, src_dtype, out_dtype,
                 debug_visits: bool, debug_flags: bool):
    if paged:
        args = args[1:]            # bt_ref: consumed by the index maps only
    q_ref, k_ref, v_ref, o_ref, *rest = args
    visits_ref = flags_ref = None
    if debug_visits:
        visits_ref, rest = rest[0], rest[1:]
    if debug_flags:
        flags_ref, rest = rest[0], rest[1:]
    acc_ref, m_ref, l_ref = rest
    step = pl.program_id(1)
    iq = qi_ref[step]
    ik = ki_ref[step]
    kvl = kvl_ref[pl.program_id(0)]      # this row's own live length

    @pl.when(ff_ref[step] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # dynamic early-out: the whole KV block lies past the live length.
    # Skipping is exact — a fully-masked block contributes p = 0 and
    # alpha = exp(0) = 1, so the online state would be bit-identical.
    active = ik * bk < kvl

    @pl.when(active)
    def _work():
        q = _widen(q_ref[0], src_fmt, src_dtype)     # (bq, D)
        k = _widen(k_ref[0], src_fmt, src_dtype)     # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap is not None:
            s = softcap_scores(s, softcap)

        q_idx = (q_offset + iq * bq
                 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
        k_idx = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_idx < kvl
        if causal:
            mask &= q_idx >= k_idx
        if window is not None:
            mask &= (q_idx - k_idx) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                         # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (m_new == NEG_INF): keep exp arg finite
        p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(jnp.where(m_new <= NEG_INF / 2, 0.0, m_prev - m_new))

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = _widen(v_ref[0], src_fmt, src_dtype)
        pv = jax.lax.dot_general(_widen(p, src_fmt, src_dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(lf_ref[step] == 1)
    def _store():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] /
                    jnp.where(l == 0.0, 1.0, l)).astype(out_dtype)

    if debug_visits:
        visits_ref[0, 0] = active.astype(jnp.int32)
    if debug_flags:
        # Per-VISIT flag counts (like debug_visits): each scheduled step's
        # K/V tiles are charged to its own (h, step) cell, masked to the
        # row's live length; the Q tile is charged once per query block, at
        # its first scheduled step.  Early-out steps write zeros.  The
        # derived p-snap at the PV input is NOT counted — telemetry tracks
        # stored-data CONV sites (q/k/v), not recomputed probabilities.
        live = (ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
                ) < kvl
        cnts = (_flag_counts(k_ref[0], src_fmt, src_dtype, live)
                + _flag_counts(v_ref[0], src_fmt, src_dtype, live))
        qc = _flag_counts(q_ref[0], src_fmt, src_dtype,
                          jnp.ones((1, 1), jnp.bool_))
        cnts = cnts + jnp.where(ff_ref[step] == 1, qc, 0)
        flags_ref[0, 0, :] = jnp.where(active, cnts, 0)


@functools.partial(jax.jit, static_argnames=(
    "group", "bq", "bk", "scale", "causal", "window", "softcap", "q_offset",
    "src_fmt_name", "src_dtype", "out_dtype", "interpret", "debug_visits",
    "debug_flags"))
def flash_attention_pallas(q, k, v, kv_len=None, block_table=None, *,
                           group: int = 1,
                           bq: int = 128, bk: int = 128, scale: float = 1.0,
                           causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           q_offset: int = 0,
                           src_fmt_name: Optional[str] = None,
                           src_dtype=jnp.bfloat16,
                           out_dtype=jnp.float32,
                           interpret: bool = True,
                           debug_visits: bool = False,
                           debug_flags: bool = False):
    """q: [BH, Sq, D]; k: [BKV, Skv, D]; v: [BKV, Skv, Dv]; BH = BKV * group.

    Paged layout (``block_table`` [BKV, nk] int32, a traced value): k/v are
    instead shared page POOLS ([n_pages, bk, D] / [n_pages, bk, Dv]) and kv
    row ``hk``'s logical KV block ``ik`` is physical page
    ``block_table[hk, ik]``.  Only the K/V BlockSpec index maps change
    (``(h // g, ki[s], 0)`` -> ``(bt[h // g, ki[s]], 0, 0)``), so numerics
    are identical to the contiguous layout; the logical KV length is
    ``nk * bk`` (chunked / continued prefill against an already-paged
    cache, e.g. extending a shared prompt prefix).

    Sq % bq == 0 and Skv % bk == 0 (ops.py pads).  ``kv_len`` masks keys at
    or past the live length — it is a DYNAMIC input (python int, 0-d array,
    traced scalar, or a per-row [BH] vector; None means Skv), so distinct
    prompt lengths — and distinct ragged length *vectors* — sharing a padded
    shape reuse one compiled kernel.  A scalar is broadcast to every row; a
    vector gives each flattened head row its own live length (ragged
    batches; ops.py expands per-sequence [B] lengths by the head count).
    ``src_fmt_name`` requests the in-kernel RNE operand snap for
    emulate-mode policies (f32 containers); native narrow ``src_dtype``
    casts need none.  With ``debug_visits`` the kernel also returns an int32
    [BH, n_steps] array flagging, per row, which scheduled grid steps did
    QK/PV work (the dynamic per-row ``kv_len`` early-outs write 0 — the
    per-sequence energy-proportionality proof).

    With ``debug_flags`` the kernel additionally returns an int32
    [BH, n_steps, 4] array of per-(row, scheduled step) IEEE flag counts
    (OF, UF, NX, NV — docs/KERNELS.md): K/V tiles are counted per VISIT
    (a KV block seen by several query blocks is charged at each), the Q
    tile once per query block at its first scheduled step; slots at or
    past ``kv_len`` and early-out steps contribute zero.  Extra outputs
    are appended in (visits, flags) order when both are requested.
    """
    bh, sq, d = q.shape
    paged = block_table is not None
    if paged:
        n_pages, page, dk = k.shape
        assert page == bk and v.shape[:2] == (n_pages, page), \
            (k.shape, v.shape, bk)
        assert block_table.shape[0] * group == bh, (block_table.shape, bh,
                                                    group)
        skv = block_table.shape[1] * bk       # logical KV length
        dv = v.shape[-1]
    else:
        bkv, skv, dk = k.shape
        _, skv_v, dv = v.shape
        assert skv == skv_v and bh == bkv * group, \
            (q.shape, k.shape, v.shape, group)
    assert d == dk, (q.shape, k.shape)
    assert sq % bq == 0 and skv % bk == 0, (q.shape, k.shape, bq, bk)
    kvl = jnp.reshape(jnp.asarray(skv if kv_len is None else kv_len,
                                  jnp.int32), (-1,))
    assert kvl.shape[0] in (1, bh), (kvl.shape, bh)
    kvl = jnp.broadcast_to(kvl, (bh,))
    qi, ki, ff, lf = block_schedule(sq, skv, bq, bk, causal=causal,
                                    window=window, q_offset=q_offset)
    n_steps = len(qi)

    kern = functools.partial(
        _attn_kernel, bq=bq, bk=bk, paged=paged, scale=scale, causal=causal,
        window=window, softcap=softcap, q_offset=q_offset,
        src_fmt=get_format(src_fmt_name) if src_fmt_name else None,
        src_dtype=src_dtype, out_dtype=out_dtype, debug_visits=debug_visits,
        debug_flags=debug_flags)
    # index maps see (grid ids..., *scalar-prefetch refs); the paged form
    # appends the page table and dereferences it for the K/V block index
    if paged:
        scalars = (kvl, jnp.asarray(qi), jnp.asarray(ki), jnp.asarray(ff),
                   jnp.asarray(lf), jnp.asarray(block_table, jnp.int32))
        q_map = lambda h, s, kvl, qi, ki, ff, lf, bt: (h, qi[s], 0)
        kv_map = lambda h, s, kvl, qi, ki, ff, lf, bt, g=group: \
            (bt[h // g, ki[s]], 0, 0)
        vis_map = lambda h, s, kvl, qi, ki, ff, lf, bt: (h, s)
        flg_map = lambda h, s, kvl, qi, ki, ff, lf, bt: (h, s, 0)
    else:
        scalars = (kvl, jnp.asarray(qi), jnp.asarray(ki), jnp.asarray(ff),
                   jnp.asarray(lf))
        q_map = lambda h, s, kvl, qi, ki, ff, lf: (h, qi[s], 0)
        kv_map = lambda h, s, kvl, qi, ki, ff, lf, g=group: \
            (h // g, ki[s], 0)
        vis_map = lambda h, s, kvl, qi, ki, ff, lf: (h, s)
        flg_map = lambda h, s, kvl, qi, ki, ff, lf: (h, s, 0)
    out_shape = [jax.ShapeDtypeStruct((bh, sq, dv), out_dtype)]
    out_specs = [pl.BlockSpec((1, bq, dv), q_map)]
    if debug_visits:
        out_shape.append(jax.ShapeDtypeStruct((bh, n_steps), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1), vis_map))
    if debug_flags:
        out_shape.append(jax.ShapeDtypeStruct((bh, n_steps, N_FLAGS),
                                              jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1, N_FLAGS), flg_map))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(bh, n_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, dv), kv_map),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ])
    out = pl.pallas_call(
        kern, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(*scalars, q, k, v)
    return tuple(out) if (debug_visits or debug_flags) else out[0]
