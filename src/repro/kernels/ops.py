"""Public jit'd wrappers around the Pallas kernels.

These handle shape padding/alignment, policy plumbing, and head flattening
so the model code can call them like ordinary jnp ops.  ``interpret=True``
everywhere in this container (CPU); on real TPUs the same code runs compiled
by flipping the flag (kept as an argument end-to-end).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.formats import get_format
from ..core.policy import PrecisionPolicy, get_policy
from . import autotune
from .tp_matmul import tp_matmul_pallas, DEFAULT_BLOCK
from .tp_quant import tp_quantize_pallas, cast_and_pack_pallas
from .flash_attention import flash_attention_pallas
from .decode_attention import decode_attention_pallas
from .dotp_ex import dotp_ex_pallas


def _pad_to(x, mults, axes):
    pads = [(0, 0)] * x.ndim
    padded = False
    for ax, m in zip(axes, mults):
        r = (-x.shape[ax]) % m
        if r:
            pads[ax] = (0, r)
            padded = True
    return (jnp.pad(x, pads), True) if padded else (x, False)


def tp_matmul(a, b, *, policy=None, out_fmt=None, block=None,
              interpret: bool = True):
    """Policy-aware Pallas matmul: a [.., M, K] @ b [K, N]."""
    policy = get_policy(policy) if policy is not None else get_policy("tp_bf16")
    mp = policy.matmul
    out = get_format(out_fmt) if out_fmt is not None else mp.resolved_out()
    lead = a.shape[:-2]
    a2 = a.reshape(-1, a.shape[-1]) if lead else a
    m, k = a2.shape
    _, n = b.shape
    if block is None:  # memoized autotuner winner, else static heuristic
        block = autotune.best_block("matmul", (m, k, n), a.dtype)
    bm, bk, bn = block
    bm, bk, bn = (max(8, min(bm, m)), max(128, bk), max(128, bn))
    a2, _ = _pad_to(a2, (bm, bk), (0, 1))
    b2, _ = _pad_to(b, (bk, bn), (0, 1))

    if policy.mode == "native":
        a2 = a2.astype(mp.src_fmt.native_dtype)
        b2 = b2.astype(mp.src_fmt.native_dtype)
        qname = None
        out_dtype = out.native_dtype
    else:
        qname = mp.src_fmt.name
        out_dtype = jnp.float32
    r = tp_matmul_pallas(a2, b2, block=(bm, bk, bn), out_dtype=out_dtype,
                         quant_fmt_name=qname, interpret=interpret)
    r = r[:m, :n]
    return r.reshape(*lead, a.shape[-2], n) if lead else r


def tp_quantize(x, *, fmt, stochastic: bool = False, key=None,
                out_dtype=None, interpret: bool = True):
    """Pallas-fused quantization of a 2D array (CONV block)."""
    fmt = get_format(fmt)
    rows, cols = x.shape
    x2, _ = _pad_to(x, (256, 128), (0, 1))
    rbits = None
    if stochastic:
        assert key is not None
        rbits = jax.random.bits(key, x2.shape, jnp.uint32)
    r = tp_quantize_pallas(x2, rbits, fmt_name=fmt.name, stochastic=stochastic,
                           out_dtype=out_dtype or jnp.float32,
                           interpret=interpret)
    return r[:rows, :cols]


def cast_and_pack(a, b, *, fmt, stochastic: bool = False, key=None,
                  interpret: bool = True):
    fmt = get_format(fmt)
    rows, cols = a.shape
    a2, _ = _pad_to(a, (256, 128), (0, 1))
    b2, _ = _pad_to(b, (256, 128), (0, 1))
    rbits = None
    if stochastic:
        assert key is not None
        rbits = jax.random.bits(key, a2.shape, jnp.uint32)
    r = cast_and_pack_pallas(a2, b2, rbits, fmt_name=fmt.name,
                             stochastic=stochastic, interpret=interpret)
    return r[:rows, :2 * cols]


def expand_kv_lens(kv_len, batch: int, heads: int, default):
    """Normalize a scalar-or-vector sequence length to one int32 entry per
    flattened head row ([batch * heads]) — the SMEM layout both attention
    kernels consume.  A scalar (python int, 0-d, or traced) is shared by
    every row; a [batch] vector is a ragged batch's per-sequence lengths,
    repeated across that sequence's heads.  ``None`` means ``default``."""
    kvl = jnp.reshape(jnp.asarray(default if kv_len is None else kv_len,
                                  jnp.int32), (-1,))
    if kvl.shape[0] == 1:
        return jnp.broadcast_to(kvl, (batch * heads,))
    assert kvl.shape[0] == batch, (kvl.shape, batch)
    return jnp.repeat(kvl, heads)


def expand_block_table(table, heads: int):
    """Expand a per-sequence page table [B, max_pages] to flat per-head
    page ids [B * heads, max_pages] — the table twin of ``expand_kv_lens``.
    The model-level pool [n_pages, Hkv, page, D] reshapes (zero-copy) to
    the kernels' flat pool [n_pages * Hkv, page, D], where page ``p`` of
    head ``hk`` sits at flat slot ``p * Hkv + hk``."""
    b, mp = table.shape
    flat = (jnp.asarray(table, jnp.int32)[:, None, :] * heads
            + jnp.arange(heads, dtype=jnp.int32)[None, :, None])
    return flat.reshape(b * heads, mp)


def resolve_backend(backend: str) -> str:
    """Shared decode/prefill attention-backend resolution.

    ``"auto"`` picks the Pallas kernels only off-CPU: on CPU the kernels run
    in interpret mode, which is ~20x slower than the dense jnp path on the
    serving hot loop (BENCH_serve.json, gemma2-9b: ``scan_pallas_kv8_tok_s``
    716 vs ``scan_tok_s`` 14043) — ``auto`` must never silently interpret
    there.  Explicit ``"pallas"`` is honored anywhere (tests/benchmarks).
    """
    if backend == "auto":
        return "dense" if jax.default_backend() == "cpu" else "pallas"
    if backend not in ("dense", "pallas"):
        raise ValueError(f"backend must be dense|pallas|auto, got {backend!r}")
    return backend


def _reduce_flag_cells(cells, b: int, h: int):
    """Reduce a kernel's per-(head-row, cell) flag counters [B*H, n, 4] to
    per-SEQUENCE counts [B, 4] (summed over cells and heads).  In-kernel
    liveness masking already zeroed dead/padded slots, so this is a plain
    sum."""
    return jnp.sum(cells.reshape(b, h, -1, cells.shape[-1]),
                   axis=(1, 2)).astype(jnp.int32)


def flash_attention(q, k, v, *, kv_len=None, policy=None,
                    block_table=None,
                    scale: Optional[float] = None,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, q_offset: int = 0,
                    bq: Optional[int] = None, bk: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    return_flags: bool = False):
    """q [B, H, S, D], k/v [B, Hkv, Skv, Dk/Dv] -> [B, H, S, Dv] (f32).

    The prefill/train attention entry point (behind ``cfg.prefill_backend``):
    heads are flattened, ``(bq, bk)`` comes from the autotuner unless pinned,
    and the kernel runs the pruned block schedule — causal future blocks and
    blocks left of a sliding window are never visited.  ``kv_len`` is a
    dynamic kernel input (padding/ragged masking without retrace): a scalar
    shared by the batch, or a per-sequence [B] vector for ragged batches,
    where each sequence's KV walk then early-outs at its own length inside
    the kernel (work proportional to the row's length, not the batch max);
    ``q_offset`` shifts query positions (prefill at a nonzero cache write
    index).  V may have a different head dim than Q/K (MLA expanded form).

    Paged cache (``block_table`` [B, max_pages] int32, traced): k/v are
    the shared page pools [n_pages, Hkv, page, D(v)] of
    ``models.paged.PagedKVCache`` — continued/chunked prefill attending
    against an already-paged cache.  As in ``decode_attention`` the pool
    reshapes zero-copy to the kernels' flat layout, the table expands per
    head, and ``bk`` is pinned to the page size (autotuned ``bq`` still
    applies).

    ``interpret=None`` auto-resolves: interpret on CPU, compiled on real
    accelerators — same hot-path contract as ``decode_attention``.

    ``return_flags=True`` additionally returns per-SEQUENCE int32 [B, 4]
    IEEE flag counts (OF, UF, NX, NV summed over heads and scheduled
    steps; per-visit semantics — docs/KERNELS.md) from the kernel's
    ``debug_flags`` counters.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    policy = get_policy(policy) if policy is not None else get_policy("tp_bf16")
    mp = policy.matmul
    if policy.mode == "native":
        src_dt, src_fmt_name = mp.src_fmt.native_dtype, None
    else:
        # f32 containers: RNE-snap operands onto the src grid in-kernel
        src_dt = jnp.float32
        src_fmt_name = mp.src_fmt.name if mp.src_fmt.name != "fp32" else None
    b, h, sq, d = q.shape
    if block_table is not None:
        n_pages, hkv, page, _ = k.shape
        skv = block_table.shape[1] * page
        dv = v.shape[-1]
    else:
        _, hkv, skv, _ = k.shape
        dv = v.shape[-1]
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    if bq is None or bk is None:
        tq, tk = autotune.best_block("attn", (sq, skv, d), q.dtype)
        bq, bk = (bq or tq), (bk or tk)
    qf = q.reshape(b * h, sq, d)
    bq_ = min(bq, max(8, sq))
    qf, _ = _pad_to(qf, (bq_,), (1,))
    if block_table is not None:
        o = flash_attention_pallas(
            qf, k.reshape(n_pages * hkv, page, d),
            v.reshape(n_pages * hkv, page, dv),
            expand_kv_lens(kv_len, b, h, skv),
            expand_block_table(block_table, hkv), group=group,
            bq=bq_, bk=page, scale=scale, causal=causal, window=window,
            softcap=softcap, q_offset=q_offset, src_fmt_name=src_fmt_name,
            src_dtype=src_dt, out_dtype=jnp.float32, interpret=interpret,
            debug_flags=return_flags)
        if return_flags:
            o, fl = o
            return (o[:, :sq].reshape(b, h, sq, dv),
                    _reduce_flag_cells(fl, b, h))
        return o[:, :sq].reshape(b, h, sq, dv)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, dv)
    bk_ = min(bk, max(128, skv))
    kf, _ = _pad_to(kf, (bk_,), (1,))
    vf, _ = _pad_to(vf, (bk_,), (1,))
    o = flash_attention_pallas(
        qf, kf, vf, expand_kv_lens(kv_len, b, h, skv), group=group,
        bq=bq_, bk=bk_, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, src_fmt_name=src_fmt_name,
        src_dtype=src_dt, out_dtype=jnp.float32, interpret=interpret,
        debug_flags=return_flags)
    if return_flags:
        o, fl = o
        return o[:, :sq].reshape(b, h, sq, dv), _reduce_flag_cells(fl, b, h)
    return o[:, :sq].reshape(b, h, sq, dv)


def decode_attention(q, k, v, *, kv_len, policy=None,
                     block_table=None,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     bk: Optional[int] = None,
                     interpret: Optional[bool] = None,
                     return_flags: bool = False):
    """Fused single-query decode attention over the (quantized) KV cache.

    q [B, H, 1, D]; k/v [B, Hkv, Smax, D] *in their storage dtype* (native
    narrow dtype, or f32 container on the ``policy.kv_fmt`` grid);
    ``kv_len`` the live cache length: a python int or traced scalar shared
    by the batch, or a per-sequence [B] vector (ragged batches — each row's
    KV-block loop early-exits at its own length in-kernel).  Either way it
    is a dynamic kernel input, so per-step calls under ``lax.scan`` never
    retrace.  Returns [B, H, 1, D] f32.

    Paged cache (``block_table`` [B, max_pages] int32, traced): k/v are
    instead the shared page pools [n_pages, Hkv, page, D] of
    ``models.paged.PagedKVCache``.  The pool reshapes zero-copy to the
    kernel's flat [n_pages * Hkv, page, D] layout, the table expands to
    flat per-head page ids (``expand_block_table``), and the kernel's
    BlockSpec index maps dereference them — no gather ever materializes
    the contiguous view.  The kernel block size is pinned to the page size
    (the page IS the block), so the autotuned ``bk`` is bypassed; choose
    ``cfg.page_size`` accordingly (>= 128 for TPU lane alignment).

    ``interpret=None`` auto-resolves: interpret on CPU, compiled on real
    accelerators — this wrapper sits on the serving hot path (behind
    ``cfg.decode_backend``), so it must not silently run the interpreter
    on TPU like the explicit ``interpret=True`` research wrappers do.

    ``return_flags=True`` additionally returns per-SEQUENCE int32 [B, 4]
    IEEE flag counts (OF, UF, NX, NV summed over heads and KV blocks;
    each live K/V element once, Q once per head row — docs/KERNELS.md)
    from the kernel's ``debug_flags`` counters.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    policy = get_policy(policy) if policy is not None else get_policy("tp_bf16")
    mp = policy.matmul
    if policy.mode == "native":
        # cache already carries the narrow dtype — widening is exact
        src_dt, kv_fmt_name, q_fmt_name = mp.src_fmt.native_dtype, None, None
    else:
        # f32 containers: snap q / KV onto their grids inside the kernel
        src_dt = jnp.float32
        kv_fmt_name = policy.kv_fmt.name if policy.kv_fmt is not None else None
        q_fmt_name = mp.src_fmt.name if mp.src_fmt.name != "fp32" else None
    b, h, sq, d = q.shape
    if block_table is not None:
        n_pages, hkv, page, _ = k.shape
        smax = block_table.shape[1] * page
    else:
        _, hkv, smax, _ = k.shape
    assert sq == 1, q.shape
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5

    qf = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    g_pad = max(8, group)                    # sublane-align the query strip
    if g_pad != group:
        qf = jnp.pad(qf, ((0, 0), (0, g_pad - group), (0, 0)))
    kvl = expand_kv_lens(kv_len, b, hkv, smax).reshape(b * hkv, 1)
    if block_table is not None:
        kf = k.reshape(n_pages * hkv, page, d)
        vf = v.reshape(n_pages * hkv, page, d)
        btf = expand_block_table(block_table, hkv)
        o = decode_attention_pallas(
            qf, kf, vf, kvl, btf, bk=page, scale=scale, window=window,
            softcap=softcap, kv_fmt_name=kv_fmt_name, q_fmt_name=q_fmt_name,
            src_dtype=src_dt, out_dtype=jnp.float32, interpret=interpret,
            debug_flags=return_flags)
        if return_flags:
            o, fl = o
            return (o[:, :group].reshape(b, hkv, group, d
                                         ).reshape(b, h, 1, d),
                    _reduce_flag_cells(fl, b, hkv))
        return o[:, :group].reshape(b, hkv, group, d).reshape(b, h, 1, d)
    kf = k.reshape(b * hkv, smax, d)
    vf = v.reshape(b * hkv, smax, d)
    if bk is None:
        bk = autotune.best_block("decode_attn", (g_pad, smax, d), src_dt)[0]
    bk = min(bk, max(128, smax))
    kf, _ = _pad_to(kf, (bk,), (1,))
    vf, _ = _pad_to(vf, (bk,), (1,))
    o = decode_attention_pallas(
        qf, kf, vf, kvl, bk=bk, scale=scale, window=window, softcap=softcap,
        kv_fmt_name=kv_fmt_name, q_fmt_name=q_fmt_name, src_dtype=src_dt,
        out_dtype=jnp.float32, interpret=interpret, debug_flags=return_flags)
    if return_flags:
        o, fl = o
        return (o[:, :group].reshape(b, hkv, group, d).reshape(b, h, 1, d),
                _reduce_flag_cells(fl, b, hkv))
    return o[:, :group].reshape(b, hkv, group, d).reshape(b, h, 1, d)


def dotp_ex(a, b, *, policy=None, interpret: bool = True):
    """Expanding dot product of two 1D streams (paper Fig 11e)."""
    policy = get_policy(policy) if policy is not None else get_policy("tp_fp16")
    src_dt = (policy.matmul.src_fmt.native_dtype
              if policy.mode == "native" else jnp.float32)
    n = a.shape[0]
    c = 128
    rows = -(-n // c)
    pad = rows * c - n
    a2 = jnp.pad(a, (0, pad)).reshape(rows, c)
    b2 = jnp.pad(b, (0, pad)).reshape(rows, c)
    br = min(256, rows)
    a2, _ = _pad_to(a2, (br,), (0,))
    b2, _ = _pad_to(b2, (br,), (0,))
    lanes = dotp_ex_pallas(a2, b2, block_rows=br, src_dtype=src_dt,
                           interpret=interpret)
    return jnp.sum(lanes)
