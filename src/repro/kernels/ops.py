"""Public jit'd wrappers around the Pallas kernels.

These handle shape padding/alignment, policy plumbing, and head flattening
so the model code can call them like ordinary jnp ops.  ``interpret=True``
everywhere in this container (CPU); on real TPUs the same code runs compiled
by flipping the flag (kept as an argument end-to-end).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.formats import get_format
from ..core.policy import PrecisionPolicy, get_policy
from .tp_matmul import tp_matmul_pallas, DEFAULT_BLOCK
from .tp_quant import tp_quantize_pallas, cast_and_pack_pallas
from .flash_attention import flash_attention_pallas
from .dotp_ex import dotp_ex_pallas


def _pad_to(x, mults, axes):
    pads = [(0, 0)] * x.ndim
    padded = False
    for ax, m in zip(axes, mults):
        r = (-x.shape[ax]) % m
        if r:
            pads[ax] = (0, r)
            padded = True
    return (jnp.pad(x, pads), True) if padded else (x, False)


def tp_matmul(a, b, *, policy=None, out_fmt=None, block=None,
              interpret: bool = True):
    """Policy-aware Pallas matmul: a [.., M, K] @ b [K, N]."""
    policy = get_policy(policy) if policy is not None else get_policy("tp_bf16")
    mp = policy.matmul
    out = get_format(out_fmt) if out_fmt is not None else mp.resolved_out()
    lead = a.shape[:-2]
    a2 = a.reshape(-1, a.shape[-1]) if lead else a
    m, k = a2.shape
    _, n = b.shape
    bm, bk, bn = block or (min(128, max(8, m)), min(512, k), min(128, n))
    bm, bk, bn = (max(8, bm), max(128, bk), max(128, bn))
    a2, _ = _pad_to(a2, (bm, bk), (0, 1))
    b2, _ = _pad_to(b, (bk, bn), (0, 1))

    if policy.mode == "native":
        a2 = a2.astype(mp.src_fmt.native_dtype)
        b2 = b2.astype(mp.src_fmt.native_dtype)
        qname = None
        out_dtype = out.native_dtype
    else:
        qname = mp.src_fmt.name
        out_dtype = jnp.float32
    r = tp_matmul_pallas(a2, b2, block=(bm, bk, bn), out_dtype=out_dtype,
                         quant_fmt_name=qname, interpret=interpret)
    r = r[:m, :n]
    return r.reshape(*lead, a.shape[-2], n) if lead else r


def tp_quantize(x, *, fmt, stochastic: bool = False, key=None,
                out_dtype=None, interpret: bool = True):
    """Pallas-fused quantization of a 2D array (CONV block)."""
    fmt = get_format(fmt)
    rows, cols = x.shape
    x2, _ = _pad_to(x, (256, 128), (0, 1))
    rbits = None
    if stochastic:
        assert key is not None
        rbits = jax.random.bits(key, x2.shape, jnp.uint32)
    r = tp_quantize_pallas(x2, rbits, fmt_name=fmt.name, stochastic=stochastic,
                           out_dtype=out_dtype or jnp.float32,
                           interpret=interpret)
    return r[:rows, :cols]


def cast_and_pack(a, b, *, fmt, stochastic: bool = False, key=None,
                  interpret: bool = True):
    fmt = get_format(fmt)
    rows, cols = a.shape
    a2, _ = _pad_to(a, (256, 128), (0, 1))
    b2, _ = _pad_to(b, (256, 128), (0, 1))
    rbits = None
    if stochastic:
        assert key is not None
        rbits = jax.random.bits(key, a2.shape, jnp.uint32)
    r = cast_and_pack_pallas(a2, b2, rbits, fmt_name=fmt.name,
                             stochastic=stochastic, interpret=interpret)
    return r[:rows, :2 * cols]


def flash_attention(q, k, v, *, policy=None, scale: Optional[float] = None,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q [B, H, S, D], k/v [B, Hkv, Skv, D] -> [B, H, S, D] (f32)."""
    policy = get_policy(policy) if policy is not None else get_policy("tp_bf16")
    src_dt = (policy.matmul.src_fmt.native_dtype
              if policy.mode == "native" else jnp.float32)
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    bq_ = min(bq, max(8, sq))
    bk_ = min(bk, max(128, skv))
    qf, _ = _pad_to(qf, (bq_,), (1,))
    kf, _ = _pad_to(kf, (bk_,), (1,))
    vf, _ = _pad_to(vf, (bk_,), (1,))
    o = flash_attention_pallas(
        qf, kf, vf, group=group, bq=bq_, bk=bk_, scale=scale, causal=causal,
        window=window, softcap=softcap, kv_len=skv, src_dtype=src_dt,
        out_dtype=jnp.float32, interpret=interpret)
    return o[:, :sq].reshape(b, h, sq, d)


def dotp_ex(a, b, *, policy=None, interpret: bool = True):
    """Expanding dot product of two 1D streams (paper Fig 11e)."""
    policy = get_policy(policy) if policy is not None else get_policy("tp_fp16")
    src_dt = (policy.matmul.src_fmt.native_dtype
              if policy.mode == "native" else jnp.float32)
    n = a.shape[0]
    c = 128
    rows = -(-n // c)
    pad = rows * c - n
    a2 = jnp.pad(a, (0, pad)).reshape(rows, c)
    b2 = jnp.pad(b, (0, pad)).reshape(rows, c)
    br = min(256, rows)
    a2, _ = _pad_to(a2, (br,), (0,))
    b2, _ = _pad_to(b2, (br,), (0,))
    lanes = dotp_ex_pallas(a2, b2, block_rows=br, src_dtype=src_dt,
                           interpret=interpret)
    return jnp.sum(lanes)
