"""Pallas TPU kernel: expanding dot-product accumulation (paper case study).

The paper's application kernel (§IV.C, Fig 10/11e): accumulate element-wise
products of two FP16 input streams into an FP32 result using the expanding
FMA (``fmacex.s.h``) — FP32 accuracy at FP16 storage/compute cost.

Kernel contract: inputs are (R, C) f32 arrays holding src_fmt-grid values;
each grid step loads a row-block tile, forms the exact products, and adds
the tile's partial sums into an f32 VMEM accumulator; the final step reduces
to a (1, 128) vector whose lane sum is the dot product (ops.py finishes the
lane reduction).  Parallel tiling reassociates the paper's sequential
accumulation order; tests bound the difference against the sequential oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dotp_kernel(a_ref, b_ref, o_ref, acc_ref, *, nsteps: int, src_dtype):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(src_dtype)
    b = b_ref[...].astype(src_dtype)
    prod = a.astype(jnp.float32) * b.astype(jnp.float32)  # exact for narrow src
    acc_ref[...] += jnp.sum(prod, axis=0, keepdims=True)  # (1, C)

    @pl.when(i == nsteps - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "src_dtype",
                                             "interpret"))
def dotp_ex_pallas(a, b, *, block_rows: int = 256, src_dtype=jnp.float16,
                   interpret: bool = True):
    """Expanding dot product of (R, C) tiles; returns (1, C) partial lanes."""
    r, c = a.shape
    assert a.shape == b.shape and r % block_rows == 0 and c % 128 == 0
    nsteps = r // block_rows
    return pl.pallas_call(
        functools.partial(_dotp_kernel, nsteps=nsteps, src_dtype=src_dtype),
        grid=(nsteps,),
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((1, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, c), jnp.float32)],
        interpret=interpret,
    )(a, b)
