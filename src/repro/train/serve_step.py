"""Serving-step factories: prefill and decode under explicit shardings.

``decode_32k`` / ``long_500k`` lower the *decode step* (one new token
against a KV cache of seq_len), ``prefill_32k`` lowers the prefill.
KV caches store in ``policy.kv_fmt`` (the paper's storage-format knob) and
shard per models/sharding.py (heads over ``model`` when divisible, else
sequence — flash-decode style with GSPMD-reduced softmax stats).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import sharding as shd
from ..models.layers import set_batch_axes
from ..models.transformer import Model, init_caches

F32 = jnp.float32


def serve_shardings(model: Model, mesh, *, batch: int, max_len: int,
                    dp_axes=("data",), model_axis="model"):
    cfg = model.cfg
    msize = mesh.shape[model_axis]
    params_shape = jax.eval_shape(model.init, jax.random.key(0))
    overrides = ({"embed": "rep", "lm_head": "rep"}
                 if cfg.embed_sharding == "replicated" else None)
    pspecs = shd.param_specs(params_shape, model_axis, msize,
                             overrides=overrides)
    caches_shape = jax.eval_shape(
        lambda: init_caches(cfg, batch, max_len, model.policy))
    cspecs = shd.cache_specs(cfg, caches_shape, batch=batch, mesh=mesh,
                             batch_axes=dp_axes, model_axis=model_axis)
    ba = shd.batch_spec_axes(batch, dp_axes, mesh)
    return params_shape, pspecs, caches_shape, cspecs, ba


def make_prefill(model: Model, mesh, *, batch: int, seq_len: int,
                 max_len: int, dp_axes=("data",), model_axis="model"):
    cfg = model.cfg
    set_batch_axes(dp_axes)
    params_shape, pspecs, caches_shape, cspecs, ba = serve_shardings(
        model, mesh, batch=batch, max_len=max_len, dp_axes=dp_axes,
        model_axis=model_axis)

    def prefill(params, tokens, frontend_embeds=None):
        return model.prefill(params, tokens, max_len=max_len,
                             frontend_embeds=frontend_embeds, mesh=mesh)

    args = [params_shape,
            jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)]
    in_sh = [shd.named(mesh, pspecs),
             NamedSharding(mesh, P(ba, None))]
    if cfg.frontend is not None:
        n = (cfg.n_frontend_tokens if cfg.frontend == "patch"
             else cfg.encoder.n_frames)
        args.append(jax.ShapeDtypeStruct((batch, n, cfg.d_model), F32))
        in_sh.append(NamedSharding(mesh, P(ba, None, None)))
    out_sh = (NamedSharding(mesh, P(ba, None, model_axis)),
              shd.named(mesh, cspecs))
    jitted = jax.jit(prefill, in_shardings=tuple(in_sh),
                     out_shardings=out_sh)
    return jitted, tuple(args)


def make_decode_step(model: Model, mesh, *, batch: int, max_len: int,
                     dp_axes=("data",), model_axis="model"):
    """One-token decode step against a ``max_len`` cache (the decode_32k /
    long_500k dry-run target)."""
    cfg = model.cfg
    set_batch_axes(dp_axes)
    params_shape, pspecs, caches_shape, cspecs, ba = serve_shardings(
        model, mesh, batch=batch, max_len=max_len, dp_axes=dp_axes,
        model_axis=model_axis)

    def decode(params, token, caches, pos):
        return model.decode_step(params, token, caches, pos, mesh=mesh)

    args = (params_shape,
            jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            caches_shape,
            jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (shd.named(mesh, pspecs),
             NamedSharding(mesh, P(ba, None)),
             shd.named(mesh, cspecs),
             NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, P(ba, None, model_axis)),
              shd.named(mesh, cspecs))
    jitted = jax.jit(decode, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(2,))
    return jitted, args
