"""Train-step factory: model + optimizer + policy -> one jitted SPMD step.

The step is a pure function
    (params, opt_state, batch[, ef, sr_key]) ->
    (params, opt_state, metrics[, ef])
with explicit in/out shardings so the same factory serves the smoke tests
(1 device), the single-pod mesh (256) and the multi-pod mesh (512).

Distributed-optimization features (all policy/flag driven):
  * gradient compression (fp8/bf16 + stochastic rounding + error feedback):
    the whole fwd/bwd runs inside ``shard_map`` with the data axes manual
    (per-replica local gradients) and the model axis auto (GSPMD tensor
    parallelism); the data-parallel gradient sync is then an explicit psum
    whose wire payload is the narrow format — width-proportional ICI
    bytes, the paper's SIMD-lane insight applied to the dominant
    collective.  Error-feedback state is carried as a [n_dp, ...] buffer
    sharded over the data axes (each replica owns its slice).
  * ZeRO-1 optimizer-state sharding over ``data``,
  * remat (activation checkpointing) around each scanned layer group,
  * stochastic rounding when re-quantizing params from fp32 master.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.compat import shard_map_compat
from ..core.policy import PrecisionPolicy, get_policy
from ..models import sharding as shd
from ..models.layers import set_batch_axes
from ..models.transformer import Model
from ..optim import grad_compress
from ..optim.optimizer import OptConfig, apply_update, init_opt_state, \
    opt_state_specs

F32 = jnp.float32


def train_input_shardings(mesh, batch: int, dp_axes=("data",),
                          with_frontend=False):
    ba = shd.batch_spec_axes(batch, dp_axes, mesh)
    specs = {"tokens": P(ba, None), "labels": P(ba, None)}
    if with_frontend:
        specs["frontend_embeds"] = P(ba, None, None)
    return specs


def _dp_size(mesh, dp_axes):
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n


def init_error_feedback(params, mesh=None, dp_axes=()):
    """[n_dp, ...]-leading error-feedback buffers (one slice per replica)."""
    n = _dp_size(mesh, dp_axes) if mesh is not None else 1
    return jax.tree.map(lambda p: jnp.zeros((n,) + p.shape, F32), params)


def make_train_step(model: Model, opt_cfg: OptConfig, mesh, *,
                    dp_axes: Tuple[str, ...] = ("data",),
                    model_axis: str = "model",
                    compress_grads: Optional[str] = None,
                    remat: bool = True, aux_coef: float = 0.01,
                    loss_chunk: int = 1024):
    """Returns step(params, opt_state, batch[, ef][, key_data]) -> ... .

    ``compress_grads``: None (GSPMD all-reduce in the compute dtype) or a
    format name ('fp8', 'fp16alt') for the explicit compressed sync."""
    policy = model.policy
    use_compress = compress_grads is not None and mesh is not None
    use_key = use_compress or policy.stochastic_grad_round

    def loss_fn(params, batch):
        return model.forward_train(
            params, batch["tokens"], batch["labels"],
            frontend_embeds=batch.get("frontend_embeds"), mesh=mesh,
            remat=remat, aux_coef=aux_coef, loss_chunk=loss_chunk)

    if not use_compress:
        set_batch_axes(dp_axes)

        def step(params, opt_state, batch, key=None):
            if key is not None:
                key = jax.random.wrap_key_data(key)
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state, metrics = apply_update(
                params, grads, opt_state, opt_cfg, policy, sr_key=key)
            metrics["loss"] = loss
            return params, opt_state, metrics

        return step

    # ---- compressed-gradient variant: grads computed per data replica ----
    set_batch_axes(())         # inside shard_map the batch dim is local
    n_dp = _dp_size(mesh, dp_axes)
    fmt = compress_grads

    def local_grad_body(params, batch, ef, key):
        """Runs with dp_axes manual, model axis auto.  ef leaves arrive as
        [1, ...] slices; key is a shared typed PRNG key."""
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        idx = jax.lax.axis_index(dp_axes)
        key = jax.random.fold_in(key, idx)  # decorrelate SR across replicas
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        ef_leaves = treedef.flatten_up_to(ef)
        keys = jax.random.split(key, len(leaves))
        synced, new_ef = [], []
        for g, e, kk in zip(leaves, ef_leaves, keys):
            s, e2 = grad_compress.compress_sync_local(
                g, e[0], axes=dp_axes, fmt=fmt, key=kk, n_replicas=n_dp)
            synced.append(s)
            new_ef.append(e2[None])
        loss = jax.lax.pmean(loss, dp_axes)
        return (loss, jax.tree_util.tree_unflatten(treedef, synced),
                jax.tree_util.tree_unflatten(treedef, new_ef))

    dpa = tuple(dp_axes)
    ef_spec = P(dpa)

    def step(params, opt_state, batch, ef, key):
        key = jax.random.wrap_key_data(key)
        kq, ksr = jax.random.split(key)
        batch_specs = {k: P(dpa, *([None] * (v.ndim - 1)))
                       for k, v in batch.items()}
        loss, grads, ef = shard_map_compat(
            local_grad_body, mesh=mesh,
            in_specs=(P(), batch_specs, ef_spec, P()),
            out_specs=(P(), P(), ef_spec),
            axis_names=set(dpa), check_vma=False,
        )(params, batch, ef, kq)
        params, opt_state, metrics = apply_update(
            params, grads, opt_state, opt_cfg, policy,
            sr_key=ksr if policy.stochastic_grad_round else None)
        metrics["loss"] = loss
        return params, opt_state, metrics, ef

    return step


def jit_train_step(model: Model, opt_cfg: OptConfig, mesh, *,
                   batch_size: int, seq_len: int = 4096, dp_axes=("data",),
                   model_axis="model", compress_grads=None, donate=True,
                   **kw):
    """Fully-sharded jit of the step for real execution or dry-run lowering.

    Returns (jitted, example_args_as_ShapeDtypeStructs, spec dict)."""
    cfg = model.cfg
    step = make_train_step(model, opt_cfg, mesh, dp_axes=dp_axes,
                           model_axis=model_axis,
                           compress_grads=compress_grads, **kw)
    msize = mesh.shape[model_axis]
    use_compress = compress_grads is not None
    use_key = use_compress or model.policy.stochastic_grad_round

    params_shape = jax.eval_shape(model.init, jax.random.key(0))
    overrides = ({"embed": "rep", "lm_head": "rep"}
                 if model.cfg.embed_sharding == "replicated" else None)
    pspecs = shd.param_specs(params_shape, model_axis, msize,
                             overrides=overrides)
    opt_shape = jax.eval_shape(
        lambda p: init_opt_state(p, opt_cfg, model.policy), params_shape)
    ospecs = opt_state_specs(pspecs, opt_shape, zero_axis=dp_axes[-1],
                             mesh=mesh)
    bspecs = train_input_shardings(mesh, batch_size, dp_axes,
                                   with_frontend=cfg.frontend is not None)

    in_shardings = [shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                    shd.named(mesh, bspecs)]
    out_shardings = [shd.named(mesh, pspecs), shd.named(mesh, ospecs), None]
    args = [params_shape, opt_shape,
            _batch_shapes(cfg, batch_size, seq_len)]
    if use_compress:
        ef_shape = jax.eval_shape(
            lambda p: init_error_feedback(p, mesh, dp_axes), params_shape)
        efspecs = jax.tree.map(
            lambda _: P(tuple(dp_axes)), ef_shape)
        in_shardings.append(shd.named(mesh, efspecs))
        out_shardings.append(shd.named(mesh, efspecs))
        args.append(ef_shape)
    if use_key:
        args.append(jax.ShapeDtypeStruct((2,), jnp.uint32))
        in_shardings.append(NamedSharding(mesh, P()))

    jitted = jax.jit(step,
                     in_shardings=tuple(in_shardings),
                     out_shardings=tuple(out_shardings),
                     donate_argnums=(0, 1) if donate else ())
    return jitted, tuple(args), {"params": pspecs, "opt": ospecs,
                                 "batch": bspecs}


def _batch_shapes(cfg, batch_size, seq_len=4096):
    shapes = {"tokens": jax.ShapeDtypeStruct((batch_size, seq_len),
                                             jnp.int32),
              "labels": jax.ShapeDtypeStruct((batch_size, seq_len),
                                             jnp.int32)}
    if cfg.frontend == "patch":
        shapes["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.n_frontend_tokens, cfg.d_model), F32)
    elif cfg.frontend == "audio":
        shapes["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.encoder.n_frames, cfg.d_model), F32)
    return shapes
