from .train_step import make_train_step, train_input_shardings
from .serve_step import make_prefill, make_decode_step
from .loop import TrainLoop, LoopConfig
from .fault import StragglerMonitor, SimulatedFailure
