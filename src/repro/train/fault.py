"""Fault tolerance: straggler detection and failure/restart machinery.

On a real multi-pod deployment the failure modes are: a host crashing
(process exit -> restart from checkpoint), a chip slowing down
(straggler -> flag, drain, reschedule), and a pod-slice loss (restore onto
a smaller mesh — covered by mesh-elastic checkpoints in ckpt/).

This module provides the process-level pieces that are testable on CPU:
  * :class:`StragglerMonitor` — per-step wall-clock EWMA + deviation
    flagging (the signal a cluster scheduler consumes),
  * :class:`SimulatedFailure` — deterministic fault injection for tests
    and the fault-tolerance example,
  * :func:`run_with_restarts` — supervisor loop: run -> crash -> restore
    from the latest checkpoint -> continue, bounded retries.

Serving-side counterparts (launch/engine.py drives these off its decode-
round clock instead of the training step counter):
  * :class:`ServeFaultPlan` — deterministic injection of page-pool
    exhaustion episodes, slow-burst stragglers and NaN-poisoned logits at
    chosen rounds (same replayability contract as :class:`FailurePlan`:
    one plan + one queue -> one trajectory),
  * :class:`ServeWatchdog` — consecutive no-progress detector that turns
    a livelocked scheduler loop into a clean :class:`EngineStuckError`,
  * :class:`PoisonedLogitsError` — non-finite logits reached a sampler
    outside a masking fault harness (fail fast, don't emit garbage).

Replica-level counterparts (the data-parallel serving fleet of
``launch/engine.py::ReplicatedEngine``):
  * :class:`ReplicaFaultPlan` — deterministically KILLS a replica at a
    chosen burst (simulated device loss: :class:`ReplicaLostError`
    raised through the burst dispatch, device memory unreachable) or
    HANGS it (the replica stops responding; the host's heartbeat view
    declares it dead after missed beats, device memory still readable —
    the distinction decides whether live K/V pages can migrate by
    swap-out or must be recomputed by re-ingest),
  * :class:`ReplicaLostError` — subclasses :class:`SimulatedFailure`, so
    an unrecoverable loss (no surviving replica) propagates into
    :func:`run_with_restarts`, whose restart diagnostics name the
    replica that triggered each attempt.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class StragglerMonitor:
    """EWMA of step wall-clock; flags steps slower than ``threshold`` x the
    running mean (after a warmup)."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.n = 0
        self.flagged: list = []

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        straggler = False
        if self.ewma is None:
            self.ewma = dt
        else:
            if self.n > self.warmup and dt > self.threshold * self.ewma:
                straggler = True
                self.flagged.append((step, dt, self.ewma))
            # EWMA update excludes flagged outliers (keeps baseline honest)
            if not straggler:
                self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return straggler


class SimulatedFailure(RuntimeError):
    """Injected node failure."""


@dataclasses.dataclass
class FailurePlan:
    """Deterministic fault injection: raise at the listed step indices
    (global step count, each raised once)."""
    fail_at: tuple = ()
    raised: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.raised:
            self.raised.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


class ReplicaLostError(SimulatedFailure):
    """A serving replica died (simulated device loss).  Raised through
    the victim's burst dispatch by a :class:`ReplicaFaultPlan` kill, or
    by the replicated host loop when a hung replica exhausts its
    heartbeat patience — and re-raised by ``ReplicatedEngine`` when NO
    replica survives to absorb the victim's requests (at which point
    recovery is a full restart: :func:`run_with_restarts` + the request
    journal).  ``replica`` / ``burst`` locate the failure."""

    def __init__(self, msg: str, *, replica: int, burst: int = -1):
        super().__init__(msg)
        self.replica = replica
        self.burst = burst


@dataclasses.dataclass
class ReplicaFaultPlan:
    """Deterministic replica-level failure injection for the data-parallel
    serving fleet, keyed to the VICTIM's burst counter (each replica's
    burst sequence is deterministic for a given queue partition, so one
    plan + one queue replays to the identical failure point).

    ``replica`` picks the victim, ``at_burst`` the burst index (0-based:
    the fault fires when the victim is ABOUT to dispatch that burst).
    ``mode="kill"`` raises :class:`ReplicaLostError` through the burst
    dispatch — the device is gone, its pool pages are UNREACHABLE, so
    in-flight rows can only migrate by free-and-reingest (recompute).
    ``mode="hang"`` makes the replica unresponsive from that burst on:
    the host loop's heartbeat view counts missed beats and declares the
    replica dead after its patience — device memory is still READABLE,
    so live pages can migrate as swap-out payloads (no recompute).

    A kill fires ONCE per plan (a restarted fleet does not re-die unless
    :meth:`reset` is called — that is what lets ``run_with_restarts``
    recover); a hang is sticky for the plan's lifetime.  ``events`` logs
    what actually fired, like :class:`ServeFaultPlan`."""
    replica: int = 0
    at_burst: int = 1
    mode: str = "kill"

    def __post_init__(self):
        if self.mode not in ("kill", "hang"):
            raise ValueError(f"mode must be kill|hang, got {self.mode!r}")
        self.reset()

    def reset(self) -> None:
        self._killed = False
        self._hung = False
        self.events: list = []

    def note(self, kind: str, **kw) -> None:
        self.events.append((kind, kw))

    def take_kill(self, replica: int, burst: int) -> bool:
        """True exactly once: the victim replica reaching (or jumping
        past) the planned burst in kill mode."""
        if (self.mode != "kill" or self._killed
                or replica != self.replica or burst < self.at_burst):
            return False
        self._killed = True
        self.note("kill", replica=replica, burst=burst)
        return True

    def hang_due(self, replica: int, burst: int) -> bool:
        """True (sticky) once the victim reaches the planned burst in
        hang mode — the replica stops responding from here on."""
        if self.mode != "hang" or replica != self.replica:
            return False
        if not self._hung:
            if burst < self.at_burst:
                return False
            self._hung = True
            self.note("hang", replica=replica, burst=burst)
        return True


class PoisonedLogitsError(RuntimeError):
    """Non-finite logits reached a sampling site with no fault harness
    masking them — the serving loop fails fast instead of silently
    emitting argmax-of-garbage token 0."""


class EngineStuckError(RuntimeError):
    """The serving watchdog tripped: the scheduler kept iterating without
    admitting, prefilling, decoding or finishing anything.  ``diag``
    carries the engine's slot/queue/pool snapshot at abort time."""

    def __init__(self, msg: str, diag: Optional[dict] = None):
        super().__init__(msg)
        self.diag = diag or {}


@dataclasses.dataclass
class ServeFaultPlan:
    """Deterministic serving-path fault injection, keyed to the engine's
    decode-round clock (the logical time admission/preemption already run
    on, so a plan + a queue replays to the same trajectory bit for bit).

    ``exhaust_at``: rounds at which the engine grabs the allocator's
    entire free list and holds it for ``exhaust_for`` rounds — admission
    and lazy page growth must survive ``try_alloc`` returning ``None``.
    ``slow_at``: rounds before whose burst the engine sleeps ``slow_s``
    seconds — a slow-burst straggler the :class:`StragglerMonitor` must
    flag.  ``poison_at``: decode rounds whose logits are overwritten with
    NaN inside the compiled burst; ``mask_poison=True`` lets the guard
    mask-and-count them, ``False`` makes the engine raise
    :class:`PoisonedLogitsError` (fail-fast mode).

    Numerical-health injections (PR 7): ``overflow_at`` lists decode
    rounds whose K/V writes are scaled by ``overflow_scale`` before
    write-time quantization — values that overflow the narrow KV rung and
    drive the escalation path (the write-side twin of ``poison_at``).
    ``corrupt_swap_at`` lists swap-out EVENTS (0-based, in the order the
    engine swaps victims out) whose host page payloads get one
    deterministic bit flipped — a silent-data-corruption the checksum
    verification at swap-in must catch and recover from via reingest.

    The plan is reusable: the engine calls :meth:`reset` at run start, so
    replaying the same plan object is deterministic.  ``events`` logs
    every injection actually fired (round, kind, payload)."""
    exhaust_at: tuple = ()
    exhaust_for: int = 4
    slow_at: tuple = ()
    slow_s: float = 0.05
    poison_at: tuple = ()
    mask_poison: bool = True
    overflow_at: tuple = ()
    overflow_scale: float = 65536.0
    corrupt_swap_at: tuple = ()

    def __post_init__(self):
        self.reset()

    def reset(self) -> None:
        self._fired_exhaust: set = set()
        self._fired_slow: set = set()
        self._swap_seen: int = 0
        self.events: list = []

    def note(self, kind: str, **kw) -> None:
        self.events.append((kind, kw))

    def take_exhaustion(self, round_no: int) -> Optional[int]:
        """Duration of an exhaustion episode starting by ``round_no``
        (each listed round fires once; catch-up included — the engine's
        round clock can jump over idle stretches), else None."""
        due = [r for r in self.exhaust_at
               if r <= round_no and r not in self._fired_exhaust]
        if not due:
            return None
        self._fired_exhaust.update(due)
        return self.exhaust_for

    def take_slow(self, round_no: int) -> float:
        """Seconds of straggler stall due at ``round_no`` (0.0 if none)."""
        due = [r for r in self.slow_at
               if r <= round_no and r not in self._fired_slow]
        self._fired_slow.update(due)
        return self.slow_s * len(due)

    def next_poison(self, lo: int, hi: int) -> Optional[int]:
        """First poisoned round in ``[lo, hi)`` — the engine converts it
        to a burst-relative index.  Stateless: the round window advances
        monotonically, and a burst that exits before reaching the round
        re-schedules it in the next window."""
        hits = [r for r in self.poison_at if lo <= r < hi]
        return min(hits) if hits else None

    def next_overflow(self, lo: int, hi: int) -> Optional[int]:
        """First overflow-injection round in ``[lo, hi)`` (stateless
        window scan, same contract as :meth:`next_poison`)."""
        hits = [r for r in self.overflow_at if lo <= r < hi]
        return min(hits) if hits else None

    def take_corrupt(self) -> bool:
        """True when the CURRENT swap-out event (0-based, counted per
        call) is listed in ``corrupt_swap_at`` — the engine flips one bit
        in that victim's host payload.  Stateful: each call consumes one
        swap-event index, so the plan replays exactly."""
        idx = self._swap_seen
        self._swap_seen += 1
        return idx in self.corrupt_swap_at


class ServeWatchdog:
    """Turns scheduler livelock into a clean abort: ``tick(False)`` for
    ``patience`` consecutive loop iterations (no admission, no prefill
    progress, no decode rounds, no finishes) raises
    :class:`EngineStuckError` with the caller's diagnostics snapshot.
    Any real progress resets the counter — waiting out backoff windows or
    a bounded exhaustion episode is fine; waiting forever is not."""

    def __init__(self, patience: int = 200):
        assert patience >= 1
        self.patience = patience
        self.stalled = 0

    def tick(self, progressed: bool, diag=None) -> None:
        if progressed:
            self.stalled = 0
            return
        self.stalled += 1
        if self.stalled >= self.patience:
            d = diag() if callable(diag) else (diag or {})
            raise EngineStuckError(
                f"serving loop made no progress for {self.stalled} "
                f"consecutive iterations: {d}", d)


def run_with_restarts(make_runner: Callable[[], "object"],
                      max_restarts: int = 3):
    """Supervisor: build a runner (which restores from the latest
    checkpoint), run it; on failure rebuild and continue.  Returns the
    final runner and the number of restarts consumed.

    A restarted attempt must not inherit the previous attempt's health
    baselines: a pre-crash straggler EWMA would mis-flag the restart's
    warm-up steps, and stale watchdog stall counts would trip spuriously.
    ``make_runner`` usually builds a fresh runner, but factories that
    (re)use a long-lived runner object are common in restore-from-latest
    setups — so the supervisor explicitly calls the runner's
    ``reset_monitors()`` (when it has one) before every attempt.  For a
    ``ReplicatedEngine`` that call fans out to every replica's watchdog
    and straggler monitor.

    Each failed attempt is recorded in ``attempt_log`` — a list of
    ``(attempt_index, error_type_name, replica_or_None, message)``
    tuples attached to the error that is finally re-raised when the
    restart budget is exhausted, so the diagnostics name which replica
    triggered each restart (``ReplicaLostError.replica``; ``None`` for
    non-replica failures)."""
    restarts = 0
    attempt_log: list = []
    while True:
        runner = make_runner()
        reset = getattr(runner, "reset_monitors", None)
        if callable(reset):
            reset()
        try:
            runner.run()
            return runner, restarts
        except SimulatedFailure as err:
            attempt_log.append((restarts, type(err).__name__,
                                getattr(err, "replica", None), str(err)))
            restarts += 1
            if restarts > max_restarts:
                err.attempt_log = attempt_log
                raise
