"""Fault tolerance: straggler detection and failure/restart machinery.

On a real multi-pod deployment the failure modes are: a host crashing
(process exit -> restart from checkpoint), a chip slowing down
(straggler -> flag, drain, reschedule), and a pod-slice loss (restore onto
a smaller mesh — covered by mesh-elastic checkpoints in ckpt/).

This module provides the process-level pieces that are testable on CPU:
  * :class:`StragglerMonitor` — per-step wall-clock EWMA + deviation
    flagging (the signal a cluster scheduler consumes),
  * :class:`SimulatedFailure` — deterministic fault injection for tests
    and the fault-tolerance example,
  * :func:`run_with_restarts` — supervisor loop: run -> crash -> restore
    from the latest checkpoint -> continue, bounded retries.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class StragglerMonitor:
    """EWMA of step wall-clock; flags steps slower than ``threshold`` x the
    running mean (after a warmup)."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.n = 0
        self.flagged: list = []

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        straggler = False
        if self.ewma is None:
            self.ewma = dt
        else:
            if self.n > self.warmup and dt > self.threshold * self.ewma:
                straggler = True
                self.flagged.append((step, dt, self.ewma))
            # EWMA update excludes flagged outliers (keeps baseline honest)
            if not straggler:
                self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return straggler


class SimulatedFailure(RuntimeError):
    """Injected node failure."""


@dataclasses.dataclass
class FailurePlan:
    """Deterministic fault injection: raise at the listed step indices
    (global step count, each raised once)."""
    fail_at: tuple = ()
    raised: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.raised:
            self.raised.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


def run_with_restarts(make_runner: Callable[[], "object"],
                      max_restarts: int = 3):
    """Supervisor: build a runner (which restores from the latest
    checkpoint), run it; on failure rebuild and continue.  Returns the
    final runner and the number of restarts consumed."""
    restarts = 0
    while True:
        runner = make_runner()
        try:
            runner.run()
            return runner, restarts
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
