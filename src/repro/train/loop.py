"""The training loop: data + step + checkpoint + fault handling.

Single-process version that is mesh-agnostic (1 CPU device for tests and
examples; 256/512-device meshes on real hardware — the loop code is
identical, only the mesh differs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.checkpoint import CheckpointManager
from ..data.pipeline import DataConfig, SyntheticLMData
from ..models.transformer import Model
from ..optim.optimizer import OptConfig, init_opt_state
from ..optim import grad_compress
from .fault import FailurePlan, StragglerMonitor
from .train_step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    compress_grads: Optional[str] = None
    remat: bool = True
    seed: int = 0


class TrainLoop:
    """Build everything, optionally restore, run; safe to re-instantiate
    after a crash (run_with_restarts does exactly that)."""

    def __init__(self, model: Model, opt_cfg: OptConfig, data_cfg: DataConfig,
                 loop_cfg: LoopConfig, mesh=None,
                 failure_plan: Optional[FailurePlan] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.loop_cfg = loop_cfg
        self.mesh = mesh
        self.failure_plan = failure_plan
        self.data = SyntheticLMData(data_cfg)
        self.monitor = StragglerMonitor()
        self.metrics_log: list = []

        from .train_step import init_error_feedback
        dp_axes = () if mesh is None else ("data",)
        self.step_fn = jax.jit(make_train_step(
            model, opt_cfg, mesh, dp_axes=dp_axes,
            compress_grads=loop_cfg.compress_grads, remat=loop_cfg.remat))

        key = jax.random.key(loop_cfg.seed)
        self.params = model.init(key)
        self.opt_state = init_opt_state(self.params, opt_cfg, model.policy)
        self.ef = (init_error_feedback(self.params, mesh, dp_axes)
                   if loop_cfg.compress_grads and mesh is not None else None)
        self.step = 0
        self.ckpt = (CheckpointManager(loop_cfg.ckpt_dir,
                                       keep=loop_cfg.keep_ckpts)
                     if loop_cfg.ckpt_dir else None)
        if self.ckpt is not None:
            self._try_restore()

    # -- checkpoint plumbing -------------------------------------------------
    def _state_tree(self):
        t = {"params": self.params, "opt": self.opt_state}
        if self.ef is not None:
            t["ef"] = self.ef
        return t

    def _try_restore(self):
        like = self._state_tree()
        step, tree, extra = self.ckpt.restore_latest(like)
        if step is not None:
            self.params = tree["params"]
            self.opt_state = tree["opt"]
            if self.ef is not None:
                self.ef = tree["ef"]
            self.step = int(extra["step"])
            self.data.load_state_dict(extra["data"])

    def _save(self, sync=False):
        if self.ckpt is None:
            return
        self.ckpt.save(self.step, self._state_tree(),
                       extra={"data": self.data.state_dict()}, sync=sync)

    # -- the loop -------------------------------------------------------------
    def run(self):
        lc = self.loop_cfg
        use_key = (self.ef is not None
                   or self.model.policy.stochastic_grad_round)
        while self.step < lc.total_steps:
            if self.failure_plan is not None:
                self.failure_plan.maybe_fail(self.step)
            batch = self.data.batch_at(self.data.step)
            t0 = time.perf_counter()
            args = [self.params, self.opt_state, batch]
            if self.ef is not None:
                args.append(self.ef)
            if use_key:
                args.append(jax.random.key_data(jax.random.fold_in(
                    jax.random.key(lc.seed + 1), self.step)).astype(
                        jnp.uint32))
            out = self.step_fn(*args)
            if self.ef is not None:
                self.params, self.opt_state, metrics, self.ef = out
            else:
                self.params, self.opt_state, metrics = out
            metrics = {k: float(v) for k, v in metrics.items()}
            jax.block_until_ready(self.params)
            dt = time.perf_counter() - t0
            straggler = self.monitor.record(self.step, dt)
            metrics.update(step=self.step, dt=dt, straggler=straggler)
            self.metrics_log.append(metrics)
            if lc.log_every and self.step % lc.log_every == 0:
                print(f"step {self.step:5d} loss {metrics['loss']:.4f} "
                      f"lr {metrics['lr']:.2e} gnorm "
                      f"{metrics['grad_norm']:.3f} {dt*1e3:.0f}ms"
                      + (" STRAGGLER" if straggler else ""))
            self.step += 1
            self.data.step = self.step
            if lc.ckpt_every and self.step % lc.ckpt_every == 0:
                self._save()
        self._save(sync=True)
        return self.metrics_log
