"""Compressed gradient all-reduce with error feedback — the paper's
"communicate in a narrower format" insight applied to the data-parallel
gradient sync (the dominant collective at scale).

Scheme (per tensor):
  1. g' = g_local + error_feedback          (EF keeps the sync unbiased)
  2. shared scale s = psum_max(|g'|) / max_normal(fmt)   (tiny collective)
  3. q = Q_stochastic(g'/s, fmt)            (SR removes quantization bias)
  4. g_sync = psum(q) * s / n_replicas      (the big collective, in fmt)
  5. ef_new = g' - q*s

Implemented inside ``jax.shard_map`` with the data axes manual and the
model axis auto (GSPMD keeps handling tensor parallelism).  On the wire the
payload is ``fmt``-width: the psum operand is cast to the narrow native
dtype (bf16/fp8) — width-proportional ICI bytes, the SIMD-lane analogue of
paper §II.B.3.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import softfloat
from ..core.formats import FPFormat, get_format

F32 = jnp.float32


def _comm_dtype(fmt: FPFormat):
    # psum on float8 is not universally supported; bf16 carries any fp8-grid
    # value exactly (e5m2/e4m3 grids are subsets of bf16's only in exponent
    # range — bf16(8,7) mantissa superset of m<=7 grids), so the wire format
    # models fmt-width while the emulation container is the narrowest safe
    # native dtype.
    if fmt.native_dtype is not None and fmt.width >= 16:
        return fmt.native_dtype
    return jnp.bfloat16


def compress_sync_local(g, ef, *, axes: Tuple[str, ...], fmt,
                        key: Optional[jax.Array], n_replicas: int):
    """Body-level (inside shard_map) compressed psum of one tensor."""
    fmt = get_format(fmt)
    gf = g.astype(F32) + ef.astype(F32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axes)
    scale = jnp.maximum(amax / fmt.max_normal, 1e-30)
    scaled = gf / scale
    if key is not None:
        q = softfloat.quantize(scaled, fmt, "stochastic", key=key)
    else:
        q = softfloat.quantize(scaled, fmt)
    ef_new = gf - q * scale
    wire = q.astype(_comm_dtype(fmt))
    synced = jax.lax.psum(wire.astype(F32), axes)
    return synced * (scale / n_replicas), ef_new


def init_error_feedback(grads_like):
    """Zero EF buffers shaped like the gradients (single-replica form used
    by unit tests; the train step uses train_step.init_error_feedback's
    [n_dp, ...] layout)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads_like)
