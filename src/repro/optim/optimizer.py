"""Transprecision optimizers: AdamW and Adafactor in pure JAX.

The paper's per-op-group format configurability extends naturally to the
optimizer (a "CONV + ADDMUL" consumer of gradients):

  * master weights in ``policy.master_fmt`` (fp32) — the expanding-FMA
    destination of the weight update,
  * model weights stored in ``policy.param_fmt`` (bf16/fp16), re-quantized
    from master each step (optionally with stochastic rounding),
  * Adam moments stored in ``policy.opt_m_fmt`` / ``opt_v_fmt`` (bf16
    halves optimizer HBM, the dominant memory term at scale) with the
    update math always in f32.

ZeRO-1 (optimizer-state sharding over the data axis) is expressed purely
through shardings: ``opt_state_specs`` places the data axis on the first
divisible dimension of every state tensor; GSPMD then turns the update
into reduce-scatter + all-gather automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import ops as tp
from ..core import softfloat
from ..core.policy import PrecisionPolicy

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"               # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    # adafactor
    decay_adafactor: float = 0.8


def lr_at(cfg: OptConfig, step):
    step = jnp.asarray(step, F32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def _q_state(x, fmt, policy):
    """Quantize an optimizer-state tensor to its storage format."""
    if fmt is None:
        return x
    if policy.mode == "native" and fmt.native_dtype is not None:
        return x.astype(fmt.native_dtype)
    return softfloat.quantize(x, fmt)


def _is_matrix(x) -> bool:
    return x.ndim >= 2 and x.shape[-1] > 1 and x.shape[-2] > 1


def init_opt_state(params, cfg: OptConfig, policy: PrecisionPolicy) -> dict:
    def zeros_like_fmt(x, fmt):
        z = jnp.zeros(x.shape, F32)
        return _q_state(z, fmt, policy)

    state = {"step": jnp.zeros((), jnp.int32),
             "master": jax.tree.map(lambda x: x.astype(F32), params)}
    if cfg.name == "adamw":
        state["m"] = jax.tree.map(
            lambda x: zeros_like_fmt(x, policy.opt_m_fmt), params)
        state["v"] = jax.tree.map(
            lambda x: zeros_like_fmt(x, policy.opt_v_fmt), params)
    elif cfg.name == "adafactor":
        def fac(x):
            if _is_matrix(x):
                return {"row": jnp.zeros(x.shape[:-1], F32),
                        "col": jnp.zeros(x.shape[:-2] + x.shape[-1:], F32)}
            return {"full": jnp.zeros(x.shape, F32)}
        state["v"] = jax.tree.map(fac, params)
    else:
        raise ValueError(cfg.name)
    return state


def _global_norm(tree):
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(F32))), tree, 0.0)
    return jnp.sqrt(sq)


def apply_update(params, grads, state, cfg: OptConfig,
                 policy: PrecisionPolicy, *, sr_key=None):
    """One optimizer step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    grads = jax.tree.map(lambda g: g.astype(F32), grads)
    gnorm = _global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    new_state = {"step": step}
    if cfg.name == "adamw":
        bc1 = 1 - cfg.b1 ** step.astype(F32)
        bc2 = 1 - cfg.b2 ** step.astype(F32)
        m_new = jax.tree.map(
            lambda g, m: cfg.b1 * m.astype(F32) + (1 - cfg.b1) * g,
            grads, state["m"])
        v_new = jax.tree.map(
            lambda g, v: cfg.b2 * v.astype(F32) + (1 - cfg.b2) * g * g,
            grads, state["v"])

        def upd(master, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            wd = cfg.weight_decay * master if master.ndim >= 2 else 0.0
            return master - lr * (u + wd)

        master_new = jax.tree.map(upd, state["master"], m_new, v_new)
        new_state["m"] = jax.tree.map(
            lambda m: _q_state(m, policy.opt_m_fmt, policy), m_new)
        new_state["v"] = jax.tree.map(
            lambda v: _q_state(v, policy.opt_v_fmt, policy), v_new)
    else:  # adafactor
        t = step.astype(F32)
        rho = 1.0 - t ** (-cfg.decay_adafactor)
        is_vdict = lambda d: isinstance(d, dict) and ("full" in d
                                                      or "row" in d)

        def v_upd(g, v):
            if "full" in v:
                return {"full": rho * v["full"] + (1 - rho) * g * g}
            return {"row": rho * v["row"] + (1 - rho) * jnp.mean(g * g,
                                                                 axis=-1),
                    "col": rho * v["col"] + (1 - rho) * jnp.mean(g * g,
                                                                 axis=-2)}

        def upd(master, g, v):
            if "full" in v:
                precond = g * jax.lax.rsqrt(v["full"] + cfg.eps)
            else:
                rfac = v["row"] / jnp.maximum(
                    jnp.mean(v["row"], axis=-1, keepdims=True), 1e-30)
                precond = g * jax.lax.rsqrt(
                    rfac[..., None] * v["col"][..., None, :] + cfg.eps)
            # relative update clipping (Adafactor d=1)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms)
            wd = cfg.weight_decay * master if master.ndim >= 2 else 0.0
            return master - lr * (precond + wd)

        # map grads-tree functions against the v-tree (one extra dict level)
        v_new = jax.tree.map(v_upd, grads, state["v"],
                             is_leaf=lambda x: is_vdict(x))
        # align trees: v_new leaves are dicts under is_vdict
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["master"])
        flat_v = jax.tree_util.tree_flatten(
            v_new, is_leaf=is_vdict)[0]
        master_new = jax.tree_util.tree_unflatten(
            treedef, [upd(m, g, v) for m, g, v in
                      zip(flat_m, flat_g, flat_v)])
        new_state["v"] = v_new

    new_state["master"] = master_new

    # re-quantize model weights from master (CONV group; optional SR)
    def requant(path, master, old):
        if policy.mode == "native":
            if policy.stochastic_grad_round and sr_key is not None:
                kk = jax.random.fold_in(sr_key, hash(str(path)) % (1 << 30))
                q = softfloat.quantize(master, policy.param_fmt,
                                       "stochastic", key=kk)
                return q.astype(old.dtype)
            return master.astype(old.dtype)
        return softfloat.quantize(master, policy.param_fmt)

    new_params = jax.tree_util.tree_map_with_path(requant, master_new, params)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state sharding specs
# ---------------------------------------------------------------------------
def opt_state_specs(param_specs_tree, opt_state, *, zero_axis: str = "data",
                    mesh=None):
    """Shard master/m/v over ``zero_axis`` on the first dimension that (a)
    is unsharded in the parameter's own spec and (b) divides by the axis
    size.  Falls back to the parameter's spec (replication over data)."""
    size = mesh.shape[zero_axis] if mesh is not None else 1

    def place(spec: P, leaf):
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (p, dim) in enumerate(zip(parts, leaf.shape)):
            if p is None and dim % size == 0 and dim >= size:
                parts[i] = zero_axis
                return P(*parts)
        return P(*parts)

    def visit(sub_specs, sub_state):
        return jax.tree.map(place, sub_specs, sub_state,
                            is_leaf=lambda x: isinstance(x, P))

    out = {"step": P()}
    for k in opt_state:
        if k == "step":
            continue
        if k == "v" and isinstance(jax.tree.leaves(opt_state[k]), list):
            pass
        # m/v/master mirror the param tree structure (adafactor v has an
        # extra dict level; map with the state as reference)
        def spec_for(path, leaf):
            # find the matching param spec by walking the same path prefix
            node = param_specs_tree
            for entry in path:
                key = getattr(entry, "key", getattr(entry, "idx", None))
                if isinstance(node, dict) and key in node:
                    node = node[key]
                elif isinstance(node, (list, tuple)) and isinstance(key, int) \
                        and key < len(node):
                    node = node[key]
                else:
                    node = None
                    break
            base = node if isinstance(node, P) else P()
            return place(base, leaf)

        out[k] = jax.tree_util.tree_map_with_path(spec_for, opt_state[k])
    return out
