from .optimizer import OptConfig, init_opt_state, apply_update, lr_at
from .grad_compress import compress_sync_local, init_error_feedback
