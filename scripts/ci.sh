#!/usr/bin/env bash
# CI entrypoint: tier-1 tests + a smoke serving-decode benchmark.
#
# Mirrors the tier-1 verify line in ROADMAP.md; the benchmark smoke run
# exercises the scan-based generation path, the fused Pallas decode kernel,
# and the dense-vs-pallas pruned-grid prefill A/B end-to-end without
# writing BENCH_serve.json (use `python -m benchmarks.serve_decode` for the
# full tracked run).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serve decode smoke benchmark =="
python -m benchmarks.serve_decode --quick

echo "CI OK"
