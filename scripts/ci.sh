#!/usr/bin/env bash
# CI entrypoint: tier-1 tests + a smoke serving-decode benchmark.
#
# Mirrors the tier-1 verify line in ROADMAP.md; the benchmark smoke run
# exercises the scan-based generation path, the fused Pallas decode kernel,
# and the dense-vs-pallas pruned-grid prefill A/B end-to-end without
# writing BENCH_serve.json (use `python -m benchmarks.serve_decode` for the
# full tracked run).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# includes tests/test_ragged_attention.py — the ragged-batch kernel/model
# suite runs in Pallas interpret mode on CPU like every other kernel test
python -m pytest -x -q

echo "== serve decode smoke benchmark =="
python -m benchmarks.serve_decode --quick

echo "== BENCH_serve.json schema =="
python - <<'EOF'
import json, sys
REQUIRED = [
    "prefill_dense_ms", "prefill_pallas_ms", "python_tok_s", "scan_tok_s",
    "scan_speedup", "scan_pallas_kv8_tok_s",
    "ragged_prefill_ms", "ragged_decode_tok_s", "ragged_lens",
]
report = json.load(open("BENCH_serve.json"))
bad = [(arch, c) for arch, row in report["archs"].items()
       for c in REQUIRED if c not in row]
if bad:
    sys.exit(f"BENCH_serve.json schema drift — missing columns: {bad}")
print(f"schema OK ({len(report['archs'])} arch rows x "
      f"{len(REQUIRED)} required columns)")
EOF

echo "CI OK"
