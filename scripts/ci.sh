#!/usr/bin/env bash
# CI entrypoint: tier-1 tests + docs checks + a smoke serving benchmark.
#
# Mirrors the tier-1 verify line in ROADMAP.md; the benchmark smoke run
# exercises the scan-based generation path, the fused Pallas decode kernel,
# the dense-vs-pallas pruned-grid prefill A/B, and the paged-KV A/B
# end-to-end without writing BENCH_serve.json (use
# `python -m benchmarks.serve_decode` for the full tracked run).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (engine + fault modules gated separately below) =="
# includes tests/test_ragged_attention.py (per-row length plumbing) and
# tests/test_paged_attention.py (block-table indirection: paged kernels
# vs the paged oracles, allocator misuse errors, preemption-batch frees,
# prefix sharing) — all kernel tests run in Pallas interpret mode on CPU
python -m pytest -x -q --ignore=tests/test_engine.py \
    --ignore=tests/test_engine_faults.py \
    --ignore=tests/test_speculative.py \
    --ignore=tests/test_replica_ha.py

echo "== continuous-batching engine tests =="
# the PR-5 serving engine gate, run once as its own named step so a
# failure is unmissable: while_loop==scan bit-parity, early exit,
# admission determinism, page accounting, penalties parity, no-retrace
python -m pytest -q tests/test_engine.py

echo "== serving fault / robustness tests =="
# the PR-6 overload gate: preempt-resume bit-parity (free-and-reingest
# AND swap-to-host), fp8-exact degraded swap, fault-plan replay
# determinism, deadline accounting, poisoned-logits fail-fast, watchdog
# abort, and the overload soak draining under injected faults
python -m pytest -q tests/test_engine_faults.py

echo "== speculative decoding tests =="
# the PR-9 gate: draft/verify parity — chunk-form verify bitwise equals
# sequential decode (logits AND cache bytes, contiguous + paged, across
# KV formats), the accepted stream equals plain greedy decode under
# layer-skip and narrow-format drafts, rollback leaves the live cache
# bit-identical, EOS-mid-chunk / forced-0%-accept accounting, and engine
# composition with per-request caps, preemption and escalation.
# -p no:randomly pins declaration order if pytest-randomly is ever
# installed: the module-scoped engine fixture and probe-derived stop
# tokens assume a stable order within this file.
python -m pytest -q -p no:randomly tests/test_speculative.py

echo "== replica fault-tolerance / HA tests =="
# the PR-10 gate: replica loss with token-bit-identical results — kill
# (reingest migration) and hang (CRC-tagged swap-blob migration) parity,
# no_degrade and mid-escalation victims surviving migration intact,
# foreign-blob refusal, journal replay through run_with_restarts with
# two-recovery determinism, torn-tail recovery + truncation, and the
# session-flavor HA soak draining through a kill
python -m pytest -q tests/test_replica_ha.py

echo "== numerical-health tests =="
# the PR-7 gate: IEEE flag casts vs an ml_dtypes oracle (exhaustive
# 16-bit sweep, both overflow modes), kernel flag counters under ragged
# lengths + scrambled page tables, the saturating KV write ladder,
# flag-driven escalation finishing wider with zero poison, CRC-checked
# swap detecting injected bit flips with bit-identical recovery, and
# fresh-monitor state across restarts
python -m pytest -q tests/test_numerical_health.py

echo "== mesh-sharded serving tests (8 simulated devices) =="
# the PR-8 gate, run on an 8-way forced-host-platform mesh: head-sharded
# attention bit-identical per head to single-device on EVERY route
# (dense/pallas x prefill/decode x contiguous/paged), fp32-psum row-
# parallel projections allclose (bitwise under the tp_bf16 output snap),
# full-model logits parity, engine + data-parallel replica token parity,
# version-gate shims (both branches, monkeypatched), divisibility
# fallback warnings, per-replica allocator isolation, and the shipped
# pre-warmed autotuner cache loader
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m pytest -q tests/test_sharded_serving.py tests/test_autotune.py

echo "== docs: link + module-coverage check =="
# every public kernels/ and models/ module must be mentioned in the docs
# surface (README.md + docs/), and every relative markdown link must
# resolve — documentation that names dead files or skips live ones rots.
python - <<'EOF'
import os, re, sys

DOCS = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir("docs") if f.endswith(".md"))
text = {p: open(p).read() for p in DOCS}
errs = []

# relative links resolve (skip URLs and intra-page anchors)
for p, t in text.items():
    for m in re.finditer(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)", t):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(p), target))
        if not os.path.exists(resolved):
            errs.append(f"{p}: dead link -> {target}")

# module coverage: public modules under kernels/, models/ and launch/
# (launch/engine.py — the continuous-batching scheduler — must stay on
# the documented surface) are named somewhere in the docs
blob = "\n".join(text.values())
for pkg in ("src/repro/kernels", "src/repro/models", "src/repro/launch"):
    for f in sorted(os.listdir(pkg)):
        if not f.endswith(".py") or f.startswith("_"):
            continue
        mod = f"{os.path.basename(pkg)}/{f}"
        if mod not in blob:
            errs.append(f"docs never mention {mod}")

if errs:
    sys.exit("docs check FAILED:\n  " + "\n  ".join(errs))
print(f"docs OK ({len(DOCS)} files, links + kernels/ + models/ + launch/ "
      f"coverage)")
EOF

echo "== serve decode smoke benchmark =="
python -m benchmarks.serve_decode --quick

echo "== BENCH_serve.json schema =="
python - <<'EOF'
import json, sys
REQUIRED = [
    "prefill_dense_ms", "prefill_pallas_ms", "python_tok_s", "scan_tok_s",
    "scan_speedup", "scan_pallas_kv8_tok_s",
    "ragged_prefill_ms", "ragged_decode_tok_s", "ragged_lens",
    "paged_decode_tok_s", "paged_page_size",
    "continuous_decode_tok_s", "fixed_batch_tok_s", "continuous_speedup",
    "continuous_batch_occupancy", "peak_live_pages",
    "soak_drained", "soak_preemptions", "soak_shed_events", "soak_degraded",
    "soak_deadline_miss_rate", "soak_poisoned_rounds", "soak_faults_exhaust",
    "flag_telemetry_overhead", "esc_soak_drained", "esc_soak_escalations",
    "esc_soak_poisoned_rounds", "sdc_soak_injected", "sdc_soak_detected",
    "sdc_soak_reingest", "sdc_soak_token_parity",
    "shard_decode_tok_s", "shard_devices", "shard_speedup",
    "spec_decode_tok_s", "spec_accept_rate", "spec_token_parity",
    "ha_drained", "ha_kills", "ha_migrations", "ha_token_parity",
    "ha_replay_parity",
]
report = json.load(open("BENCH_serve.json"))
bad = [(arch, c) for arch, row in report["archs"].items()
       for c in REQUIRED if c not in row]
if bad:
    sys.exit(f"BENCH_serve.json schema drift — missing columns: {bad}")
for arch, row in report["archs"].items():
    ps = row["paged_page_size"]
    if not (isinstance(ps, int) and ps > 0):
        sys.exit(f"BENCH_serve.json: {arch} paged_page_size must be a "
                 f"positive int, got {ps!r}")
    for col in ("paged_decode_tok_s", "continuous_decode_tok_s",
                "fixed_batch_tok_s"):
        ts = row[col]
        if ts is not None and not (isinstance(ts, (int, float)) and ts > 0):
            sys.exit(f"BENCH_serve.json: {arch} {col} must be "
                     f"null or a positive number, got {ts!r}")
    occ = row["continuous_batch_occupancy"]
    if occ is not None and not (isinstance(occ, (int, float))
                                and 0.0 < occ <= 1.0):
        sys.exit(f"BENCH_serve.json: {arch} continuous_batch_occupancy "
                 f"must be null or in (0, 1], got {occ!r}")
    peak = row["peak_live_pages"]
    if peak is not None:
        fixed_eq = row.get("continuous_fixed_equiv_pages")
        if not (isinstance(peak, int) and 0 < peak):
            sys.exit(f"BENCH_serve.json: {arch} peak_live_pages must be "
                     f"null or a positive int, got {peak!r}")
        if isinstance(fixed_eq, int) and peak > fixed_eq:
            sys.exit(f"BENCH_serve.json: {arch} steady-state live pages "
                     f"({peak}) exceed the fixed-batch equivalent "
                     f"({fixed_eq}) — page recycling is not working")
    # robustness soak: for archs that can page, the soak must have
    # DRAINED (zero stuck/lost requests under injected faults), the
    # counters must be well-formed, and the constrained pool must have
    # actually exercised the backpressure machinery
    drained = row["soak_drained"]
    if drained is not None:
        if drained is not True:
            sys.exit(f"BENCH_serve.json: {arch} soak_drained must be true "
                     f"— the overload soak lost or stuck requests")
        for col in ("soak_preemptions", "soak_shed_events", "soak_degraded",
                    "soak_poisoned_rounds", "soak_faults_exhaust"):
            v = row[col]
            if not (isinstance(v, int) and v >= 0):
                sys.exit(f"BENCH_serve.json: {arch} {col} must be a "
                         f"non-negative int, got {v!r}")
        if row["soak_preemptions"] + row["soak_shed_events"] == 0:
            sys.exit(f"BENCH_serve.json: {arch} soak never engaged "
                     f"preemption or shedding — the pool was not "
                     f"constrained enough to test backpressure")
        mr = row["soak_deadline_miss_rate"]
        if not (isinstance(mr, (int, float)) and 0.0 <= mr <= 1.0):
            sys.exit(f"BENCH_serve.json: {arch} soak_deadline_miss_rate "
                     f"must be in [0, 1], got {mr!r}")
    # flag telemetry must have been measured (a positive overhead ratio)
    fo = row["flag_telemetry_overhead"]
    if not (isinstance(fo, (int, float)) and fo > 0):
        sys.exit(f"BENCH_serve.json: {arch} flag_telemetry_overhead must "
                 f"be a positive ratio, got {fo!r}")
    # numerical-health soak: for archs that can page, the escalation leg
    # must drain with at least one escalation and ZERO poisoned rounds
    # (saturating casts + widening beat the injected overflow), and the
    # SDC leg must detect EVERY injected swap corruption (zero undetected)
    # and recover with token parity against the uncorrupted twin
    esc = row["esc_soak_drained"]
    if esc is not None:
        if esc is not True:
            sys.exit(f"BENCH_serve.json: {arch} esc_soak_drained must be "
                     f"true — escalation lost or stuck requests")
        if not (isinstance(row["esc_soak_escalations"], int)
                and row["esc_soak_escalations"] >= 1):
            sys.exit(f"BENCH_serve.json: {arch} escalation soak never "
                     f"escalated — the overflow fault did not build "
                     f"enough flag pressure")
        if row["esc_soak_poisoned_rounds"] != 0:
            sys.exit(f"BENCH_serve.json: {arch} escalation soak produced "
                     f"{row['esc_soak_poisoned_rounds']!r} poisoned "
                     f"rounds — saturation failed to keep logits finite")
        inj, det = row["sdc_soak_injected"], row["sdc_soak_detected"]
        if not (isinstance(inj, int) and inj >= 1):
            sys.exit(f"BENCH_serve.json: {arch} SDC soak never injected a "
                     f"swap corruption (got {inj!r}) — swap preemption "
                     f"did not engage")
        if det != inj or row["sdc_soak_reingest"] != inj:
            sys.exit(f"BENCH_serve.json: {arch} UNDETECTED swap "
                     f"corruption: {inj} injected, {det} detected, "
                     f"{row['sdc_soak_reingest']} recovered")
        if row["sdc_soak_token_parity"] is not True:
            sys.exit(f"BENCH_serve.json: {arch} SDC recovery broke token "
                     f"parity with the uncorrupted run")
    # replica-HA soak: for archs that can page, the killed fleet must
    # have DRAINED to zero stuck requests through at least one replica
    # kill and at least one live-request migration, with token parity
    # against the unfailed fleet AND journal-replay parity after a full
    # fleet loss — fault tolerance that changes tokens is data loss
    ha = row["ha_drained"]
    if ha is not None:
        if ha is not True:
            sys.exit(f"BENCH_serve.json: {arch} ha_drained must be true "
                     f"— the HA soak lost or stuck requests")
        if not (isinstance(row["ha_kills"], int) and row["ha_kills"] >= 1):
            sys.exit(f"BENCH_serve.json: {arch} HA soak never killed a "
                     f"replica (got {row['ha_kills']!r}) — the fault "
                     f"plan did not fire")
        if not (isinstance(row["ha_migrations"], int)
                and row["ha_migrations"] >= 1):
            sys.exit(f"BENCH_serve.json: {arch} HA soak never migrated a "
                     f"request (got {row['ha_migrations']!r}) — the "
                     f"victim had nothing in flight")
        if row["ha_token_parity"] is not True:
            sys.exit(f"BENCH_serve.json: {arch} replica loss changed "
                     f"tokens vs the unfailed fleet — migration broke "
                     f"bit parity")
        if row["ha_replay_parity"] is not True:
            sys.exit(f"BENCH_serve.json: {arch} journal replay after a "
                     f"full fleet loss did not reproduce the oracle "
                     f"streams — the journal lost or reordered tokens")
    # speculative decoding A/B: for archs that can page, the draft/verify
    # engine must have kept BIT-IDENTICAL tokens vs plain greedy serving
    # (speculation may only change speed) and the accept rate must be a
    # real measurement — the bonus token makes (0, 1] the only legal range
    sp = row["spec_decode_tok_s"]
    if sp is not None:
        if not (isinstance(sp, (int, float)) and sp > 0):
            sys.exit(f"BENCH_serve.json: {arch} spec_decode_tok_s must be "
                     f"null or a positive number, got {sp!r}")
        ar = row["spec_accept_rate"]
        if not (isinstance(ar, (int, float)) and 0.0 < ar <= 1.0):
            sys.exit(f"BENCH_serve.json: {arch} spec_accept_rate must be "
                     f"in (0, 1] — every verify round accepts at least "
                     f"the bonus token — got {ar!r}")
        if row["spec_token_parity"] is not True:
            sys.exit(f"BENCH_serve.json: {arch} speculative decoding "
                     f"changed tokens vs plain greedy serving — the "
                     f"draft/verify contract is broken")
    # mesh-sharded serving A/B: for archs whose heads split over the
    # model axis, the probe must have run on a real multi-device mesh
    # with token parity; the dryrun legs must cover the production scale
    sd = row["shard_devices"]
    if sd is not None:
        if not (isinstance(sd, int) and sd >= 2):
            sys.exit(f"BENCH_serve.json: {arch} shard_devices must be an "
                     f"int >= 2 (a 1-way mesh proves nothing), got {sd!r}")
        for col in ("shard_decode_tok_s", "shard_speedup"):
            v = row[col]
            if not (isinstance(v, (int, float)) and v > 0):
                sys.exit(f"BENCH_serve.json: {arch} {col} must be a "
                         f"positive number, got {v!r}")
        if row.get("shard_token_parity") is not True:
            sys.exit(f"BENCH_serve.json: {arch} sharded decode broke "
                     f"token parity with the single-device engine")
        devs = row.get("shard_dryrun_devices")
        if devs is not None and (not devs or min(devs) < 256):
            sys.exit(f"BENCH_serve.json: {arch} shard dryrun must cover "
                     f">= 256 devices, got {devs!r}")
print(f"schema OK ({len(report['archs'])} arch rows x "
      f"{len(REQUIRED)} required columns, paged + continuous + soak + "
      f"numerical-health + shard + speculative + replica-HA fields "
      f"validated)")
EOF

echo "CI OK"
