"""Pytest path setup: make `repro` (src layout) and `benchmarks` importable
regardless of how pytest is invoked.  NOTE: deliberately does NOT set
XLA_FLAGS — tests must see the real single CPU device; only the dry-run
spawns 512 placeholder devices (in its own process)."""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (os.path.join(_ROOT, "src"), _ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)
