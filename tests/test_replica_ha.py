"""Replica-level fault tolerance: failure injection, live-request
migration, and the crash-consistent request journal.

Contract under test (the HA half of the serving story):

  * replica loss is SURVIVABLE with token-bit-identical results — a
    killed replica (``ReplicaLostError`` through burst dispatch, device
    memory gone) force-reingests its in-flight requests onto a survivor
    from host-side emitted tokens; a hung replica (missed heartbeats,
    memory still readable) migrates them as CRC-verified swap-blob
    continuations when ``migrate="swap"``;
  * migration composes with every robustness feature it rides over —
    ``no_degrade`` victims stay bit-exact through a degrading swap
    store, mid-escalation victims keep their precision rung on the
    surviving replica;
  * swap payloads carry pool provenance (``SwapBlobTag``): a foreign
    blob (dtype or page-size mismatch) is REFUSED with ``ValueError``
    instead of silently reinterpreting page bytes;
  * the journal makes a FULL fleet loss recoverable: a restarted run
    replays every unfinished request from its last journaled token and
    finishes with bit-parity; ``RequestJournal.load`` drops (and
    truncates) a crash-torn tail line but hard-errors on mid-file
    corruption; two independent recovery runs from the same journal are
    identical;
  * ``run_with_restarts`` resets every replica's monitors per attempt
    and its exhaustion diagnostics name the replica behind each failed
    attempt;
  * the ``"session"`` trace flavor emits multi-turn conversations over
    a growing shared prefix, and the HA soak over it drains to zero
    stuck requests through a replica kill.
"""
import shutil

import numpy as np
import pytest

import jax

from repro.launch import mesh as meshmod
from repro.launch.engine import (ContinuousEngine, ReplicatedEngine,
                                 Request, synthetic_trace)
from repro.launch.journal import RequestJournal
from repro.models.paged import SwapBlobTag, check_blob_tag
from repro.train.fault import (ReplicaFaultPlan, ReplicaLostError,
                               ServeFaultPlan, run_with_restarts)


@pytest.fixture(scope="module")
def setup():
    from conftest import cached_model
    return cached_model("gemma2-9b", paged_kv=True, page_size=16)


def _toks(fin):
    return {f.rid: list(f.tokens) for f in fin}


def _queue(vocab):
    """Eight mixed requests over two arrival waves — enough work per
    replica that a burst-1 kill lands mid-run with residents in flight."""
    return synthetic_trace(8, 4, 16, 8, vocab)


def _long_queue(vocab, n=4, no_degrade_rid=None):
    """Long-budget residents: every row is mid-decode for several bursts,
    so a hang finds swappable K/V pages to migrate."""
    rng = np.random.RandomState(3)
    return [Request(rid=i, tokens=rng.randint(0, vocab, size=6).tolist(),
                    max_new=14, arrival=0,
                    no_degrade=(i == no_degrade_rid))
            for i in range(n)]


def _fleet(setup, **kw):
    model, params = setup
    kw.setdefault("replicas", 2)
    kw.setdefault("slots", 2)
    kw.setdefault("chunk", 8)
    kw.setdefault("burst_cap", 4)
    return ReplicatedEngine(model, params, **kw)


@pytest.fixture(scope="module")
def baseline(setup):
    """Unfailed 2-replica fleet over the kill queue: the parity oracle."""
    model, _ = setup
    reqs = _queue(model.cfg.vocab)
    ml = max(r.prompt_len + r.max_new for r in reqs)
    fin, stats = _fleet(setup, max_len=ml).run(reqs)
    assert stats["ha_kills"] == stats["ha_migrations"] == 0
    return reqs, ml, _toks(fin)


# ---------------------------------------------------------------------------
# failure injection + migration parity
# ---------------------------------------------------------------------------
def test_kill_reingest_migration_parity(setup, baseline):
    reqs, ml, base = baseline
    plan = ReplicaFaultPlan(replica=0, at_burst=1, mode="kill")
    fleet = _fleet(setup, max_len=ml, migrate="reingest",
                   replica_fault=plan)
    fin, st = fleet.run(reqs)
    assert _toks(fin) == base
    assert [f.rid for f in fin] == [r.rid for r in reqs]
    assert st["ha_kills"] == 1 and st["ha_hangs"] == 0
    assert st["ha_migrations"] >= 1
    assert st["ha_migrated_reingest"] == st["ha_migrations"]
    assert st["ha_migrated_swap"] == 0
    assert st["heartbeats"][0]["status"] == "dead"
    assert st["heartbeats"][1]["status"] == "live"
    assert any(k == "kill" for k, _ in plan.events)


def test_kill_under_swap_mode_falls_back_to_reingest(setup, baseline):
    """A killed replica's device memory is GONE: even with
    ``migrate="swap"`` requested, evacuation must re-ingest from
    host-side emitted tokens — and still hit token parity."""
    reqs, ml, base = baseline
    plan = ReplicaFaultPlan(replica=0, at_burst=1, mode="kill")
    fleet = _fleet(setup, max_len=ml, migrate="swap", preempt="swap",
                   replica_fault=plan)
    fin, st = fleet.run(reqs)
    assert _toks(fin) == base
    assert st["ha_kills"] == 1 and st["ha_migrations"] >= 1
    assert st["ha_migrated_swap"] == 0
    assert st["ha_migrated_reingest"] == st["ha_migrations"]


def test_hang_swap_blob_migration_parity(setup):
    """A hung replica's pages are still readable: residents travel as
    tagged swap blobs into the survivor's pool, bit-identically."""
    model, _ = setup
    reqs = _long_queue(model.cfg.vocab)
    ml = 6 + 14
    base, _ = _fleet(setup, max_len=ml, preempt="swap").run(reqs)
    plan = ReplicaFaultPlan(replica=0, at_burst=2, mode="hang")
    fleet = _fleet(setup, max_len=ml, preempt="swap", migrate="swap",
                   hang_patience=1, replica_fault=plan)
    fin, st = fleet.run(reqs)
    assert _toks(fin) == _toks(base)
    assert st["ha_hangs"] == 1 and st["ha_kills"] == 0
    assert st["ha_migrated_swap"] >= 1
    assert st["heartbeats"][0]["status"] == "dead"
    assert st["heartbeats"][0]["missed"] >= 1


def test_no_degrade_victim_stays_exact_through_migration(setup):
    """The quality-sensitive opt-out survives migration: a ``no_degrade``
    request on the hung replica migrates through a DEGRADING (fp8) swap
    store yet matches the solo un-preempted run bit-for-bit."""
    model, params = setup
    import jax.numpy as jnp
    reqs = _long_queue(model.cfg.vocab, no_degrade_rid=0)
    ml = 6 + 14
    plan = ReplicaFaultPlan(replica=0, at_burst=2, mode="hang")
    fleet = _fleet(setup, max_len=ml, preempt="swap", migrate="swap",
                   degrade_fmt="fp8", hang_patience=1, replica_fault=plan)
    fin, st = fleet.run(reqs)
    assert st["ha_hangs"] == 1 and st["ha_migrations"] >= 1
    g = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=14, max_len=ml)[0])
    solo = np.asarray(g(params, jnp.asarray(
        reqs[0].tokens, jnp.int32)[None]))[0].tolist()
    f0 = next(f for f in fin if f.rid == 0)
    assert f0.tokens == solo
    assert not f0.degraded
    assert all(len(f.tokens) == r.max_new for r, f in zip(reqs, fin))


def test_mid_escalation_victim_keeps_rung(setup):
    """A request that escalated its KV rung before the failure keeps the
    rung on the surviving replica (``_QEntry.esc_level`` rides the
    migration) — tokens match the unfailed escalating fleet."""
    from conftest import cached_model
    from repro.core.policy import EscalationPolicy
    model, params = cached_model("gemma2-9b", policy="fp32",
                                 paged_kv=True, page_size=16)
    rng = np.random.RandomState(5)
    reqs = [Request(rid=i, tokens=rng.randint(
                0, model.cfg.vocab, size=12).tolist(), max_new=16)
            for i in range(4)]
    mk = lambda fault: ReplicatedEngine(
        model, params, replicas=2, slots=2, max_len=30, chunk=8,
        burst_cap=4, migrate="reingest", replica_fault=fault,
        escalate=EscalationPolicy(of_threshold=4),
        fault_plan=ServeFaultPlan(overflow_at=(2,),
                                  overflow_scale=65536.0))
    base, bst = mk(None).run(reqs)
    assert bst["escalations"] >= 1
    plan = ReplicaFaultPlan(replica=0, at_burst=3, mode="hang")
    fin, st = mk(plan).run(reqs)
    assert _toks(fin) == _toks(base)
    assert st["ha_hangs"] == 1 and st["ha_migrations"] >= 1
    assert st["escalations"] >= 1
    assert {f.rid: f.escalated for f in fin} == \
           {f.rid: f.escalated for f in base}


# ---------------------------------------------------------------------------
# swap-blob provenance
# ---------------------------------------------------------------------------
def test_blob_tag_unit():
    ok = SwapBlobTag(replica=0, dtype="bfloat16", page=16)
    check_blob_tag(ok, dtype="bfloat16", page=16)
    check_blob_tag(None, dtype="bfloat16", page=16)    # legacy untagged
    # replica provenance alone is NOT foreign — migration is the point
    check_blob_tag(ok._replace(replica=7), dtype="bfloat16", page=16)
    with pytest.raises(ValueError, match="foreign swap blob"):
        check_blob_tag(ok._replace(dtype="float32"),
                       dtype="bfloat16", page=16)
    with pytest.raises(ValueError, match="foreign swap blob"):
        check_blob_tag(ok._replace(page=8), dtype="bfloat16", page=16)


def test_adopt_refuses_foreign_blob(setup):
    """End-to-end: an evacuated swap blob whose tag disagrees with the
    receiving pool's layout is refused at ``adopt`` time."""
    model, _ = setup
    reqs = _long_queue(model.cfg.vocab)
    fleet = _fleet(setup, max_len=20, preempt="swap")
    parts = fleet.partition(reqs)
    e0, e1 = fleet.engines
    e0.start(parts[0])
    e1.start(parts[1])
    for _ in range(3):
        e0.step()
    entries = e0.evacuate(readable=True, mode="swap")
    blob = next(e for e in entries
                if e.resume is not None and e.resume.blobs is not None)
    good = blob.resume.tag
    assert isinstance(good, SwapBlobTag) and good.replica == 0
    blob.resume.tag = good._replace(page=good.page * 2)
    with pytest.raises(ValueError, match="foreign swap blob"):
        e1.adopt([blob])
    blob.resume.tag = good._replace(replica=7)     # same layout: adoptable
    assert e1.adopt([blob]) == 1
    while e1.step():
        pass
    res, _ = e1.finalize()
    assert len(res[blob.req.rid].tokens) == blob.req.max_new


# ---------------------------------------------------------------------------
# the crash-consistent journal
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def crashed_journal(setup, tmp_path_factory):
    """A single-replica journaled fleet killed mid-run: no survivor, so
    the loss re-raises — the on-disk journal is the only memory.
    Returns (journal path, queue, unfailed single-engine oracle)."""
    model, params = setup
    reqs = synthetic_trace(6, 2, 16, 8, model.cfg.vocab)
    ml = max(r.prompt_len + r.max_new for r in reqs)
    eng = ContinuousEngine(model, params, slots=2, max_len=ml, chunk=8,
                           burst_cap=2)
    base, _ = eng.run(reqs)
    path = tmp_path_factory.mktemp("ha") / "journal.jsonl"
    jr = RequestJournal(str(path))
    plan = ReplicaFaultPlan(replica=0, at_burst=2, mode="kill")
    fleet = _fleet(setup, replicas=1, max_len=ml, burst_cap=2,
                   migrate="reingest", replica_fault=plan, journal=jr)
    with pytest.raises(ReplicaLostError, match="replay the journal"):
        fleet.run(reqs)
    jr.close()
    counts = RequestJournal.load(str(path)).counts()
    assert counts["replica_lost"] == 1
    assert counts.get("finish", 0) < len(reqs)      # the crash lost work
    return path, reqs, ml, _toks(base)


def test_restart_replays_journal_to_parity(setup, crashed_journal,
                                           tmp_path):
    """``run_with_restarts`` over the journaled fleet: attempt 1 dies,
    attempt 2 replays the journal and finishes every request with
    tokens identical to the run that never crashed."""
    path, reqs, ml, base = crashed_journal
    p = tmp_path / "journal.jsonl"
    shutil.copy(path, p)
    jr = RequestJournal.load(str(p))
    plan = ReplicaFaultPlan(replica=0, at_burst=2, mode="kill")
    fleet = _fleet(setup, replicas=1, max_len=ml, burst_cap=2,
                   migrate="reingest", replica_fault=plan,
                   journal=jr).bind(reqs)
    runner, restarts = run_with_restarts(lambda: fleet, max_restarts=2)
    assert runner is fleet and restarts == 1
    # every request now has a finish record: a further recovery run
    # answers entirely from the journal, re-serving nothing
    fin, st = fleet.run()
    assert _toks(fin) == base
    assert jr.counts()["replay"] >= 1
    assert st["decode_rounds"] == 0


def test_two_recovery_runs_are_identical(setup, crashed_journal,
                                         tmp_path):
    """Recovery is deterministic: two independent engines replaying
    copies of the same crashed journal emit identical streams — and both
    match the unfailed oracle."""
    path, reqs, ml, base = crashed_journal
    outs = []
    for tag in ("a", "b"):
        p = tmp_path / f"journal_{tag}.jsonl"
        shutil.copy(path, p)
        jr = RequestJournal.load(str(p))
        fleet = _fleet(setup, replicas=1, max_len=ml, burst_cap=2,
                       migrate="reingest", journal=jr)
        fin, st = fleet.run(reqs)
        assert st["journal_replayed"] >= 1
        outs.append(_toks(fin))
        jr.close()
    assert outs[0] == outs[1] == base


def test_journal_torn_tail_dropped_and_truncated(tmp_path):
    p = tmp_path / "j.jsonl"
    jr = RequestJournal(str(p))
    jr.append("admit", rid=0)
    jr.append("tokens", rid=0, toks=[1, 2])
    jr.close()
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"kind":"tok')                     # crash mid-append
    j2 = RequestJournal.load(str(p))
    assert [r["kind"] for r in j2.records] == ["admit", "tokens"]
    assert j2.emitted(0) == [1, 2]
    # the torn bytes are gone from the FILE too: appending after
    # recovery must not concatenate onto a half-written line
    j2.append("tokens", rid=0, toks=[3])
    j2.close()
    j3 = RequestJournal.load(str(p))
    assert j3.emitted(0) == [1, 2, 3]


def test_journal_whole_record_without_newline_is_torn(tmp_path):
    """A parseable last line whose newline never landed is the same
    lost append quantum — dropped, so the next append cannot corrupt."""
    p = tmp_path / "j.jsonl"
    jr = RequestJournal(str(p))
    jr.append("tokens", rid=0, toks=[1])
    jr.close()
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"kind":"tokens","rid":0,"toks":[9]}')   # no newline
    j2 = RequestJournal.load(str(p))
    assert j2.emitted(0) == [1]


def test_journal_midfile_corruption_is_hard_error(tmp_path):
    p = tmp_path / "j.jsonl"
    jr = RequestJournal(str(p))
    jr.append("admit", rid=0)
    jr.append("finish", rid=0, toks=[1])
    jr.close()
    lines = p.read_text().splitlines()
    p.write_text(lines[0] + "\n" + "NOT JSON\n" + lines[1] + "\n")
    with pytest.raises(ValueError, match="corrupt at byte"):
        RequestJournal.load(str(p))


# ---------------------------------------------------------------------------
# supervisor + topology satellites (no model needed)
# ---------------------------------------------------------------------------
def test_run_with_restarts_attempt_log_names_replica():
    made = []

    class Fleet:
        def __init__(self):
            self.resets = 0

        def reset_monitors(self):
            self.resets += 1

        def run(self):
            raise ReplicaLostError("replica 1 killed at burst 3",
                                   replica=1, burst=3)

    def mk():
        f = Fleet()
        made.append(f)
        return f

    with pytest.raises(ReplicaLostError) as ei:
        run_with_restarts(mk, max_restarts=1)
    log = ei.value.attempt_log
    assert [(a, t, r) for a, t, r, _ in log] == \
           [(0, "ReplicaLostError", 1), (1, "ReplicaLostError", 1)]
    assert all("burst 3" in msg for _, _, _, msg in log)
    assert [f.resets for f in made] == [1, 1]       # fresh monitors per
                                                    # attempt, every time


def test_replica_meshes_meshless():
    assert meshmod.replica_meshes(None, 3) == [None, None, None]
    with pytest.raises(ValueError, match="replica count"):
        meshmod.replica_meshes(None)
    with pytest.raises(ValueError, match="replica count"):
        meshmod.replica_meshes(None, 0)


def test_replicated_engine_validation():
    with pytest.raises(ValueError, match="swap|reingest"):
        ReplicatedEngine(None, None, replicas=1, migrate="teleport")


# ---------------------------------------------------------------------------
# the session trace flavor + the HA soak over it
# ---------------------------------------------------------------------------
def test_session_trace_growing_shared_prefix():
    n, slots, plen, gen = 12, 3, 16, 16
    reqs = synthetic_trace(n, slots, plen, gen, 5000, flavor="session")
    assert [r.rid for r in reqs] == list(range(n))
    worst = plen + 2 * (gen // 4 + max(1, plen // 4))
    assert max(r.prompt_len for r in reqs) <= worst
    for s in range(n // 3):
        turns = reqs[3 * s:3 * s + 3]
        for a, b in zip(turns, turns[1:]):
            # turn t re-sends turn t-1's whole conversation as prefix
            assert list(b.tokens[:a.prompt_len]) == list(a.tokens)
            assert b.prompt_len >= a.prompt_len + a.max_new + 1
            assert b.arrival >= a.arrival + a.max_new
        assert [t.priority for t in turns] == [0, 0, 1]
        assert all(t.no_degrade == (s % 5 == 3) for t in turns)
    # deterministic: the HA soak replays it bit-identically
    assert synthetic_trace(n, slots, plen, gen, 5000,
                           flavor="session") == reqs
    with pytest.raises(ValueError, match="chat|soak|session"):
        synthetic_trace(4, 2, 8, 8, 100, flavor="bogus")


def test_ha_soak_session_drains_through_kill(setup):
    """The HA soak: a multi-turn session trace served by a 2-replica
    fleet with a journal, one replica killed mid-run — every request
    (including later turns of the victim's sessions) drains to its full
    budget on the survivor, nothing stuck, everything journaled."""
    model, _ = setup
    reqs = synthetic_trace(10, 3, 16, 16, model.cfg.vocab,
                           flavor="session")
    ml = max(r.prompt_len + r.max_new for r in reqs)
    jr = RequestJournal()
    plan = ReplicaFaultPlan(replica=1, at_burst=2, mode="kill")
    fleet = _fleet(setup, slots=3, max_len=ml, burst_cap=2,
                   migrate="reingest", replica_fault=plan, journal=jr)
    fin, st = fleet.run(reqs)
    assert len(fin) == len(reqs)
    assert [f.rid for f in fin] == [r.rid for r in reqs]
    assert all(len(f.tokens) == r.max_new for r, f in zip(reqs, fin))
    assert st["ha_kills"] == 1 and st["ha_migrations"] >= 1
    assert st["heartbeats"][1]["status"] == "dead"
    c = jr.counts()
    assert c["finish"] == len(reqs)
    assert c.get("migrate", 0) == st["ha_migrations"]
    assert c["replica_lost"] == 1
