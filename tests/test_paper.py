"""The paper-claims benchmarks as tests: Table III / Table IV / Fig 7 /
Fig 8 reproductions must keep passing their internal assertions."""
import pytest


def test_table3_case_study():
    from benchmarks import table3_case_study
    rows = table3_case_study.main()
    assert len(rows) == 5
    # energy ratios within 0.05 of the paper's published values
    for k, bits, bits_p, core, core_p, sys, sys_p in rows:
        assert abs(core - core_p) < 0.05, (k, core, core_p)
        assert abs(sys - sys_p) < 0.05, (k, sys, sys_p)
        assert abs(bits - bits_p) <= 2, (k, bits, bits_p)


def test_table4_fma():
    from benchmarks import table4_fma
    table4_fma.main()


def test_fig7_energy():
    from benchmarks import fig7_instruction_energy
    fig7_instruction_energy.main()


def test_fig8_dvfs():
    from benchmarks import fig8_dvfs
    fig8_dvfs.main()


def test_energy_model_energy_proportionality():
    """The framework-level thesis: per-flop energy strictly decreases with
    format width, scalar and SIMD (paper's energy proportionality)."""
    from repro.core import energy
    order = ["fp64", "fp32", "fp16", "fp16alt", "fp8"]
    prev = float("inf")
    for f in order:
        e = energy.FMA_PJ_PER_FLOP[(f, False)]
        assert e < prev or f == "fp16alt"   # fp16alt ~ fp16 band
        prev = min(prev, e)
    assert energy.FMA_PJ_PER_FLOP[("fp8", True)] == min(
        v for v in energy.FMA_PJ_PER_FLOP.values())
