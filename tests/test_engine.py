"""Continuous-batching serving engine, end to end.

Contract under test (the serving-loop analogue of the ragged/paged PRs):

  * while_loop generation — ``generate(loop="while")`` is bit-identical
    to the scan form (tokens always; logits for executed rounds), exits
    strictly before ``gen_len - 1`` trips when every row finishes early,
    and composes with EOS + sampling + penalties in one carry.
  * penalties — repetition/presence penalties key off a prompt+emitted
    count histogram, apply before temperature/top-k/top-p, and leave the
    default greedy graph bit-identical.
  * chunked prefill — ``Model.prefill_chunk`` through the paged read
    path reconstructs full-prefill logits exactly and a decode started
    from chunked caches emits the tokens full prefill would.
  * engine — requests served in a shared continuous batch emit exactly
    the tokens they'd get served alone (greedy); same queue -> same
    tokens; pages drain back to the allocator (scratch only) with a
    high-water mark below the fixed-batch equivalent; admission, page
    churn and EOS never retrace the single compiled burst program.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import LENS, cached_model, small_batch
from repro.launch.engine import ContinuousEngine, Request, synthetic_trace
from repro.models.paged import PageAllocator
from repro.models.registry import build_model
from repro.models.transformer import (apply_penalties, init_caches,
                                      token_counts)


def _setup(policy="tp_bf16", **cfg):
    model, params = cached_model("gemma2-9b", policy=policy, **cfg)
    toks, lens = small_batch(model.cfg.vocab)
    return model, params, toks, lens


# ---------------------------------------------------------------------------
# while_loop generation
# ---------------------------------------------------------------------------
def test_while_matches_scan_greedy_tokens_and_logits():
    model, params, toks, _ = _setup()
    g_s, lg_s = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=6, max_len=40, return_logits=True))(params, toks)
    g_w, lg_w, trips = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=6, max_len=40, return_logits=True, loop="while",
        return_trips=True))(params, toks)
    np.testing.assert_array_equal(np.asarray(g_s), np.asarray(g_w))
    # no stop token: the while form runs the full (capped) trip count and
    # every per-round logit matches the scan's bitwise
    assert int(trips) == 5
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_w))


def test_while_matches_scan_with_eos_ragged_sampling():
    model, params, toks, lens = _setup()
    f = lambda loop: jax.jit(lambda p, t, l, k: model.generate(
        p, t, gen_len=8, max_len=48, prompt_lens=l, stop_token=3,
        temperature=0.9, top_k=40, key=k, loop=loop)[0])
    a = f("scan")(params, toks, lens, jax.random.key(7))
    b = f("while")(params, toks, lens, jax.random.key(7))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_while_early_exit_trip_count():
    """All rows hitting EOS at step k must exit the loop with k trips —
    strictly below the gen_len - 1 cap — with tokens still bit-identical
    to the scan form (whose frozen tail the while form pre-freezes).
    (A crushing repetition penalty makes the greedy rollout all-distinct,
    so any mid-run token is a stop that first fires exactly there.)"""
    model, params, toks, _ = _setup()
    toks = jnp.broadcast_to(toks[0:1], (3, 32))         # identical rows
    g0 = np.asarray(jax.jit(lambda p, t: model.generate(
        p, t, gen_len=10, max_len=48, repetition_penalty=1e9)[0])(params,
                                                                  toks))
    k = 5
    assert g0[0, k] not in g0[0, :k]                    # all-distinct row
    stop = int(g0[0, k])
    g_s = np.asarray(jax.jit(lambda p, t: model.generate(
        p, t, gen_len=10, max_len=48, stop_token=stop,
        repetition_penalty=1e9)[0])(params, toks))
    g_w, _, trips = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=10, max_len=48, stop_token=stop,
        repetition_penalty=1e9, loop="while",
        return_trips=True))(params, toks)
    np.testing.assert_array_equal(g_s, np.asarray(g_w))
    assert int(trips) == k < 9, (int(trips), k)
    assert (np.asarray(g_w)[:, k:] == stop).all()


# ---------------------------------------------------------------------------
# repetition / presence penalties
# ---------------------------------------------------------------------------
def test_apply_penalties_semantics():
    lg = jnp.asarray([[2.0, -2.0, 1.0, 0.5]])
    counts = jnp.asarray([[1, 2, 0, 0]], jnp.int32)
    out = np.asarray(apply_penalties(lg, counts, repetition_penalty=2.0))
    # seen positive logit divided, seen negative multiplied, unseen intact
    np.testing.assert_allclose(out, [[1.0, -4.0, 1.0, 0.5]])
    out = np.asarray(apply_penalties(lg, counts, presence_penalty=0.75))
    np.testing.assert_allclose(out, [[1.25, -2.75, 1.0, 0.5]])
    # neutral knobs are the identity
    np.testing.assert_array_equal(
        np.asarray(apply_penalties(lg, counts, repetition_penalty=1.0,
                                   presence_penalty=0.0)), np.asarray(lg))


def test_token_counts_masks_ragged_pad():
    toks = jnp.asarray([[5, 6, 5, 0], [7, 0, 0, 0]], jnp.int32)
    cnt = np.asarray(token_counts(toks, 10, jnp.asarray([3, 1], jnp.int32)))
    assert cnt[0, 5] == 2 and cnt[0, 6] == 1 and cnt[0, 0] == 0
    assert cnt[1, 7] == 1 and cnt[1].sum() == 1


def test_penalties_default_is_bit_identical_and_active_differs():
    model, params, toks, _ = _setup()
    g0 = np.asarray(jax.jit(lambda p, t: model.generate(
        p, t, gen_len=6, max_len=40)[0])(params, toks))
    g_neutral = np.asarray(jax.jit(lambda p, t: model.generate(
        p, t, gen_len=6, max_len=40, repetition_penalty=1.0,
        presence_penalty=0.0)[0])(params, toks))
    np.testing.assert_array_equal(g0, g_neutral)
    # a crushing repetition penalty forbids re-emitting ANY seen token:
    # within the generated window every token is then unique per row
    g_r = np.asarray(jax.jit(lambda p, t: model.generate(
        p, t, gen_len=6, max_len=40, repetition_penalty=1e9)[0])(params,
                                                                toks))
    for b in range(g_r.shape[0]):
        assert len(set(g_r[b].tolist())) == g_r.shape[1], g_r[b]


def test_penalties_compose_with_sampling_eos_and_while_loop():
    model, params, toks, lens = _setup()
    f = lambda loop: jax.jit(lambda p, t, l, k: model.generate(
        p, t, gen_len=6, max_len=48, prompt_lens=l, stop_token=3,
        temperature=0.8, top_k=50, key=k, repetition_penalty=1.3,
        presence_penalty=0.2, loop=loop)[0])
    s1 = np.asarray(f("scan")(params, toks, lens, jax.random.key(9)))
    s2 = np.asarray(f("scan")(params, toks, lens, jax.random.key(9)))
    w1 = np.asarray(f("while")(params, toks, lens, jax.random.key(9)))
    np.testing.assert_array_equal(s1, s2)          # key-deterministic
    np.testing.assert_array_equal(s1, w1)          # loop-form parity


# ---------------------------------------------------------------------------
# chunked prefill through the paged read path
# ---------------------------------------------------------------------------
def _chunked_prefill(model, params, toks, lens, *, max_len, chunk):
    caches = init_caches(model.cfg, toks.shape[0], max_len, model.policy)
    lg = None
    for off in range(0, toks.shape[1], chunk):
        cl = jnp.clip(lens - off, 0, chunk)
        lg_c, caches = model.prefill_chunk(params, toks[:, off:off + chunk],
                                           caches, q_offset=off,
                                           chunk_lens=cl)
        lg = lg_c if lg is None else jnp.where((cl > 0)[:, None, None],
                                               lg_c, lg)
    return lg, caches


@pytest.mark.parametrize("chunk", [8, 16])
def test_prefill_chunk_matches_full_paged_prefill(chunk):
    """Chunk boundaries must be invisible: same last-live logits BITWISE,
    and greedy decode from chunked caches emits the tokens a full paged
    prefill + generate would."""
    model, params, toks, lens = _setup(paged_kv=True, page_size=16)
    lg_f, _ = jax.jit(lambda p, t, l: model.prefill(
        p, t, max_len=48, prompt_lens=l))(params, toks, lens)
    lg_c, caches = jax.jit(lambda p, t, l: _chunked_prefill(
        model, p, t, l, max_len=48, chunk=chunk))(params, toks, lens)
    np.testing.assert_array_equal(np.asarray(lg_f), np.asarray(lg_c))

    gen_ref = np.asarray(jax.jit(lambda p, t, l: model.generate(
        p, t, gen_len=5, max_len=48, prompt_lens=l)[0])(params, toks, lens))

    def roll(p, c, l):
        tok = jnp.argmax(lg_c[:, -1], -1).astype(jnp.int32)[:, None]
        outs, pos = [tok], l
        for _ in range(4):
            lg, c = model.decode_step(p, outs[-1], c, pos)
            outs.append(jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None])
            pos = pos + 1
        return jnp.concatenate(outs, 1)

    got = np.asarray(jax.jit(roll)(params, caches, lens))
    np.testing.assert_array_equal(gen_ref, got)


def test_prefill_chunk_row_subset_matches_batch():
    """A single-slot (traced row index) chunk writes exactly what the
    full-batch chunk writes for that row — the admission code path."""
    model, params, toks, lens = _setup(paged_kv=True, page_size=16)
    _, c_batch = jax.jit(lambda p, t, l: _chunked_prefill(
        model, p, t, l, max_len=48, chunk=16))(params, toks, lens)

    def rowwise(p, t, l):
        caches = init_caches(model.cfg, t.shape[0], 48, model.policy)
        for b in range(t.shape[0]):
            for off in range(0, t.shape[1], 16):
                cl = jnp.clip(l[b:b + 1] - off, 0, 16)
                _, caches = model.prefill_chunk(
                    p, t[b:b + 1, off:off + 16], caches, q_offset=off,
                    row=jnp.asarray(b, jnp.int32), chunk_lens=cl)
        return caches

    c_rows = jax.jit(rowwise)(params, toks, lens)
    for a, b in zip(jax.tree.leaves(c_batch), jax.tree.leaves(c_rows)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefill_chunk_requires_paged():
    model, params, toks, lens = _setup()                 # contiguous
    caches = init_caches(model.cfg, 3, 48, model.policy)
    with pytest.raises(ValueError, match="paged"):
        model.prefill_chunk(params, toks[:, :16], caches, q_offset=0)


def test_paged_prefill_pallas_reads_pool_matches_dense():
    """The satellite gate: under cfg.paged_kv Model.prefill routes reads
    through the paged flash path (block_table in the kernel's index maps);
    it must match the dense gather fallback at the usual model parity
    tolerance, and the gather fallback itself is bit-identical to the
    contiguous model (covered by test_paged_attention)."""
    model, params, toks, lens = _setup(paged_kv=True, page_size=16)
    lg_d, _ = jax.jit(lambda p, t, l: model.prefill(
        p, t, max_len=48, prompt_lens=l))(params, toks, lens)
    mp = model.with_cfg(prefill_backend="pallas")
    lg_p, _ = jax.jit(lambda p, t, l: mp.prefill(
        p, t, max_len=48, prompt_lens=l))(params, toks, lens)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                               rtol=5e-2, atol=1e-1)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
def _mk_requests(vocab, seed=0):
    rng = np.random.RandomState(seed)
    lens = (8, 20, 32, 13, 27, 5, 32, 16)
    budgets = (4, 9, 3, 7, 5, 8, 2, 6)
    arrivals = (0, 0, 0, 0, 2, 2, 5, 9)
    return [Request(rid=i, tokens=rng.randint(0, vocab, size=L).tolist(),
                    max_new=B, arrival=A)
            for i, (L, B, A) in enumerate(zip(lens, budgets, arrivals))]


@pytest.fixture(scope="module")
def engine_run():
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    reqs = _mk_requests(model.cfg.vocab)
    eng = ContinuousEngine(model, params, slots=3, max_len=48, chunk=16)
    fin1, stats1 = eng.run(reqs)
    fin2, stats2 = eng.run(reqs)       # same engine, same queue, again
    return model, params, reqs, eng, (fin1, stats1), (fin2, stats2)


def test_engine_matches_solo_generate(engine_run):
    """Every request served in the shared continuous batch (3 slots, 8
    requests, multi-chunk prompts, mid-generation admission) emits
    exactly the tokens it would get served ALONE through generate()."""
    model, params, reqs, _, (fin, _), _ = engine_run
    gen = jax.jit(lambda p, t, g: model.generate(
        p, t, gen_len=g, max_len=48)[0], static_argnums=2)
    for r, f in zip(reqs, fin):
        want = np.asarray(gen(params, jnp.asarray(r.tokens, jnp.int32)[None],
                              r.max_new))[0].tolist()
        assert f.tokens == want, (r.rid, f.tokens, want)
        assert len(f.tokens) == r.max_new


def test_engine_admission_determinism(engine_run):
    """Same queue -> same tokens, same rounds, same page watermark."""
    _, _, _, _, (fin1, st1), (fin2, st2) = engine_run
    for a, b in zip(fin1, fin2):
        assert a.tokens == b.tokens and a.finish_round == b.finish_round
    assert st1["rounds"] == st2["rounds"]
    assert st1["peak_live_pages"] == st2["peak_live_pages"]


def test_engine_page_accounting(engine_run):
    """Pages recycle: after the run only the scratch page is live, and
    the high-water mark stayed below the fixed-batch equivalent (lazy
    allocation tracks live lengths, not slots x max_len)."""
    _, _, reqs, eng, (fin, stats), _ = engine_run
    assert eng.alloc.n_live == 1                       # scratch only
    # reported stats exclude the always-live scratch page
    assert eng.alloc.stats()["peak_live"] == stats["peak_live_pages"] + 1
    assert 1 < stats["peak_live_pages"] < stats["fixed_equiv_pages"]
    # admission interleaves with decode: some request finished before the
    # last one was even admitted (mid-generation page recycling)
    first_fin = min(f.finish_round for f in fin)
    last_admit = max(f.admit_round for f in fin)
    assert first_fin <= last_admit


def test_engine_no_retrace_across_admissions(engine_run):
    """Admission, EOS churn, table swaps: ONE compiled burst program for
    the whole run (state and tables are traced), and chunk programs only
    per static (offset, wave-width) pair."""
    _, _, _, eng, _, _ = engine_run
    assert eng._burst._cache_size() == 1
    assert all(fn._cache_size() == 1 for fn in eng._chunk_fns.values())


def test_engine_stop_token_frees_early():
    """A stop token cuts a row's generation below its budget and the
    tokens match solo generate's EOS semantics (stop kept, then freeze)."""
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    # the rid-4 prompt's greedy rollout changes token mid-run (probed):
    # its first divergent token is a stop that fires mid-decode
    probe = _mk_requests(model.cfg.vocab)[4]
    g = np.asarray(jax.jit(lambda p, t: model.generate(
        p, t, gen_len=9, max_len=48)[0])(
            params, jnp.asarray(probe.tokens, jnp.int32)[None]))[0]
    k = next((i for i in range(1, 9) if g[i] != g[0]), None)
    if k is None:
        pytest.skip("constant greedy rollout; no mid-run stop available")
    stop = int(g[k])
    reqs = [Request(rid=0, tokens=probe.tokens, max_new=9),
            Request(rid=1, tokens=probe.tokens[:5], max_new=4)]
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           stop_token=stop)
    fin, _ = eng.run(reqs)
    f0 = fin[0]
    assert f0.tokens == g[:k + 1].tolist()             # ends at the stop
    assert len(f0.tokens) == k + 1 <= 9
    assert eng.alloc.n_live == 1


def test_engine_penalties_match_solo_generate(engine_run):
    """Repetition/presence penalties threaded through the continuous
    path (count histograms seeded at admission, bumped inside the burst
    carry, re-seeded across bursts) emit exactly what solo generate's
    penalty carry produces — the --continuous flags behave like the solo
    ones."""
    model, params, reqs, _, _, _ = engine_run
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           repetition_penalty=1.3, presence_penalty=0.4)
    sub = reqs[:5]
    fin, _ = eng.run(sub)
    gen = jax.jit(lambda p, t, g: model.generate(
        p, t, gen_len=g, max_len=48, repetition_penalty=1.3,
        presence_penalty=0.4)[0], static_argnums=2)
    for r, f in zip(sub, fin):
        want = np.asarray(gen(params, jnp.asarray(r.tokens, jnp.int32)[None],
                              r.max_new))[0].tolist()
        assert f.tokens == want, (r.rid, f.tokens, want)
    assert eng._burst._cache_size() == 1       # penalties don't retrace


def test_engine_refuses_unpageable_and_unpaged():
    model, params = cached_model("gemma2-9b")
    with pytest.raises(ValueError, match="paged_kv"):
        ContinuousEngine(model, params, slots=2, max_len=32)
    zamba = build_model("zamba2-1.2b", policy="tp_bf16",
                        reduced=True).with_cfg(paged_kv=True)
    with pytest.raises(ValueError, match="cannot page"):
        ContinuousEngine(zamba, zamba.init(jax.random.key(0)), slots=2,
                         max_len=32)


def test_engine_oversized_request_rejected():
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    eng = ContinuousEngine(model, params, slots=2, max_len=32, chunk=16)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.run([Request(rid=0, tokens=[1] * 30, max_new=8)])


def test_synthetic_trace_deterministic():
    a = synthetic_trace(12, 4, 32, 64, 256)
    b = synthetic_trace(12, 4, 32, 64, 256)
    assert [(r.tokens, r.max_new, r.arrival) for r in a] == \
        [(r.tokens, r.max_new, r.arrival) for r in b]
    assert any(r.max_new == 64 for r in a) and any(r.arrival > 0 for r in a)


# ---------------------------------------------------------------------------
# allocator hooks
# ---------------------------------------------------------------------------
def test_allocator_peak_and_probe():
    a = PageAllocator(4)
    assert a.try_alloc(5) is None and a.n_live == 0     # probe, no effect
    ids = a.alloc(3)
    assert a.peak_live == 3
    a.free(ids)
    assert a.n_live == 0 and a.peak_live == 3           # watermark sticks
    a.reset_peak()
    assert a.peak_live == 0
    got = a.try_alloc(2)
    assert got is not None and a.peak_live == 2
    assert a.stats() == {"n_pages": 4, "n_live": 2, "n_free": 2,
                         "peak_live": 2}
