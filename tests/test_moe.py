"""MoE dispatch correctness: the sort-based capacity scheme must equal a
naive per-expert gather-scatter reference, and the EP all_to_all path must
equal the local path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import PRESETS
from repro.models.moe import MoEConfig, moe_core, moe_params


def naive_moe(x, params, cfg, policy):
    """Reference: loop over experts, full capacity (no drops)."""
    t, d = x.shape
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros((t, d), jnp.float32)
    for e in range(cfg.n_experts):
        w_g, w_u, w_d = (params["w_gate"][e], params["w_up"][e],
                         params["w_down"][e])
        h = jax.nn.silu(x @ w_g) * (x @ w_u)
        out_e = h @ w_d
        for k in range(cfg.top_k):
            sel = (idx[:, k] == e).astype(jnp.float32) * gates[:, k]
            y = y + sel[:, None] * out_e.astype(jnp.float32)
    return y


def test_sort_dispatch_matches_naive():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16,
                    capacity_factor=8.0)   # capacity large: no drops
    pol = PRESETS["fp32"]
    key = jax.random.key(0)
    params = moe_params(key, 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, 32))
    got, aux = moe_core(x, {k: v for k, v in params.items()
                            if k != "shared"}, cfg, pol)
    want = naive_moe(x, params, cfg, pol)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_capacity_drops_tokens():
    cfg = MoEConfig(n_experts=4, top_k=1, d_expert=8, capacity_factor=0.25)
    pol = PRESETS["fp32"]
    params = moe_params(jax.random.key(0), 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, 16))
    y, _ = moe_core(x, {k: v for k, v in params.items() if k != "shared"},
                    cfg, pol)
    # over-capacity tokens get zero output (dropped), so some rows are 0
    norms = np.linalg.norm(np.asarray(y), axis=-1)
    assert (norms == 0).sum() > 0
    assert (norms > 0).sum() > 0


def test_ep_all_to_all_matches_local():
    """shard_map EP on a 1x1 mesh must equal the plain local dispatch."""
    from repro.models.moe import moe_block
    from repro.models.layers import set_batch_axes
    set_batch_axes(("data",))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, n_shared=1)
    pol = PRESETS["fp32"]
    params = moe_params(jax.random.key(0), 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    y_local, aux_local = moe_block(x, params, cfg, pol, mesh=None)
    y_ep, aux_ep = jax.jit(
        lambda x, p: moe_block(x, p, cfg, pol, mesh=mesh))(x, params)
    set_batch_axes(())
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_local), float(aux_ep), rtol=1e-5)
