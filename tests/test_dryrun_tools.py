"""Unit tests for the dry-run machinery: the collective-schedule parser,
differential algebra, roofline factors and model-flops estimates."""
import pytest

from repro.launch.dryrun import (_coll_diff, _coll_scale_add, _lin,
                                 parse_collectives)

HLO_SAMPLE = """
ENTRY %main {
  %p0 = bf16[8,128,2048]{2,1,0} parameter(0)
  %ar = f32[8,4096]{1,0} all-reduce(%x), channel_id=3, replica_groups=[16,16]<=[16,16]T(1,0), use_global_device_ids=true, to_apply=%add
  %ag = bf16[8,128,2048]{2,1,0} all-gather(%p0), channel_id=4, replica_groups=[32,8]<=[256], dimensions={2}
  %rs = f32[512]{0} reduce-scatter(%y), channel_id=5, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %cp = bf16[64]{0} collective-permute(%z), source_target_pairs={{0,1},{1,0}}
  %a2a = f32[2,16]{1,0} all-to-all(%w), replica_groups=[8,2]<=[16], dimensions={0}
}
"""


def test_parse_collectives():
    out = parse_collectives(HLO_SAMPLE)
    assert out["all-reduce@16"] == {"count": 1, "bytes": 8 * 4096 * 4}
    assert out["all-gather@8"] == {"count": 1, "bytes": 8 * 128 * 2048 * 2}
    assert out["reduce-scatter@4"] == {"count": 1, "bytes": 512 * 4}
    assert out["collective-permute@2"] == {"count": 1, "bytes": 64 * 2}
    assert out["all-to-all@2"] == {"count": 1, "bytes": 2 * 16 * 4}


def test_coll_algebra():
    a = {"all-reduce@16": {"count": 3, "bytes": 300}}
    b = {"all-reduce@16": {"count": 1, "bytes": 100},
         "all-gather@8": {"count": 1, "bytes": 50}}
    d = _coll_diff(a, b)
    assert d["all-reduce@16"] == {"count": 2, "bytes": 200}
    assert d["all-gather@8"] == {"count": 0, "bytes": 0}  # clipped
    s = _coll_scale_add((2, a), (1, b))
    assert s["all-reduce@16"] == {"count": 7, "bytes": 700}


def test_lin_extrapolation():
    v1 = {"flops": 100.0, "bytes": 10.0, "transcendentals": 1.0,
          "coll": {"all-reduce@16": {"count": 1, "bytes": 8}}}
    v2 = {"flops": 160.0, "bytes": 14.0, "transcendentals": 1.5,
          "coll": {"all-reduce@16": {"count": 2, "bytes": 16}}}
    t = _lin(v1, v2, 5)
    assert t["flops"] == 100 + 4 * 60
    assert t["bytes"] == 10 + 4 * 4
    assert t["coll"]["all-reduce@16"]["bytes"] == 8 + 4 * 8


def test_roofline_ring_factors():
    from benchmarks.roofline import coll_bytes_moved
    coll = {"all-reduce@16": {"count": 1, "bytes": 160},
            "all-gather@16": {"count": 1, "bytes": 160},
            "reduce-scatter@16": {"count": 1, "bytes": 10},
            "collective-permute@2": {"count": 1, "bytes": 7}}
    got = coll_bytes_moved(coll)
    want = 2 * 160 * 15 / 16 + 160 * 15 / 16 + 10 * 15 + 7
    assert got == pytest.approx(want)


def test_model_flops_estimates():
    from benchmarks.roofline import model_flops_global
    # granite train: 6*N*D within 2x of the pure-param estimate (attention
    # quadratic term adds on top)
    n = 20.5e9
    d = 256 * 4096
    est = model_flops_global("granite-20b", "train_4k")
    assert 6 * n * d < est < 6 * n * d * 1.5
    # decode: per-token cost ~ 2*N*B plus cache reads
    est_d = model_flops_global("granite-20b", "decode_32k")
    assert est_d < est / 1000
