"""Beyond-paper perf knobs must not change semantics: windowed KV slicing
equals the dense-masked baseline; bf16 CE tracks fp32 CE; dryrun --set
override machinery round-trips types."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import build_model, get_config


def _with(model, **kw):
    return dataclasses.replace(model, cfg=dataclasses.replace(model.cfg,
                                                              **kw))


def test_windowed_slice_matches_dense_mask():
    model = build_model("gemma2-9b", policy="fp32", reduced=True)
    # reduced gemma2 has window=16 locals; use seq >> window
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0,
                              model.cfg.vocab)
    labels = jax.random.randint(jax.random.key(2), (2, 64), 0,
                                model.cfg.vocab)
    base = _with(model, attn_chunk=16)
    opt = _with(model, attn_chunk=16, windowed_slice=True)
    l0 = float(base.forward_train(params, toks, labels, remat=False))
    l1 = float(opt.forward_train(params, toks, labels, remat=False))
    np.testing.assert_allclose(l0, l1, rtol=1e-6)

    lg0, _ = base.prefill(params, toks, max_len=80)
    lg1, _ = opt.prefill(params, toks, max_len=80)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), rtol=1e-5,
                               atol=1e-5)


def test_bf16_ce_close_to_fp32():
    model = build_model("granite-20b", policy="fp32", reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0,
                              model.cfg.vocab)
    labels = jax.random.randint(jax.random.key(2), (2, 64), 0,
                                model.cfg.vocab)
    l0 = float(model.forward_train(params, toks, labels, remat=False))
    l1 = float(_with(model, ce_dtype="fp16alt").forward_train(
        params, toks, labels, remat=False))
    assert abs(l0 - l1) < 0.02 * abs(l0), (l0, l1)


def test_dryrun_set_override_typing():
    from repro.launch.dryrun import _apply_sets
    cfg = get_config("gemma2-9b")
    out = _apply_sets(cfg, ["attn_chunk=256", "windowed_slice=true",
                            "ce_dtype=fp16alt"])
    assert out.attn_chunk == 256 and out.windowed_slice is True
    assert out.ce_dtype == "fp16alt"
    assert cfg.attn_chunk == 512  # original untouched
