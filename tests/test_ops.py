"""Tests for core.ops — the multi-format operation semantics (paper §II.B.4,
§III.A.2): expanding FMA with single rounding, policy-driven einsum,
cast-and-pack, STE gradients, per-op-group elementwise formats.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property-based tests")
from hypothesis import given, settings, strategies as st

from repro.core import ops as tp
from repro.core import softfloat
from repro.core.formats import get_format
from repro.core.policy import MatmulPolicy, PrecisionPolicy, PRESETS, get_policy

F32 = np.float32
finite = st.floats(width=16, allow_nan=False, allow_infinity=False)


def em_policy(src, acc, out=None):
    return PrecisionPolicy(
        name=f"t_{src}_{acc}", mode="emulate",
        matmul=MatmulPolicy(get_format(src), get_format(acc),
                            get_format(out) if out else None))


# ---------------------------------------------------------------------------
# expanding FMA: dst fma(src a, src b, dst c) with ONE rounding
# ---------------------------------------------------------------------------
@given(a=finite, b=finite, c=finite)
@settings(max_examples=300, deadline=None)
def test_tp_fma_single_rounding_fp16_fp32(a, b, c):
    """Emulated fmacex.s.h == round_fp32(exact(a16*b16) + c32): the product
    of two fp16 values is exact in f32, the f32 add is the single rounding."""
    pol = em_policy("fp16", "fp32")
    got = tp.tp_fma(jnp.float32(a), jnp.float32(b), jnp.float32(c), pol)
    qa = float(np.asarray(softfloat.quantize(jnp.float32(a), "fp16")))
    qb = float(np.asarray(softfloat.quantize(jnp.float32(b), "fp16")))
    want = F32(np.float64(qa) * np.float64(qb) + np.float64(F32(c)))
    if np.isnan(want):
        assert np.isnan(float(got))
    else:
        assert float(got) == want


@given(a=finite, b=finite)
@settings(max_examples=200, deadline=None)
def test_tp_fma_fp8_src_exact_product(a, b):
    """fp8 (5,2) products are exact in f32 (2*3 significand bits <= 24)."""
    pol = em_policy("fp8", "fp16")
    got = float(tp.tp_fma(jnp.float32(a), jnp.float32(b), jnp.float32(0), pol))
    qa = float(np.asarray(softfloat.quantize(jnp.float32(a), "fp8")))
    qb = float(np.asarray(softfloat.quantize(jnp.float32(b), "fp8")))
    want = float(np.asarray(softfloat.quantize(
        jnp.float32(np.float64(qa) * np.float64(qb)), "fp16")))
    if np.isnan(want):
        assert np.isnan(got)
    else:
        assert got == want


def test_fma_beats_narrow_accumulation():
    """The paper's Fig 10/11 point: fp16-multiply + fp32-accumulate keeps
    fp32-level accuracy while fp16-accumulate drifts."""
    rs = np.random.RandomState(0)
    a = rs.uniform(0.5, 1.5, 4096).astype(F32)
    b = rs.uniform(0.5, 1.5, 4096).astype(F32)
    exact = float(np.dot(a.astype(np.float64), b.astype(np.float64)))

    pol_ex = em_policy("fp16", "fp32")
    pol_narrow = em_policy("fp16", "fp16")

    def run(pol):
        def step(acc, ab):
            return tp.tp_fma(ab[0], ab[1], acc, pol), ()
        out, _ = jax.lax.scan(step, jnp.float32(0.0), (jnp.asarray(a), jnp.asarray(b)))
        return float(out)

    qa = np.asarray(softfloat.quantize(jnp.asarray(a), "fp16"), np.float64)
    qb = np.asarray(softfloat.quantize(jnp.asarray(b), "fp16"), np.float64)
    exact_q = float(qa @ qb)  # exact dot of the quantized inputs

    err_ex = abs(run(pol_ex) - exact_q)
    err_narrow = abs(run(pol_narrow) - exact_q)
    assert err_ex < 1e-2
    assert err_narrow > 50 * max(err_ex, 1e-9)


# ---------------------------------------------------------------------------
# tp_einsum / tp_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("src,acc,out", [
    ("fp16", "fp32", "fp16"), ("fp8", "fp32", "fp16alt"),
    ("fp16alt", "fp32", None)])
def test_tp_einsum_emulate_matches_manual(src, acc, out):
    pol = em_policy(src, acc, out)
    rs = np.random.RandomState(1)
    a = rs.randn(8, 32).astype(F32)
    b = rs.randn(32, 16).astype(F32)
    got = np.asarray(tp.tp_einsum("ij,jk->ik", a, b, pol))
    qa = np.asarray(softfloat.quantize(jnp.asarray(a), src))
    qb = np.asarray(softfloat.quantize(jnp.asarray(b), src))
    want = qa @ qb
    want = np.asarray(softfloat.quantize(jnp.asarray(want),
                                         pol.matmul.resolved_out()))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_tp_einsum_native_dtypes():
    pol = get_policy("tp_bf16")
    a = jnp.ones((4, 8), jnp.float32)
    b = jnp.ones((8, 4), jnp.float32)
    r = tp.tp_einsum("ij,jk->ik", a, b, pol)
    assert r.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(r, F32), 8.0)


def test_tp_matmul_batched():
    pol = em_policy("fp16", "fp32")
    rs = np.random.RandomState(2)
    a = rs.randn(2, 3, 8, 16).astype(F32)
    b = rs.randn(16, 12).astype(F32)
    got = tp.tp_matmul(a, b, pol)
    assert got.shape == (2, 3, 8, 12)


# ---------------------------------------------------------------------------
# STE gradient
# ---------------------------------------------------------------------------
def test_quantize_ste_gradient_passthrough():
    g = jax.grad(lambda x: jnp.sum(tp.quantize_ste(x, get_format("fp8"))))(
        jnp.arange(8, dtype=jnp.float32))
    np.testing.assert_array_equal(np.asarray(g), np.ones(8, F32))


def test_tp_einsum_differentiable():
    pol = em_policy("fp8", "fp32")
    rs = np.random.RandomState(3)
    a = jnp.asarray(rs.randn(4, 8).astype(F32))
    b = jnp.asarray(rs.randn(8, 4).astype(F32))
    ga, gb = jax.grad(lambda a, b: jnp.sum(tp.tp_einsum("ij,jk->ik", a, b, pol)),
                      argnums=(0, 1))(a, b)
    # STE: dL/da = ones @ qb.T on the quantized grid
    qb = np.asarray(softfloat.quantize(b, "fp8"))
    np.testing.assert_allclose(np.asarray(ga), np.ones((4, 4)) @ qb.T,
                               rtol=1e-5)
    assert gb.shape == b.shape


# ---------------------------------------------------------------------------
# cast_and_pack / conversions
# ---------------------------------------------------------------------------
def test_cast_and_pack_interleaves():
    a = jnp.asarray(np.arange(8, dtype=F32).reshape(2, 4))
    b = -a
    pol = em_policy("fp16", "fp32")
    r = np.asarray(tp.cast_and_pack(a, b, "fp8", pol))
    assert r.shape == (2, 8)
    np.testing.assert_array_equal(r[:, 0::2], np.asarray(
        softfloat.quantize(a, "fp8")))
    np.testing.assert_array_equal(r[:, 1::2], np.asarray(
        softfloat.quantize(b, "fp8")))


def test_tp_cast_native_and_emulate_agree():
    rs = np.random.RandomState(4)
    x = rs.randn(128).astype(F32) * 10
    em = np.asarray(tp.tp_cast(x, "fp16alt",
                               PRESETS["em_fp16"].replace(rounding="rne")))
    nat = np.asarray(tp.tp_cast(x, "fp16alt", None).astype(jnp.float32))
    np.testing.assert_array_equal(em, nat)


# ---------------------------------------------------------------------------
# elementwise group + policies
# ---------------------------------------------------------------------------
def test_tp_elementwise_runs_in_elem_fmt():
    pol = PRESETS["em_fp16"].replace(elem_fmt=get_format("fp8"))
    x = jnp.linspace(0.1, 2.0, 16)
    r = np.asarray(tp.tp_elementwise("rsqrt", x, policy=pol))
    # every output value must be on the fp8 grid
    q = np.asarray(softfloat.quantize(jnp.asarray(r), "fp8"))
    np.testing.assert_array_equal(r, q)


def test_policy_presets_valid():
    for name, p in PRESETS.items():
        assert p.matmul.src_fmt is not None
        assert p.mode in ("native", "emulate")
        if p.mode == "native":
            assert p.matmul.src_fmt.native_dtype is not None


def test_native_policy_rejects_unrepresentable_format():
    with pytest.raises(ValueError):
        PrecisionPolicy(
            name="bad", mode="native",
            matmul=MatmulPolicy(get_format("fp6_e3m2"), get_format("fp32")))
