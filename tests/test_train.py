"""Substrate tests: data determinism, optimizers, transprecision optimizer
state, gradient compression, checkpointing (atomic/keep-N/mesh-elastic),
the train loop end-to-end, and fault injection + restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, restore_pytree, \
    save_pytree
from repro.core.policy import PRESETS
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.registry import build_model
from repro.optim.optimizer import OptConfig, apply_update, init_opt_state, \
    lr_at
from repro.train.fault import FailurePlan, SimulatedFailure, \
    StragglerMonitor, run_with_restarts
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.train_step import make_train_step


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=8)
    d1 = SyntheticLMData(cfg)
    batches = [next(d1) for _ in range(3)]
    d2 = SyntheticLMData(cfg)
    d2.load_state_dict({"step": 2})
    b2 = next(d2)
    np.testing.assert_array_equal(np.asarray(batches[2]["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_data_host_sharding_partitions_batch():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=8)
    h0 = SyntheticLMData(cfg, host_index=0, host_count=2).batch_at(0)
    h1 = SyntheticLMData(cfg, host_index=1, host_count=2).batch_at(0)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"]))


def test_data_is_learnable_structure():
    """Tokens follow the arithmetic progression except noise positions."""
    cfg = DataConfig(vocab=512, seq_len=128, global_batch=4, noise=0.0)
    b = SyntheticLMData(cfg).batch_at(0)
    t = np.asarray(b["tokens"])
    d = np.diff(t, axis=1) % cfg.vocab
    assert (d == d[:, :1]).all()        # constant stride per row


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    pol = PRESETS["fp32"]
    cfg = OptConfig(name=name, lr=0.1, warmup_steps=0, total_steps=100,
                    weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.ones((8, 8)) * 3.0}
    state = init_opt_state(params, cfg, pol)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state, _ = apply_update(params, grads, state, cfg, pol)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_lr_schedule():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert abs(float(lr_at(cfg, 10)) - 1.0) < 1e-6
    assert abs(float(lr_at(cfg, 110)) - 0.1) < 1e-6
    assert float(lr_at(cfg, 60)) == pytest.approx(0.55, abs=0.01)


def test_transprecision_moments_stored_narrow():
    pol = PRESETS["prod_tp"]    # bf16 moments
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = init_opt_state(params, cfg, pol)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert state["v"]["w"].dtype == jnp.bfloat16
    assert state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4, 4), 0.5)}
    params2, state2, _ = apply_update(params, grads, state, cfg, pol,
                                      sr_key=jax.random.key(0))
    assert state2["m"]["w"].dtype == jnp.bfloat16
    assert params2["w"].dtype == jnp.bfloat16
    assert float(state2["master"]["w"][0, 0]) != 1.0


# ---------------------------------------------------------------------------
# gradient compression (semantics on a trivial mesh; the 512-device lowering
# is exercised by the dry-run)
# ---------------------------------------------------------------------------
def test_compress_sync_error_feedback_converges():
    from repro.optim.grad_compress import compress_sync_local
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = jnp.asarray(np.random.RandomState(0).randn(16, 16).astype(np.float32))
    ef = jnp.zeros_like(g)
    total_synced = jnp.zeros_like(g)

    def one(g, ef, i):
        def body(g, ef):
            return compress_sync_local(g, ef, axes=("data",), fmt="fp8",
                                       key=jax.random.key(i), n_replicas=1)
        from repro.core.compat import shard_map_compat
        return jax.jit(shard_map_compat(
            body, mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(),) * 2,
            out_specs=(jax.sharding.PartitionSpec(),) * 2,
            axis_names={"data"}, check_vma=False))(g, ef)

    # with a CONSTANT gradient, error feedback must make the cumulative
    # synced sum converge to the cumulative true sum
    for i in range(20):
        s, ef = one(g, ef, i)
        total_synced = total_synced + s
    err = float(jnp.max(jnp.abs(total_synced - 20 * g)))
    # EF bounds the cumulative error by one quantization step (fp8-scaled)
    assert err < float(jnp.max(jnp.abs(g))) * 0.25, err


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": (jnp.float32(3.5), jnp.arange(4, dtype=jnp.int32)),
            "k": jnp.zeros((2,), jnp.float16)}
    save_pytree(str(tmp_path / "c"), tree, {"step": 7})
    got, extra = restore_pytree(str(tmp_path / "c"), tree)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manager_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((2,))}
    for s in (10, 20, 30):
        mgr.save(s, tree, sync=True)
    assert mgr.latest_step() == 30
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [20, 30]
    step, got, extra = mgr.restore_latest(tree)
    assert step == 30 and extra["step"] == 30


def test_checkpoint_atomic_no_partial_state(tmp_path):
    """A tmp dir left by a 'crashed' save must not shadow the real one."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((2,))}
    mgr.save(5, tree, sync=True)
    os.makedirs(str(tmp_path / "step_00000009.tmp"))  # simulated crash debris
    assert mgr.latest_step() == 5


# ---------------------------------------------------------------------------
# train loop end-to-end + fault tolerance
# ---------------------------------------------------------------------------
def _mk_loop(tmp_path, fail_at=(), total=24):
    model = build_model("fpnew-case-study", policy="tp_bf16", reduced=True)
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=total,
                    weight_decay=0.0)
    data = DataConfig(vocab=model.cfg.vocab, seq_len=64, global_batch=8,
                      noise=0.0)
    lc = LoopConfig(total_steps=total, log_every=0, ckpt_every=8,
                    ckpt_dir=str(tmp_path / "ckpt"))
    return TrainLoop(model, opt, data, lc,
                     failure_plan=FailurePlan(fail_at=fail_at)
                     if fail_at else None)


def test_loop_loss_decreases(tmp_path):
    loop = _mk_loop(tmp_path, total=30)
    log = loop.run()
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    assert last < first - 0.5, (first, last)


def test_loop_restart_after_failure_resumes_not_restarts(tmp_path):
    plan = FailurePlan(fail_at=(13,))

    def make():
        loop = _mk_loop(tmp_path, total=24)
        loop.failure_plan = plan
        return loop

    loop, restarts = run_with_restarts(make, max_restarts=2)
    assert restarts == 1
    assert loop.step == 24
    # resumed from the step-8 checkpoint, not from scratch
    assert loop.metrics_log[0]["step"] == 8


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """Fault tolerance must be *exact*: crash+restore = never-crashed."""
    a = _mk_loop(tmp_path / "a", total=16)
    a.run()
    plan = FailurePlan(fail_at=(12,))

    def make():
        loop = _mk_loop(tmp_path / "b", total=16)
        loop.failure_plan = plan
        return loop

    b, restarts = run_with_restarts(make, max_restarts=1)
    assert restarts == 1
    la = jax.tree.leaves(a.params)
    lb = jax.tree.leaves(b.params)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_straggler_monitor():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=3)
    for i in range(8):
        assert not mon.record(i, 1.0)
    assert mon.record(8, 5.0)           # 5x the EWMA -> straggler
    assert mon.flagged[0][0] == 8
    assert not mon.record(9, 1.0)       # baseline not poisoned by outlier


def test_checkpoint_mesh_elastic_restore(tmp_path):
    """A checkpoint written from unsharded state must restore under a
    different (mesh) sharding layout — the pod-loss recovery path."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((8,), jnp.bfloat16)}
    save_pytree(str(tmp_path / "c"), tree, {"step": 3})
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P("data", "model")),
                 "b": NamedSharding(mesh, P(None))}
    got, extra = restore_pytree(str(tmp_path / "c"), tree, shardings)
    assert extra["step"] == 3
    assert got["w"].sharding.spec == P("data", "model")
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
