"""Block-shape autotuner: candidate legality, JSON memoization round-trip,
and the ops.py default-picker wiring."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops as kops, ref


@pytest.fixture
def tuner_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.reset()
    yield path
    autotune.reset()


@pytest.mark.parametrize("op,shape", [
    ("matmul", (256, 512, 256)),
    ("matmul", (8, 100, 70)),
    ("attn", (256, 512, 64)),
    ("decode_attn", (8, 384, 64)),
])
def test_candidates_legal_and_include_default(op, shape):
    cands = autotune.candidates(op, shape)
    assert cands[0] == autotune.default_block(op, shape)
    assert len(cands) == len(set(cands)) >= 1  # tiny shapes may collapse
    for c in cands:
        assert all(x > 0 for x in c)
        if op == "matmul":
            bm, bk, bn = c
            assert bm <= max(8, shape[0]) and bk % 128 == 0 and bn % 128 == 0
        elif op == "attn":
            bq, bk = c
            assert bq <= max(8, shape[0]) and bk % 128 == 0
        else:
            assert c[0] % 128 == 0


def test_record_lookup_roundtrip(tuner_cache):
    shape, block = (64, 256, 128), (32, 128, 128)
    assert autotune.lookup("matmul", shape, jnp.float32) is None
    assert autotune.best_block("matmul", shape, jnp.float32) == \
        autotune.default_block("matmul", shape)
    autotune.record("matmul", shape, jnp.float32, block)
    assert autotune.lookup("matmul", shape, jnp.float32) == block
    assert autotune.best_block("matmul", shape, jnp.float32) == block
    # other dtype / backend keys do not collide
    assert autotune.lookup("matmul", shape, jnp.bfloat16) is None
    # persisted: a fresh process (reset drops the in-memory mirror) reloads
    autotune.reset()
    assert json.loads(tuner_cache.read_text())
    assert autotune.lookup("matmul", shape, jnp.float32) == block


def test_sweep_picks_and_persists_winner(tuner_cache):
    winner, timings = autotune.autotune_decode(2, 256, 64, heads=2,
                                               repeats=1)
    assert winner in timings and winner in autotune.candidates(
        "decode_attn", (8, 256, 64))
    assert autotune.lookup("decode_attn", (8, 256, 64), jnp.float32) == winner
    assert os.path.exists(str(tuner_cache))


def test_cache_keys_carry_jax_version(tuner_cache):
    """Entries are keyed by the jax version that timed them: winners from a
    different jax install never resolve (stale-on-upgrade invalidation)."""
    import jax

    shape, block = (64, 256, 128), (32, 128, 128)
    autotune.record("matmul", shape, jnp.float32, block)
    disk = json.loads(tuner_cache.read_text())
    assert all(k.endswith(f"|jax-{jax.__version__}") for k in disk)
    # simulate an entry timed on another jax version: must not resolve
    other = next(iter(disk)).replace(f"|jax-{jax.__version__}", "|jax-0.0.0")
    disk[other] = [256, 512, 256]
    tuner_cache.write_text(json.dumps(disk))
    autotune.reset()
    assert autotune.lookup("matmul", shape, jnp.float32) == block


def test_legacy_cache_file_migrates(tuner_cache):
    """Pre-versioning cache files (4-field keys) load without error and are
    adopted once under the running jax version; malformed entries are
    skipped, not fatal."""
    tuner_cache.write_text(json.dumps({
        "matmul|64x256x128|float32|cpu": [32, 128, 128],
        "attn|256x512x64|float32|cpu": [64, 256],
        "bogus": "not-a-block",
        "matmul|8x8x8|float32|cpu|jax-0.0.0|extra": [8, 128, 128],
    }))
    autotune.reset()
    assert autotune.lookup("matmul", (64, 256, 128), jnp.float32,
                           backend="cpu") == (32, 128, 128)
    assert autotune.lookup("attn", (256, 512, 64), jnp.float32,
                           backend="cpu") == (64, 256)


def test_recorded_block_drives_tp_matmul(tuner_cache):
    """tp_matmul with block=None uses the memoized winner: the result is
    bit-exact against the oracle with the RECORDED K-blocking (bk=128) —
    the default heuristic for this shape would use a single K block, whose
    accumulation order differs bitwise."""
    rs = np.random.RandomState(0)
    a = jnp.asarray(rs.randn(64, 256).astype(np.float32))
    b = jnp.asarray(rs.randn(256, 128).astype(np.float32))
    autotune.record("matmul", (64, 256, 128), jnp.float32, (32, 128, 128))
    got = kops.tp_matmul(a, b, policy="fp32")
    want = ref.tp_matmul_ref(a, b, out_dtype=jnp.float32, bk=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# shipped pre-warmed cache (kernels/pretuned.json)
# ---------------------------------------------------------------------------
@pytest.fixture
def pretuned(tmp_path, monkeypatch):
    """Isolated disk cache AND a writable pretuned path; returns a helper
    that writes a pretuned file and reloads the tuner."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "user.json"))
    path = tmp_path / "pretuned.json"
    monkeypatch.setenv("REPRO_PRETUNED_CACHE", str(path))

    def ship(entries, **hdr):
        path.write_text(json.dumps({"jax": "x", "backend": "cpu",
                                    "entries": entries, **hdr}))
        autotune.reset()

    autotune.reset()
    yield ship
    autotune.reset()


def _pkey(op, shape, version=None):
    k = autotune._key(op, shape, jnp.float32, backend="cpu")
    if version is not None:
        k = k.rsplit("|", 1)[0] + f"|jax-{version}"
    return k


def test_pretuned_warm_hit(pretuned):
    shape, block = (64, 256, 128), (32, 128, 128)
    pretuned({_pkey("matmul", shape): block})
    assert autotune.lookup("matmul", shape, jnp.float32,
                           backend="cpu") == tuple(block)
    assert autotune.best_block("matmul", shape, jnp.float32,
                               backend="cpu") == tuple(block)


def test_pretuned_cold_miss_falls_back_to_heuristic(pretuned):
    # no pretuned file at all: loader is silent, heuristics serve
    autotune.reset()
    shape = (64, 256, 128)
    assert autotune.lookup("matmul", shape, jnp.float32,
                           backend="cpu") is None
    assert autotune.best_block("matmul", shape, jnp.float32, backend="cpu") \
        == autotune.default_block("matmul", shape)
    # file present but the key is for a different shape: still a miss
    pretuned({_pkey("matmul", (128, 64, 64)): [128, 128, 128]})
    assert autotune.lookup("matmul", shape, jnp.float32,
                           backend="cpu") is None


def test_pretuned_stale_version_not_adopted(pretuned):
    shape = (64, 256, 128)
    pretuned({_pkey("matmul", shape, version="0.0.0"): [32, 128, 128]})
    assert autotune.lookup("matmul", shape, jnp.float32,
                           backend="cpu") is None
    assert autotune.best_block("matmul", shape, jnp.float32, backend="cpu") \
        == autotune.default_block("matmul", shape)


def test_pretuned_user_cache_wins(pretuned, tmp_path):
    shape = (64, 256, 128)
    (tmp_path / "user.json").write_text(json.dumps(
        {_pkey("matmul", shape): [64, 128, 128]}))
    pretuned({_pkey("matmul", shape): [32, 128, 128]})
    # setdefault order: the user's locally-swept winner beats the shipped one
    assert autotune.lookup("matmul", shape, jnp.float32,
                           backend="cpu") == (64, 128, 128)


def test_pretuned_malformed_entries_skipped(pretuned):
    shape = (64, 256, 128)
    pretuned({_pkey("matmul", shape): "not-a-block",
              "v2|matmul|truncated": [8, 128, 128],
              _pkey("attn", (256, 512, 64)): [64, 256]})
    assert autotune.lookup("matmul", shape, jnp.float32,
                           backend="cpu") is None
    assert autotune.lookup("attn", (256, 512, 64), jnp.float32,
                           backend="cpu") == (64, 256)


def test_shipped_pretuned_file_is_wellformed():
    """The repo's own kernels/pretuned.json: valid JSON, v2 keys, integer
    blocks — so the loader adopts it wholesale when versions match."""
    with open(os.path.join(os.path.dirname(autotune.__file__),
                           "pretuned.json")) as f:
        ship = json.load(f)
    assert ship["entries"]
    for k, v in ship["entries"].items():
        parts = k.split("|")
        assert parts[0] == "v2" and len(parts) == 6
        assert parts[1] in ("matmul", "attn", "decode_attn")
        assert all(isinstance(x, int) and x > 0 for x in v)
