"""Transprecision speculative decoding: the draft/verify parity harness.

Contract under test (the FPnew energy-proportionality move applied to
decoding itself — spend the cheap format on proposals, pay target
precision once per chunk to verify):

  * chunk-form == step-form — ``verify_chunk`` scores k+1 positions in
    ONE call by folding the chunk into the batch axis of the *decode*
    attend path; its logits AND every cache byte it writes are bitwise
    identical to k+1 sequential ``decode_step`` calls, across policies
    (bf16 / fp16 / fp8-KV) and both pool layouts (contiguous + paged).
  * accepted stream == greedy stream — ``speculate_decode`` emits
    exactly ``generate(temperature=0)``'s tokens no matter how good or
    bad the draft is (layer-skip depth, narrow draft policy, or a
    forced never-matching draft): a wrong proposal costs SPEED only.
  * rollback is bitwise — rejected positions sit at/past each row's
    ``lens``; the live cache region after rejected rounds equals a
    never-drafted run's bit for bit.
  * accounting — EOS mid-chunk clamps acceptance at the stop token,
    the forced-0%-accept worst case still terminates in ``gen_len - 1``
    rounds, and the full-accept self-draft needs ``ceil((gen_len-1)/
    (k+1))`` rounds.
  * engine composition — spec-vs-plain token parity on the synthetic
    trace, per-request ``spec_k``/``no_speculate`` caps, preemption
    (free-and-reingest AND swap) and flag-driven escalation all
    compose; ``spec_accept_rate`` lands in (0, 1].
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import cached_model, small_batch
from repro.core.policy import EscalationPolicy
from repro.launch.engine import ContinuousEngine, Request, synthetic_trace
from repro.train.fault import ServeFaultPlan

POLICIES = ["tp_bf16", "tp_fp16", "tp_bf16_kv8"]
GEN, K = 10, 3


def _paged_cfg(paged):
    return dict(paged_kv=True, page_size=16) if paged else {}


def _greedy(model, params, toks, lens=None, **kw):
    fn = jax.jit(lambda p, t, l: model.generate(
        p, t, gen_len=GEN, max_len=48, prompt_lens=l, **kw)[0])
    return np.asarray(fn(params, toks, lens))


def _spec(model, params, toks, lens=None, **kw):
    fn = jax.jit(lambda p, t, l: model.speculate_decode(
        p, t, gen_len=GEN, spec_k=K, max_len=48, prompt_lens=l, **kw))
    return np.asarray(fn(params, toks, lens))


def _leaf_live_equal(ca, cb, lens):
    """Bitwise equality of two cache pytrees on the LIVE region: every
    KV leaf ([..., B, H, S, D] with batch at axis -4 and tokens at axis
    -2) is compared per row up to that row's length — dead slots past
    ``lens`` are the rollback scratch space and intentionally differ."""
    la, lb = jax.tree.leaves(ca), jax.tree.leaves(cb)
    assert len(la) == len(lb)
    n_kv = 0
    for a, b in zip(la, lb):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape
        if a.ndim < 4:
            continue
        n_kv += 1
        a = np.moveaxis(a, -4, 0)
        b = np.moveaxis(b, -4, 0)
        for r, L in enumerate(lens):
            np.testing.assert_array_equal(a[r, ..., :L, :], b[r, ..., :L, :])
    assert n_kv > 0


# ---------------------------------------------------------------------------
# chunk-form verify == step-form decode, bitwise (logits AND cache bytes)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.parametrize("policy", POLICIES)
def test_verify_chunk_bitwise_matches_sequential(policy, paged):
    model, params = cached_model("gemma2-9b", policy=policy,
                                 **_paged_cfg(paged))
    toks, lens = small_batch(model.cfg.vocab)
    b = toks.shape[0]
    lg0, c_seq = jax.jit(lambda p, t, l: model.prefill(
        p, t, max_len=48, prompt_lens=l))(params, toks, lens)
    _, c_chk = jax.jit(lambda p, t, l: model.prefill(
        p, t, max_len=48, prompt_lens=l))(params, toks, lens)
    tok = jnp.argmax(lg0[jnp.arange(b), lens - 1], -1).astype(
        jnp.int32)[:, None]
    # sequential: 4 greedy decode steps, collecting logits per position
    chunk, seq_lg = [tok], []
    pos = jnp.asarray(lens)
    step = jax.jit(lambda p, t, c, i: model.decode_step(
        p, t, c, i, kv_len=i + 1))
    for i in range(4):
        lg, c_seq = step(params, chunk[-1], c_seq, pos + i)
        seq_lg.append(lg[:, -1])
        chunk.append(jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None])
    # chunk: ONE verify call over the same 4 tokens at the same slots
    ct = jnp.concatenate(chunk[:4], axis=1)
    offs = pos[:, None] + jnp.arange(4, dtype=jnp.int32)
    v_lg, c_chk = jax.jit(lambda p, t, c, i, kl: model.verify_chunk(
        p, t, c, i, kv_len=kl))(params, ct, c_chk, pos, offs + 1)
    np.testing.assert_array_equal(
        np.stack([np.asarray(x, np.float32) for x in seq_lg], 1),
        np.asarray(v_lg, np.float32))
    for a, b_ in zip(jax.tree.leaves(c_seq), jax.tree.leaves(c_chk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


# ---------------------------------------------------------------------------
# accepted stream == plain greedy stream
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.parametrize("stop", [None, 7])
def test_speculate_decode_matches_generate(paged, stop):
    model, params = cached_model("gemma2-9b", **_paged_cfg(paged))
    toks, lens = small_batch(model.cfg.vocab)
    want = _greedy(model, params, toks, lens, stop_token=stop)
    got = _spec(model, params, toks, lens, stop_token=stop)
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("dr", [0, 1], ids=["embed-only", "1-repeat"])
def test_layer_skip_and_narrow_draft_parity(dr):
    """A shallow draft (down to zero scanned repeats) under a NARROWER
    policy (fp8 KV reads) changes only the accept rate, never a token."""
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    toks, lens = small_batch(model.cfg.vocab)
    want = _greedy(model, params, toks, lens)
    got = _spec(model, params, toks, lens, draft_repeats=dr,
                draft_policy="tp_bf16_kv8")
    np.testing.assert_array_equal(want, got)


def test_speculate_decode_moe_arch_paged():
    """The MoE arch (qk-norm, 8 experts top-2) through the paged pool."""
    model, params = cached_model("qwen3-moe-30b-a3b", paged_kv=True,
                                 page_size=16)
    toks, lens = small_batch(model.cfg.vocab)
    want = _greedy(model, params, toks, lens)
    got = _spec(model, params, toks, lens, draft_repeats=1)
    np.testing.assert_array_equal(want, got)


# ---------------------------------------------------------------------------
# rollback + accounting
# ---------------------------------------------------------------------------
def test_rollback_leaves_live_cache_bitwise_identical():
    """Rounds of REJECTED drafts (a constant never-matching proposal)
    must leave the live cache region exactly as a never-drafted run:
    rejected writes land at/past ``lens`` and the next chunk overwrites
    them before they can become live."""
    model, params = cached_model("gemma2-9b")
    toks, lens = small_batch(model.cfg.vocab)
    b = toks.shape[0]
    pre = jax.jit(lambda p, t, l: model.prefill(
        p, t, max_len=48, prompt_lens=l))
    lg0, c_spec = pre(params, toks, lens)
    _, c_plain = pre(params, toks, lens)
    tok = jnp.argmax(lg0[jnp.arange(b), lens - 1], -1).astype(
        jnp.int32)[:, None]
    pos = jnp.asarray(lens)
    done = jnp.zeros((b,), bool)
    limit = pos + 100
    bad_draft = lambda t, p: jnp.full((b, K), model.vocab_out - 1,
                                      jnp.int32)
    sstep = jax.jit(lambda p, t, c, i, l, d: model.speculate_step(
        p, t, c, i, lens=l, done=d, limit=limit, spec_k=K,
        _draft_fn=bad_draft))
    s_tok, s_pos, s_lens = tok, pos, pos
    spec_out = []
    for _ in range(3):
        g, n, s_tok, s_pos, s_lens, done, c_spec = sstep(
            params, s_tok, c_spec, s_pos, s_lens, done)
        assert np.all(np.asarray(n) == 1)          # 0% accept: bonus only
        spec_out.append(np.asarray(g[:, 0]))
    p_tok = tok
    step = jax.jit(lambda p, t, c, i: model.decode_step(
        p, t, c, i, kv_len=i + 1))
    plain_out = []
    for i in range(3):
        lg, c_plain = step(params, p_tok, c_plain, pos + i)
        p_tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        plain_out.append(np.asarray(p_tok[:, 0]))
    np.testing.assert_array_equal(np.stack(spec_out, 1),
                                  np.stack(plain_out, 1))
    _leaf_live_equal(c_spec, c_plain, np.asarray(s_lens))


def test_forced_zero_accept_terminates_and_matches():
    """Worst case: a draft that NEVER matches.  Every round accepts
    exactly the bonus token, so the run takes ``gen_len - 1`` rounds —
    and still emits the plain greedy stream."""
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    toks, lens = small_batch(model.cfg.vocab)
    b = toks.shape[0]
    bad = lambda t, p: jnp.full((b, K), model.vocab_out - 1, jnp.int32)
    got, rounds, emitted = jax.jit(lambda p, t, l: model.speculate_decode(
        p, t, gen_len=GEN, spec_k=K, max_len=48, prompt_lens=l,
        _draft_fn=bad, return_stats=True))(params, toks, lens)
    np.testing.assert_array_equal(_greedy(model, params, toks, lens),
                                  np.asarray(got))
    assert int(rounds) == GEN - 1
    assert int(emitted) == b * (GEN - 1)


def test_full_accept_round_count_and_rate():
    """The full-depth self-draft proposes the verify argmax chain, so
    every draft is accepted: ``ceil((gen_len-1)/(k+1))`` rounds."""
    model, params = cached_model("gemma2-9b")
    toks, lens = small_batch(model.cfg.vocab)
    b = toks.shape[0]
    got, rounds, emitted = jax.jit(lambda p, t, l: model.speculate_decode(
        p, t, gen_len=GEN, spec_k=K, max_len=48, prompt_lens=l,
        return_stats=True))(params, toks, lens)
    np.testing.assert_array_equal(_greedy(model, params, toks, lens),
                                  np.asarray(got))
    assert int(rounds) == -(-(GEN - 1) // (K + 1))
    assert int(emitted) == b * (GEN - 1)


def test_eos_mid_chunk_accounting():
    """A stop token that fires MID-CHUNK clamps acceptance there: the
    emitted stream (stop kept, tail frozen at the pad) matches plain
    EOS decode, and the emitted count stops at each row's stop."""
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    toks, lens = small_batch(model.cfg.vocab)
    plain = _greedy(model, params, toks, lens)
    # any mid-stream token works as the stop: rows that happen to open
    # with it just freeze immediately (live contribution 0)
    stop = int(plain[0, GEN // 2])
    want = _greedy(model, params, toks, lens, stop_token=stop)
    got, rounds, emitted = jax.jit(lambda p, t, l: model.speculate_decode(
        p, t, gen_len=GEN, spec_k=K, max_len=48, prompt_lens=l,
        stop_token=stop, return_stats=True))(params, toks, lens)
    np.testing.assert_array_equal(want, np.asarray(got))
    # emitted == sum of live tokens past each row's first (frozen rows
    # pad with the stop token and contribute nothing further)
    live = [(np.where(want[r] == stop)[0][0] if stop in want[r]
             else GEN - 1) for r in range(want.shape[0])]
    assert int(emitted) == int(sum(live))


def test_speculate_headroom_and_gating():
    """No silent cache corruption: missing draft lookahead raises at the
    model layer AND the engine layer; sampling/penalty engines refuse
    ``spec_k`` outright (acceptance is argmax-defined)."""
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    toks, _ = small_batch(model.cfg.vocab)
    with pytest.raises(ValueError, match="headroom"):
        model.speculate_decode(params, toks, gen_len=8, spec_k=K,
                               max_len=toks.shape[1] + 8)
    with pytest.raises(ValueError, match="greedy-only"):
        ContinuousEngine(model, params, slots=2, max_len=64,
                         spec_k=K, temperature=0.7)
    with pytest.raises(ValueError, match="penalties"):
        ContinuousEngine(model, params, slots=2, max_len=64,
                         spec_k=K, repetition_penalty=1.3)
    eng = ContinuousEngine(model, params, slots=2, max_len=32, spec_k=K)
    with pytest.raises(ValueError, match="speculative lookahead"):
        eng.run([Request(rid=0, tokens=[1] * 24, max_new=8)])


# ---------------------------------------------------------------------------
# engine composition
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def eng_setup():
    return cached_model("gemma2-9b", paged_kv=True, page_size=16)


def _trace(model, n=10):
    return synthetic_trace(n, 4, 6, 10, model.vocab_out, seed=3)


def _run(model, params, reqs, **kw):
    eng = ContinuousEngine(model, params, slots=4, max_len=48, chunk=8,
                           stop_token=7, burst_cap=16, **kw)
    fin, st = eng.run(reqs)
    return {f.rid: f.tokens for f in fin}, st


def test_engine_spec_vs_plain_token_parity(eng_setup):
    """THE acceptance gate: the speculative engine serves the synthetic
    trace with bit-identical tokens, fewer decode rounds, and an accept
    rate in (0, 1]."""
    model, params = eng_setup
    reqs = _trace(model)
    plain, st0 = _run(model, params, reqs)
    spec, st1 = _run(model, params, reqs, spec_k=K)
    assert all(plain[r.rid] == spec[r.rid] for r in reqs)
    assert 0.0 < st1["spec_accept_rate"] <= 1.0
    assert st1["decode_rounds"] <= st0["decode_rounds"]
    assert st1["spec_emitted"] >= st1["spec_rounds"]  # bonus >= 1/round


def test_engine_per_request_caps_and_no_speculate(eng_setup):
    """``no_speculate`` rows (cap 0) and per-request ``spec_k`` caps ride
    the SAME burst program as full-speculation rows, all at parity."""
    model, params = eng_setup
    reqs = _trace(model)
    plain, _ = _run(model, params, reqs)
    mix = [dataclasses.replace(r, no_speculate=(i % 3 == 0),
                               spec_k=(1 if i % 3 == 1 else None))
           for i, r in enumerate(reqs)]
    spec, st = _run(model, params, mix, spec_k=K)
    assert all(plain[r.rid] == spec[r.rid] for r in reqs)
    assert 0.0 < st["spec_accept_rate"] <= 1.0


@pytest.mark.parametrize("mode", ["free", "swap"])
def test_engine_spec_composes_with_preemption(eng_setup, mode):
    """A speculating victim preempted under page pressure resumes to its
    exact un-preempted stream on both mechanisms (the swap path stores
    only ``lens`` tokens — rejected-slot scratch is recomputed)."""
    model, params = eng_setup
    rng = np.random.RandomState(0)
    mk = lambda n: rng.randint(0, model.cfg.vocab, size=n).tolist()
    # budgets long enough that the residents are still mid-generation
    # when the priority arrival lands (speculation finishes rows up to
    # (k+1)x faster, so the plain-engine pressure recipe is too short);
    # the pool fits both residents' +spec_k reservations but not the
    # arrival's, forcing the preemption path rather than a free admit
    reqs = [Request(rid=0, tokens=mk(20), max_new=24, arrival=0),
            Request(rid=1, tokens=mk(20), max_new=24, arrival=0),
            Request(rid=2, tokens=mk(16), max_new=8, arrival=4, priority=2)]
    solo = jax.jit(lambda p, t, n: model.generate(
        p, t, gen_len=n, max_len=48)[0], static_argnums=2)
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=7, preempt=mode, spec_k=K)
    fin, stats = eng.run(reqs)
    assert stats["preemptions"] >= 1 and stats["resumed"] >= 1
    for r, f in zip(reqs, fin):
        want = np.asarray(solo(params, jnp.asarray(
            r.tokens, jnp.int32)[None], r.max_new))[0].tolist()
        assert f.tokens == want, (mode, r.rid)


def test_engine_spec_composes_with_escalation():
    """Flag-driven KV escalation under an injected overflow storm: the
    speculating engine drains every budget, escalates at least one row,
    and keeps all logits finite (saturating chunk writes)."""
    model, params = cached_model("gemma2-9b", policy="fp32",
                                 paged_kv=True, page_size=16)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, tokens=rng.randint(
        0, model.cfg.vocab, size=12).tolist(), max_new=16, arrival=0)
        for i in range(2)]
    plan = ServeFaultPlan(overflow_at=(2,), overflow_scale=65536.0)
    eng = ContinuousEngine(model, params, slots=2, max_len=64, chunk=16,
                           n_pages=12, burst_cap=4, spec_k=K,
                           escalate=EscalationPolicy(of_threshold=4),
                           fault_plan=plan)
    fin, stats = eng.run(reqs)
    assert stats["escalations"] >= 1
    assert stats["poisoned_rounds"] == 0
    assert any(f.escalated >= 1 for f in fin)
    for r, f in zip(reqs, fin):
        assert len(f.tokens) == r.max_new
    assert 0.0 < stats["spec_accept_rate"] <= 1.0


def test_engine_spec_replay_deterministic(eng_setup):
    """Same queue, same speculative engine, twice: same tokens, same
    accept-rate accounting (the whole draft/verify path is replayable)."""
    model, params = eng_setup
    reqs = _trace(model, n=6)
    eng = ContinuousEngine(model, params, slots=4, max_len=48, chunk=8,
                           stop_token=7, burst_cap=16, spec_k=K)
    fin1, st1 = eng.run(reqs)
    fin2, st2 = eng.run(reqs)
    assert [f.tokens for f in fin1] == [f.tokens for f in fin2]
    assert st1["spec_rounds"] == st2["spec_rounds"]
    assert st1["spec_emitted"] == st2["spec_emitted"]
