"""Ragged-batch serving: per-sequence length-aware attention, end to end.

Contract under test (the per-sequence generalization of the scalar
``kv_len``):

  * kernels — ``flash_attention_pallas`` / ``decode_attention_pallas``
    accept a per-row length vector, are BIT-EXACT per row against the
    per-sequence blocked oracles in ref.py, and their ``debug_visits``
    instrumentation proves each row does work proportional to its OWN
    length, not the batch max (the work-level energy-proportionality claim
    of the FPnew reproduction).
  * no-retrace — differing length *vectors* share one compiled kernel,
    exactly like the scalar case (the serving-loop contract).
  * model — ragged prefill/decode of a padded batch is row-independent
    (each row equals itself served alone at the same padded width), and
    pallas-vs-dense logits agree per row across bf16/fp16/fp8-kv policies.
  * EOS — ``generate(stop_token=...)`` freezes finished rows' tokens and
    live cache length without perturbing unfinished rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import block_schedule, flash_attention_pallas
from repro.models.registry import build_model

F32 = np.float32


def rnd(*shape, seed=0, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(F32)


def _qkv(bh, bkv, sq, skv, d, seed=0):
    q = jnp.asarray(rnd(bh, sq, d, seed=seed))
    k = jnp.asarray(rnd(bkv, skv, d, seed=seed + 1))
    v = jnp.asarray(rnd(bkv, skv, d, seed=seed + 2))
    return q, k, v


# ---------------------------------------------------------------------------
# kernel-level: per-row bit-exactness vs the per-sequence blocked oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", [None, "fp16", "fp8"])
def test_ragged_flash_bit_exact_vs_per_row_oracle(fmt):
    """Two sequences x two heads, lengths 100 and 256 in one padded batch:
    the kernel with the per-row vector equals the blocked oracle walking
    each row at its own length — bitwise, across storage-format snaps."""
    lens = [100, 256]
    group = 2                      # 2 q heads per kv head; B = len(lens)
    q, k, v = _qkv(4, 2, 256, 256, 64, seed=3)
    kvl = jnp.asarray(np.repeat(lens, group), jnp.int32)   # per flat head
    kw = dict(group=group, scale=0.125, causal=True, src_fmt_name=fmt,
              src_dtype=jnp.float32, out_dtype=jnp.float32)
    got = flash_attention_pallas(q, k, v, kvl, bq=128, bk=128, **kw)
    want = ref.flash_attention_ref(q, k, v, kv_len=np.repeat(lens, group),
                                   bq=128, bk=128, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and each row equals the SAME row under a uniform batch of its length
    for b, L in enumerate(lens):
        uni = flash_attention_pallas(q, k, v, L, bq=128, bk=128, **kw)
        np.testing.assert_array_equal(
            np.asarray(got[b * group:(b + 1) * group]),
            np.asarray(uni[b * group:(b + 1) * group]))


@pytest.mark.parametrize("fmt", [None, "fp16alt", "fp16", "fp8"])
def test_ragged_decode_bit_exact_vs_per_row_oracle(fmt):
    """Per-row decode lengths across every supported KV storage grid."""
    lens = [1, 77, 129, 256]
    q = jnp.asarray(rnd(4, 8, 64, seed=5))
    k = jnp.asarray(rnd(4, 256, 64, seed=6))
    v = jnp.asarray(rnd(4, 256, 64, seed=7))
    kvl = jnp.asarray(lens, jnp.int32)
    kw = dict(bk=128, scale=0.125, kv_fmt_name=fmt, src_dtype=jnp.float32,
              out_dtype=jnp.float32)
    got = decode_attention_pallas(q, k, v, kvl, **kw)
    want = ref.decode_attention_ref(q, k, v, kv_len=np.asarray(lens), **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ragged_decode_zero_length_row_emits_zeros():
    """A kv_len == 0 row (continuous-batching edge: an empty slot in the
    pack) yields exact zeros from kernel AND oracle — the l == 0 store
    guard, not NaN from 0/0, and no oracle crash on the empty block list."""
    q = jnp.asarray(rnd(2, 8, 64, seed=25))
    k = jnp.asarray(rnd(2, 256, 64, seed=26))
    v = jnp.asarray(rnd(2, 256, 64, seed=27))
    kw = dict(bk=128, scale=0.125, src_dtype=jnp.float32)
    lens = np.asarray([0, 128])
    got = decode_attention_pallas(q, k, v, jnp.asarray(lens, jnp.int32), **kw)
    want = ref.decode_attention_ref(q, k, v, kv_len=lens, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert (np.asarray(got[0]) == 0.0).all()
    assert np.isfinite(np.asarray(got)).all()
    # the dense model path agrees (zeros, not uniform weights over garbage)
    from repro.core.policy import PRESETS
    from repro.models.attention import _decode_attend
    qd = jnp.asarray(rnd(2, 4, 1, 64, seed=28))
    kd = jnp.asarray(rnd(2, 2, 128, 64, seed=29))
    vd = jnp.asarray(rnd(2, 2, 128, 64, seed=30))
    out = _decode_attend(qd, kd, vd, PRESETS["tp_bf16"],
                         kv_len=jnp.asarray([0, 64]), window=None, cap=None,
                         backend="dense")
    assert (np.asarray(out[0]) == 0.0).all()
    assert (np.asarray(out[1]) != 0.0).any()


def test_ragged_decode_dead_rows_ignore_garbage():
    """Slots past each ROW's length must not affect that row (ragged caches
    have per-row garbage tails of different sizes)."""
    lens = [50, 200]
    q = jnp.asarray(rnd(2, 4, 64, seed=9))
    k = jnp.asarray(rnd(2, 256, 64, seed=10))
    v = jnp.asarray(rnd(2, 256, 64, seed=11))
    kvl = jnp.asarray(lens, jnp.int32)
    kw = dict(bk=128, scale=0.125, src_dtype=jnp.float32)
    got = decode_attention_pallas(q, k, v, kvl, **kw)
    k2 = jnp.stack([k[0].at[lens[0]:].set(1e9), k[1].at[lens[1]:].set(1e9)])
    v2 = jnp.stack([v[0].at[lens[0]:].set(-1e9), v[1].at[lens[1]:].set(-1e9)])
    got2 = decode_attention_pallas(q, k2, v2, kvl, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


# ---------------------------------------------------------------------------
# debug_visits: per-row work proportional to per-row length
# ---------------------------------------------------------------------------
def test_flash_debug_visits_per_row_pruning():
    """A ragged batch with rows at 1/4 and 4/4 of max length visits strictly
    fewer blocks than the uniform max-length batch, and each row's count is
    exactly the blocks intersecting its own causal run up to its length."""
    sq = skv = 512
    bq = bk = 128
    lens = [128, 512]              # 1/4 and 4/4 of the padded length
    q, k, v = _qkv(2, 2, sq, skv, 64, seed=13)
    kw = dict(group=1, bq=bq, bk=bk, scale=0.125, causal=True,
              src_dtype=jnp.float32, debug_visits=True)
    qi, ki, _, _ = block_schedule(sq, skv, bq, bk, causal=True, window=None)

    _, vis_ragged = flash_attention_pallas(
        q, k, v, jnp.asarray(lens, jnp.int32), **kw)
    _, vis_uniform = flash_attention_pallas(q, k, v, skv, **kw)
    vis_ragged, vis_uniform = np.asarray(vis_ragged), np.asarray(vis_uniform)
    assert vis_ragged.shape == vis_uniform.shape == (2, len(qi))

    # exact per-row expectation: scheduled steps whose KV block starts
    # before the row's own length do work, the rest early-out
    for b, L in enumerate(lens):
        want = (np.asarray(ki) * bk < L).astype(np.int32)
        np.testing.assert_array_equal(vis_ragged[b], want)
    # the full-length row is untouched by pruning; the short row visits
    # ~1/4 of its causal schedule; the batch total strictly shrinks
    np.testing.assert_array_equal(vis_ragged[1], vis_uniform[1])
    assert vis_ragged[0].sum() < vis_uniform[0].sum()
    assert vis_ragged.sum() < vis_uniform.sum()
    # proportionality: per-row visit counts ordered like per-row lengths
    assert vis_ragged[0].sum() == (np.asarray(ki) * bk < lens[0]).sum()


def test_decode_debug_visits_per_row_pruning():
    """Decode: each row's KV-block loop early-exits at its own length —
    rows at 1/4 and 4/4 of the cache visit 1/4 and 4/4 of the blocks."""
    lens = [128, 512]
    bk = 128
    q = jnp.asarray(rnd(2, 8, 64, seed=15))
    k = jnp.asarray(rnd(2, 512, 64, seed=16))
    v = jnp.asarray(rnd(2, 512, 64, seed=17))
    kw = dict(bk=bk, scale=0.125, src_dtype=jnp.float32, debug_visits=True)
    _, vis = decode_attention_pallas(q, k, v, jnp.asarray(lens, jnp.int32),
                                     **kw)
    _, vis_uni = decode_attention_pallas(q, k, v,
                                         jnp.array([[512]], jnp.int32), **kw)
    vis, vis_uni = np.asarray(vis), np.asarray(vis_uni)
    np.testing.assert_array_equal(vis[0], [1, 0, 0, 0])   # 128/512 -> 1 block
    np.testing.assert_array_equal(vis[1], [1, 1, 1, 1])   # full row
    np.testing.assert_array_equal(vis_uni, np.ones((2, 4), np.int32))
    assert vis.sum() < vis_uni.sum()


# ---------------------------------------------------------------------------
# no-retrace: differing length vectors share one compiled kernel
# ---------------------------------------------------------------------------
def test_ragged_no_retrace_across_length_vectors():
    q, k, v = _qkv(2, 2, 256, 256, 64, seed=19)
    traces = []

    @jax.jit
    def run_flash(kvl):
        traces.append(None)
        return flash_attention_pallas(q, k, v, kvl, group=1, bq=128, bk=128,
                                      scale=0.125, causal=True,
                                      src_dtype=jnp.float32)

    for lens in ([256, 256], [100, 200], [1, 37]):
        got = run_flash(jnp.asarray(lens, jnp.int32))
        want = ref.flash_attention_ref(
            q, k, v, kv_len=np.asarray(lens), bq=128, bk=128, group=1,
            scale=0.125, causal=True, src_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert len(traces) == 1, "length vectors must not retrace"

    qd = jnp.asarray(rnd(2, 8, 64, seed=20))
    fn = jax.jit(lambda kvl: decode_attention_pallas(
        qd, k, v, kvl, bk=128, scale=0.125, src_dtype=jnp.float32))
    for lens in ([256, 256], [5, 129], [77, 1]):
        got = fn(jnp.asarray(lens, jnp.int32))
        want = ref.decode_attention_ref(qd, k, v, kv_len=np.asarray(lens),
                                        bk=128, scale=0.125,
                                        src_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert fn._cache_size() == 1


def test_ops_wrappers_expand_per_sequence_lengths():
    """kops.flash_attention / decode_attention take [B] per-SEQUENCE vectors
    and expand them across heads (the model-facing contract)."""
    b, h, hkv, s, d = 2, 4, 2, 128, 64
    lens = np.asarray([40, 128])
    q = jnp.asarray(rnd(b, h, s, d, seed=21))
    k = jnp.asarray(rnd(b, hkv, s, d, seed=22))
    v = jnp.asarray(rnd(b, hkv, s, d, seed=23))
    got = kops.flash_attention(q, k, v, kv_len=jnp.asarray(lens, jnp.int32),
                               causal=True, bq=128, bk=128, policy="fp32")
    want = ref.flash_attention_ref(
        q.reshape(b * h, s, d), k.reshape(b * hkv, s, d),
        v.reshape(b * hkv, s, d), group=h // hkv, scale=d ** -0.5,
        causal=True, kv_len=np.repeat(lens, h), bq=128, bk=128,
        src_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got.reshape(b * h, s, d)),
                                  np.asarray(want))

    qd = jnp.asarray(rnd(b, h, 1, d, seed=24))
    got = kops.decode_attention(qd, k, v,
                                kv_len=jnp.asarray(lens, jnp.int32),
                                policy="fp32", bk=128)
    qr = jnp.pad(qd.reshape(b, hkv, h // hkv, d).reshape(b * hkv,
                                                         h // hkv, d),
                 ((0, 0), (0, 8 - h // hkv), (0, 0)))
    want = ref.decode_attention_ref(qr, k.reshape(b * hkv, s, d),
                                    v.reshape(b * hkv, s, d),
                                    kv_len=np.repeat(lens, hkv), bk=128,
                                    scale=d ** -0.5, src_dtype=jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(got.reshape(b * hkv, h // hkv, d)),
        np.asarray(want[:, :h // hkv]))


# ---------------------------------------------------------------------------
# model-level: ragged row-independence + pallas-vs-dense per-row parity
# ---------------------------------------------------------------------------
LENS = [8, 20, 32]


def _ragged_setup(arch, policy):
    model = build_model(arch, policy=policy, reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (len(LENS), 32), 0,
                              model.cfg.vocab)
    return model, params, toks, jnp.asarray(LENS, jnp.int32)


def test_model_ragged_prefill_row_independent():
    """Each row of a ragged padded batch produces the logits it would
    produce served ALONE (same padded width) — padding rows never leak."""
    model, params, toks, lens = _ragged_setup("gemma2-9b", "tp_bf16")
    fn = jax.jit(lambda p, t, l: model.prefill(p, t, max_len=40,
                                               prompt_lens=l))
    lg, _ = fn(params, toks, lens)
    for i, L in enumerate(LENS):
        lg_i, _ = fn(params, toks[i:i + 1], jnp.asarray([L], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[i]), np.asarray(lg_i[0]),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("arch,policy", [
    ("gemma2-9b", "tp_bf16"),        # window + softcap layers
    ("gemma2-9b", "tp_fp16"),
    ("gemma2-9b", "tp_bf16_kv8"),    # fp8 KV cache policy
    ("minicpm3-4b", "tp_bf16"),      # MLA (latent cache) ragged
])
def test_model_ragged_pallas_vs_dense_per_row(arch, policy):
    """Ragged prefill logits: pruned-grid Pallas vs dense chunked softmax,
    per row, across precision policies (same math, different reduction
    schedule -> tolerance comparison, like the uniform-batch test)."""
    model, params, toks, lens = _ragged_setup(arch, policy)
    lg_d, _ = jax.jit(lambda p, t, l: model.prefill(
        p, t, max_len=40, prompt_lens=l))(params, toks, lens)
    mp = model.with_cfg(prefill_backend="pallas")
    lg_p, _ = jax.jit(lambda p, t, l: mp.prefill(
        p, t, max_len=40, prompt_lens=l))(params, toks, lens)
    for i in range(len(LENS)):
        np.testing.assert_allclose(np.asarray(lg_p[i]), np.asarray(lg_d[i]),
                                   rtol=5e-2, atol=1e-1)


@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_model_ragged_generate_matches_solo_rows(backend):
    """Greedy ragged generation (dense and fused-kernel decode) equals each
    row generated alone — the per-row write-index / kv_len plumbing."""
    model, params, toks, lens = _ragged_setup("gemma2-9b", "tp_bf16")
    model = model.with_cfg(decode_backend=backend)
    fn = jax.jit(lambda p, t, l: model.generate(
        p, t, gen_len=4, max_len=40, prompt_lens=l)[0])
    gen = fn(params, toks, lens)
    for i, L in enumerate(LENS):
        g_i = fn(params, toks[i:i + 1], jnp.asarray([L], jnp.int32))
        np.testing.assert_array_equal(np.asarray(gen[i]), np.asarray(g_i[0]))


# ---------------------------------------------------------------------------
# EOS stop-token early-exit
# ---------------------------------------------------------------------------
def test_generate_eos_freezes_rows_without_perturbing_others():
    model = build_model("gemma2-9b", policy="tp_bf16", reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (3, 32), 0, model.cfg.vocab)
    base = jax.jit(lambda p, t: model.generate(p, t, gen_len=5,
                                               max_len=40)[0])
    g0 = np.asarray(base(params, toks))
    # choose a stop token that actually interrupts some row mid-generation
    stop = int(g0[1, 2]) if g0[1, 2] != g0[1, 0] else int(g0[0, 0])
    gs = np.asarray(jax.jit(lambda p, t: model.generate(
        p, t, gen_len=5, max_len=40, stop_token=stop)[0])(params, toks))
    for b in range(3):
        hit = np.where(g0[b] == stop)[0]
        cut = int(hit[0]) if len(hit) else 5
        # identical up to and including the stop; frozen to stop after
        np.testing.assert_array_equal(gs[b, :cut + 1], g0[b, :cut + 1])
        assert (gs[b, cut:] == stop).all()


def test_generate_eos_composes_with_ragged_and_sampling():
    """stop_token + prompt_lens + sampling share one scan carry; frozen
    rows stay frozen and runs are key-deterministic."""
    model, params, toks, lens = _ragged_setup("gemma2-9b", "tp_bf16")
    fn = jax.jit(lambda p, t, l, k: model.generate(
        p, t, gen_len=6, max_len=48, prompt_lens=l, stop_token=3,
        temperature=0.9, top_k=50, key=k)[0])
    s1 = np.asarray(fn(params, toks, lens, jax.random.key(7)))
    s2 = np.asarray(fn(params, toks, lens, jax.random.key(7)))
    np.testing.assert_array_equal(s1, s2)
    for b in range(s1.shape[0]):
        hit = np.where(s1[b] == 3)[0]
        if len(hit):
            assert (s1[b, hit[0]:] == 3).all()


def test_ragged_rejected_for_ssm_mixers():
    """Recurrent mixers cannot mask pad tokens out of their state scan:
    prompt_lens must refuse, not silently return padding-dependent rows."""
    model = build_model("zamba2-1.2b", policy="tp_bf16", reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, model.cfg.vocab)
    with pytest.raises(ValueError, match="ragged"):
        model.prefill(params, toks, max_len=24,
                      prompt_lens=jnp.asarray([8, 16], jnp.int32))
    with pytest.raises(ValueError, match="ragged"):
        model.generate(params, toks, gen_len=2, max_len=24,
                       prompt_lens=jnp.asarray([8, 16], jnp.int32))


def test_generate_no_stop_token_path_unchanged():
    """stop_token=None must leave the greedy scan graph untouched —
    bit-identical tokens to a run that never heard of EOS."""
    model = build_model("gemma2-9b", policy="tp_bf16", reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, model.cfg.vocab)
    g0, _ = jax.jit(lambda p, t: model.generate(p, t, gen_len=4))(params, toks)
    g1, _ = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=4, stop_token=None))(params, toks)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
