"""Preemption, backpressure, degradation and fault injection for the
continuous-batching engine.

Contract under test (the robustness half of the serving story):

  * preempt-resume parity — a row evicted under pressure and resumed
    later emits exactly the tokens of an un-preempted solo run, on BOTH
    mechanisms: ``preempt="free"`` (chunked re-ingest of prompt+emitted)
    and ``preempt="swap"`` (K/V pages round-tripped through a host-side
    numpy store).  With the fp8 KV policy the degraded swap store is
    value-exact too.
  * graceful degradation — ``degrade_fmt="fp8"`` on a bf16 pool is lossy
    but tracked (``Finished.degraded``); ``Request.no_degrade`` opts a
    quality-sensitive request out and keeps it bit-exact.
  * fault-plan replay — the same plan + the same queue produce the same
    tokens, the same counters and the same injection event log, twice.
  * deadlines — impossible deadlines are counted as misses, generous
    ones are not, and the per-request flag lands on ``Finished``.
  * overload soak — a bursty over-committed trace with injected
    exhaustion, stragglers and poisoned logits drains COMPLETELY (every
    request finishes with its full budget; nothing is lost or stuck).
  * failure modes — unmasked poisoned logits fail fast
    (``PoisonedLogitsError``); a livelocked loop aborts cleanly
    (``EngineStuckError`` with diagnostics) instead of hanging.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.engine import ContinuousEngine, Request, synthetic_trace
from repro.train.fault import (EngineStuckError, PoisonedLogitsError,
                               ServeFaultPlan, ServeWatchdog,
                               StragglerMonitor)


@pytest.fixture(scope="module")
def setup():
    from conftest import cached_model
    return cached_model("gemma2-9b", paged_kv=True, page_size=16)


def _solo(model, params, req, **gen_kw):
    g = jax.jit(lambda p, t, n: model.generate(
        p, t, gen_len=n, max_len=48, **gen_kw)[0], static_argnums=2)
    return np.asarray(g(params, jnp.asarray(req.tokens, jnp.int32)[None],
                        req.max_new))[0].tolist()


def _pressure_queue(vocab, seed=0, no_degrade=False):
    """Two low-priority residents fill a 5-page pool; a priority-2
    arrival at round 4 cannot fit without preempting one of them."""
    rng = np.random.RandomState(seed)
    mk = lambda n: rng.randint(0, vocab, size=n).tolist()
    return [Request(rid=0, tokens=mk(20), max_new=12, arrival=0,
                    no_degrade=no_degrade),
            Request(rid=1, tokens=mk(20), max_new=12, arrival=0),
            Request(rid=2, tokens=mk(16), max_new=8, arrival=4, priority=2)]


# ---------------------------------------------------------------------------
# preempt-resume parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["free", "swap"])
def test_preempt_resume_bit_parity(setup, mode):
    """The victim of a priority preemption, resumed after the intruder
    drains, emits EXACTLY its un-preempted solo tokens — whether its
    continuation was re-ingested ("free") or swapped to host ("swap")."""
    model, params = setup
    reqs = _pressure_queue(model.cfg.vocab)
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=5, preempt=mode)
    fin, stats = eng.run(reqs)
    assert stats["preemptions"] >= 1 and stats["resumed"] >= 1
    assert stats["preempt_swap" if mode == "swap"
                 else "preempt_reingest"] >= 1
    victims = [f for f in fin if f.preemptions > 0]
    assert victims, "pressure scenario failed to preempt anyone"
    for r, f in zip(reqs, fin):
        assert f.tokens == _solo(model, params, r), (mode, r.rid)
        assert len(f.tokens) == r.max_new


def test_degraded_swap_is_exact_on_fp8_pool():
    """Policy tp_bf16_kv8 already stores K/V in fp8 — the degraded swap
    store is the pool's own container, so the round-trip is value-exact
    and the preempted row stays bit-identical to its solo run."""
    from conftest import cached_model
    model, params = cached_model("gemma2-9b", policy="tp_bf16_kv8",
                                 paged_kv=True, page_size=16)
    reqs = _pressure_queue(model.cfg.vocab)
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=5, preempt="swap", degrade_fmt="fp8")
    fin, stats = eng.run(reqs)
    assert stats["degraded"] >= 1
    assert any(f.degraded for f in fin)
    for r, f in zip(reqs, fin):
        assert f.tokens == _solo(model, params, r), r.rid


def test_degrade_tracked_and_refusable(setup):
    """On a bf16 pool the fp8 swap store is lossy: the victim is flagged
    ``degraded`` (tokens may drift — that's the graceful part) and keeps
    its full budget.  A ``no_degrade`` victim swaps at full width
    instead: unflagged and bit-exact."""
    model, params = setup
    for refuse in (False, True):
        reqs = _pressure_queue(model.cfg.vocab, no_degrade=refuse)
        eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                               n_pages=5, preempt="swap", degrade_fmt="fp8")
        fin, stats = eng.run(reqs)
        victims = [f for f in fin if f.preemptions > 0]
        assert victims
        for f in victims:
            assert len(f.tokens) == reqs[f.rid].max_new
            if refuse and f.rid == 0:
                assert not f.degraded
                assert f.tokens == _solo(model, params, reqs[0])
        if not refuse:
            assert stats["degraded"] >= 1


# ---------------------------------------------------------------------------
# fault-plan replay + injections
# ---------------------------------------------------------------------------
def test_fault_plan_replay_deterministic(setup):
    """Same plan + same queue -> same tokens, same robustness counters,
    same injection event log.  Exhaustion, a straggler stall and masked
    poison all fire."""
    model, params = setup
    reqs = synthetic_trace(8, 2, 16, 16, model.cfg.vocab, flavor="soak")
    plan = ServeFaultPlan(exhaust_at=(6,), exhaust_for=3,
                          slow_at=(3,), slow_s=0.01,
                          poison_at=tuple(range(8, 13)), mask_poison=True)
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=5, fault_plan=plan)
    fin1, st1 = eng.run(reqs)
    ev1 = list(plan.events)
    fin2, st2 = eng.run(reqs)
    assert [f.tokens for f in fin1] == [f.tokens for f in fin2]
    for k in ("rounds", "preemptions", "shed_events", "poisoned_rounds",
              "faults_exhaust", "faults_slow", "deadline_misses"):
        assert st1[k] == st2[k], k
    assert ev1 == list(plan.events)
    assert st1["faults_exhaust"] >= 1
    assert st1["faults_slow"] >= 1
    assert st1["poisoned_rounds"] >= 1
    assert len(fin1) == len(reqs)


def test_poison_fail_fast_without_masking(setup):
    """Unmasked NaN logits must raise, not emit argmax-of-garbage."""
    model, params = setup
    rng = np.random.RandomState(0)
    reqs = [Request(rid=0, tokens=rng.randint(
        0, model.cfg.vocab, size=8).tolist(), max_new=8)]
    plan = ServeFaultPlan(poison_at=tuple(range(0, 40)), mask_poison=False)
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           fault_plan=plan)
    with pytest.raises(PoisonedLogitsError):
        eng.run(reqs)


def test_watchdog_aborts_livelock(setup):
    """shed=False + a never-released exhaustion hold = a loop that can
    never place its request: the watchdog must abort with diagnostics
    instead of spinning forever."""
    model, params = setup
    rng = np.random.RandomState(0)
    reqs = [Request(rid=0, tokens=rng.randint(
        0, model.cfg.vocab, size=8).tolist(), max_new=4)]
    plan = ServeFaultPlan(exhaust_at=(0,), exhaust_for=10**6)
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=4, shed=False, fault_plan=plan,
                           watchdog_patience=10)
    with pytest.raises(EngineStuckError) as ei:
        eng.run(reqs)
    assert ei.value.diag["pool"]["n_free"] == 0
    assert ei.value.diag["pending"]


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_deadline_accounting(setup):
    model, params = setup
    rng = np.random.RandomState(0)
    mk = lambda n: rng.randint(0, model.cfg.vocab, size=n).tolist()
    reqs = [Request(rid=0, tokens=mk(8), max_new=4, deadline=2),
            Request(rid=1, tokens=mk(8), max_new=4, deadline=200),
            Request(rid=2, tokens=mk(8), max_new=4)]
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16)
    fin, stats = eng.run(reqs)
    assert fin[0].deadline_miss and fin[0].deadline == 2
    assert not fin[1].deadline_miss
    assert fin[2].deadline is None and not fin[2].deadline_miss
    assert stats["deadline_total"] == 2
    assert stats["deadline_misses"] == 1
    assert stats["deadline_miss_rate"] == 0.5


# ---------------------------------------------------------------------------
# the overload soak: bursty arrivals + long documents + injected faults
# must drain completely on a constrained pool
# ---------------------------------------------------------------------------
def test_soak_drains_under_faults(setup):
    model, params = setup
    reqs = synthetic_trace(12, 2, 16, 16, model.cfg.vocab, flavor="soak")
    plan = ServeFaultPlan(exhaust_at=(5, 30), exhaust_for=3,
                          slow_at=(3,), slow_s=0.005,
                          poison_at=(7, 8, 9), mask_poison=True)
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=5, preempt="swap", degrade_fmt="fp8",
                           fault_plan=plan)
    fin, stats = eng.run(reqs)
    # zero stuck, zero lost: every request finishes with its FULL budget
    assert len(fin) == len(reqs)
    for r, f in zip(reqs, fin):
        assert f.rid == r.rid and len(f.tokens) == r.max_new
    # the pool was genuinely over-committed: pressure machinery engaged
    assert stats["preemptions"] + stats["shed_events"] > 0
    assert stats["faults_exhaust"] >= 1
    assert stats["deadline_total"] >= 1
    # pages drained back (scratch only) and the trail is well-formed
    assert eng.alloc.n_live == 1
    assert stats["pages_live_end"] == 0
    assert 0.0 <= stats["deadline_miss_rate"] <= 1.0


def test_soak_trace_deterministic_and_mixed():
    a = synthetic_trace(16, 2, 16, 16, 64, flavor="soak")
    b = synthetic_trace(16, 2, 16, 16, 64, flavor="soak")
    assert [(r.tokens, r.arrival, r.priority, r.deadline, r.no_degrade)
            for r in a] == \
           [(r.tokens, r.arrival, r.priority, r.deadline, r.no_degrade)
            for r in b]
    assert {r.priority for r in a} == {0, 1, 2}
    assert any(r.deadline is not None for r in a)
    assert any(r.no_degrade for r in a)
    with pytest.raises(ValueError):
        synthetic_trace(4, 2, 16, 16, 64, flavor="nope")


# ---------------------------------------------------------------------------
# fault primitives (no model)
# ---------------------------------------------------------------------------
def test_serve_fault_plan_primitives():
    plan = ServeFaultPlan(exhaust_at=(3, 5), exhaust_for=2,
                          slow_at=(4,), slow_s=0.5, poison_at=(6, 9))
    # catch-up: a round-clock jump over both listed rounds fires once
    assert plan.take_exhaustion(10) == 2
    assert plan.take_exhaustion(10) is None
    assert plan.take_slow(4) == 0.5
    assert plan.take_slow(4) == 0.0
    assert plan.next_poison(0, 7) == 6
    assert plan.next_poison(7, 20) == 9
    assert plan.next_poison(10, 20) is None
    plan.reset()
    assert plan.take_exhaustion(10) == 2      # reusable after reset


def test_serve_watchdog_and_straggler_monitor():
    wd = ServeWatchdog(patience=3)
    wd.tick(False), wd.tick(False)
    wd.tick(True)                              # progress resets
    wd.tick(False), wd.tick(False)
    with pytest.raises(EngineStuckError):
        wd.tick(False, diag=lambda: {"where": "here"})
    mon = StragglerMonitor(warmup=2)
    flags = [mon.record(i, 0.01) for i in range(5)]
    assert not any(flags)
    assert mon.record(5, 0.5)                  # 50x the EWMA: flagged
    assert mon.flagged and mon.flagged[0][0] == 5
