"""Pruned-grid prefill flash attention: schedule pruning proofs, bit-exact
kernel-vs-oracle parity at block boundaries, dynamic-kv_len no-retrace, and
model-level dense-vs-pallas prefill parity.

Kernel contract: flash_attention_pallas walks ONLY the (iq, ik) block pairs
``block_schedule`` emits (causal future blocks and blocks left of a sliding
window are never visited) and is BIT-EXACT against
ref.flash_attention_ref with the matching ``(bq, bk)`` blocking in
interpret mode.  ``kv_len`` is a dynamic input: distinct lengths share one
compiled kernel, and blocks past the live length ``pl.when``-skip at run
time (observable via ``debug_visits``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.flash_attention import block_schedule, flash_attention_pallas
from repro.models.registry import build_model

F32 = np.float32


def rnd(*shape, seed=0, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(F32)


def _qkv(bh, bkv, sq, skv, d, dv=None, seed=0):
    q = jnp.asarray(rnd(bh, sq, d, seed=seed))
    k = jnp.asarray(rnd(bkv, skv, d, seed=seed + 1))
    v = jnp.asarray(rnd(bkv, skv, dv or d, seed=seed + 2))
    return q, k, v


# ---------------------------------------------------------------------------
# the pruned schedule: provable block-visit savings
# ---------------------------------------------------------------------------
def test_schedule_causal_half_the_dense_grid():
    """Causal sq == skv prefill schedules <= ~55% of the dense grid."""
    sq = skv = 2048
    bq = bk = 128
    qi, ki, ff, lf = block_schedule(sq, skv, bq, bk, causal=True, window=None)
    dense = (sq // bq) * (skv // bk)
    assert len(qi) / dense <= 0.55, (len(qi), dense)
    # exact expectation: query block iq sees key blocks 0..iq
    assert len(qi) == sum(i + 1 for i in range(sq // bq))


def test_schedule_window_constant_blocks_per_query_block():
    """A window <= 2*bk layer visits O(window) key blocks per query block,
    independent of sequence length."""
    bq = bk = 128
    window = 2 * bk
    for skv in (1024, 4096):
        qi, ki, _, _ = block_schedule(skv, skv, bq, bk, causal=True,
                                      window=window)
        per_q = np.bincount(qi)
        # a window of W covers at most W/bk + 1 key blocks (straddle), and
        # causality cannot add blocks — constant in skv
        assert per_q.max() <= window // bk + 1
        assert len(qi) <= (skv // bq) * (window // bk + 1)


def test_schedule_covers_every_query_block_exactly_once():
    for causal, window, off in [(True, None, 0), (True, 64, 128),
                                (False, None, 0), (True, 100, 0)]:
        qi, ki, ff, lf = block_schedule(512, 512, 128, 128, causal=causal,
                                        window=window, q_offset=off)
        assert sorted(set(qi.tolist())) == [0, 1, 2, 3]
        assert int(ff.sum()) == 4 and int(lf.sum()) == 4  # one init/store each
        # within a query block the kv walk is ordered (online softmax)
        for iq in range(4):
            ks = ki[qi == iq]
            assert (np.diff(ks) == 1).all()


def test_kernel_debug_visits_counts_kv_len_early_outs():
    """Blocks scheduled statically but past the dynamic kv_len do no work."""
    q, k, v = _qkv(1, 1, 512, 512, 64, seed=3)
    kw = dict(group=1, bq=128, bk=128, scale=0.125, causal=True,
              src_dtype=jnp.float32, debug_visits=True)
    qi, ki, _, _ = block_schedule(512, 512, 128, 128, causal=True, window=None)
    _, vis = flash_attention_pallas(q, k, v, 130, **kw)
    # per-row instrumentation [BH, n_steps]; one row here — only key
    # blocks 0 and 1 intersect kv_len=130
    want = (np.asarray(ki) * 128 < 130).astype(np.int32)
    assert vis.shape == (1, len(qi))
    np.testing.assert_array_equal(np.asarray(vis)[0], want)
    assert int(vis.sum()) < len(qi)


# ---------------------------------------------------------------------------
# bit-exact parity vs the blocked oracle at block boundaries
# ---------------------------------------------------------------------------
BOUNDARY_CASES = [
    # (bh, bkv, sq, skv, d, dv, causal, window, softcap, kvl, bq, bk)
    (2, 2, 256, 256, 64, 64, True, None, None, None, 128, 128),   # plain causal
    (2, 1, 256, 256, 64, 64, True, 128, None, None, 128, 128),    # window == bk
    (2, 2, 256, 384, 64, 64, True, 100, None, None, 128, 128),    # window straddles
    (2, 2, 256, 256, 64, 64, True, None, 30.0, 200, 128, 128),    # kv_len mid-block
    (4, 2, 256, 256, 64, 64, True, 64, None, 10, 128, 128),       # fully-masked rows
    (2, 2, 128, 512, 64, 32, False, None, None, 77, 128, 128),    # Dv != D, non-causal
    (2, 2, 256, 256, 64, 64, True, 32, 50.0, 129, 128, 128),      # everything at once
]


@pytest.mark.parametrize(
    "bh,bkv,sq,skv,d,dv,causal,window,softcap,kvl,bq,bk", BOUNDARY_CASES)
def test_pruned_kernel_bit_exact_vs_blocked_ref(bh, bkv, sq, skv, d, dv,
                                                causal, window, softcap, kvl,
                                                bq, bk):
    group = bh // bkv
    q, k, v = _qkv(bh, bkv, sq, skv, d, dv, seed=7)
    kw = dict(group=group, scale=d ** -0.5, causal=causal, window=window,
              softcap=softcap, src_dtype=jnp.float32, out_dtype=jnp.float32)
    got = flash_attention_pallas(q, k, v, kvl, bq=bq, bk=bk, **kw)
    want = ref.flash_attention_ref(q, k, v, kv_len=kvl, bq=bq, bk=bk, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fully_masked_query_rows_emit_zero():
    """Rows whose window lies entirely past kv_len see no keys: l == 0 and
    the store guard emits exact zeros (no NaN from 0/0)."""
    q, k, v = _qkv(1, 1, 256, 256, 64, seed=9)
    got = flash_attention_pallas(q, k, v, 10, group=1, bq=128, bk=128,
                                 scale=0.125, causal=True, window=64,
                                 src_dtype=jnp.float32)
    got = np.asarray(got)
    assert np.isfinite(got).all()
    # rows >= 10 + 64 - 1 can reach no key < kv_len under the window mask
    assert (got[:, 80:] == 0.0).all()
    assert (got[:, :10] != 0.0).any()


@pytest.mark.parametrize("fmt", ["fp16", "fp8"])
def test_emulate_mode_operand_snap_bit_exact(fmt):
    """Emulate-mode policies: the in-kernel RNE snap (f32 containers on the
    src grid) matches the oracle's softfloat snap bit-for-bit."""
    q, k, v = _qkv(2, 2, 256, 256, 64, seed=11)
    kw = dict(group=1, scale=0.125, causal=True, src_fmt_name=fmt,
              src_dtype=jnp.float32, out_dtype=jnp.float32)
    got = flash_attention_pallas(q, k, v, bq=128, bk=128, **kw)
    want = ref.flash_attention_ref(q, k, v, bq=128, bk=128, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# dynamic kv_len: one compiled kernel for every prompt length
# ---------------------------------------------------------------------------
def test_dynamic_kv_len_no_retrace():
    q, k, v = _qkv(2, 2, 256, 256, 64, seed=13)
    traces = []

    @jax.jit
    def run(q, k, v, kvl):
        traces.append(None)            # python body runs only while tracing
        return flash_attention_pallas(q, k, v, kvl, group=1, bq=128, bk=128,
                                      scale=0.125, causal=True,
                                      src_dtype=jnp.float32)

    for kvl in (256, 130, 37):
        got = run(q, k, v, jnp.asarray(kvl, jnp.int32))
        want = ref.flash_attention_ref(q, k, v, kv_len=kvl, bq=128, bk=128,
                                       group=1, scale=0.125, causal=True,
                                       src_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert len(traces) == 1, "distinct kv_len values must not retrace"


def test_wrapper_dynamic_kv_len_and_q_offset():
    """ops.flash_attention: traced kv_len passes through; q_offset shifts
    the causal/window masks (prefill at a nonzero cache index)."""
    q = jnp.asarray(rnd(1, 2, 128, 64, seed=15))
    k = jnp.asarray(rnd(1, 2, 256, 64, seed=16))
    v = jnp.asarray(rnd(1, 2, 256, 64, seed=17))
    got = jax.jit(lambda kvl: kops.flash_attention(
        q, k, v, kv_len=kvl, causal=True, window=96, q_offset=128,
        bq=128, bk=128, policy="fp32"))(jnp.asarray(200, jnp.int32))
    want = ref.flash_attention_ref(
        q.reshape(2, 128, 64), k.reshape(2, 256, 64), v.reshape(2, 256, 64),
        group=1, scale=64 ** -0.5, causal=True, window=96, q_offset=128,
        kv_len=200, src_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got.reshape(2, 128, 64)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model-level: prefill logits parity, dense vs pallas backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,policy", [
    ("gemma2-9b", "tp_bf16"),        # window + softcap layers
    ("gemma2-9b", "tp_fp16"),
    ("gemma2-9b", "tp_bf16_kv8"),    # fp8 KV cache policy
    ("minicpm3-4b", "tp_bf16"),      # MLA expanded prefill (Dv != Dqk)
])
def test_model_prefill_logits_parity(arch, policy):
    model = build_model(arch, policy=policy, reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 48), 0, model.cfg.vocab)
    lg_d, _ = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=64))(params, toks)
    mp = model.with_cfg(prefill_backend="pallas")
    lg_p, _ = jax.jit(
        lambda p, t: mp.prefill(p, t, max_len=64))(params, toks)
    # same math, different (pruned, online-softmax) reduction schedule:
    # tolerance comparison on the logits
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                               rtol=5e-2, atol=1e-1)


def test_prefill_backend_auto_resolves_dense_on_cpu():
    assert jax.default_backend() == "cpu"
    assert kops.resolve_backend("auto") == "dense"
    assert kops.resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError):
        kops.resolve_backend("magic")
    # model-level: "auto" on CPU must produce the dense path's exact logits
    model = build_model("gemma2-9b", policy="tp_bf16", reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, model.cfg.vocab)
    lg_d, _ = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=40))(params, toks)
    ma = model.with_cfg(prefill_backend="auto", decode_backend="auto")
    lg_a, _ = jax.jit(
        lambda p, t: ma.prefill(p, t, max_len=40))(params, toks)
    np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_a))
