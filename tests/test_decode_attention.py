"""Fused decode-attention kernel + scan-generation equivalence tests.

Kernel contract: decode_attention_pallas is BIT-EXACT against
ref.decode_attention_ref (matching ``bk`` accumulation schedule) in
interpret mode — across all supported kv_fmt storage grids (bf16 / fp16 /
fp8), GQA group sizes, window/softcap combinations, and partial cache fill
(``kv_len < Smax``).  The ops wrapper must agree with the model's dense
decode path, and scan-based ``Model.generate`` must reproduce the seed
per-step Python loop token-for-token.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.models.registry import build_model

F32 = np.float32


def rnd(*shape, seed=0, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(F32)


def _qkv(bh, g, smax, d, seed=0):
    q = jnp.asarray(rnd(bh, g, d, seed=seed))
    k = jnp.asarray(rnd(bh, smax, d, seed=seed + 1))
    v = jnp.asarray(rnd(bh, smax, d, seed=seed + 2))
    return q, k, v


# ---------------------------------------------------------------------------
# kernel vs oracle: bit-exact across the full feature grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_fmt", [None, "fp16alt", "fp16", "fp8"])
@pytest.mark.parametrize("g,window,softcap,kvl,bk", [
    (1, None, None, 256, 128),     # MQA, full cache
    (2, 64, None, 200, 128),       # GQA + sliding window, partial fill
    (4, None, 30.0, 129, 128),     # softcap, fill just past a block edge
    (2, 32, 50.0, 77, 256),        # window + softcap, single block
    (8, None, None, 1, 128),       # first decode step (one live slot)
])
def test_decode_kernel_bit_exact_vs_ref(kv_fmt, g, window, softcap, kvl, bk):
    bh, smax, d = 4, 256, 64
    q, k, v = _qkv(bh, g, smax, d, seed=3)
    if g < 2:
        # mimic the ops.py sublane padding: an M=1 query strip lowers to a
        # gemv whose accumulation codegen is fusion-context-dependent — the
        # kernel contract is the padded strip the wrapper actually sends
        q = jnp.pad(q, ((0, 0), (0, 8 - g), (0, 0)))
    kw = dict(scale=d ** -0.5, window=window, softcap=softcap,
              kv_fmt_name=kv_fmt, src_dtype=jnp.float32,
              out_dtype=jnp.float32)
    got = decode_attention_pallas(q, k, v, jnp.array([[kvl]], jnp.int32),
                                  bk=bk, **kw)
    want = ref.decode_attention_ref(q, k, v, kv_len=kvl, bk=bk, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the blocked oracle is itself the plain dense path up to f32 summation
    dense = ref.decode_attention_ref(q, k, v, kv_len=kvl, bk=None, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_decode_kernel_q_fmt_snap():
    """Emulate-mode query snap (CONV on the q operand) is bit-exact too."""
    q, k, v = _qkv(2, 2, 128, 64, seed=9)
    kw = dict(scale=0.125, kv_fmt_name="fp8", q_fmt_name="fp16alt",
              src_dtype=jnp.float32)
    got = decode_attention_pallas(q, k, v, jnp.array([[100]], jnp.int32),
                                  bk=128, **kw)
    want = ref.decode_attention_ref(q, k, v, kv_len=100, bk=128, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_kernel_dead_slots_masked():
    """Garbage beyond kv_len must not affect the output (cache slots past
    the live length are uninitialized in serving)."""
    q, k, v = _qkv(2, 4, 256, 64, seed=5)
    kvl = 150
    kw = dict(bk=128, scale=0.125, src_dtype=jnp.float32)
    got = decode_attention_pallas(q, k, v, jnp.array([[kvl]], jnp.int32), **kw)
    k2 = k.at[:, kvl:].set(1e9)
    v2 = v.at[:, kvl:].set(-1e9)
    got2 = decode_attention_pallas(q, k2, v2, jnp.array([[kvl]], jnp.int32),
                                   **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_decode_kernel_dynamic_kv_len_no_retrace():
    """kv_len is a dynamic input: stepping it must not retrace (the scan
    contract), and each step must equal the per-length oracle."""
    q, k, v = _qkv(2, 2, 256, 64, seed=7)
    fn = jax.jit(lambda kvl: decode_attention_pallas(
        q, k, v, kvl, bk=128, scale=0.125, src_dtype=jnp.float32))
    for kvl in (1, 64, 129, 256):
        got = fn(jnp.array([[kvl]], jnp.int32))
        want = ref.decode_attention_ref(q, k, v, kv_len=kvl, bk=128,
                                        scale=0.125, src_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert fn._cache_size() == 1


# ---------------------------------------------------------------------------
# ops wrapper vs the model's dense decode path
# ---------------------------------------------------------------------------
def test_decode_wrapper_matches_dense_model_path():
    from repro.core.policy import PRESETS
    from repro.models.attention import _decode_attend

    b, h, hkv, smax, d = 2, 4, 2, 192, 64
    q = jnp.asarray(rnd(b, h, 1, d, seed=11))
    k = jnp.asarray(rnd(b, hkv, smax, d, seed=12)).astype(jnp.bfloat16)
    v = jnp.asarray(rnd(b, hkv, smax, d, seed=13)).astype(jnp.bfloat16)
    pol = PRESETS["tp_bf16"]
    for window, cap, kvl in [(None, None, 192), (64, 50.0, 100)]:
        got = kops.decode_attention(q, k, v, kv_len=kvl, policy=pol,
                                    window=window, softcap=cap)
        want = _decode_attend(q, k, v, pol, kv_len=kvl, window=window,
                              cap=cap, backend="dense")
        assert got.shape == want.shape == (b, h, 1, d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# scan-based generation vs the seed per-step Python loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_generate_scan_matches_python_loop(backend):
    model = build_model("gemma2-9b", policy="tp_bf16",
                        reduced=True).with_cfg(decode_backend=backend)
    params = model.init(jax.random.key(0))
    B, P, G = 2, 16, 6
    max_len = P + G
    toks = jax.random.randint(jax.random.key(1), (B, P), 0, model.cfg.vocab)

    lg, caches = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=max_len))(params, toks)
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    loop_toks, loop_lgs = [tok], [lg]
    for i in range(G - 1):
        lg, caches = step(params, tok, caches, P + i)
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        loop_toks.append(tok)
        loop_lgs.append(lg)
    loop_toks = np.concatenate([np.asarray(t) for t in loop_toks], axis=1)
    loop_lgs = np.concatenate([np.asarray(l) for l in loop_lgs], axis=1)

    gen, lgs = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=G, max_len=max_len, return_logits=True))(params, toks)
    np.testing.assert_array_equal(loop_toks, np.asarray(gen))
    np.testing.assert_allclose(loop_lgs, np.asarray(lgs),
                               rtol=2e-4, atol=2e-4)


def test_generate_single_token():
    model = build_model("gemma2-9b", policy="tp_bf16", reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, model.cfg.vocab)
    gen, lgs = model.generate(params, toks, gen_len=1, return_logits=True)
    assert gen.shape == (2, 1)
    assert lgs.shape == (2, 1, model.vocab_out)


# ---------------------------------------------------------------------------
# sampling in generate(): PRNG key through the scan carry
# ---------------------------------------------------------------------------
def test_sample_token_greedy_and_truncations():
    from repro.models.transformer import sample_token

    lg = jnp.asarray(rnd(4, 64, seed=21, scale=3.0))
    key = jax.random.key(0)
    # temperature<=0: exact argmax, key ignored
    np.testing.assert_array_equal(
        np.asarray(sample_token(lg, key, temperature=0.0)),
        np.asarray(jnp.argmax(lg, -1).astype(jnp.int32)))
    # top_k=1 collapses the distribution onto the argmax
    np.testing.assert_array_equal(
        np.asarray(sample_token(lg, key, temperature=1.0, top_k=1)),
        np.asarray(jnp.argmax(lg, -1).astype(jnp.int32)))
    # tiny top_p keeps only the head of the distribution
    np.testing.assert_array_equal(
        np.asarray(sample_token(lg, key, temperature=1.0, top_p=1e-6)),
        np.asarray(jnp.argmax(lg, -1).astype(jnp.int32)))
    # top_k truncation: samples always land in the top-k set
    for seed in range(5):
        s = sample_token(lg, jax.random.key(seed), temperature=2.0, top_k=4)
        topk = jax.lax.top_k(lg, 4)[1]
        assert all(int(s[i]) in np.asarray(topk[i]) for i in range(4))


def test_generate_sampling_deterministic_and_greedy_default():
    model = build_model("gemma2-9b", policy="tp_bf16", reduced=True)
    params = model.init(jax.random.key(0))
    B, P, G = 2, 12, 6
    toks = jax.random.randint(jax.random.key(1), (B, P), 0, model.cfg.vocab)

    # greedy default is bit-identical to an explicit temperature=0 call
    g0, _ = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=G))(params, toks)
    g1, _ = jax.jit(lambda p, t: model.generate(
        p, t, gen_len=G, temperature=0.0, key=jax.random.key(5)))(params, toks)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))

    # sampling: deterministic given the key, in-vocab (pad never sampled)
    fn = jax.jit(lambda p, t, k: model.generate(
        p, t, gen_len=G, temperature=0.9, top_k=50, top_p=0.95, key=k)[0])
    s1 = fn(params, toks, jax.random.key(7))
    s2 = fn(params, toks, jax.random.key(7))
    s3 = fn(params, toks, jax.random.key(8))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert s1.shape == (B, G)
    assert bool(jnp.all((s1 >= 0) & (s1 < model.cfg.vocab)))
    assert not np.array_equal(np.asarray(s1), np.asarray(s3))  # key matters
