"""core.ops.cast_and_pack general-axis interleave (paper §III.A.2c).

The seed silently ignored ``axis`` for anything but -1 (returning the
un-flattened stack); the contract is now: interleave along ``axis`` with
``out.shape[axis] == 2 * in.shape[axis]`` for ANY axis.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops as tp


def _ab(shape, seed=0):
    rs = np.random.RandomState(seed)
    return (jnp.asarray(rs.randn(*shape).astype(np.float32)),
            jnp.asarray(rs.randn(*shape).astype(np.float32)))


@pytest.mark.parametrize("axis", [-1, 0, 1, -2])
def test_interleave_any_axis(axis):
    a, b = _ab((4, 6))
    out = tp.cast_and_pack(a, b, "fp16alt", axis=axis)
    ax = axis % 2
    want_shape = [4, 6]
    want_shape[ax] *= 2
    assert out.shape == tuple(want_shape)
    qa = np.asarray(tp.tp_cast(a, "fp16alt"), np.float32)
    qb = np.asarray(tp.tp_cast(b, "fp16alt"), np.float32)
    got = np.asarray(out, np.float32)
    even = np.take(got, np.arange(0, want_shape[ax], 2), axis=ax)
    odd = np.take(got, np.arange(1, want_shape[ax], 2), axis=ax)
    np.testing.assert_array_equal(even, qa)
    np.testing.assert_array_equal(odd, qb)


def test_axis_minus_one_matches_seed_behavior():
    """The axis=-1 fast path keeps its original semantics."""
    a, b = _ab((3, 5), seed=1)
    out = tp.cast_and_pack(a, b, "fp8", axis=-1)
    qa = np.asarray(tp.tp_cast(a, "fp8"), np.float32)
    qb = np.asarray(tp.tp_cast(b, "fp8"), np.float32)
    want = np.stack([qa, qb], axis=-1).reshape(3, 10)
    np.testing.assert_array_equal(np.asarray(out, np.float32), want)


def test_3d_middle_axis():
    a, b = _ab((2, 3, 4), seed=2)
    out = tp.cast_and_pack(a, b, "fp16", axis=1)
    assert out.shape == (2, 6, 4)
    np.testing.assert_array_equal(np.asarray(out[:, 0::2]),
                                  np.asarray(tp.tp_cast(a, "fp16")))
    np.testing.assert_array_equal(np.asarray(out[:, 1::2]),
                                  np.asarray(tp.tp_cast(b, "fp16")))
