"""Pallas kernel sweeps: every kernel vs its ref.py pure-jnp oracle, across
shapes and dtypes, in interpret mode (CPU).  Paper-level contract: identical
format semantics between kernel and oracle (operand format, f32 accumulation,
store format).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import get_format
from repro.core.policy import PRESETS
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.dotp_ex import dotp_ex_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.tp_matmul import tp_matmul_pallas
from repro.kernels.tp_quant import cast_and_pack_pallas, tp_quantize_pallas

F32 = np.float32


def rnd(*shape, seed=0, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(F32)


# ---------------------------------------------------------------------------
# tp_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n,block", [
    (128, 128, 128, (128, 128, 128)),
    (256, 512, 128, (128, 256, 128)),
    (128, 384, 256, (64, 128, 128)),
])
@pytest.mark.parametrize("in_dtype,out_dtype", [
    (jnp.float32, jnp.float32),
    (jnp.bfloat16, jnp.bfloat16),
    (jnp.bfloat16, jnp.float32),
    (jnp.float16, jnp.float32),
])
def test_tp_matmul_dtypes(m, k, n, block, in_dtype, out_dtype):
    a = jnp.asarray(rnd(m, k, seed=1), in_dtype)
    b = jnp.asarray(rnd(k, n, seed=2), in_dtype)
    got = tp_matmul_pallas(a, b, block=block, out_dtype=out_dtype)
    want = ref.tp_matmul_ref(a, b, out_dtype=out_dtype, bk=block[1])
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(got, F32), np.asarray(want, F32))


@pytest.mark.parametrize("quant_fmt", ["fp16", "fp16alt", "fp8", "fp8_e4m3"])
def test_tp_matmul_fused_quantization(quant_fmt):
    """Fused CONV->ADDMUL operand snap inside the kernel == oracle snap."""
    a = jnp.asarray(rnd(128, 256, seed=3))
    b = jnp.asarray(rnd(256, 128, seed=4))
    got = tp_matmul_pallas(a, b, block=(128, 128, 128),
                           quant_fmt_name=quant_fmt)
    want = ref.tp_matmul_ref(a, b, quant_fmt_name=quant_fmt, bk=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tp_matmul_wrapper_pads_and_batches():
    a = jnp.asarray(rnd(2, 50, 100, seed=5))
    b = jnp.asarray(rnd(100, 70, seed=6))
    got = kops.tp_matmul(a, b, policy=PRESETS["em_fp16"])
    qa = np.asarray(jax.vmap(lambda x: x)(a))
    want = np.stack([
        np.asarray(ref.tp_matmul_ref(a[i], b, quant_fmt_name="fp16",
                                     out_dtype=jnp.float32))
        for i in range(2)])
    assert got.shape == (2, 50, 70)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tp_quantize / cast_and_pack
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", ["fp16", "fp16alt", "fp8", "fp8_e4m3", "tf32"])
@pytest.mark.parametrize("rows,cols", [(256, 128), (512, 256)])
def test_tp_quantize_vs_ref(fmt, rows, cols):
    x = jnp.asarray(rnd(rows, cols, seed=7, scale=100.0))
    got = tp_quantize_pallas(x, fmt_name=fmt)
    want = ref.tp_quantize_ref(x, fmt_name=fmt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tp_quantize_stochastic_statistics():
    fmt = get_format("fp8")
    x = jnp.full((256, 128), 1.0 + 0.25 * fmt.eps, jnp.float32)
    rbits = jax.random.bits(jax.random.key(0), x.shape, jnp.uint32)
    got = np.asarray(tp_quantize_pallas(x, rbits, fmt_name="fp8",
                                        stochastic=True))
    lo, hi = 1.0, 1.0 + fmt.eps
    assert set(np.unique(got)) <= {F32(lo), F32(hi)}
    frac_hi = (got == F32(hi)).mean()
    assert 0.15 < frac_hi < 0.35  # E = 0.25


def test_cast_and_pack_vs_ref():
    a = jnp.asarray(rnd(256, 128, seed=8))
    b = jnp.asarray(rnd(256, 128, seed=9))
    got = cast_and_pack_pallas(a, b, fmt_name="fp8")
    want = ref.cast_and_pack_ref(a, b, fmt_name="fp8")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_wrapper_unpadded():
    x = jnp.asarray(rnd(100, 60, seed=10))
    got = kops.tp_quantize(x, fmt="fp16alt")
    want = ref.tp_quantize_ref(x, fmt_name="fp16alt")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (bh, bkv, sq, skv, d, causal, window, softcap)
    (2, 2, 256, 256, 64, True, None, None),     # dense causal
    (4, 2, 256, 256, 64, True, None, None),     # GQA group=2
    (2, 2, 128, 384, 64, False, None, None),    # cross-attention-like
    (2, 2, 256, 256, 64, True, 128, None),      # sliding window
    (2, 2, 256, 256, 64, True, None, 50.0),     # gemma softcap
    (8, 2, 128, 512, 128, True, 256, 30.0),     # everything at once
]


@pytest.mark.parametrize("bh,bkv,sq,skv,d,causal,window,softcap", ATTN_CASES)
def test_flash_attention_vs_ref(bh, bkv, sq, skv, d, causal, window, softcap):
    group = bh // bkv
    q = jnp.asarray(rnd(bh, sq, d, seed=11))
    k = jnp.asarray(rnd(bkv, skv, d, seed=12))
    v = jnp.asarray(rnd(bkv, skv, d, seed=13))
    scale = d ** -0.5
    kw = dict(group=group, scale=scale, causal=causal, window=window,
              softcap=softcap, src_dtype=jnp.bfloat16, out_dtype=jnp.float32)
    got = flash_attention_pallas(q, k, v, bq=128, bk=128, **kw)
    want = ref.flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_flash_attention_kv_len_masking():
    """Padding keys beyond kv_len must not affect the output."""
    q = jnp.asarray(rnd(2, 128, 64, seed=14))
    k = jnp.asarray(rnd(2, 256, 64, seed=15))
    v = jnp.asarray(rnd(2, 256, 64, seed=16))
    kv_len = 200
    got = flash_attention_pallas(q, k, v, group=1, scale=0.125, causal=False,
                                 kv_len=kv_len)
    k2 = k.at[:, kv_len:].set(1e9)
    got2 = flash_attention_pallas(q, k2, v, group=1, scale=0.125, causal=False,
                                  kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2), atol=1e-6)


def test_flash_attention_wrapper_4d():
    q = jnp.asarray(rnd(2, 4, 200, 64, seed=17))
    k = jnp.asarray(rnd(2, 2, 200, 64, seed=18))
    v = jnp.asarray(rnd(2, 2, 200, 64, seed=19))
    got = kops.flash_attention(q, k, v, causal=True)
    assert got.shape == (2, 4, 200, 64)
    want = ref.flash_attention_ref(
        q.reshape(8, 200, 64), k.reshape(4, 200, 64), v.reshape(4, 200, 64),
        group=2, scale=64 ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.reshape(2, 4, 200, 64)),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# dotp_ex — the paper's case-study kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1024, 4096, 5000])
@pytest.mark.parametrize("src_dtype", [jnp.float16, jnp.bfloat16])
def test_dotp_ex_vs_parallel_oracle(n, src_dtype):
    a = jnp.asarray(rnd(n, seed=20, scale=0.5))
    b = jnp.asarray(rnd(n, seed=21, scale=0.5))
    pol = PRESETS["tp_fp16" if src_dtype == jnp.float16 else "tp_bf16"]
    got = float(kops.dotp_ex(a, b, policy=pol))
    want = float(ref.dotp_ex_ref(a, b, src_dtype=src_dtype))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_dotp_ex_close_to_sequential_paper_semantics():
    """Parallel-tiled accumulation vs the paper's sequential fmacex loop:
    reassociation error must stay at the fp32-rounding scale."""
    n = 2048
    a = jnp.asarray(rnd(n, seed=22, scale=0.3) + 1.0)
    b = jnp.asarray(rnd(n, seed=23, scale=0.3) + 1.0)
    got = float(kops.dotp_ex(a, b, policy=PRESETS["tp_fp16"]))
    seq = float(ref.dotp_sequential_ref(np.asarray(a), np.asarray(b),
                                        src_fmt="fp16", acc_fmt="fp32"))
    np.testing.assert_allclose(got, seq, rtol=1e-5)
