"""Property suites for the sampling ops (``sample_token``,
``apply_penalties``, ``token_counts``).

Two tiers: deterministic seeded sweeps that ALWAYS run (wide random
logits x many PRNG keys, exhaustive over the property), and
``hypothesis`` variants that fuzz the same invariants with minimized
counterexamples when the library is present (it is not baked into the
container, so those gate on import).

Invariants:
  * temperature <= 0 is exact argmax and ignores the key entirely;
  * top-k never emits a token outside the k highest logits, and
    ``top_k=1`` degenerates to greedy even at high temperature;
  * top-p only emits tokens from the nucleus — the smallest sorted
    prefix whose mass reaches ``top_p`` — and that set's probability
    mass is always >= min(top_p, 1);
  * near-zero temperature converges to greedy on gapped logits;
  * penalties key off presence, commute with each other, leave unseen
    tokens untouched, and are identity at neutral knobs;
  * the count histogram is permutation-invariant and the incremental
    carry (``_bump_counts``) agrees with a from-scratch recount.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (_bump_counts, apply_penalties,
                                      sample_token, token_counts)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False

V = 64


def _logits(seed, b=8, v=V, scale=4.0):
    return scale * jax.random.normal(jax.random.key(seed), (b, v))


# ---------------------------------------------------------------------------
# deterministic sweeps — always run
# ---------------------------------------------------------------------------
def test_greedy_is_argmax_and_key_free():
    lg = _logits(0)
    want = np.asarray(jnp.argmax(lg, -1), np.int32)
    for seed in range(5):
        got = sample_token(lg, jax.random.key(seed), temperature=0.0)
        np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(
        np.asarray(sample_token(lg, jax.random.key(9), temperature=-1.0)),
        want)


@pytest.mark.parametrize("k", [1, 4, 13])
def test_top_k_never_escapes_the_k_set(k):
    for seed in range(4):
        lg = _logits(seed)
        topk = np.asarray(jax.lax.top_k(lg, k)[1])
        for draw in range(8):
            tok = np.asarray(sample_token(
                lg, jax.random.key(100 * seed + draw),
                temperature=1.5, top_k=k))
            for r in range(tok.shape[0]):
                assert tok[r] in topk[r], (k, seed, draw, r)


def test_top_k_one_is_greedy_at_any_temperature():
    lg = _logits(1)
    want = np.asarray(jnp.argmax(lg, -1), np.int32)
    for t in (0.5, 1.0, 5.0):
        got = sample_token(lg, jax.random.key(2), temperature=t, top_k=1)
        np.testing.assert_array_equal(np.asarray(got), want)


def _nucleus(lg, top_p, temperature):
    """Reference nucleus per row: smallest sorted prefix whose exclusive
    mass is < top_p (first always kept)."""
    lg = np.asarray(lg, np.float64) / temperature
    out = []
    for row in lg:
        order = np.argsort(-row)
        p = np.exp(row[order] - row[order].max())
        p /= p.sum()
        excl = np.cumsum(p) - p
        out.append(set(order[excl < top_p].tolist()))
    return out


@pytest.mark.parametrize("p", [0.1, 0.5, 0.9])
def test_top_p_stays_in_nucleus_with_mass_bound(p):
    for seed in range(4):
        lg = _logits(seed)
        nuc = _nucleus(lg, p, 1.0)
        prob = np.asarray(jax.nn.softmax(lg, -1), np.float64)
        for r, keep in enumerate(nuc):
            assert len(keep) >= 1
            assert prob[r, sorted(keep)].sum() >= min(p, 1.0) - 1e-6
        for draw in range(8):
            tok = np.asarray(sample_token(
                lg, jax.random.key(7 * seed + draw),
                temperature=1.0, top_p=p))
            for r in range(tok.shape[0]):
                assert int(tok[r]) in nuc[r], (p, seed, draw, r)


def test_tiny_temperature_converges_to_greedy():
    lg = _logits(3, scale=8.0)
    want = np.asarray(jnp.argmax(lg, -1), np.int32)
    for seed in range(6):
        got = sample_token(lg, jax.random.key(seed), temperature=1e-2)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_penalties_neutral_knobs_are_identity():
    lg, cnt = _logits(4), token_counts(
        jax.random.randint(jax.random.key(5), (8, 16), 0, V), V)
    out = apply_penalties(lg, cnt, repetition_penalty=1.0,
                          presence_penalty=0.0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(lg, np.float32))


def test_penalties_touch_only_seen_tokens():
    """The combined call IS repetition-then-presence (the documented
    order), both orders leave unseen tokens bit-untouched, and
    discouraging knobs (>1 rep, >0 pres) never raise a seen logit."""
    lg = _logits(5)
    cnt = token_counts(jax.random.randint(
        jax.random.key(6), (8, 16), 0, V), V)
    seen = np.asarray(cnt) > 0
    both = np.asarray(apply_penalties(
        lg, cnt, repetition_penalty=1.3, presence_penalty=0.7))
    rep_then_pres = np.asarray(apply_penalties(
        apply_penalties(lg, cnt, repetition_penalty=1.3),
        cnt, presence_penalty=0.7))
    pres_then_rep = np.asarray(apply_penalties(
        apply_penalties(lg, cnt, presence_penalty=0.7),
        cnt, repetition_penalty=1.3))
    np.testing.assert_array_equal(both, rep_then_pres)
    lgf = np.asarray(lg, np.float32)
    np.testing.assert_array_equal(both[~seen], lgf[~seen])
    np.testing.assert_array_equal(pres_then_rep[~seen], lgf[~seen])
    assert np.all(both[seen] <= lgf[seen] + 1e-6)  # >1 rep, >0 pres: down


def test_histogram_is_permutation_invariant_and_carry_matches():
    toks = jax.random.randint(jax.random.key(8), (4, 24), 0, V)
    perm = toks[:, jax.random.permutation(jax.random.key(9), 24)]
    np.testing.assert_array_equal(np.asarray(token_counts(toks, V)),
                                  np.asarray(token_counts(perm, V)))
    # incremental carry over a generated suffix == from-scratch recount
    lens = jnp.asarray([10, 24, 17, 3], jnp.int32)
    cnt = token_counts(toks, V, prompt_lens=lens)
    emitted = jax.random.randint(jax.random.key(10), (4, 5), 0, V)
    for i in range(5):
        cnt = _bump_counts(cnt, emitted[:, i:i + 1])
    full = np.asarray(token_counts(toks, V, prompt_lens=lens)) + \
        np.asarray(token_counts(emitted, V))
    np.testing.assert_array_equal(np.asarray(cnt), full)


def test_histogram_masks_pad_tail():
    toks = jnp.full((2, 8), 3, jnp.int32)
    cnt = np.asarray(token_counts(toks, V, prompt_lens=jnp.asarray([8, 2])))
    assert cnt[0, 3] == 8 and cnt[1, 3] == 2
    assert cnt.sum() == 10


# ---------------------------------------------------------------------------
# hypothesis variants — run only when the library is installed
# ---------------------------------------------------------------------------
if HAVE_HYP:
    _row = st.lists(st.floats(-20.0, 20.0, allow_nan=False, width=32),
                    min_size=V, max_size=V)

    @settings(max_examples=25, deadline=None)
    @given(row=_row, k=st.integers(1, V), seed=st.integers(0, 2**31 - 1))
    def test_hyp_top_k_membership(row, k, seed):
        lg = jnp.asarray([row], jnp.float32)
        tok = int(sample_token(lg, jax.random.key(seed),
                               temperature=1.0, top_k=k)[0])
        kth = float(np.sort(np.asarray(lg[0], np.float32))[-k])
        assert float(lg[0, tok]) >= kth

    @settings(max_examples=25, deadline=None)
    @given(row=_row, p=st.floats(0.01, 0.99), seed=st.integers(0, 2**31 - 1))
    def test_hyp_top_p_membership(row, p, seed):
        lg = jnp.asarray([row], jnp.float32)
        tok = int(sample_token(lg, jax.random.key(seed),
                               temperature=1.0, top_p=p)[0])
        assert tok in _nucleus(lg, p, 1.0)[0]

    @settings(max_examples=25, deadline=None)
    @given(row=_row, rp=st.floats(1.0, 3.0), pp=st.floats(0.0, 2.0))
    def test_hyp_penalties_order_independent(row, rp, pp):
        lg = jnp.asarray([row], jnp.float32)
        cnt = token_counts(jnp.asarray([[1, 5, 5, 9]], jnp.int32), V)
        both = np.asarray(apply_penalties(
            lg, cnt, repetition_penalty=rp, presence_penalty=pp))
        seq = np.asarray(apply_penalties(
            apply_penalties(lg, cnt, repetition_penalty=rp),
            cnt, presence_penalty=pp))
        np.testing.assert_array_equal(both, seq)
        seen = np.asarray(cnt)[0] > 0
        np.testing.assert_array_equal(both[0, ~seen],
                                      np.asarray(lg, np.float32)[0, ~seen])
