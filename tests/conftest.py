"""Shared fixtures for the tier-1 suite.

``cached_model`` is the session-scoped (arch, policy, cfg) -> (model,
params) table: the engine/paged/sharded/speculative suites all serve the
same reduced archs, and re-running ``build_model(...).init(...)`` per
module was pure wall-clock waste.  Params are treated as IMMUTABLE by
every consumer — engines ``device_put`` their own copies for tensor
parallelism and only donate *cache* operands, ``generate``/``*_burst``
never alias params — so sharing one pytree across tests is safe.

Import the helpers directly (``from conftest import cached_model``):
pytest puts this directory on ``sys.path`` (no ``__init__.py``), and a
plain function composes with each suite's existing ``_setup(**cfg)``
idiom better than a fixture-only surface would.  The fixture wrappers
exist for suites that prefer declarative injection.
"""
import jax
import jax.numpy as jnp
import pytest

#: the house ragged-batch lengths (rows at 1/4, 5/8, full prompt width)
LENS = [8, 20, 32]

_MODELS = {}


def cached_model(arch="gemma2-9b", policy="tp_bf16", **cfg):
    """Session-cached ``(model, params)`` for a reduced arch, with any
    ``with_cfg`` overrides folded into the cache key.  Weights always
    come from ``jax.random.key(0)`` — the seed every suite already
    used — so hoisting changes no test's numbers."""
    key = (arch, policy, tuple(sorted(cfg.items())))
    if key not in _MODELS:
        from repro.models.registry import build_model
        model = build_model(arch, policy=policy, reduced=True)
        if cfg:
            model = model.with_cfg(**cfg)
        _MODELS[key] = (model, model.init(jax.random.key(0)))
    return _MODELS[key]


def small_batch(vocab, n=3, width=32):
    """The house prompt pack: ``[n, width]`` tokens from key(1) plus the
    ragged ``LENS`` lengths (cycled when ``n != 3``)."""
    toks = jax.random.randint(jax.random.key(1), (n, width), 0, vocab)
    lens = [LENS[i % len(LENS)] for i in range(n)]
    return toks, jnp.asarray(lens, jnp.int32)


@pytest.fixture(scope="session")
def model_factory():
    """Fixture flavor of ``cached_model`` for declarative injection."""
    return cached_model


@pytest.fixture(scope="session")
def engine_model():
    """The continuous-engine house model: reduced gemma2 over a paged
    16-token-page pool (what every engine suite builds first)."""
    return cached_model("gemma2-9b", paged_kv=True, page_size=16)
