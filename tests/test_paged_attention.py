"""Paged KV cache: block-table indirection, end to end.

Contract under test (the memory-side analogue of the ragged-batch PR):

  * kernels — ``decode_attention_pallas`` / ``flash_attention_pallas``
    accept a page pool + per-row block table and are BIT-EXACT against the
    paged oracles in ref.py (gather + blocked walk) across KV storage
    grids, scrambled tables, and partial tail pages; only the BlockSpec
    index maps changed, so paged output equals the contiguous kernel on
    the same values.
  * no-retrace — differing block tables share one compiled kernel (tables
    are traced, like the per-row ``kv_lens``).
  * allocator — refcounted free-list: alloc/free, reuse-after-free (LIFO),
    shared pages survive until their last reference dies, exhaustion
    raises.
  * model — paged prefill/generate (identity table) is bit-identical to
    the contiguous cache on the dense path and matches the fused-kernel
    path per row; prefix-sharing tables (rows aliasing common-prompt
    pages) produce logits identical to the unshared layout; non-attention
    mixers refuse.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.paged import (PageAllocator, PagedKVCache, build_tables,
                                gather_paged_kv, identity_block_table,
                                init_paged_kv_cache, num_pages,
                                paged_update_rows)
from repro.models.registry import build_model

F32 = np.float32


def rnd(*shape, seed=0, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(F32)


def _scatter_pages(x, table, page):
    """Host-side truth: spread contiguous [rows, S, D] rows into a pool
    [n_pages, page, D] laid out by ``table`` [rows, nk]."""
    rows, s, d = x.shape
    nk = table.shape[1]
    assert s == nk * page, (x.shape, table.shape, page)
    pool = np.zeros((int(table.max()) + 1, page, d), F32)
    for h in range(rows):
        for j in range(nk):
            pool[table[h, j]] = x[h, j * page:(j + 1) * page]
    return jnp.asarray(pool)


def _scrambled_table(rows, nk, n_pages, seed=0):
    perm = np.random.RandomState(seed).permutation(n_pages)[:rows * nk]
    return perm.reshape(rows, nk).astype(np.int32)


# ---------------------------------------------------------------------------
# kernel-level: bit-exactness vs the paged oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", [None, "fp16alt", "fp16", "fp8"])
def test_paged_decode_bit_exact_vs_paged_oracle(fmt):
    """Scrambled physical pages, per-row lengths with partial tail pages,
    every supported KV storage grid: kernel == paged oracle, bitwise."""
    lens = [1, 77, 129, 256]           # 77 and 129: partial tail pages
    page = 128
    q = jnp.asarray(rnd(4, 8, 64, seed=5))
    k = rnd(4, 256, 64, seed=6)
    v = rnd(4, 256, 64, seed=7)
    bt = _scrambled_table(4, 256 // page, 16, seed=1)
    kp, vp = _scatter_pages(k, bt, page), _scatter_pages(v, bt, page)
    kvl = jnp.asarray(lens, jnp.int32)
    kw = dict(scale=0.125, kv_fmt_name=fmt, src_dtype=jnp.float32,
              out_dtype=jnp.float32)
    got = decode_attention_pallas(q, kp, vp, kvl, jnp.asarray(bt),
                                  bk=page, **kw)
    want = ref.decode_attention_paged_ref(q, kp, vp, bt,
                                          kv_len=np.asarray(lens), **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # ... and equals the contiguous kernel on the same values (the paged
    # kernel changed only the index maps, never the math)
    base = decode_attention_pallas(q, jnp.asarray(k), jnp.asarray(v), kvl,
                                   bk=page, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


@pytest.mark.parametrize("fmt", [None, "fp16", "fp8"])
def test_paged_flash_bit_exact_vs_paged_oracle(fmt):
    """Paged prefill reads (continued prefill against a paged cache):
    kernel == gather + blocked oracle, bitwise, with GQA head mapping."""
    lens = [100, 256]
    group, page = 2, 128
    q = jnp.asarray(rnd(4, 256, 64, seed=3))
    k = rnd(2, 256, 64, seed=4)
    v = rnd(2, 256, 64, seed=5)
    bt = _scrambled_table(2, 256 // page, 8, seed=2)
    kp, vp = _scatter_pages(k, bt, page), _scatter_pages(v, bt, page)
    kvl = jnp.asarray(np.repeat(lens, group), jnp.int32)
    kw = dict(group=group, scale=0.125, causal=True, src_fmt_name=fmt,
              src_dtype=jnp.float32, out_dtype=jnp.float32)
    got = flash_attention_pallas(q, kp, vp, kvl, jnp.asarray(bt),
                                 bq=128, bk=page, **kw)
    want = ref.flash_attention_paged_ref(q, kp, vp, bt, bq=128,
                                         kv_len=np.repeat(lens, group), **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    base = flash_attention_pallas(q, jnp.asarray(k), jnp.asarray(v), kvl,
                                  bq=128, bk=page, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_paged_decode_prefix_sharing_aliases_pages():
    """Two rows whose tables alias the SAME first page (a shared prompt
    prefix stored once in the pool) produce per-row outputs identical to
    the unshared layout with that page duplicated."""
    page = 128
    q = jnp.asarray(rnd(2, 8, 64, seed=11))
    k = rnd(2, 256, 64, seed=12)
    v = rnd(2, 256, 64, seed=13)
    k[1, :page] = k[0, :page]          # common prefix in the values
    v[1, :page] = v[0, :page]
    bt_unshared = identity_block_table(2, 2)              # [[0,1],[2,3]]
    bt_shared = np.asarray([[0, 1], [0, 3]], np.int32)    # page 0 aliased
    kpu, vpu = _scatter_pages(k, bt_unshared, page), \
        _scatter_pages(v, bt_unshared, page)
    kps, vps = _scatter_pages(k, bt_shared, page), \
        _scatter_pages(v, bt_shared, page)
    kvl = jnp.asarray([200, 256], jnp.int32)
    kw = dict(bk=page, scale=0.125, src_dtype=jnp.float32)
    out_u = decode_attention_pallas(q, kpu, vpu, kvl,
                                    jnp.asarray(bt_unshared), **kw)
    out_s = decode_attention_pallas(q, kps, vps, kvl,
                                    jnp.asarray(bt_shared), **kw)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_u))


def test_paged_decode_dead_pages_ignore_garbage():
    """Pool pages not reachable through any table entry below kv_len must
    not affect any row (freed pages hold stale garbage by design)."""
    page = 128
    lens = [130, 256]
    q = jnp.asarray(rnd(2, 8, 64, seed=15))
    k = rnd(2, 256, 64, seed=16)
    v = rnd(2, 256, 64, seed=17)
    bt = _scrambled_table(2, 2, 8, seed=3)
    kp, vp = _scatter_pages(k, bt, page), _scatter_pages(v, bt, page)
    kvl = jnp.asarray(lens, jnp.int32)
    kw = dict(bk=page, scale=0.125, src_dtype=jnp.float32)
    got = decode_attention_pallas(q, kp, vp, kvl, jnp.asarray(bt), **kw)
    # poison every page NOT referenced by the tables + the tail of row 0's
    # partial last page (tokens at k_idx >= 130 are masked by kv_len)
    live = set(bt.reshape(-1).tolist())
    dead = [i for i in range(8) if i not in live]
    kp2 = kp.at[jnp.asarray(dead)].set(1e9)
    vp2 = vp.at[jnp.asarray(dead)].set(-1e9)
    kp2 = kp2.at[bt[0, 1], (lens[0] % page):].set(1e9)
    vp2 = vp2.at[bt[0, 1], (lens[0] % page):].set(-1e9)
    got2 = decode_attention_pallas(q, kp2, vp2, kvl, jnp.asarray(bt), **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_paged_no_retrace_across_tables():
    """Differing block tables (page churn, re-sharing) must share one
    compiled kernel — tables are traced values like the length vectors."""
    page = 128
    q = jnp.asarray(rnd(2, 8, 64, seed=19))
    k = rnd(2, 256, 64, seed=20)
    v = rnd(2, 256, 64, seed=21)
    kvl = jnp.asarray([256, 256], jnp.int32)

    fn = jax.jit(lambda kp, vp, bt: decode_attention_pallas(
        q, kp, vp, kvl, bt, bk=page, scale=0.125, src_dtype=jnp.float32))
    for seed in (1, 2, 3):
        bt = _scrambled_table(2, 2, 8, seed=seed)
        kp, vp = _scatter_pages(k, bt, page), _scatter_pages(v, bt, page)
        # pad pools to a fixed page count so shapes match across tables
        kp = jnp.pad(kp, ((0, 8 - kp.shape[0]), (0, 0), (0, 0)))
        vp = jnp.pad(vp, ((0, 8 - vp.shape[0]), (0, 0), (0, 0)))
        got = fn(kp, vp, jnp.asarray(bt))
        want = ref.decode_attention_paged_ref(
            q, kp, vp, bt, kv_len=np.asarray([256, 256]), scale=0.125,
            src_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert fn._cache_size() == 1, "block tables must not retrace"


def test_ops_wrappers_expand_block_tables():
    """kops.decode_attention takes the MODEL layout — [n_pages, Hkv, page,
    D] pools + a per-SEQUENCE [B, max_pages] table — and matches the
    contiguous wrapper on the gathered values (same page-size blocking)."""
    b, h, hkv, smax, d, page = 2, 4, 2, 256, 64, 128
    lens = np.asarray([130, 256])
    qd = jnp.asarray(rnd(b, h, 1, d, seed=24))
    k = jnp.asarray(rnd(b, hkv, smax, d, seed=22))
    v = jnp.asarray(rnd(b, hkv, smax, d, seed=23))
    mp = smax // page
    table = jnp.asarray(_scrambled_table(b, mp, b * mp, seed=5))
    pool_shape = (b * mp, hkv, page, d)
    kp = jnp.zeros(pool_shape, jnp.float32)
    vp = jnp.zeros(pool_shape, jnp.float32)
    for row in range(b):
        for j in range(mp):
            kp = kp.at[table[row, j], :, :, :].set(
                k[row, :, j * page:(j + 1) * page])
            vp = vp.at[table[row, j], :, :, :].set(
                v[row, :, j * page:(j + 1) * page])
    # the gather helper reconstructs the contiguous layout exactly
    np.testing.assert_array_equal(np.asarray(gather_paged_kv(kp, table)),
                                  np.asarray(k))
    got = kops.decode_attention(qd, kp, vp, block_table=table,
                                kv_len=jnp.asarray(lens, jnp.int32),
                                policy="fp32")
    want = kops.decode_attention(qd, k, v, kv_len=jnp.asarray(lens,
                                                             jnp.int32),
                                 policy="fp32", bk=page)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# paged writes
# ---------------------------------------------------------------------------
def test_paged_update_rows_matches_contiguous_writes():
    """Prefill-style (S tokens at pos 0) and ragged decode-style (1 token
    at per-row pos) writes through the table reconstruct exactly what the
    contiguous writer would hold."""
    from repro.models.attention import update_cache_rows
    b, hkv, page, dh, mp = 2, 2, 16, 8, 3
    smax = mp * page
    table = jnp.asarray(_scrambled_table(b, mp, b * mp, seed=7))
    pool = jnp.zeros((b * mp, hkv, page, dh), jnp.float32)
    buf = jnp.zeros((b, hkv, smax, dh), jnp.float32)

    new = jnp.asarray(rnd(b, hkv, 20, dh, seed=8))      # partial tail page
    pool = paged_update_rows(pool, table, new, 0)
    buf = update_cache_rows(buf, new, 0, axis=2)
    np.testing.assert_array_equal(np.asarray(gather_paged_kv(pool, table)),
                                  np.asarray(buf))

    tok = jnp.asarray(rnd(b, hkv, 1, dh, seed=9))
    pos = jnp.asarray([20, 33], jnp.int32)              # crosses a page
    pool = paged_update_rows(pool, table, tok, pos)
    buf = update_cache_rows(buf, tok, pos, axis=2)
    np.testing.assert_array_equal(np.asarray(gather_paged_kv(pool, table)),
                                  np.asarray(buf))


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------
def test_allocator_reuse_after_free():
    a = PageAllocator(4)
    first = a.alloc(3)
    assert first == [0, 1, 2] and a.n_live == 3 and a.n_free == 1
    a.free([1])
    assert a.n_free == 2
    # LIFO warm reuse: the freed page comes back before the never-used one
    again = a.alloc(2)
    assert again[0] == 1 and set(first[:1] + first[2:] + again) == {0, 1, 2, 3}
    with pytest.raises(MemoryError):
        a.alloc(1)
    with pytest.raises(ValueError):
        a.free([1, 1, 1])              # more frees than references


def test_allocator_shared_pages_survive_partial_free():
    a = PageAllocator(6)
    prefix = a.alloc(2)
    a.share(prefix)                    # two rows reference the prefix
    assert a.free(prefix) == 0         # row 0 leaves; nothing released yet
    assert a.n_live == 2               # row 1 still holds them
    assert a.free(prefix) == 2         # row 1 leaves; pages really return
    assert a.n_live == 0 and a.n_free == 6


def test_allocator_free_shared_id_in_preemption_batch():
    # a preemption sweep frees a victim's whole page list in one call;
    # pages shared with a surviving row must NOT return to the free list,
    # and the released count must reflect the refcounts, not the list
    a = PageAllocator(8)
    shared = a.alloc(2)
    private = a.alloc(3)
    a.share(shared)                    # surviving row references the prefix
    released = a.free(shared + private)          # victim preempted
    assert released == 3               # only the private pages came back
    assert a.n_live == 2 and a.n_free == 6
    # survivor's view is intact: its pages cannot be re-allocated
    assert set(a.alloc(6)).isdisjoint(shared)
    with pytest.raises(MemoryError):
        a.alloc(1)
    assert a.free(shared) == 2         # survivor leaves; pool drains
    assert a.n_live == 6


def test_allocator_double_free_and_dead_share_raise():
    a = PageAllocator(4)
    ids = a.alloc(2)
    a.free(ids)
    with pytest.raises(ValueError, match="double free"):
        a.free([ids[0]])
    with pytest.raises(ValueError, match="dead page"):
        a.share([ids[1]])
    # a duplicate id inside ONE call trips once the references run out
    b = a.alloc(1)
    with pytest.raises(ValueError, match="double free"):
        a.free([b[0], b[0]])


def test_allocator_try_alloc_exhaustion_probe():
    # try_alloc is the admission probe: a miss must not mutate anything,
    # and a later free must make the same probe succeed (the engine's
    # worst-case reservation can race injected exhaustion — the probe,
    # not the reservation arithmetic, is the ground truth)
    a = PageAllocator(4)
    held = a.alloc(3)
    assert a.try_alloc(2) is None
    assert a.n_free == 1 and a.n_live == 3       # probe left no trace
    got = a.try_alloc(1)
    assert got is not None and a.n_free == 0
    a.free(held[:2])
    assert a.try_alloc(2) is not None            # freed pages admit again
    assert a.n_free == 0


def test_build_tables_shared_prefix_layout():
    page_budget = 10
    a = PageAllocator(page_budget)
    t = build_tables(a, batch=3, max_pages=3, shared_pages=2)
    # rows agree on the first 2 pages, diverge after
    assert (t[:, :2] == t[0, :2]).all()
    assert len(set(t[:, 2].tolist())) == 3
    # 2 shared + 3 private = 5 live pages, not 9
    assert a.n_live == 5
    # freeing every row returns the pool to empty (refcounts balance)
    for b in range(3):
        a.free(t[b].tolist())
    assert a.n_free == page_budget


# ---------------------------------------------------------------------------
# model-level
# ---------------------------------------------------------------------------
from conftest import LENS, cached_model, small_batch


def _setup(arch="gemma2-9b", policy="tp_bf16", **cfg):
    model, params = cached_model(arch, policy=policy, **cfg)
    toks, lens = small_batch(model.cfg.vocab)
    return model, params, toks, lens


def test_model_paged_generate_bit_identical_dense():
    """Identity-table paged serving == contiguous serving, bitwise, on the
    dense path (the gather is pure data movement), ragged lens included."""
    model, params, toks, lens = _setup()
    fn = jax.jit(lambda p, t, l: model.generate(
        p, t, gen_len=4, max_len=40, prompt_lens=l, return_logits=True))
    mp = model.with_cfg(paged_kv=True, page_size=16)
    fn_p = jax.jit(lambda p, t, l: mp.generate(
        p, t, gen_len=4, max_len=40, prompt_lens=l, return_logits=True))
    g0, lg0 = fn(params, toks, lens)
    g1, lg1 = fn_p(params, toks, lens)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    np.testing.assert_array_equal(np.asarray(lg0), np.asarray(lg1))


@pytest.mark.parametrize("policy", ["tp_bf16", "tp_bf16_kv8"])
def test_model_paged_pallas_decode_matches_solo_rows(policy):
    """Fused-kernel paged decode (incl. the fp8 quantized-KV pool): each
    ragged row generates the tokens it would generate served alone —
    the paged write/read plumbing is row-independent."""
    model, params, toks, lens = _setup(
        policy=policy, paged_kv=True, page_size=16, decode_backend="pallas")
    fn = jax.jit(lambda p, t, l: model.generate(
        p, t, gen_len=4, max_len=40, prompt_lens=l)[0])
    gen = fn(params, toks, lens)
    for i, L in enumerate(LENS):
        g_i = fn(params, toks[i:i + 1], jnp.asarray([L], jnp.int32))
        np.testing.assert_array_equal(np.asarray(gen[i]), np.asarray(g_i[0]))


def test_model_prefix_sharing_identical_to_unshared():
    """Rows aliasing their common-prompt pages (the pool stores the prefix
    once) produce logits and generations identical to the unshared identity
    layout — decode writes land in private pages past the shared run."""
    model, params, toks, _ = _setup(paged_kv=True, page_size=16)
    toks = jnp.broadcast_to(toks[0:1], (3, 32))         # identical prompts
    mp = num_pages(40, 16)
    alloc = PageAllocator(3 * mp)
    shared = jnp.asarray(build_tables(alloc, 3, mp,
                                      shared_pages=32 // 16))
    assert alloc.n_live < 3 * mp                        # pool actually shrank
    fn = jax.jit(lambda p, t, tb: model.generate(
        p, t, gen_len=4, max_len=40, page_table=tb, n_pages=3 * mp,
        return_logits=True))
    g_s, lg_s = fn(params, toks, shared)
    g_u, lg_u = fn(params, toks,
                   jnp.asarray(identity_block_table(3, mp)))
    np.testing.assert_array_equal(np.asarray(g_s), np.asarray(g_u))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_u))


def test_model_paged_composes_with_eos_and_sampling():
    """paged_kv + stop_token + sampling share one scan carry and stay
    key-deterministic (the full serving feature set in one program)."""
    model, params, toks, lens = _setup(paged_kv=True, page_size=16)
    fn = jax.jit(lambda p, t, l, k: model.generate(
        p, t, gen_len=6, max_len=48, prompt_lens=l, stop_token=3,
        temperature=0.9, top_k=50, key=k)[0])
    s1 = np.asarray(fn(params, toks, lens, jax.random.key(7)))
    s2 = np.asarray(fn(params, toks, lens, jax.random.key(7)))
    np.testing.assert_array_equal(s1, s2)


def test_paged_rejected_for_stateful_mixers():
    """Recurrent state and cross-attention caches have no page axis:
    cfg.paged_kv must refuse, not silently keep a contiguous cache."""
    for arch in ("zamba2-1.2b", "minicpm3-4b"):
        model = build_model(arch, policy="tp_bf16", reduced=True)
        model = model.with_cfg(paged_kv=True)
        params = model.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                  model.cfg.vocab)
        with pytest.raises(ValueError, match="paged_kv"):
            model.prefill(params, toks, max_len=24)


def test_page_table_requires_paged_cfg():
    model, params, toks, _ = _setup()
    with pytest.raises(ValueError, match="paged_kv"):
        model.prefill(params, toks, max_len=40,
                      page_table=jnp.zeros((3, 3), jnp.int32))
