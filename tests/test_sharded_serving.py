"""Mesh-sharded serving: tensor-parallel attention parity + satellites.

The 8-device tests need ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(scripts/ci.sh runs this file under that flag as its own gate) and skip
cleanly under the plain tier-1 run, where jax sees one CPU device.  The
parity claims they pin:

  * head-sharded attention (dense + Pallas, prefill + decode, contiguous
    + paged) is BIT-identical per head to the single-device path — head
    slices are independent, concat is data movement;
  * the row-parallel output projection psums per-shard partials in fp32
    and snaps the policy format ONCE after the reduce, so full outputs
    are allclose at fp32 tolerance (and bitwise under tp_bf16, whose
    output snap absorbs the fp32 reduction-order noise);
  * the continuous engine and its data-parallel replication emit
    token-identical streams with and without a mesh.

The 1-device satellite tests (compat/version-gate branches, divisibility
fallback, per-replica allocator isolation, paged cache specs, queue
partitioning) always run.
"""
import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map_compat
from repro.launch import mesh as meshmod
from repro.launch.engine import ReplicatedEngine, Request
from repro.models.attention import KVCache, gqa_attention, gqa_params
from repro.models.paged import (PageAllocator, PagedKVCache, aggregate_stats,
                                init_paged_kv_cache)
from repro.models.sharding import cache_specs, param_specs
from repro.models.transformer import Caches

need8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

B, S, DM, H, HKV, HD = 2, 16, 32, 8, 8, 16
PAGE, MAXLEN = 8, 32


def tp_mesh(tp=8):
    return meshmod.replica_meshes(meshmod.make_serving_mesh(1, tp))[0]


def setup():
    params = gqa_params(jax.random.key(0), DM, H, HKV, HD, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (B, S, DM), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    return params, x, pos


def attend(mesh, x, params, pos, *, policy="tp_bf16", return_attend=True,
           **kw):
    return gqa_attention(x, params, policy, n_heads=H, n_kv_heads=HKV,
                         head_dim=HD, positions=pos, mesh=mesh,
                         return_attend=return_attend, **kw)


# ---------------------------------------------------------------------------
# per-head bit-exactness: every attend route, mesh vs single-device
# ---------------------------------------------------------------------------
@need8
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_contiguous_prefill_attend_bitexact(backend):
    params, x, pos = setup()
    kw = dict(prefill_backend=backend)
    a, _ = jax.jit(lambda m=None: attend(m, x, params, pos, **kw))()
    b, _ = jax.jit(lambda: attend(tp_mesh(), x, params, pos, **kw))()
    assert np.array_equal(np.asarray(a), np.asarray(b))


@need8
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_contiguous_decode_attend_bitexact(backend):
    params, x, pos = setup()
    zeros = jnp.zeros((B, HKV, MAXLEN, HD), jnp.float32)
    _, cache = attend(None, x, params, pos, cache=KVCache(zeros, zeros),
                      cache_pos=0)
    x1 = jax.random.normal(jax.random.key(2), (B, 1, DM), jnp.float32)
    p1 = jnp.full((B, 1, 1), S, jnp.int32)
    kw = dict(cache=cache, cache_pos=jnp.full((B,), S, jnp.int32),
              kv_len=jnp.full((B,), S + 1, jnp.int32),
              decode_backend=backend)
    a, _ = jax.jit(lambda: attend(None, x1, params, p1, **kw))()
    b, _ = jax.jit(lambda: attend(tp_mesh(), x1, params, p1, **kw))()
    assert np.array_equal(np.asarray(a), np.asarray(b))


@need8
@pytest.mark.parametrize("backend", ["dense", "pallas"])
@pytest.mark.parametrize("q_offset", [0, 4])
def test_paged_prefill_attend_bitexact(backend, q_offset):
    params, x, pos = setup()
    kw = dict(cache=init_paged_kv_cache(B, HKV, MAXLEN, PAGE, HD,
                                        jnp.float32),
              cache_pos=q_offset,
              kv_len=jnp.full((B,), q_offset + S, jnp.int32),
              prefill_backend=backend)
    a, _ = jax.jit(lambda: attend(None, x, params, pos + q_offset, **kw))()
    b, _ = jax.jit(lambda: attend(tp_mesh(), x, params, pos + q_offset,
                                  **kw))()
    assert np.array_equal(np.asarray(a), np.asarray(b))


@need8
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_paged_decode_attend_bitexact(backend):
    params, x, pos = setup()
    _, cache = attend(None, x, params, pos,
                      cache=init_paged_kv_cache(B, HKV, MAXLEN, PAGE, HD,
                                                jnp.float32),
                      cache_pos=0, kv_len=jnp.full((B,), S, jnp.int32))
    x1 = jax.random.normal(jax.random.key(2), (B, 1, DM), jnp.float32)
    p1 = jnp.full((B, 1, 1), S, jnp.int32)
    kw = dict(cache=cache, cache_pos=jnp.full((B,), S, jnp.int32),
              kv_len=jnp.full((B,), S + 1, jnp.int32),
              decode_backend=backend)
    a, _ = jax.jit(lambda: attend(None, x1, params, p1, **kw))()
    b, _ = jax.jit(lambda: attend(tp_mesh(), x1, params, p1, **kw))()
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# projected outputs: psum boundary
# ---------------------------------------------------------------------------
@need8
def test_projection_bitexact_under_bf16_snap():
    # tp_bf16 snaps the psum'd fp32 partial sums to bf16 AFTER the reduce;
    # the snap absorbs the reduction-order noise, so full outputs are
    # bitwise here (the fp32 policy below shows the underlying tolerance)
    params, x, pos = setup()
    a, _ = jax.jit(lambda: attend(None, x, params, pos,
                                  return_attend=False))()
    b, _ = jax.jit(lambda: attend(tp_mesh(), x, params, pos,
                                  return_attend=False))()
    assert np.array_equal(np.asarray(a), np.asarray(b))


@need8
def test_projection_allclose_fp32():
    params, x, pos = setup()
    a, _ = jax.jit(lambda: attend(None, x, params, pos, policy="fp32",
                                  return_attend=False))()
    b, _ = jax.jit(lambda: attend(tp_mesh(), x, params, pos, policy="fp32",
                                  return_attend=False))()
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=0, atol=1e-5)


@need8
def test_full_model_logits_allclose():
    from conftest import cached_model
    model, params = cached_model("gemma2-9b")
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0,
                              model.cfg.vocab)
    lg0, _ = jax.jit(lambda p, t: model.prefill(p, t, max_len=24))(
        params, toks)
    mesh = tp_mesh(2)        # reduced arch: 4 heads / 2 kv heads
    lg1, _ = jax.jit(lambda p, t: model.prefill(p, t, max_len=24,
                                                mesh=mesh))(params, toks)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# engine: tensor-parallel + data-parallel token parity
# ---------------------------------------------------------------------------
def _engine_fixture():
    from conftest import cached_model
    from repro.launch.engine import ContinuousEngine, synthetic_trace
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    reqs = synthetic_trace(6, 3, 16, 16, model.cfg.vocab)
    max_len = max(r.prompt_len + r.max_new for r in reqs)
    mk = lambda mesh: ContinuousEngine(model, params, slots=3,
                                       max_len=max_len, chunk=8, mesh=mesh)
    return mk, reqs


@need8
def test_engine_tp_token_parity():
    mk, reqs = _engine_fixture()
    base, _ = mk(None).run(reqs)
    tp, _ = mk(tp_mesh(2)).run(reqs)
    assert all(a.tokens == b.tokens for a, b in zip(base, tp))


@need8
def test_replicated_engine_token_parity_and_stats():
    from conftest import cached_model
    mk, reqs = _engine_fixture()
    base, _ = mk(None).run(reqs)
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    max_len = max(r.prompt_len + r.max_new for r in reqs)
    rep = ReplicatedEngine(model, params,
                           mesh=meshmod.make_serving_mesh(2, 2),
                           slots=3, max_len=max_len, chunk=8)
    fin, st = rep.run(reqs)
    assert all(a.tokens == b.tokens for a, b in zip(base, fin))
    assert [f.rid for f in fin] == [r.rid for r in reqs]
    assert st["replicas_n"] == 2 and len(st["replicas"]) == 2
    assert st["pool"]["n_pages"] == sum(
        s["n_pages"] for s in st["pool"]["replicas"])
    assert st["decode_rounds"] == sum(
        s["decode_rounds"] for s in st["replicas"])


@need8
def test_moe_ep_on_model_only_mesh():
    # regression: a serving replica's ("model",) sub-mesh has no "data"
    # axis — the MoE EP specs must only name axes the mesh actually has
    from repro.core.policy import PRESETS
    from repro.models.layers import set_batch_axes
    from repro.models.moe import MoEConfig, moe_block, moe_params
    set_batch_axes(("data",))
    try:
        cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, n_shared=1)
        pol = PRESETS["fp32"]
        params = moe_params(jax.random.key(0), 32, cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 16, 32))
        y0, aux0 = moe_block(x, params, cfg, pol, mesh=None)
        y1, aux1 = jax.jit(lambda x, p: moe_block(
            x, p, cfg, pol, mesh=tp_mesh(2)))(x, params)
    finally:
        set_batch_axes(())
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux0), float(aux1), rtol=1e-5)


# ---------------------------------------------------------------------------
# satellite 1: version-gate shims — BOTH branches, monkeypatched
# ---------------------------------------------------------------------------
def test_shard_map_compat_new_api_branch(monkeypatch):
    calls = {}

    def fake(f, *, mesh, in_specs, out_specs, check_vma, **kw):
        calls.update(kw, mesh=mesh, check_vma=check_vma)
        return "new-api"

    monkeypatch.setattr(jax, "shard_map", fake, raising=False)
    r = shard_map_compat(lambda x: x, mesh="M", in_specs=(P(),),
                         out_specs=P(), axis_names={"model"})
    assert r == "new-api"
    assert calls["mesh"] == "M" and calls["axis_names"] == {"model"}
    assert calls["check_vma"] is False


def test_shard_map_compat_legacy_branch(monkeypatch):
    # force the 0.4.x path even on a newer jax, and prove it RUNS
    monkeypatch.delattr(jax, "shard_map", raising=False)
    mesh = tp_mesh(1)
    f = shard_map_compat(lambda x: x * 2, mesh=mesh, in_specs=(P(),),
                         out_specs=P(), axis_names=set(mesh.axis_names))
    np.testing.assert_array_equal(np.asarray(f(jnp.arange(4))),
                                  np.arange(4) * 2)


def test_mk_mesh_new_api_branch(monkeypatch):
    calls = {}

    def fake(shape, axes, **kw):
        calls.update(shape=shape, axes=axes, **kw)
        return "made"

    monkeypatch.setattr(jax, "make_mesh", fake, raising=False)
    assert meshmod._mk_mesh((1, 1), ("data", "model")) == "made"
    assert calls["shape"] == (1, 1) and calls["axes"] == ("data", "model")


def test_mk_mesh_classic_branch(monkeypatch):
    monkeypatch.delattr(jax, "make_mesh", raising=False)
    m = meshmod._mk_mesh((1, 1), ("data", "model"))
    assert m.axis_names == ("data", "model") and m.devices.size == 1
    with pytest.raises(ValueError, match="needs"):
        meshmod._mk_mesh((4096,), ("model",))


def test_production_mesh_axis_type_probe(monkeypatch):
    seen = {}
    monkeypatch.setattr(jax, "make_mesh",
                        lambda shape, axes, **kw: seen.update(kw) or "m",
                        raising=False)
    fake_at = types.SimpleNamespace(Auto="AUTO")
    monkeypatch.setattr(jax.sharding, "AxisType", fake_at, raising=False)
    assert meshmod.make_production_mesh() == "m"
    assert seen == {"axis_types": ("AUTO", "AUTO")}
    seen.clear()
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    assert meshmod.make_production_mesh() == "m"
    assert seen == {}


def test_serving_mesh_validation():
    with pytest.raises(ValueError, match=">= 1"):
        meshmod.make_serving_mesh(0, 1)
    m = meshmod.make_serving_mesh(1, 1)
    subs = meshmod.replica_meshes(m)
    assert len(subs) == 1 and subs[0].axis_names == ("model",)
    with pytest.raises(ValueError, match="serving mesh"):
        meshmod.replica_meshes(
            jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("pod",)))


# ---------------------------------------------------------------------------
# satellite 2: divisibility fallback warns and replicates
# ---------------------------------------------------------------------------
def test_param_divisibility_fallback_warns():
    params = {"wq": jax.ShapeDtypeStruct((32, 13), jnp.float32),
              "g": jax.ShapeDtypeStruct((32,), jnp.float32)}
    with pytest.warns(UserWarning,
                      match=r"'wq' \(32, 13\).*16-way 'model'.*replicated"):
        specs = param_specs(params, model_size=16)
    assert specs["wq"] == P()            # pinned: fallback is replication
    assert specs["g"] == P()             # 'rep' role: no warning expected


def test_param_specs_divisible_no_warning(recwarn):
    params = {"wq": jax.ShapeDtypeStruct((32, 64), jnp.float32)}
    specs = param_specs(params, model_size=16)
    assert specs["wq"] == P(None, "model")
    assert not [w for w in recwarn.list
                if "replicated instead" in str(w.message)]


def _fake_mesh(model=2, data=1):
    return types.SimpleNamespace(shape={"model": model, "data": data},
                                 axis_names=("data", "model"))


def test_cache_specs_paged_leaves():
    from repro.configs.base import ModelConfig
    paged = PagedKVCache(
        jax.ShapeDtypeStruct((12, 4, 8, 16), jnp.float32),
        jax.ShapeDtypeStruct((12, 4, 8, 16), jnp.float32),
        jax.ShapeDtypeStruct((3, 4), jnp.int32))
    caches = Caches(prefix=(paged,), pattern=None, suffix=None)
    specs = cache_specs(None, caches, batch=3, mesh=_fake_mesh(model=2),
                        batch_axes=())
    got = specs.prefix[0]
    assert got.k_pool == P(None, "model", None, None)
    assert got.v_pool == P(None, "model", None, None)
    assert got.block_table == P(None, None)
    # indivisible head count: pool replicates, table spec unchanged
    bad = PagedKVCache(
        jax.ShapeDtypeStruct((12, 3, 8, 16), jnp.float32),
        jax.ShapeDtypeStruct((12, 3, 8, 16), jnp.float32),
        jax.ShapeDtypeStruct((3, 4), jnp.int32))
    specs = cache_specs(None, Caches(prefix=(bad,), pattern=None,
                                     suffix=None),
                        batch=3, mesh=_fake_mesh(model=2), batch_axes=())
    assert specs.prefix[0].k_pool == P(None, None, None, None)


# ---------------------------------------------------------------------------
# satellite 4: per-replica allocator isolation + aggregation
# ---------------------------------------------------------------------------
def test_allocator_isolation():
    a, b = PageAllocator(8), PageAllocator(8)
    got_a = a.alloc(8)                   # drain A completely
    assert a.try_alloc(1) is None
    assert b.n_free == 8                 # B untouched: disjoint pools
    got_b = b.alloc(3)
    a.free(got_a[:4])
    assert b.n_live == 3 and b.n_free == 5   # A's churn invisible to B
    assert a.n_free == 4
    b.free(got_b)
    assert a.peak_live == 8 and b.peak_live == 3


def test_aggregate_stats():
    allocs = [PageAllocator(8), PageAllocator(4)]
    allocs[0].alloc(5)
    allocs[1].alloc(2)
    allocs[1].free(allocs[1].alloc(2))   # push replica-1 peak to 4
    agg = aggregate_stats(allocs)
    assert agg["n_pages"] == 12 and agg["n_live"] == 7
    assert agg["n_free"] == 5
    assert agg["peak_live"] == 5 + 4     # sums of independent pool peaks
    assert [s["n_pages"] for s in agg["replicas"]] == [8, 4]


def test_replicated_partition_round_robin():
    eng = ReplicatedEngine.__new__(ReplicatedEngine)
    eng.engines = [object(), object()]
    reqs = [Request(rid=i, tokens=[1], max_new=1, arrival=a)
            for i, a in ((0, 5), (1, 0), (2, 0), (3, 2))]
    parts = ReplicatedEngine.partition(eng, reqs)
    # (arrival, rid) order = 1, 2, 3, 0 -> round-robin over 2 replicas
    assert [r.rid for r in parts[0]] == [1, 3]
    assert [r.rid for r in parts[1]] == [2, 0]
    for part in parts:                   # per-replica arrival order intact
        assert [r.arrival for r in part] == sorted(r.arrival for r in part)
