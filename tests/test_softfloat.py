"""Property + bit-exactness tests for core.softfloat (paper §II.A.1, Fig 1).

The emulation layer must behave exactly like an IEEE-754-2008 hardware
rounding stage for *any* (e, m) format: these tests pin that down against
ml_dtypes' reference conversions (for formats with native implementations)
and against grid-membership / ordering properties (for arbitrary formats).
"""
import math

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property-based tests")
from hypothesis import given, settings, strategies as st

from repro.core import softfloat
from repro.core.formats import FPFormat, get_format

F32 = np.float32

# formats with a trusted third-party reference conversion
NATIVE_FMTS = [
    ("fp16", np.float16),
    ("fp16alt", ml_dtypes.bfloat16),
    ("fp8", ml_dtypes.float8_e5m2),
]
# arbitrary-(e,m) formats exercising the generic machinery
CUSTOM_FMTS = ["fp8_e4m3", "tf32", "fp6_e3m2", (3, 4), (6, 1)]

finite_f32 = st.floats(width=32, allow_nan=False, allow_infinity=False,
                       allow_subnormal=True)
any_f32 = st.floats(width=32, allow_nan=True, allow_infinity=True,
                    allow_subnormal=True)


def q(x, fmt, mode="rne", **kw):
    out = softfloat.quantize(jnp.asarray(x, jnp.float32), fmt, mode, **kw)
    return np.asarray(out)


# ---------------------------------------------------------------------------
# bit-exactness vs ml_dtypes (RNE)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt_name,ref_dtype", NATIVE_FMTS)
@given(x=any_f32)
@settings(max_examples=300, deadline=None)
def test_rne_matches_mldtypes(fmt_name, ref_dtype, x):
    got = q(x, fmt_name)
    want = np.asarray(F32(x)).astype(ref_dtype).astype(F32)
    if np.isnan(want):
        assert np.isnan(got)
    else:
        assert got == want and np.signbit(got) == np.signbit(want), (
            fmt_name, x, got, want)


@pytest.mark.parametrize("fmt_name,ref_dtype", NATIVE_FMTS)
def test_rne_matches_mldtypes_exhaustive_grid(fmt_name, ref_dtype):
    """Sweep every boundary-adjacent value: all 16-bit patterns upcast."""
    bits = np.arange(0, 1 << 16, dtype=np.uint16)
    xs = bits.view(np.float16).astype(F32)
    xs = xs[np.isfinite(xs)]
    got = q(xs, fmt_name)
    want = xs.astype(ref_dtype).astype(F32)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(np.signbit(got), np.signbit(want))


@pytest.mark.parametrize("fmt_name,ref_dtype", NATIVE_FMTS)
def test_specials(fmt_name, ref_dtype):
    assert np.isnan(q(np.nan, fmt_name))
    assert q(np.inf, fmt_name) == np.inf
    assert q(-np.inf, fmt_name) == -np.inf
    z, nz = q(0.0, fmt_name), q(-0.0, fmt_name)
    assert z == 0 and not np.signbit(z)
    assert nz == 0 and np.signbit(nz)


# ---------------------------------------------------------------------------
# generic grid properties (any format, any mode)
# ---------------------------------------------------------------------------
def _on_grid(v, fmt: FPFormat) -> bool:
    """A finite v is representable in fmt iff v = M * 2^(e-m) with |M| < 2^(m+1)
    and emin <= e <= emax (normals), or v = M * 2^(emin-m), |M| < 2^m (subs)."""
    if v == 0 or not math.isfinite(v):
        return True
    a = abs(float(v))
    if a > fmt.max_normal:
        return False
    e = math.floor(math.log2(a))
    e = max(e, fmt.emin)
    scaled = a / 2.0 ** (e - fmt.m_bits)
    return scaled == int(scaled)


@pytest.mark.parametrize("fmt_name", [n for n, _ in NATIVE_FMTS] + CUSTOM_FMTS)
@pytest.mark.parametrize("mode", ["rne", "rtz", "rdn", "rup", "rmm"])
@given(x=finite_f32)
@settings(max_examples=200, deadline=None)
def test_result_on_grid(fmt_name, mode, x):
    fmt = get_format(fmt_name)
    got = float(q(x, fmt, mode))
    assert _on_grid(got, fmt), (fmt_name, mode, x, got)


@pytest.mark.parametrize("fmt_name", ["fp16", "fp16alt", "fp8", "fp8_e4m3"])
@given(x=finite_f32)
@settings(max_examples=200, deadline=None)
def test_directed_modes_bracket(fmt_name, x):
    dn = float(q(x, fmt_name, "rdn"))
    up = float(q(x, fmt_name, "rup"))
    tz = float(q(x, fmt_name, "rtz"))
    ne = float(q(x, fmt_name, "rne"))
    assert dn <= x <= up
    assert abs(tz) <= abs(x)
    assert dn <= ne <= up
    # rne picks one of the two enclosing grid points
    assert ne in (dn, up)


@pytest.mark.parametrize("fmt_name", [n for n, _ in NATIVE_FMTS] + CUSTOM_FMTS)
@pytest.mark.parametrize("mode", ["rne", "rtz", "rdn", "rup", "rmm"])
@given(x=finite_f32)
@settings(max_examples=100, deadline=None)
def test_idempotent(fmt_name, mode, x):
    once = q(x, fmt_name, mode)
    twice = q(once, fmt_name, mode)
    np.testing.assert_array_equal(once, twice)


@pytest.mark.parametrize("fmt_name", ["fp16", "fp8", "fp8_e4m3"])
def test_monotone(fmt_name):
    xs = np.sort(np.random.RandomState(0).uniform(-100, 100, 4096).astype(F32))
    for mode in ("rne", "rtz", "rdn", "rup", "rmm"):
        ys = q(xs, fmt_name, mode)
        assert np.all(np.diff(ys) >= 0), mode


# ---------------------------------------------------------------------------
# subnormals / overflow
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt_name", ["fp16", "fp16alt", "fp8", "fp8_e4m3"])
def test_gradual_underflow(fmt_name):
    fmt = get_format(fmt_name)
    sub = fmt.min_subnormal
    # every multiple of min_subnormal below min_normal is exactly representable
    ks = np.arange(1, 1 << fmt.m_bits)
    xs = (ks * sub).astype(F32)
    np.testing.assert_array_equal(q(xs, fmt), xs)
    # halfway points round to even neighbours under RNE
    half = F32(0.5 * sub)
    assert q(half, fmt) == 0.0          # ties-to-even: 0 is even
    assert q(F32(1.5 * sub), fmt) == F32(2 * sub)
    # below half of min subnormal flushes to (signed) zero
    tiny = F32(0.49 * sub)
    assert q(tiny, fmt) == 0.0
    assert np.signbit(q(-tiny, fmt))


@pytest.mark.parametrize("fmt_name", ["fp16", "fp16alt", "fp8", "fp8_e4m3"])
def test_overflow_modes(fmt_name):
    fmt = get_format(fmt_name)
    mx = F32(fmt.max_normal)
    # finite f32 value safely above the format's RNE overflow boundary
    big = F32(min(fmt.max_normal * 4.0, float(np.finfo(np.float32).max)))
    assert q(big, fmt, "rne") == np.inf
    assert q(-big, fmt, "rne") == -np.inf
    assert q(big, fmt, "rtz") == mx
    assert q(big, fmt, "rdn") == mx
    assert q(big, fmt, "rup") == np.inf
    assert q(-big, fmt, "rdn") == -np.inf
    assert q(-big, fmt, "rup") == -mx
    assert q(big, fmt, "rne", saturate=True) == mx
    assert q(-big, fmt, "rne", saturate=True) == -mx
    # just over max_normal but under the rounding boundary stays finite (RNE)
    eps_under = F32(fmt.max_normal * (1 + 2.0 ** (-fmt.m_bits - 2)))
    assert q(eps_under, fmt, "rne") == mx


# ---------------------------------------------------------------------------
# saturating casts (PR 7): overflow clamps to max-normal instead of Inf
# ---------------------------------------------------------------------------
def test_fp8_saturation_max_normal_boundary():
    """e5m2 saturation edges: max_normal = 1.75 * 2^15 = 57344, the RNE
    overflow boundary is the midpoint to the next (absent) binade,
    57344 + 8192/2 * ... -> 61440."""
    fmt = get_format("fp8")
    mx = F32(fmt.max_normal)                       # 57344
    assert mx == F32(57344.0)
    # exactly max normal: representable, both modes identical
    assert q(mx, fmt, saturate=True) == mx
    assert q(mx, fmt) == mx
    # strictly inside the rounding boundary: rounds DOWN to max normal in
    # both modes (saturation must not change non-overflowing results)
    below = np.nextafter(F32(61440.0), F32(0.0), dtype=F32)
    assert q(below, fmt) == mx
    assert q(below, fmt, saturate=True) == mx
    # well above: Inf without saturation, clamp with
    for big in (F32(61441.0), F32(1e38)):
        assert q(big, fmt) == np.inf
        assert q(-big, fmt) == -np.inf
        assert q(big, fmt, saturate=True) == mx
        assert q(-big, fmt, saturate=True) == -mx


def test_fp8_saturation_rne_tie():
    """61440 is EXACTLY halfway between max normal (1.11 x 2^15, odd
    mantissa) and the overflowed 2^16 (even) — ties-to-even rounds UP,
    so the tie overflows under RNE and must clamp under saturation."""
    fmt = get_format("fp8")
    tie = F32(61440.0)
    assert q(tie, fmt) == np.inf
    assert q(-tie, fmt) == -np.inf
    assert q(tie, fmt, saturate=True) == F32(fmt.max_normal)
    assert q(-tie, fmt, saturate=True) == -F32(fmt.max_normal)


@pytest.mark.parametrize("fmt_name", ["fp16", "fp16alt", "fp8", "fp8_e4m3"])
def test_saturation_preserves_specials(fmt_name):
    """Saturation clamps OVERFLOWED finite inputs only: true infinities
    pass through as infinities and NaN stays (canonical quiet) NaN."""
    assert q(np.inf, fmt_name, saturate=True) == np.inf
    assert q(-np.inf, fmt_name, saturate=True) == -np.inf
    got = q(np.float32(np.nan), fmt_name, saturate=True)
    assert np.isnan(got)
    # canonical quiet NaN: payloads are not preserved (hardware-style
    # canonicalization, FPnew §II.B) — both modes agree
    payload = np.uint32(0x7FC00001).view(F32)
    assert np.isnan(q(payload, fmt_name, saturate=True))
    assert np.isnan(q(payload, fmt_name))


@pytest.mark.parametrize("fmt_name,ref_dtype", NATIVE_FMTS)
@given(x=any_f32)
@settings(max_examples=300, deadline=None)
def test_saturating_cast_vs_mldtypes(fmt_name, ref_dtype, x):
    """Both cast modes vs the ml_dtypes oracle: non-saturating matches the
    reference conversion bit for bit; saturating matches the reference
    with finite-input overflows clamped to signed max-normal."""
    fmt = get_format(fmt_name)
    want = np.asarray(F32(x)).astype(ref_dtype).astype(F32)
    got_inf = q(x, fmt_name)
    got_sat = q(x, fmt_name, saturate=True)
    if np.isnan(want):
        assert np.isnan(got_inf) and np.isnan(got_sat)
        return
    assert got_inf == want and np.signbit(got_inf) == np.signbit(want)
    if np.isinf(want) and np.isfinite(x):
        want = F32(math.copysign(fmt.max_normal, x))
    assert got_sat == want and np.signbit(got_sat) == np.signbit(want), (
        fmt_name, x, got_sat, want)


# ---------------------------------------------------------------------------
# stochastic rounding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt_name", ["fp16", "fp8", "fp8_e4m3"])
def test_stochastic_lands_on_neighbours(fmt_name):
    fmt = get_format(fmt_name)
    rs = np.random.RandomState(1)
    xs = rs.uniform(-8, 8, 512).astype(F32)
    lo, hi = q(xs, fmt, "rdn"), q(xs, fmt, "rup")
    got = q(xs, fmt, "stochastic", key=jax.random.key(0))
    assert np.all((got == lo) | (got == hi))


def test_stochastic_unbiased():
    fmt = get_format("fp8")
    x = F32(1.0 + 0.3 * fmt.eps)  # strictly between two fp8 grid points
    n = 4000
    keys = jax.random.split(jax.random.key(42), n)
    vals = jax.vmap(
        lambda k: softfloat.quantize(jnp.float32(x), fmt, "stochastic", key=k)
    )(keys)
    mean = float(jnp.mean(vals))
    # E[q] = x; tolerance ~4 sigma of Bernoulli(p)*ulp / sqrt(n)
    ulp = fmt.eps
    assert abs(mean - float(x)) < 4 * ulp * 0.5 / math.sqrt(n)


# ---------------------------------------------------------------------------
# arbitrary-format sanity: widths, constants
# ---------------------------------------------------------------------------
def test_format_constants():
    fp8 = get_format("fp8")
    assert (fp8.e_bits, fp8.m_bits, fp8.width) == (5, 2, 8)
    assert fp8.max_normal == 57344.0          # e5m2 max
    assert fp8.min_normal == 2.0 ** -14
    bf16 = get_format("fp16alt")
    assert bf16.max_normal == float(ml_dtypes.finfo(ml_dtypes.bfloat16).max)
    fp16 = get_format("fp16")
    assert fp16.max_normal == 65504.0
    e4m3 = get_format("fp8_e4m3")
    # IEEE-style e4m3 keeps inf/NaN encodings (paper principles, Fig 1):
    # max = (2 - 2^-3) * 2^7 = 240, unlike the OCP e4m3fn variant's 448.
    assert e4m3.max_normal == 240.0


def test_tuple_format_construction():
    f = get_format((4, 3))
    assert f.e_bits == 4 and f.m_bits == 3
    with pytest.raises(ValueError):
        FPFormat("bad", 1, 3)


def test_identity_for_wide_targets():
    xs = np.random.RandomState(0).randn(64).astype(F32)
    np.testing.assert_array_equal(q(xs, "fp32"), xs)
    np.testing.assert_array_equal(q(xs, "fp64"), xs)
