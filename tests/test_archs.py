"""Per-architecture smoke tests: for every assigned arch, instantiate the
REDUCED same-family config and run (a) one forward/train step and (b) a
prefill + two decode steps, on CPU, asserting output shapes, finiteness and
cache consistency.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import ARCHS, build_model, get_config
from repro.models import transformer as tfm

BATCH, SEQ = 2, 32


def _inputs(model, key):
    cfg = model.cfg
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (BATCH, SEQ), 0, cfg.vocab)
    labels = jax.random.randint(ks[1], (BATCH, SEQ), 0, cfg.vocab)
    fe = None
    if cfg.frontend == "patch":
        fe = jax.random.normal(
            ks[2], (BATCH, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio":
        fe = jax.random.normal(
            ks[2], (BATCH, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    return tokens, labels, fe


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    model = build_model(arch, policy="tp_bf16", reduced=True)
    params = model.init(jax.random.key(0))
    tokens, labels, fe = _inputs(model, jax.random.key(1))

    def loss_fn(p):
        return model.forward_train(p, tokens, labels, frontend_embeds=fe,
                                   remat=True)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    # a sensible initial LM loss is ~log(vocab)
    assert 0.5 * np.log(model.cfg.vocab) < float(loss) < 3 * np.log(
        model.cfg.vocab), (arch, float(loss))
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.abs(g.astype(jnp.float32))), grads, 0.0)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    model = build_model(arch, policy="tp_bf16", reduced=True)
    cfg = model.cfg
    params = model.init(jax.random.key(0))
    tokens, _, fe = _inputs(model, jax.random.key(1))
    max_len = SEQ + 8

    lg, caches = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=max_len,
                                   frontend_embeds=fe))(params, tokens)
    assert lg.shape == (BATCH, 1, model.vocab_out)
    assert np.all(np.isfinite(np.asarray(lg, np.float32))), arch

    step = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos))
    tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for i in range(2):
        lg2, caches = step(params, tok, caches, SEQ + i)
        assert lg2.shape == (BATCH, 1, model.vocab_out)
        assert np.all(np.isfinite(np.asarray(lg2, np.float32))), arch
        tok = jnp.argmax(lg2[:, -1], axis=-1).astype(jnp.int32)[:, None]


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    counts = cfg.param_counts()
    assert counts["total"] > 0 and counts["active"] > 0
    assert counts["active"] <= counts["total"]
    assert counts["flops"] >= counts["active"]
    assert len(cfg.layer_list()) == cfg.n_layers


def test_decode_matches_prefill_continuation():
    """Decoding token-by-token must equal prefilling the longer prompt
    (KV-cache correctness, the core serving invariant)."""
    model = build_model("granite-20b", policy="fp32", reduced=True)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, model.cfg.vocab)
    max_len = 16

    lg_a, caches = model.prefill(params, toks[:, :8], max_len=max_len)
    for i in range(4):
        lg_a, caches = model.decode_step(params, toks[:, 8 + i:9 + i],
                                         caches, 8 + i)
    lg_b, _ = model.prefill(params, toks, max_len=max_len)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               rtol=2e-4, atol=2e-4)


def test_param_counts_against_public_sizes():
    """Sanity-check the config dims against the models' public parameter
    counts (loose bands — our configs are backbone-only)."""
    bands = {
        "gemma2-9b": (8e9, 11e9),
        "gemma3-12b": (10e9, 14e9),
        "granite-20b": (18e9, 22e9),
        "minicpm3-4b": (3.5e9, 5e9),
        # assignment dims (proj_factor 2, headwise qkv) give ~1.9e9;
        # the public 1.3B uses narrower internals — recorded in DESIGN.md
        "xlstm-1.3b": (1.4e9, 2.2e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "internvl2-26b": (18e9, 22e9),   # LLM backbone of the 26B (ViT stub)
        "whisper-small": (0.2e9, 0.3e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_counts()["total"]
        assert lo <= n <= hi, (arch, f"{n:.2e}", lo, hi)
