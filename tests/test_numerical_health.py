"""Numerical-health telemetry: IEEE exception flags through the stack.

Contract under test (the FPnew §II.B ``fflags`` story, carried from the
cast emulation layer up to the serving scheduler):

  * cast flags — ``softfloat.quantize_with_flags`` returns per-element
    OF/UF/NX/NV masks that match an independent ml_dtypes-derived oracle
    on an exhaustive 16-bit sweep, in BOTH cast modes (IEEE overflow to
    ±Inf, and saturating: clamp to ±max-normal — same flags, different
    value).  ``quant_common``'s bit-twiddling twin agrees bitwise.
  * kernel flags — the Pallas decode / flash kernels accumulate per-row
    flag counters (``debug_flags``) that equal the schedule-aware ref.py
    oracles under ragged ``kv_lens`` and scrambled paged block tables:
    per-row EXACT, dead/padded slots contribute zero, and turning the
    telemetry on leaves the attention output bit-identical.
  * write-path ladder — ``models.attention.quantize_kv_rows`` snaps K/V
    writes to each row's escalation rung (saturating) and reports per-row
    OF/UF pressure.
  * engine escalation — overflow-injected requests under an
    ``EscalationPolicy`` finish their full budget at a wider KV rung with
    ZERO poisoned rounds (saturation keeps logits finite while pressure
    accumulates); escalation is refusable, deferrable under page
    pressure, and replay-deterministic.
  * SDC-checked swap — a bit-flipped swap payload is detected by the
    swap-in checksum, recovered by re-ingest, and the recovered request's
    tokens are bit-identical to an uncorrupted run.
  * restart hygiene — ``run_with_restarts`` gives every attempt fresh
    watchdog / straggler state.
"""
import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.core import softfloat
from repro.core.formats import get_format
from repro.core.policy import EscalationPolicy, get_policy
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quant_common import (quantize_flag_masks,
                                        quantize_rne_bits)
from repro.launch.engine import ContinuousEngine, Request
from repro.models.attention import quantize_kv_rows
from repro.train.fault import (ServeFaultPlan, ServeWatchdog,
                               SimulatedFailure, StragglerMonitor,
                               run_with_restarts)

F32 = np.float32

NATIVE = [("fp8", ml_dtypes.float8_e5m2),
          ("fp16", np.float16),
          ("fp16alt", ml_dtypes.bfloat16)]


def _sweep16():
    """Every f32 value reachable from a 16-bit pattern: all fp16 bit
    patterns upcast — covers normals, subnormals, ±0, ±Inf and NaNs."""
    return np.arange(1 << 16, dtype=np.uint16).view(np.float16).astype(F32)


def _flag_oracle(xs, fmt, ref_dtype):
    """Independent flag oracle from the reference conversion: OF = finite
    input overflowed, NV = NaN input, NX = value changed (non-NaN), UF =
    tiny (below min normal, before rounding) and inexact."""
    with np.errstate(invalid="ignore"):
        ieee = xs.astype(ref_dtype).astype(F32)
    nv = np.isnan(xs)
    of = np.isinf(ieee) & np.isfinite(xs)
    nx = np.zeros(xs.shape, bool)
    m = ~nv
    nx[m] = ieee[m] != xs[m]
    uf = (xs != 0) & (np.abs(xs) < fmt.min_normal) & nx
    return ieee, of, uf, nx, nv


def _bits_equal(a, b):
    """NaN-aware bitwise comparison of f32 arrays (canonical NaN only
    needs isnan parity, finite values must match exactly incl. -0)."""
    a, b = np.asarray(a, F32), np.asarray(b, F32)
    np.testing.assert_array_equal(np.isnan(a), np.isnan(b))
    m = ~np.isnan(a)
    np.testing.assert_array_equal(a[m], b[m])
    np.testing.assert_array_equal(np.signbit(a[m]), np.signbit(b[m]))


# ---------------------------------------------------------------------------
# cast-level: flag-producing quantization vs the ml_dtypes-derived oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt_name,ref_dtype", NATIVE)
@pytest.mark.parametrize("saturate", [False, True])
def test_cast_flags_exhaustive_vs_oracle(fmt_name, ref_dtype, saturate):
    xs = _sweep16()
    fmt = get_format(fmt_name)
    ieee, of, uf, nx, nv = _flag_oracle(xs, fmt, ref_dtype)
    y, fl = softfloat.quantize_with_flags(jnp.asarray(xs), fmt,
                                          saturate=saturate)
    want = ieee
    if saturate:
        want = np.where(of, np.copysign(F32(fmt.max_normal), xs), ieee)
    _bits_equal(y, want)
    np.testing.assert_array_equal(np.asarray(fl["of"]), of)
    np.testing.assert_array_equal(np.asarray(fl["uf"]), uf)
    np.testing.assert_array_equal(np.asarray(fl["nx"]), nx)
    np.testing.assert_array_equal(np.asarray(fl["nv"]), nv)


def test_flag_invariants_fp8():
    """OF implies NX (the overflowed value is by definition inexact), UF
    implies NX, saturation changes the VALUE of overflowed elements only
    and never the telemetry."""
    xs = _sweep16()
    fmt = get_format("fp8")
    y0, f0 = softfloat.quantize_with_flags(jnp.asarray(xs), fmt)
    y1, f1 = softfloat.quantize_with_flags(jnp.asarray(xs), fmt,
                                           saturate=True)
    of = np.asarray(f0["of"])
    assert not np.any(of & ~np.asarray(f0["nx"]))
    assert not np.any(np.asarray(f0["uf"]) & ~np.asarray(f0["nx"]))
    for name in softfloat.FLAG_NAMES:
        np.testing.assert_array_equal(np.asarray(f0[name]),
                                      np.asarray(f1[name]))
    diff = np.asarray(y0) != np.asarray(y1)
    diff &= ~(np.isnan(np.asarray(y0)) & np.isnan(np.asarray(y1)))
    np.testing.assert_array_equal(diff, of)
    assert np.isfinite(np.asarray(y1)[np.isfinite(xs)]).all()


@pytest.mark.parametrize("fmt_name", ["fp8", "fp16", "fp16alt", "fp8_e4m3"])
@pytest.mark.parametrize("saturate", [False, True])
def test_quant_common_matches_ftz_oracle(fmt_name, saturate):
    """The kernels' bit-twiddling cast (quant_common, the FTZ flavor used
    by the MXU input stage) agrees bitwise with its documented oracle —
    ``softfloat.quantize`` + flush-to-zero for the value, and
    ``ref._flag_masks_ref`` for the masks — both overflow modes."""
    fmt = get_format(fmt_name)
    xs = _sweep16()
    y, of, uf, nx, nv = quantize_flag_masks(jnp.asarray(xs), fmt,
                                            saturate=saturate)
    want = np.asarray(ref._ftz(softfloat.quantize(
        jnp.asarray(xs), fmt, saturate=saturate), fmt))
    _bits_equal(y, want)
    oracle = ref._flag_masks_ref(jnp.asarray(xs), fmt)
    for got, w, name in zip((of, uf, nx, nv), oracle, softfloat.FLAG_NAMES):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(w),
                                      err_msg=name)
    _bits_equal(quantize_rne_bits(jnp.asarray(xs), fmt, saturate=saturate),
                want)


# ---------------------------------------------------------------------------
# kernel-level: per-row flag accumulation vs the ref.py oracles
# ---------------------------------------------------------------------------
def _mixed(shape, seed):
    """Log-uniform magnitudes across ~13 decades: exercises OF (beyond
    fp8's 61440 rounding boundary), UF (below 2^-14) and NX everywhere."""
    rs = np.random.RandomState(seed)
    x = rs.randn(*shape).astype(F32)
    return (x * (10.0 ** rs.uniform(-7, 6, size=shape))).astype(F32)


def _scrambled_table(rows, nk, n_pages, seed=0):
    perm = np.random.RandomState(seed).permutation(n_pages)[:rows * nk]
    return perm.reshape(rows, nk).astype(np.int32)


def _scatter_pages(x, table, page):
    rows, s, d = x.shape
    nk = table.shape[1]
    pool = np.zeros((int(table.max()) + 1, page, d), F32)
    for h in range(rows):
        for j in range(nk):
            pool[table[h, j]] = x[h, j * page:(j + 1) * page]
    return jnp.asarray(pool)


def test_decode_flags_ragged_vs_ref():
    """Contiguous decode, per-row lengths incl. an EMPTY row and a
    partial block: kernel counters == oracle per row; the zero-length row
    reports zero; output is bit-identical with telemetry on."""
    lens = [0, 1, 77, 256]
    q = jnp.asarray(_mixed((4, 8, 64), seed=1))
    k = jnp.asarray(_mixed((4, 256, 64), seed=2))
    v = jnp.asarray(_mixed((4, 256, 64), seed=3))
    kvl = jnp.asarray(lens, jnp.int32)
    kw = dict(bk=128, scale=0.125, kv_fmt_name="fp8", q_fmt_name="fp8",
              src_dtype=jnp.float32, out_dtype=jnp.float32)
    o, fl = decode_attention_pallas(q, k, v, kvl, debug_flags=True, **kw)
    want = ref.decode_flag_counts_ref(q, k, v, kv_len=np.asarray(lens),
                                      kv_fmt_name="fp8", q_fmt_name="fp8")
    got = np.asarray(fl).reshape(4, -1, 4).sum(axis=1)
    np.testing.assert_array_equal(got, np.asarray(want))
    assert got.sum() > 0 and got[2:].min(axis=0)[:3].min() > 0  # OF/UF/NX
    assert (got[0] == 0).all()                   # empty row: zero flags
    o_plain = decode_attention_pallas(q, k, v, kvl, **kw)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_plain))


def test_decode_flags_dead_slots_contribute_zero():
    """Poisoning every position >= kv_len with Inf/NaN changes neither
    the counters nor the output: dead slots are invisible."""
    lens = [40, 130]
    q = jnp.asarray(_mixed((2, 8, 64), seed=4))
    k = _mixed((2, 256, 64), seed=5)
    v = _mixed((2, 256, 64), seed=6)
    kw = dict(bk=128, scale=0.125, kv_fmt_name="fp8",
              src_dtype=jnp.float32, out_dtype=jnp.float32,
              debug_flags=True)
    kvl = jnp.asarray(lens, jnp.int32)
    o0, f0 = decode_attention_pallas(q, jnp.asarray(k), jnp.asarray(v),
                                     kvl, **kw)
    for b, L in enumerate(lens):
        k[b, L:], v[b, L:] = np.inf, np.nan
    o1, f1 = decode_attention_pallas(q, jnp.asarray(k), jnp.asarray(v),
                                     kvl, **kw)
    np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))
    np.testing.assert_array_equal(np.asarray(o0), np.asarray(o1))


def test_decode_flags_paged_scrambled_vs_ref():
    """Paged decode through a scrambled physical page layout: the flag
    walk follows the block table and still matches the gather oracle."""
    lens = [1, 77, 129, 256]
    page = 128
    q = jnp.asarray(_mixed((4, 8, 64), seed=7))
    k = _mixed((4, 256, 64), seed=8)
    v = _mixed((4, 256, 64), seed=9)
    bt = _scrambled_table(4, 256 // page, 16, seed=1)
    kp, vp = _scatter_pages(k, bt, page), _scatter_pages(v, bt, page)
    kvl = jnp.asarray(lens, jnp.int32)
    kw = dict(scale=0.125, kv_fmt_name="fp8", src_dtype=jnp.float32,
              out_dtype=jnp.float32)
    o, fl = decode_attention_pallas(q, kp, vp, kvl, jnp.asarray(bt),
                                    bk=page, debug_flags=True, **kw)
    want = ref.decode_flag_counts_paged_ref(
        q, kp, vp, jnp.asarray(bt), kv_len=np.asarray(lens),
        kv_fmt_name="fp8")
    got = np.asarray(fl).reshape(4, -1, 4).sum(axis=1)
    np.testing.assert_array_equal(got, np.asarray(want))
    o_plain = decode_attention_pallas(q, kp, vp, kvl, jnp.asarray(bt),
                                      bk=page, **kw)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_plain))


def test_flash_flags_ragged_vs_ref():
    """Flash prefill, ragged lengths, grouped heads: per-VISIT counters
    along the pruned causal schedule == the oracle's walk, per row."""
    lens = [100, 256]
    group = 2
    q = jnp.asarray(_mixed((4, 256, 64), seed=10))
    k = jnp.asarray(_mixed((2, 256, 64), seed=11))
    v = jnp.asarray(_mixed((2, 256, 64), seed=12))
    kvl = jnp.asarray(np.repeat(lens, group), jnp.int32)
    kw = dict(group=group, scale=0.125, causal=True, src_fmt_name="fp8",
              src_dtype=jnp.float32, out_dtype=jnp.float32)
    o, fl = flash_attention_pallas(q, k, v, kvl, bq=128, bk=128,
                                   debug_flags=True, **kw)
    want = ref.flash_flag_counts_ref(q, k, v, group=group,
                                     kv_len=np.repeat(lens, group),
                                     causal=True, src_fmt_name="fp8",
                                     bq=128, bk=128)
    got = np.asarray(fl).reshape(4, -1, 4).sum(axis=1)
    np.testing.assert_array_equal(got, np.asarray(want))
    assert got.sum() > 0
    o_plain = flash_attention_pallas(q, k, v, kvl, bq=128, bk=128, **kw)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_plain))


def test_flash_flags_paged_scrambled_vs_ref():
    lens = [100, 256]
    page = 128
    q = jnp.asarray(_mixed((2, 256, 64), seed=13))
    k = _mixed((2, 256, 64), seed=14)
    v = _mixed((2, 256, 64), seed=15)
    bt = _scrambled_table(2, 256 // page, 8, seed=2)
    kp, vp = _scatter_pages(k, bt, page), _scatter_pages(v, bt, page)
    kvl = jnp.asarray(lens, jnp.int32)
    kw = dict(group=1, scale=0.125, causal=True, src_fmt_name="fp8",
              src_dtype=jnp.float32, out_dtype=jnp.float32)
    o, fl = flash_attention_pallas(q, kp, vp, kvl, jnp.asarray(bt),
                                   bq=128, bk=page, debug_flags=True, **kw)
    want = ref.flash_flag_counts_paged_ref(
        q, kp, vp, jnp.asarray(bt), bq=128, kv_len=np.asarray(lens),
        causal=True, src_fmt_name="fp8", group=1)
    got = np.asarray(fl).reshape(2, -1, 4).sum(axis=1)
    np.testing.assert_array_equal(got, np.asarray(want))


def test_ops_return_flags_reduction():
    """The ops wrapper reduces kernel cells to per-SEQUENCE [B, 4] and
    keeps the output bit-identical to the flags-off call."""
    pol = get_policy("em_fp8").replace(kv_fmt="fp8")
    lens = [33, 256]
    q = jnp.asarray(_mixed((2, 4, 1, 64), seed=16))
    k = jnp.asarray(_mixed((2, 2, 256, 64), seed=17))
    v = jnp.asarray(_mixed((2, 2, 256, 64), seed=18))
    kvl = jnp.asarray(lens, jnp.int32)
    o, fl = kops.decode_attention(q, k, v, kv_len=kvl, policy=pol,
                                  interpret=True, return_flags=True)
    o_plain = kops.decode_attention(q, k, v, kv_len=kvl, policy=pol,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_plain))
    want = ref.decode_flag_counts_ref(
        q.reshape(2 * 2, 2, 64), k.reshape(2 * 2, 256, 64),
        v.reshape(2 * 2, 256, 64), kv_len=np.repeat(lens, 2),
        kv_fmt_name="fp8", q_fmt_name="fp8")
    np.testing.assert_array_equal(
        np.asarray(fl), np.asarray(want).reshape(2, 2, 4).sum(axis=1))


# ---------------------------------------------------------------------------
# write-path ladder: per-row saturating quantization + OF/UF pressure
# ---------------------------------------------------------------------------
def test_quantize_kv_rows_ladder():
    esc = EscalationPolicy()
    fmts = esc.formats
    x = jnp.asarray(_mixed((3, 2, 4, 16), seed=19))
    levels = jnp.asarray([0, 1, 2], jnp.int32)
    y, counts = quantize_kv_rows(x, fmts, levels)
    assert np.isfinite(np.asarray(y)).all()      # saturating: never Inf
    for b, fmt in enumerate(fmts):
        want = ref._ftz(softfloat.quantize(x[b], fmt, saturate=True), fmt)
        np.testing.assert_array_equal(np.asarray(y[b]), np.asarray(want))
        of, uf, _, _ = ref._flag_masks_ref(x[b], fmt)
        assert int(counts[b, 0]) == int(jnp.sum(of))
        assert int(counts[b, 1]) == int(jnp.sum(uf))
    # narrowest rung overflows on these magnitudes, the top rung must not
    assert int(counts[0, 0]) > 0 and int(counts[2, 0]) == 0


# ---------------------------------------------------------------------------
# engine: flag-driven escalation + SDC-checked swap
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def esc_setup():
    from conftest import cached_model
    return cached_model("gemma2-9b", policy="fp32", paged_kv=True,
                        page_size=16)


def _mk_reqs(vocab, n=2, plen=12, budget=16, seed=0, **kw):
    rng = np.random.RandomState(seed)
    return [Request(rid=i, tokens=rng.randint(0, vocab, size=plen).tolist(),
                    max_new=budget, arrival=0, **kw) for i in range(n)]


def _esc_engine(model, params, plan, policy=None, **kw):
    return ContinuousEngine(model, params, slots=2, max_len=64, chunk=16,
                            n_pages=10, burst_cap=4,
                            escalate=policy or EscalationPolicy(
                                of_threshold=4),
                            fault_plan=plan, **kw)


def test_escalation_finishes_wider_with_no_poison(esc_setup):
    """THE acceptance scenario: an overflow-injected request under the
    escalation policy drains its FULL budget, ends at a wider KV rung,
    and never trips the non-finite-logits guard (saturating writes keep
    the logits finite while pressure accumulates)."""
    model, params = esc_setup
    reqs = _mk_reqs(model.cfg.vocab)
    plan = ServeFaultPlan(overflow_at=(2,), overflow_scale=65536.0)
    fin, stats = _esc_engine(model, params, plan).run(reqs)
    assert stats["escalations"] >= 1
    assert stats["poisoned_rounds"] == 0
    assert any(f.escalated >= 1 for f in fin)
    for r, f in zip(reqs, fin):
        assert len(f.tokens) == r.max_new
    kinds = [k for k, _ in plan.events]
    assert "overflow" in kinds and "escalate" in kinds


def test_escalation_replay_deterministic(esc_setup):
    model, params = esc_setup
    reqs = _mk_reqs(model.cfg.vocab)
    plan = ServeFaultPlan(overflow_at=(2,), overflow_scale=65536.0)
    fin1, st1 = _esc_engine(model, params, plan).run(reqs)
    ev1 = list(plan.events)
    fin2, st2 = _esc_engine(model, params, plan).run(reqs)
    assert [f.tokens for f in fin1] == [f.tokens for f in fin2]
    assert st1["escalations"] == st2["escalations"]
    assert ev1 == plan.events


def test_escalation_refusable(esc_setup):
    """``no_escalate`` requests ride out the pressure at their rung: the
    refusal is counted once, the row finishes un-escalated (saturation
    still protects the logits), and its budget is honoured."""
    model, params = esc_setup
    reqs = _mk_reqs(model.cfg.vocab, no_escalate=True)
    plan = ServeFaultPlan(overflow_at=(2,), overflow_scale=65536.0)
    fin, stats = _esc_engine(model, params, plan).run(reqs)
    assert stats["escalations"] == 0 and stats["esc_refused"] >= 1
    assert all(f.escalated == 0 for f in fin)
    assert all(len(f.tokens) == r.max_new for r, f in zip(reqs, fin))


def test_escalation_deferred_under_page_pressure(esc_setup):
    """A free-list shorter than ``min_free_pages`` defers escalation (an
    escalating row re-prefills its whole history — the worst moment to
    fight admission for pages); the run still drains."""
    model, params = esc_setup
    reqs = _mk_reqs(model.cfg.vocab)
    plan = ServeFaultPlan(overflow_at=(2,), overflow_scale=65536.0)
    pol = EscalationPolicy(of_threshold=4, min_free_pages=1000)
    fin, stats = _esc_engine(model, params, plan, policy=pol).run(reqs)
    assert stats["escalations"] == 0 and stats["esc_deferred"] >= 1
    assert all(len(f.tokens) == r.max_new for r, f in zip(reqs, fin))


def test_escalation_requires_wide_pool(esc_setup):
    """A narrow-container pool policy (kv_fmt set) cannot host the
    write-time rung selection — constructing the engine must refuse."""
    from conftest import cached_model
    model8, params8 = cached_model("gemma2-9b", policy="tp_bf16_kv8",
                                   paged_kv=True, page_size=16)
    with pytest.raises(ValueError, match="escalat"):
        ContinuousEngine(model8, params8, slots=2, max_len=64, chunk=16,
                         escalate=EscalationPolicy())


@pytest.fixture(scope="module")
def swap_setup():
    from conftest import cached_model
    model, params = cached_model("gemma2-9b", paged_kv=True, page_size=16)
    rng = np.random.RandomState(0)
    mk = lambda n: rng.randint(0, model.cfg.vocab, size=n).tolist()
    reqs = [Request(rid=0, tokens=mk(20), max_new=12, arrival=0),
            Request(rid=1, tokens=mk(20), max_new=12, arrival=0),
            Request(rid=2, tokens=mk(16), max_new=8, arrival=4, priority=2)]
    return model, params, reqs


def test_sdc_detected_and_recovered_bit_exact(swap_setup):
    """Every injected swap-payload bit flip is caught by the swap-in
    checksum and recovered via free-and-reingest — tokens bit-identical
    to the same pressure scenario without corruption."""
    model, params, reqs = swap_setup
    plan = ServeFaultPlan(corrupt_swap_at=(0,))
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=5, preempt="swap", fault_plan=plan)
    fin, stats = eng.run(reqs)
    assert stats["preempt_swap"] >= 1
    assert stats["sdc_injected"] >= 1
    assert stats["sdc_injected"] == stats["sdc_detected"]
    assert stats["sdc_detected"] == stats["sdc_reingest"]
    kinds = [k for k, _ in plan.events]
    assert kinds.count("sdc_inject") == kinds.count("sdc_detect")
    clean = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                             n_pages=5, preempt="swap")
    fin_c, stats_c = clean.run(reqs)
    assert stats_c["sdc_injected"] == stats_c["sdc_detected"] == 0
    assert [f.tokens for f in fin] == [f.tokens for f in fin_c]


def test_clean_swap_checksums_verify_silently(swap_setup):
    """Without injection, checksums verify on every swap-in and the SDC
    counters stay zero (the verification itself must not misfire)."""
    model, params, reqs = swap_setup
    plan = ServeFaultPlan()          # no corruption listed
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=5, preempt="swap", fault_plan=plan)
    fin, stats = eng.run(reqs)
    assert stats["preempt_swap"] >= 1 and stats["resumed"] >= 1
    assert stats["sdc_detected"] == 0 and stats["sdc_reingest"] == 0


# ---------------------------------------------------------------------------
# restart hygiene: fresh monitor state per attempt
# ---------------------------------------------------------------------------
def test_run_with_restarts_resets_monitors():
    """Each attempt must start with a FRESH watchdog and straggler
    monitor even when the factory reuses one runner object — a pre-crash
    EWMA would mis-flag the restart's warm-up steps."""
    class Runner:
        attempts = resets = 0

        def reset_monitors(self):
            self.watchdog = ServeWatchdog(patience=5)
            self.monitor = StragglerMonitor(warmup=0)
            self.resets += 1

        def run(self):
            self.attempts += 1
            assert self.watchdog.stalled == 0
            assert self.monitor.ewma is None and not self.monitor.flagged
            # dirty both, then crash once
            self.watchdog.stalled = 4
            self.monitor.record(0, 1.0)
            self.monitor.record(1, 99.0)
            assert self.monitor.flagged
            if self.attempts == 1:
                raise SimulatedFailure("injected")

    r = Runner()
    runner, restarts = run_with_restarts(lambda: r, max_restarts=2)
    assert runner is r and restarts == 1
    assert r.attempts == 2 and r.resets == 2


def test_engine_run_resets_its_monitors(swap_setup):
    """The engine exposes ``reset_monitors`` (the run_with_restarts
    contract) and every ``run()`` builds fresh monitor objects, so stale
    stall counts can't trip the watchdog on a healthy rerun."""
    model, params, reqs = swap_setup
    eng = ContinuousEngine(model, params, slots=2, max_len=48, chunk=16,
                           n_pages=5, preempt="swap")
    w0, m0 = eng.watchdog, eng.monitor
    w0.stalled = 10 ** 9             # poison pre-run state
    eng.run(reqs)
    assert eng.watchdog is not w0 and eng.monitor is not m0
    assert eng.watchdog.stalled < eng.watchdog.patience
