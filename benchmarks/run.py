"""Benchmark driver: one benchmark per paper table/figure + the roofline
report.  ``PYTHONPATH=src python -m benchmarks.run``"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (fig7_instruction_energy, fig8_dvfs, roofline,
                   table3_case_study, table4_fma)
    benches = [
        ("table3_case_study (paper Table III, Fig 10/11)",
         table3_case_study.main),
        ("table4_fma (paper Table IV)", table4_fma.main),
        ("fig7_instruction_energy (paper Fig 7)",
         fig7_instruction_energy.main),
        ("fig8_dvfs (paper Fig 8)", fig8_dvfs.main),
        ("roofline (EXPERIMENTS.md §Roofline)", roofline.main),
    ]
    failures = []
    for name, fn in benches:
        t0 = time.time()
        print(f"\n{'='*72}\nBENCH {name}\n{'='*72}")
        try:
            fn()
            print(f"[{name}] OK ({time.time()-t0:.1f}s)")
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"[{name}] FAILED")
    print(f"\n{'='*72}")
    print(f"benchmarks: {len(benches) - len(failures)}/{len(benches)} OK")
    if failures:
        print("failed:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
