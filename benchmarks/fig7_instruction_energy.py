"""Fig 7 reproduction — per-instruction FPU energy across op classes.

Scalar & SIMD FMA energies are exact Table IV transcriptions; mul/add/cmp
chains follow the relative gains quoted in §IV.B.3b; conversions follow the
quoted 7.0 pJ fp64/fp32 anchor with 30%/35% steps and the vectorial /
cast-and-pack factors.  The benchmark verifies the paper's qualitative
claims: (1) scalar ops scale at-least-proportionally with width, (2) merged
CONV slices scale WORSE than parallel ADDMUL slices, (3) cast-and-pack
costs ~1.3x one scalar cast (vs 2 casts + pack without it).
"""
from __future__ import annotations

from repro.core import energy
from repro.core.formats import get_format

FMTS = ["fp64", "fp32", "fp16", "fp16alt", "fp8"]


def main():
    print("\n=== Fig 7 — per-instruction FPU energy (pJ) ===")
    hdr = f"{'op':14s}" + "".join(f"{f:>9s}" for f in FMTS)
    print(hdr)
    for (kind, simd), row in energy.OP_ENERGY_PJ.items():
        name = f"{kind}{' simd' if simd else ''}"
        cells = "".join(f"{row.get(f, float('nan')):9.2f}" for f in FMTS)
        print(f"{name:14s}{cells}")

    print("\nconversions (pJ): scalar chain "
          f"{ {f'{a}->{b}': round(v,2) for (a,b),v in energy.CONV_SCALAR_PJ.items()} }")
    print(f"cast-and-pack factor: {energy.CASTPACK_FACTOR}x one scalar cast")

    # claim 1: scalar ADDMUL energy scales at least width-proportionally
    fma = energy.OP_ENERGY_PJ[("fma", False)]
    for a, b in (("fp64", "fp32"), ("fp32", "fp16"), ("fp16", "fp8")):
        width_ratio = get_format(a).width / get_format(b).width
        assert fma[a] / fma[b] >= width_ratio * 0.95, (a, b)
    print("claim: scalar FMA energy scaling >= width-proportional  [OK]")

    # claim 2: merged CONV scales worse than parallel ADDMUL
    conv_gain = 1 - energy.CONV_SCALAR_PJ[("fp32", "fp16")] / \
        energy.CONV_SCALAR_PJ[("fp64", "fp32")]
    fma_gain = 1 - fma["fp16"] / fma["fp32"]
    assert conv_gain < fma_gain, (conv_gain, fma_gain)
    print(f"claim: merged CONV gain ({conv_gain:.0%}) < parallel ADDMUL "
          f"gain ({fma_gain:.0%})  [OK]")

    # claim 3: cast-and-pack beats two separate casts
    assert energy.CASTPACK_FACTOR < 2.0
    print("claim: cast-and-pack (1.3x) beats 2 casts + pack (>2x)  [OK]")


if __name__ == "__main__":
    main()
