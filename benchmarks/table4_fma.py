"""Table IV reproduction — FMA performance/efficiency across formats.

The paper's headline table: latency/throughput, Gflop/s, pJ/flop and
Gflop/sW for the FMA on every format, scalar and SIMD, measured on the
Kosmodrom silicon at 0.8 V / 923 MHz.  We reproduce the derived columns
from the energy model (transcribed measurements) and verify the paper's
quoted relative gains, then compare the *structure* against our TPU
adaptation (format-width-proportional MXU peaks in core/hw.py — the same
SIMD-lane law on a different substrate).
"""
from __future__ import annotations

from repro.core import energy, hw

PAPER_ROWS = [
    # fmt, simd, latency, thru(ops/cyc), Gflop/s, pJ/flop, Gflop/sW, rel
    ("fp64", False, 4, 1, 1.85, 13.36, 74.83, 1.0),
    ("fp32", False, 3, 1, 1.85, 4.72, 211.66, 2.8),
    ("fp16", False, 3, 1, 1.85, 2.48, 403.08, 5.4),
    ("fp16alt", False, 3, 1, 1.85, 2.18, 458.56, 6.1),
    ("fp8", False, 3, 1, 1.85, 1.27, 786.30, 10.5),
    ("fp32", True, 3, 2, 3.71, 5.01, 199.70, 2.7),
    ("fp16", True, 3, 4, 7.42, 2.01, 497.67, 6.7),
    ("fp16alt", True, 3, 4, 7.42, 1.72, 581.96, 7.8),
    ("fp8", True, 2, 8, 14.83, 0.80, 1244.78, 16.6),
]


def main():
    print("\n=== Table IV — FMA across formats (0.8 V, 923 MHz) ===")
    print(f"{'fmt':9s}{'simd':5s}{'Gflop/s':>9s}{'paper':>7s}"
          f"{'pJ/flop':>9s}{'Gflop/sW':>10s}{'paper':>9s}{'rel':>6s}")
    base_eff = energy.fma_efficiency_gflops_w("fp64", False)
    max_rel_err = 0.0
    for fmt, simd, lat, thru, gflops_p, pj, eff_p, rel_p in PAPER_ROWS:
        gflops = energy.fma_perf_gflops(fmt, simd)
        eff = energy.fma_efficiency_gflops_w(fmt, simd)
        rel = eff / base_eff
        for got, want in ((gflops, gflops_p), (eff, eff_p), (rel, rel_p)):
            max_rel_err = max(max_rel_err, abs(got - want) / want)
        print(f"{fmt:9s}{str(simd):5s}{gflops:9.2f}{gflops_p:7.2f}"
              f"{pj:9.2f}{eff:10.1f}{eff_p:9.1f}{rel:6.1f}")
    assert max_rel_err < 0.02, max_rel_err
    print(f"derived columns match the paper within {max_rel_err:.1%}")

    # §IV.B.3b quoted relative gains, recomputed from the table
    e = energy.FMA_PJ_PER_FLOP
    scalar_gains = {
        "fp32->fp16": 1 - e[("fp16", False)] / e[("fp32", False)],
        "fp32->fp16alt": 1 - e[("fp16alt", False)] / e[("fp32", False)],
        "fp16->fp8": 1 - e[("fp8", False)] / e[("fp16", False)],
    }
    # per-datum SIMD gains: pJ/flop ratio of next-larger format
    simd_gains = {
        "fp32->fp16": 1 - e[("fp16", True)] / e[("fp32", True)],
        "fp32->fp16alt": 1 - e[("fp16alt", True)] / e[("fp32", True)],
        "fp16->fp8": 1 - e[("fp8", True)] / e[("fp16", True)],
    }
    print("scalar FMA gains vs next-larger format:",
          {k: f"{v:.0%}" for k, v in scalar_gains.items()},
          " (paper: 48/54/49%)")
    print("SIMD per-datum gains:",
          {k: f"{v:.0%}" for k, v in simd_gains.items()},
          " (paper: 60/66/58% -> super-proportional)")
    # the paper's headline: narrow-format gains are AT LEAST proportional
    for k, v in simd_gains.items():
        assert v >= 0.49, (k, v)    # >= direct 2:1 proportionality

    # TPU adaptation: the same lane law on the MXU (hw.py peaks)
    print("\nTPU v5e adaptation (format-width-proportional MXU peaks):")
    for fmt in ("fp32", "fp16alt", "fp8"):
        print(f"  {fmt:9s} peak {hw.peak_flops(fmt)/1e12:7.1f} TFLOP/s "
              f"({hw.peak_flops(fmt)/hw.peak_flops('fp16alt'):.1f}x bf16)")


if __name__ == "__main__":
    main()
