"""Fig 8 reproduction — voltage/frequency scaling of performance and
efficiency, from the fitted alpha-power DVFS model (core/energy.py).

Published anchor points (FP64 FMA unless noted):
  0.8 V  -> 923 MHz, 74.83 Gflop/sW
  1.2 V  -> 3.17 Gflop/s peak FP64 (=> ~1585 MHz)
  low-V  -> peak efficiency 178 Gflop/sW (FP64), 2.95 Tflop/sW (FP8 SIMD)
"""
from __future__ import annotations

import numpy as np

from repro.core import energy


def main():
    m = energy.DVFSModel()
    print("\n=== Fig 8 — DVFS scaling (FP64 FMA) ===")
    print(f"{'V':>6s} {'f (MHz)':>9s} {'Gflop/s':>9s} {'Gflop/sW':>9s}")
    best_eff, best_v = 0.0, None
    for v in np.arange(0.425, 1.225, 0.025):
        f = m.f_max(v)
        perf = m.perf_gflops(v)
        eff = m.efficiency_gflops_w(v)
        if eff > best_eff:
            best_eff, best_v = eff, v
        if abs(v - 0.8) < 1e-9 or abs(v - 1.2) < 1e-9 or v < 0.46:
            print(f"{v:6.3f} {f/1e6:9.0f} {perf:9.2f} {eff:9.1f}")

    anchors = {
        "f @0.8V (MHz)": (m.f_max(0.8) / 1e6, 923.0),
        "perf @1.2V (Gflop/s)": (m.perf_gflops(1.2), 3.17),
        "eff @0.8V (Gflop/sW)": (m.efficiency_gflops_w(0.8), 74.83),
        "peak eff (Gflop/sW)": (best_eff, 178.0),
    }
    print(f"\npeak efficiency {best_eff:.0f} Gflop/sW at {best_v:.3f} V "
          f"(paper: 178 at low V)")
    worst = 0.0
    for name, (got, want) in anchors.items():
        dev = abs(got - want) / want
        worst = max(worst, dev)
        print(f"  {name:24s} model {got:8.1f}  paper {want:8.1f} "
              f"({dev:+.1%})")
    assert worst < 0.20, worst
    # FP8 SIMD peak efficiency: scale by the measured pJ/flop ratio
    fp8 = best_eff * (13.36 / 0.80)
    print(f"FP8 SIMD peak efficiency (scaled): {fp8/1e3:.2f} Tflop/sW "
          f"(paper: 2.95)")
    assert abs(fp8 / 1e3 - 2.95) / 2.95 < 0.25
    print("DVFS anchors within 20%  [OK]")


if __name__ == "__main__":
    main()
