"""Table III reproduction — the paper's transprecision case study (§IV.C).

Accumulation of element-wise products of two FP16 input streams, in the five
code variants of Fig 11:

  a) fmac.h        — FP16 multiply, FP16 accumulate          (3 instr/pair)
  b) fcvt+fmadd.s  — cast up, FP32 FMA                       (5 instr/pair)
  c) fmul.h+fadd.s — FP16 multiply, cast, FP32 add           (5 instr/pair)
  d) SIMD c)       — 2-wide vectorized c)                    (3.5 instr/pair)
  e) fmacex.s.h    — expanding FMA: FP16 mul, FP32 acc       (3 instr/pair)

We reproduce BOTH axes of Table III with our bit-exact softfloat layer and
the silicon-calibrated energy model:
  * accuracy — result precision in correct bits vs the exact (f64) result,
  * energy  — relative core/system energy, predicted from the Fig 7 / Table
    IV per-instruction energies + instruction counts (one fitted core
    overhead; the paper's FP32 variant is the 1.00 anchor).

Paper values: bits correct a/b/c/d/e = 9/22/19/19/22;
core energy rel = 0.60/1.00/1.16/0.97/0.63;
system energy rel = 0.63/1.00/1.03/0.75/0.63.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, softfloat

N = 1024
PAPER = {
    "a": dict(bits=9, core=0.60, system=0.63),
    "b": dict(bits=22, core=1.00, system=1.00),
    "c": dict(bits=19, core=1.16, system=1.03),
    "d": dict(bits=19, core=0.97, system=0.75),
    "e": dict(bits=22, core=0.63, system=0.63),
}


def _q(x, fmt, mode="rne"):
    return softfloat.quantize(jnp.asarray(x, jnp.float32), fmt, mode)


def run_variants(seed=0, n=N):
    rs = np.random.RandomState(seed)
    a64 = rs.uniform(0.0, 1.0, n)
    b64 = rs.uniform(0.0, 1.0, n)
    a16 = np.asarray(_q(a64.astype(np.float32), "fp16"), np.float64)
    b16 = np.asarray(_q(b64.astype(np.float32), "fp16"), np.float64)
    exact = float(np.sum(a16 * b16))       # inputs ARE fp16; exact in f64

    def scan_acc(fn):
        acc = jnp.float32(0.0)
        va, vb = jnp.asarray(a16, jnp.float32), jnp.asarray(b16, jnp.float32)

        def step(acc, ab):
            return fn(acc, ab[0], ab[1]), ()
        out, _ = jax.lax.scan(step, acc, (va, vb))
        return float(out)

    # a) fmac.h: acc16 = RNE16(a*b + acc)  (single rounding, fp16 result)
    res_a = scan_acc(lambda acc, x, y: softfloat.quantize(x * y + acc,
                                                          "fp16"))
    # b) fmadd.s on cast-up operands: acc32 = RNE32(a*b + acc)
    res_b = scan_acc(lambda acc, x, y: x * y + acc)   # f32 ops = RNE32
    # c)/d) fmul.h then fadd.s: p = RNE16(a*b); acc32 += p
    res_c = scan_acc(lambda acc, x, y: softfloat.quantize(x * y, "fp16")
                     + acc)
    res_d = res_c                                     # same numerics, SIMD
    # e) fmacex.s.h: exact fp16 product, single RNE32 accumulate
    res_e = res_b   # products of fp16 values are exact in f32 -> identical

    def bits(res):
        rel = abs(res - exact) / abs(exact)
        return 30 if rel == 0 else max(0, math.floor(-math.log2(rel)))

    return exact, {"a": (res_a, bits(res_a)), "b": (res_b, bits(res_b)),
                   "c": (res_c, bits(res_c)), "d": (res_d, bits(res_d)),
                   "e": (res_e, bits(res_e))}


def instruction_streams():
    """Per input pair: (n_instr, n_loads, [(fpu_op, count), ...]).

    Fig 11's assembly, per pair of inputs.  Variant d processes two pairs
    per iteration (2-wide SIMD) — counts are halved accordingly."""
    return {
        "a": (3, 2, [("fma_fp16", 1)]),
        "b": (5, 2, [("cvt", 2), ("fma_fp32", 1)]),
        "c": (5, 2, [("mul_fp16", 1), ("cvt", 1), ("add_fp32", 1)]),
        "d": (3.5, 1, [("vfmul_fp16", 0.5), ("cvt", 1), ("add_fp32", 1)]),
        "e": (3, 2, [("fmacex", 1)]),
    }


def energy_model():
    """Relative core/system energy per variant, from the RI5CY merged-slice
    energy table (core/energy.py).  The fp32-FMA energy is the paper's
    measured 3.9 pJ; core overhead + background power make up the rest
    (system energy is dominated by SoC background — the paper measures
    22.2 pJ/cycle at system level vs 3.9 pJ in the FPU, §IV.A.2)."""
    pj = energy.RI5CY_MERGED_PJ
    c = energy.RI5CY_CORE_PJ
    out = {}
    for k, (n_instr, loads, ops) in instruction_streams().items():
        fpu = sum(pj[op] * cnt for op, cnt in ops)
        core = n_instr * c["overhead_per_instr"] + loads * c["load_extra"] \
            + fpu
        syse = core + loads * c["mem_extra"] \
            + n_instr * c["background_per_instr"]
        out[k] = {"core": core, "system": syse}
    norm_c, norm_s = out["b"]["core"], out["b"]["system"]
    return {k: {"core": v["core"] / norm_c, "system": v["system"] / norm_s}
            for k, v in out.items()}


def main():
    exact, res = run_variants()
    en = energy_model()
    print("\n=== Table III — transprecision case study (paper §IV.C) ===")
    print(f"{'variant':8s} {'bits':>5s} {'paper':>6s} | "
          f"{'core':>6s} {'paper':>6s} | {'system':>6s} {'paper':>6s}")
    rows = []
    for k in "abcde":
        r, b = res[k]
        rows.append((k, b, PAPER[k]["bits"], en[k]["core"],
                     PAPER[k]["core"], en[k]["system"], PAPER[k]["system"]))
        print(f"{k:8s} {b:5d} {PAPER[k]['bits']:6d} | "
              f"{en[k]['core']:6.2f} {PAPER[k]['core']:6.2f} | "
              f"{en[k]['system']:6.2f} {PAPER[k]['system']:6.2f}")
    # headline claims: e) matches b)'s accuracy at a)'s cost
    assert res["e"][1] == res["b"][1] >= 21
    assert res["a"][1] <= 12
    assert res["c"][1] < res["b"][1]
    assert en["e"]["core"] < 0.75 and en["e"]["system"] < 0.75
    assert en["c"]["core"] > 1.0
    print("claims: e==b accuracy, a/c degraded, e saves >25% energy  [OK]")
    return rows


if __name__ == "__main__":
    main()
