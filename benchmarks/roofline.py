"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Reads results/costs_*.json (scan-corrected per-device cost terms on the
single-pod 16x16 mesh) and results/dryrun_*.json (memory analysis), and
derives the three roofline terms per (arch x shape):

  compute term    = HLO_FLOPs / peak_FLOP/s          [per device]
  memory term     = HLO_bytes / HBM_bw
  collective term = collective_bytes_moved / link_bw

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI (core/hw.py).  Collective bytes-moved applies ring-model
factors to the parsed per-op output sizes:
  all-gather: (g-1)/g * out   all-reduce: 2 (g-1)/g * out
  reduce-scatter: (g-1) * out all-to-all: (g-1)/g * out   permute: out

MODEL_FLOPS uses 6*N*D (train) / 2*N*D (prefill, + one forward) /
2*N*B (decode, per step) with N = per-use active params ("flops" count),
plus the attention term where quadratic.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

from repro.core import hw
from repro.models.registry import get_config

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}
N_DEV = 256

_RING = {
    "all-gather": lambda b, g: b * (g - 1) / g,
    "all-reduce": lambda b, g: 2 * b * (g - 1) / g,
    "reduce-scatter": lambda b, g: b * (g - 1),
    "all-to-all": lambda b, g: b * (g - 1) / g,
    "collective-permute": lambda b, g: b,
}


def coll_bytes_moved(coll: dict) -> float:
    total = 0.0
    for key, rec in coll.items():
        op, g = key.split("@")
        total += _RING[op](rec["bytes"], max(int(g), 2))
    return total


def model_flops_global(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n = cfg.param_counts()["flops"]
    seq, batch = sh["seq"], sh["batch"]
    # attention term: 4*B*S*ctx*H*Dh per attn layer (QK^T + PV, fwd)
    attn = 0.0
    for spec in cfg.layer_list():
        if spec.mixer in ("gqa", "shared_attn", "mla"):
            dh = (cfg.nope_dim + cfg.rope_dim + cfg.v_head_dim) / 2 \
                if spec.mixer == "mla" else cfg.head_dim
            if sh["kind"] == "decode":
                ctx = seq if spec.window is None else min(spec.window, seq)
                attn += 4 * batch * ctx * cfg.n_heads * dh
            else:
                ctx = seq / 2 if spec.window is None else \
                    min(spec.window, seq / 2)
                attn += 4 * batch * seq * ctx * cfg.n_heads * dh
    if sh["kind"] == "train":
        return 6 * n * batch * seq + 3 * attn
    if sh["kind"] == "prefill":
        return 2 * n * batch * seq + attn
    return 2 * n * batch + attn        # decode: one token per row


def analyze(results_dir: str = "results") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "costs_*.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        arch, shape = rec["arch"], rec["shape"]
        flops, byts = rec["flops"], rec["bytes"]
        cb = coll_bytes_moved(rec.get("coll", {}))
        t_c = flops / hw.PEAK_FLOPS_BF16
        t_m = byts / hw.HBM_BW
        t_x = cb / hw.ICI_BW_PER_LINK
        dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
        mf = model_flops_global(arch, shape) / N_DEV
        # memory-analysis record (single-pod) for HBM fit
        dr = os.path.join(results_dir, f"dryrun_{arch}_{shape}_pod1.json")
        peak = None
        if os.path.exists(dr):
            d = json.load(open(dr))
            if d.get("ok"):
                peak = d["memory"]["peak_bytes"]
        rows.append(dict(
            arch=arch, shape=shape, t_compute=t_c, t_memory=t_m,
            t_collective=t_x, dominant=dom[1],
            step_time_bound=max(t_c, t_m, t_x),
            roofline_fraction=dom[0] and t_c / max(t_c, t_m, t_x),
            model_flops=mf, hlo_flops=flops, useful=mf / flops if flops
            else 0.0, peak_bytes=peak, method=rec.get("method", "")))
    return rows


def advice(row) -> str:
    if row["dominant"] == "compute":
        if row["useful"] < 0.5:
            return ("compute-bound but <50% useful: cut remat recompute / "
                    "CE+attention overhead (fused kernels)")
        return "compute-bound near roofline: narrower formats (fp8 MXU) next"
    if row["dominant"] == "memory":
        return ("memory-bound: narrower storage formats (fp8 KV/params), "
                "fuse quantize into matmul epilogue")
    return ("collective-bound: narrower wire formats (fp8 grad/activation "
            "collectives), overlap with compute, shrink group size")


def render(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | roofline frac | MODEL/HLO flops |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful']:.2f} |")
    return "\n".join(out)


def step_energy_row(row) -> dict:
    """Cluster-scale energy per step (paper's energy-proportionality
    thesis at datacenter scale): measured per-device HLO terms x the
    calibrated per-format energy model, x 256 chips."""
    from repro.core import energy
    # matmul flops run in the policy's src format (bf16 baseline)
    e = energy.step_energy_joules(
        {"fp16alt": row["hlo_flops"]},
        hbm_bytes=row["t_memory"] * hw.HBM_BW,
        ici_bytes=row["t_collective"] * hw.ICI_BW_PER_LINK) * N_DEV
    e_fp32 = energy.step_energy_joules(
        {"fp32": row["hlo_flops"]},
        hbm_bytes=row["t_memory"] * hw.HBM_BW * 2,
        ici_bytes=row["t_collective"] * hw.ICI_BW_PER_LINK * 2) * N_DEV
    return {"joules": e, "joules_fp32_equiv": e_fp32,
            "saving": 1 - e / e_fp32}


def main(results_dir: str = "results"):
    rows = analyze(results_dir)
    if not rows:
        print(f"(no cost records in {results_dir}/ — run "
              f"`python -m repro.launch.dryrun --all` first)")
        return []
    print("\n=== Roofline (per device, single-pod 16x16, tp_bf16) ===")
    print(render(rows))
    print("\nbottleneck advice:")
    for r in sorted(rows, key=lambda r: r["roofline_fraction"])[:10]:
        print(f"  {r['arch']}/{r['shape']}: {advice(r)}")
    print("\n=== Modeled step energy, 256 chips (paper thesis at scale) ===")
    print(f"{'cell':40s} {'tp_bf16 J':>10s} {'fp32-equiv J':>13s} {'saving':>7s}")
    for r in rows:
        if r["shape"] != "train_4k":
            continue
        e = step_energy_row(r)
        print(f"{r['arch']+'/'+r['shape']:40s} {e['joules']:10.1f} "
              f"{e['joules_fp32_equiv']:13.1f} {e['saving']:7.0%}")
    return rows


if __name__ == "__main__":
    main()
