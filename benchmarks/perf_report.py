"""§Perf report: assemble the hillclimb log from results/perf/ and compute
the TPU-projection for narrow-wire knobs.

CPU-backend caveat measured in the loop: XLA:CPU's float-normalization
promotes bf16 dot outputs (and all-reduces) to f32 *before* SPMD
partitioning, so `narrow_partials` (bf16 tensor-parallel partial-sum
all-reduces) cannot change the CPU-compiled HLO byte counts — on TPU the
dot emits bf16 and the AR wire format follows.  `tpu_projection` measures
the fraction of all-reduce bytes attributable to dot partial-sums that the
model immediately converts to bf16, and halves exactly that fraction.
"""
from __future__ import annotations

import json
import re


def classify_ar_bytes(hlo_text: str) -> dict:
    """Split all-reduce bytes into dot-partials (narrowable) vs other."""
    out = {"dot_f32": 0, "other": 0}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*f32\[([0-9,]+)\][^ ]*\s+all-reduce\(", line)
        if not m:
            continue
        n = 1
        for d in m.group(1).split(","):
            n *= int(d)
        nbytes = 4 * n
        op = re.search(r'op_name="([^"]*)"', line)
        name = op.group(1) if op else ""
        if "dot_general" in name and "transpose" not in name.split("/")[-2:][0]:
            out["dot_f32"] += nbytes
        elif "dot_general" in name:
            out["dot_f32"] += nbytes      # bwd dots are narrowable too
        else:
            out["other"] += nbytes
    return out


def tpu_projection(arch: str, shape: str, sets: list) -> dict:
    """Measure the dot-AR fraction on R1/R2 variants and project the
    narrow_partials halving (TPU wire format)."""
    from repro.launch.dryrun import (SHAPES, _apply_sets, _variant,
                                     lower_and_compile)
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import get_config
    from repro.core import ops as tpops
    tpops.set_mixed_dot(True)
    cfg = _apply_sets(get_config(arch), sets)
    mesh = make_production_mesh()
    sh = SHAPES[shape]
    out = {}
    for groups in (1, 2):
        v = _variant(cfg, groups,
                     full_seq=sh["seq"] if sh["kind"] != "decode" else None)
        _, co, _ = lower_and_compile(v, shape, mesh, "tp_bf16")
        out[groups] = classify_ar_bytes(co.as_text())
    reps = cfg.repeats
    proj = {}
    for k in ("dot_f32", "other"):
        proj[k] = out[1][k] + (reps - 1) * max(out[2][k] - out[1][k], 0)
    total = proj["dot_f32"] + proj["other"]
    narrowed = proj["dot_f32"] / 2 + proj["other"]
    return {"ar_bytes_total": total, "ar_bytes_dot": proj["dot_f32"],
            "ar_bytes_tpu_narrow": narrowed,
            "reduction": 1 - narrowed / total if total else 0.0}


def main():
    import argparse
    from repro.launch.dryrun import force_dryrun_devices
    force_dryrun_devices()   # before jax init: lowering needs the 512-mesh
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internvl2-26b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--set", action="append", dest="sets",
                    default=["remat_policy=dots"])
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    r = tpu_projection(args.arch, args.shape, args.sets)
    print(json.dumps(r, indent=1))
    if args.json:
        json.dump(r, open(args.json, "w"), indent=1)


if __name__ == "__main__":
    main()
