"""Serving benchmark: prefill latency + steady-state decode tok/s.

Prefill is A/B'd dense-vs-pallas (``prefill_dense_ms`` / ``prefill_pallas_ms``:
the pure-JAX chunked softmax vs the pruned-grid Pallas flash-attention
kernel behind ``cfg.prefill_backend``), and three decode paths are compared,
on reduced archs (CPU; the same code runs compiled on TPU):

  * ``python``      — the seed per-step loop: one jit'd ``decode_step``
                      dispatch per generated token.
  * ``scan``        — ``Model.generate``: the whole generation is ONE
                      compiled ``lax.scan`` (one dispatch total).
  * ``scan+pallas`` — the scan loop with the fused in-kernel KV-dequant
                      Pallas decode-attention kernel under an fp8 KV cache
                      (policy tp_bf16_kv8): the quantized-cache serving
                      scenario of the FPnew storage-format story.

Steady-state tok/s for the scan paths is measured by differencing two
generation lengths (removes prefill + constant dispatch cost); the python
loop is timed directly over its steps (that IS its steady state).

Ragged A/B (``ragged_prefill_ms`` / ``ragged_decode_tok_s``): the same
padded batch served with four mixed prompt lengths (1/4, 1/2, 3/4, 4/4 of
``prompt_len``) through the per-sequence length plumbing, against the
uniform-padded baseline (``prefill_dense_ms`` / ``scan_tok_s`` — every row
paying the max length).  On CPU the dense path only saves masked-out FLOPs
the hardware still executes; the per-row grid pruning shows up on real
accelerators, where the Pallas kernels skip each row's dead KV blocks.

Paged A/B (``paged_decode_tok_s`` / ``paged_page_size``): the same scan
generation served from the paged KV cache (shared page pool + block-table
indirection, identity tables) against the contiguous baseline
(``scan_tok_s``).  On CPU the dense decode path pays a per-step gather to
rebuild the contiguous view — the column tracks that overhead honestly;
on TPU the Pallas kernel dereferences the table in its index maps and the
gather disappears.  Archs whose mixers cannot page (SSM/MLA/cross-attn)
carry null paged columns, like the ragged ones.

Continuous A/B (``continuous_decode_tok_s`` / ``fixed_batch_tok_s`` /
``continuous_speedup`` / ``continuous_batch_occupancy`` /
``peak_live_pages``): the PR-5 continuous-batching engine
(launch/engine.py — while_loop decode bursts, page-recycling admission,
chunked prefill) against fixed FIFO batches on ONE deterministic
heavy-tail arrival trace (``engine.synthetic_trace``).  Both sides serve
the same requests on the same slot count; useful tokens = the sum of
per-request budgets.  Fixed batching runs every batch to its max budget
(padding short rows — the pre-engine loop's cost model) while the engine
frees a finished row's pages the round it finishes and admits from the
queue mid-generation; ``peak_live_pages`` tracks the pool high-water mark
against the ``slots x max_pages`` a fixed paged batch pins for the whole
run.  Archs that cannot page carry null continuous columns.

Speculative A/B (``spec_decode_tok_s`` / ``spec_accept_rate`` /
``spec_token_parity``): the PR-9 transprecision speculative decoder — a
shallow layer-skip draft proposes k tokens per row, one chunk-scoring
verify call at target precision accepts the longest matching prefix —
against the plain greedy engine on the same trace.  Parity must be TRUE:
speculation is only allowed to change speed, never a token.

Replica-HA soak (``ha_drained`` / ``ha_kills`` / ``ha_migrations`` /
``ha_token_parity`` / ``ha_replay_parity``): the PR-10 fault-tolerance
machinery on a multi-turn ``flavor="session"`` trace — a 2-replica fleet
loses one replica to an injected kill and must drain with tokens identical
to the unfailed fleet, then a single-replica journaled fleet is killed
outright and ``run_with_restarts`` + the request journal must recover the
same streams.  Both parity columns gate TRUE; archs that cannot page carry
nulls.

Writes BENCH_serve.json at the repo root so the serving-perf trajectory is
tracked PR-over-PR.

``PYTHONPATH=src python -m benchmarks.serve_decode [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
import time

ARCHS = ("gemma2-9b", "qwen3-moe-30b-a3b")
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_serve.json") if "__file__" in globals() else \
    "BENCH_serve.json"


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def _time_call(fn, repeats=3):
    import jax
    jax.block_until_ready(fn())          # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return _median(ts)


def bench_arch(arch: str, *, batch: int, prompt_len: int, gen: int,
               repeats: int = 3, quick: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.models.registry import build_model

    short = max(2, gen // 4)
    row = {"batch": batch, "prompt_len": prompt_len, "gen": gen}

    def build(policy, backend):
        model = build_model(arch, policy=policy,
                            reduced=True).with_cfg(decode_backend=backend)
        params = model.init(jax.random.key(0))
        prompts = jax.random.randint(
            jax.random.key(1), (batch, prompt_len), 0, model.cfg.vocab)
        return model, params, prompts

    max_len = prompt_len + gen
    model, params, prompts = build("tp_bf16", "dense")

    # -- prefill latency: dense vs pruned-grid Pallas A/B -------------------
    # (pallas runs in interpret mode on CPU — expected to lose here; the A/B
    # tracks both so the TPU rerun lands in the same columns.)
    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    row["prefill_ms"] = _time_call(
        lambda: prefill(params, prompts)[0], repeats) * 1e3
    row["prefill_dense_ms"] = row["prefill_ms"]
    model_pp = model.with_cfg(prefill_backend="pallas")
    prefill_pp = jax.jit(lambda p, t: model_pp.prefill(p, t, max_len=max_len))
    row["prefill_pallas_ms"] = _time_call(
        lambda: prefill_pp(params, prompts)[0], repeats) * 1e3

    # -- python per-step loop (the seed path) -------------------------------
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    lg, caches0 = prefill(params, prompts)
    tok0 = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    _ = jax.block_until_ready(step(params, tok0, caches0, prompt_len)[0])

    def run_loop():
        tok, caches = tok0, caches0
        for i in range(gen - 1):
            lg, caches = step(params, tok, caches, prompt_len + i)
            tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        return tok

    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(run_loop())
        ts.append(time.perf_counter() - t0)
    row["python_tok_s"] = batch * (gen - 1) / _median(ts)

    # -- scan paths ---------------------------------------------------------
    def scan_tok_s(model, params, prompts, prompt_lens=None, key=""):
        long_fn = jax.jit(lambda p, t, l: model.generate(
            p, t, gen_len=gen, max_len=max_len, prompt_lens=l)[0])
        short_fn = jax.jit(lambda p, t, l: model.generate(
            p, t, gen_len=short, max_len=max_len, prompt_lens=l)[0])
        t_long = _time_call(lambda: long_fn(params, prompts, prompt_lens),
                            repeats)
        t_short = _time_call(lambda: short_fn(params, prompts, prompt_lens),
                             repeats)
        dt = t_long - t_short
        if dt <= 0:
            # timing noise swamped the per-token cost (tiny model / loaded
            # box): report the conservative whole-run rate instead of an
            # astronomical differenced number, and flag it in the row
            print(f"  [warn] unstable {key or 'scan'} differencing "
                  f"(dt={dt * 1e3:.3f} ms); falling back to whole-run rate",
                  flush=True)
            row[f"{key}steady_state_unstable"] = True
            return batch * gen / t_long
        return batch * (gen - short) / dt

    row["scan_tok_s"] = scan_tok_s(model, params, prompts)
    row["scan_speedup"] = row["scan_tok_s"] / row["python_tok_s"]

    # -- ragged A/B: 4 mixed prompt lengths vs the uniform-padded batch -----
    # (attention archs only: Model.prefill refuses prompt_lens for SSM /
    # hybrid mixers — recurrent state can't mask pad tokens — so those rows
    # carry null ragged columns, keeping the ci.sh schema gate honest.)
    from repro.launch.serve import ragged_lengths
    lens = ragged_lengths(batch, prompt_len)
    row["ragged_lens"] = lens
    if any(s.mixer in ("mamba2", "mlstm", "slstm")
           for s in model.cfg.layer_list()):
        row["ragged_prefill_ms"] = None
        row["ragged_decode_tok_s"] = None
        row["ragged_unsupported"] = "ssm mixers"
    else:
        prompt_lens = jnp.asarray(lens, jnp.int32)
        prefill_rg = jax.jit(lambda p, t, l: model.prefill(
            p, t, max_len=max_len, prompt_lens=l))
        row["ragged_prefill_ms"] = _time_call(
            lambda: prefill_rg(params, prompts, prompt_lens)[0],
            repeats) * 1e3
        row["ragged_decode_tok_s"] = scan_tok_s(model, params, prompts,
                                                prompt_lens, key="ragged_")

    # -- paged KV A/B: block-table pool vs the contiguous cache -------------
    page = max(8, prompt_len // 2)
    row["paged_page_size"] = page
    paged_why = model.cfg.paged_unsupported_reason()
    if paged_why is not None:
        row["paged_decode_tok_s"] = None
        row["paged_unsupported"] = paged_why
    else:
        model_pg = model.with_cfg(paged_kv=True, page_size=page)
        row["paged_decode_tok_s"] = scan_tok_s(model_pg, params, prompts,
                                               key="paged_")

    # -- continuous-vs-fixed A/B on a deterministic arrival trace -----------
    # (the PR-5 serving engine: while_loop decode bursts, page-recycling
    # admission, chunked prefill.  BOTH sides serve the SAME heavy-tail
    # trace on the same slot count: fixed batching runs each batch to its
    # max budget via the scan path — the pre-engine serving loop — while
    # the engine pays each row only its own budget and backfills freed
    # slots.  Archs that cannot page carry null columns.)
    cont = continuous_ab(arch, prompt_len=prompt_len, quick=quick)
    row.update(cont)

    # -- speculative-vs-plain A/B on the same engine + trace ----------------
    # (the PR-9 transprecision speculative decoder: a layer-skip draft
    # proposes k tokens per row, one chunk-scoring verify at target
    # precision accepts the longest matching prefix.  The accepted stream
    # must be BIT-IDENTICAL to plain greedy serving — ``spec_token_parity``
    # gates it — so the only thing speculation may change is speed.)
    row.update(speculative_ab(arch, prompt_len=prompt_len, quick=quick))

    # -- robustness soak: overload + injected faults must drain -------------
    # (the PR-6 backpressure machinery: bursty over-committed arrivals on a
    # constrained page pool with injected exhaustion / stragglers / poisoned
    # logits.  The gate is DRAINAGE — every request finishes its full
    # budget — with the preempt/shed/degrade/deadline counters recorded.)
    row.update(robustness_soak(arch, prompt_len=prompt_len, quick=quick))

    # -- replica HA soak: kill one replica, migrate, drain; replay a full
    # -- fleet loss from the request journal (the PR-10 machinery) ----------
    row.update(ha_soak(arch, prompt_len=prompt_len, quick=quick))

    # -- numerical health: flag-telemetry overhead + escalation/SDC soak ----
    # (the PR-7 machinery: IEEE flag counters in the decode kernel, flag-
    # driven KV-precision escalation, checksummed swap payloads.)
    row.update(flag_overhead(repeats=repeats))
    row.update(numerical_health_soak(arch, prompt_len=prompt_len,
                                     quick=quick))

    # -- mesh-sharded serving A/B + simulated-fleet dryrun stats ------------
    # (the PR-8 tensor-parallel machinery: head-sharded attention + paged
    # pools over the `model` axis, psum'd output projections.  Runs in a
    # SUBPROCESS with 8 forced host devices — the parent must keep its
    # single real CPU device for every other timing column.)
    row.update(shard_ab(arch, prompt_len=prompt_len, quick=quick))

    # -- scan + fused Pallas decode kernel over an fp8 KV cache -------------
    row["scan_pallas_kv8_tok_s"] = scan_tok_s(*build("tp_bf16_kv8", "pallas"))
    return row


def shard_probe(arch: str, *, prompt_len: int, gen: int = 64,
                slots: int = 4, n_req: int = 12) -> dict:
    """Tensor-parallel vs single-device continuous serving, INSIDE the
    multi-device subprocess (both legs share the 8-device process so the
    A/B is apples-to-apples).  The tp leg head-shards attention + the
    paged KV pools over a ``("model",)`` mesh; tokens must match the
    unsharded leg exactly (per-head attention is bitwise, the psum'd
    projection snaps once after an fp32 reduction — see
    docs/ARCHITECTURE.md).  On simulated CPU devices the shard_map
    overhead usually LOSES to single-device — ``shard_speedup`` tracks
    the honest ratio; the column exists so the TPU rerun lands in it."""
    import jax
    from repro.launch.engine import ContinuousEngine, synthetic_trace
    from repro.launch.mesh import make_serving_mesh, replica_meshes
    from repro.models.registry import build_model

    model = build_model(arch, policy="tp_bf16", reduced=True)
    why = model.cfg.paged_unsupported_reason()
    nulls = {"shard_devices": None, "shard_decode_tok_s": None,
             "shard_base_tok_s": None, "shard_speedup": None,
             "shard_token_parity": None}
    if why is not None:
        return dict(nulls, shard_unsupported=why)
    h, hkv = model.cfg.n_heads, model.cfg.n_kv_heads
    tp = next((t for t in (8, 4, 2)
               if t <= jax.device_count() and h % t == 0 and hkv % t == 0),
              1)
    if tp < 2:
        return dict(nulls,
                    shard_unsupported=f"no head split: h={h} hkv={hkv} "
                                      f"devices={jax.device_count()}")
    model_pg = model.with_cfg(paged_kv=True, page_size=16)
    params = model_pg.init(jax.random.key(0))
    max_len = prompt_len + gen
    reqs = synthetic_trace(n_req, slots, prompt_len, gen, model.cfg.vocab)
    useful = sum(r.max_new for r in reqs)

    def leg(mesh):
        eng = ContinuousEngine(model_pg, params, slots=slots,
                               max_len=max_len, chunk=16, burst_cap=256,
                               mesh=mesh)
        eng.run(reqs)                              # compile + warm
        t0 = time.perf_counter()
        fin, _ = eng.run(reqs)
        return useful / (time.perf_counter() - t0), fin

    base_rate, fin_a = leg(None)
    mesh = replica_meshes(make_serving_mesh(1, tp))[0]
    shard_rate, fin_b = leg(mesh)
    return {
        "shard_devices": tp,
        "shard_decode_tok_s": shard_rate,
        "shard_base_tok_s": base_rate,
        "shard_speedup": shard_rate / base_rate,
        "shard_token_parity": all(a.tokens == b.tokens
                                  for a, b in zip(fin_a, fin_b)),
    }


def shard_ab(arch: str, *, prompt_len: int, quick: bool = False) -> dict:
    """Drive ``shard_probe`` in a subprocess with 8 forced host devices,
    then collect dryrun cost/memory stats for the production serving
    shape at 256 (single-pod) and 512 (multi-pod) simulated devices.
    Skipped entirely under ``--quick`` (CI smoke keeps one device)."""
    import subprocess
    import sys
    import tempfile

    if quick:
        return {}
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_decode",
         "--shard-probe", arch, "--prompt-len", str(prompt_len)],
        capture_output=True, text=True, env=env)
    out = None
    for line in r.stdout.splitlines():
        if line.startswith("SHARD_JSON "):
            out = json.loads(line[len("SHARD_JSON "):])
    if out is None:
        raise RuntimeError(
            f"shard probe subprocess failed for {arch} "
            f"(rc={r.returncode}):\n{(r.stderr or '')[-2000:]}")
    assert out.get("shard_token_parity") in (True, None), \
        f"sharded serving changed tokens for {arch}"

    # dryrun leg: lower + compile the decode cell on the 256- and
    # 512-device production meshes and record the per-device footprint
    devs, peak, flops = [], [], []
    for mp in (False, True):
        with tempfile.NamedTemporaryFile(suffix=".json") as tmp:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", "decode_32k",
                   "--json", tmp.name] + (["--multi-pod"] if mp else [])
            rr = subprocess.run(cmd, capture_output=True, text=True,
                                env={**os.environ})
            try:
                with open(tmp.name) as f:
                    rec = json.load(f)
            except (OSError, ValueError):
                rec = {}
        if not rec.get("ok"):
            raise RuntimeError(
                f"dryrun decode_32k {'pod2' if mp else 'pod1'} failed for "
                f"{arch} (rc={rr.returncode}):\n"
                f"{rec.get('error', (rr.stderr or '')[-2000:])}")
        devs.append(rec["n_devices"])
        peak.append(rec["memory"]["peak_bytes"])
        flops.append(rec["hlo"]["flops"])
    out.update(shard_dryrun_devices=devs, shard_dryrun_peak_bytes=peak,
               shard_dryrun_flops=flops)
    return out


def continuous_ab(arch: str, *, prompt_len: int, quick: bool = False,
                  slots: int = 8, gen_long: int = 192,
                  n_req: int = 48) -> dict:
    """Continuous-batching engine vs fixed batches on one arrival trace.

    Useful tokens = the sum of per-request budgets (identical on both
    sides; the fixed batches' padding tokens past a row's budget are waste,
    which is exactly the point).  Also records mean batch-slot occupancy
    and the page pool's high-water mark against the ``slots x max_pages``
    a fixed paged batch would pin."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.launch.engine import ContinuousEngine, synthetic_trace
    from repro.models.registry import build_model

    if quick:
        slots, gen_long, n_req = 4, 32, 10
    model = build_model(arch, policy="tp_bf16", reduced=True)
    why = model.cfg.paged_unsupported_reason()
    if why is not None:
        return {"continuous_decode_tok_s": None, "fixed_batch_tok_s": None,
                "continuous_speedup": None, "continuous_batch_occupancy":
                None, "peak_live_pages": None, "continuous_unsupported": why}
    page = 16
    model_pg = model.with_cfg(paged_kv=True, page_size=page)
    params = model_pg.init(jax.random.key(0))
    max_len = prompt_len + gen_long
    reqs = synthetic_trace(n_req, slots, prompt_len, gen_long,
                           model.cfg.vocab)
    useful = sum(r.max_new for r in reqs)

    eng = ContinuousEngine(model_pg, params, slots=slots, max_len=max_len,
                           chunk=16, burst_cap=256)
    eng.run(reqs)                                  # compile + warm
    ts = []
    for _ in range(1 if quick else 3):
        t0 = time.perf_counter()
        fin, st = eng.run(reqs)
        ts.append(time.perf_counter() - t0)
    dt_c = _median(ts)
    assert all(len(f.tokens) == r.max_new for f, r in zip(fin, reqs))

    # fixed baseline: FIFO batches of up to `slots` arrived requests, each
    # run to its max budget through the scan path (the pre-engine loop)
    def fixed_plan():
        q = sorted(reqs, key=lambda r: (r.arrival, r.rid))
        plan, clock, i = [], 0, 0
        while i < len(q):
            clock = max(clock, q[i].arrival)
            batch = [r for r in q[i:i + slots] if r.arrival <= clock]
            i += len(batch)
            g = max(r.max_new for r in batch)
            plan.append((batch, g))
            clock += g
        return plan

    plan = fixed_plan()
    fns = {}

    def fx(bsz, g):
        if (bsz, g) not in fns:
            fns[(bsz, g)] = jax.jit(lambda p, t, l: model_pg.generate(
                p, t, gen_len=g, max_len=max_len, prompt_lens=l)[0])
        return fns[(bsz, g)]

    def batch_args(batch):
        toks = np.zeros((len(batch), prompt_len), np.int32)
        lens = np.asarray([r.prompt_len for r in batch], np.int32)
        for j, r in enumerate(batch):
            toks[j, :r.prompt_len] = r.tokens
        return jnp.asarray(toks), jnp.asarray(lens)

    for batch, g in plan:                          # compile + warm
        t, l = batch_args(batch)
        jax.block_until_ready(fx(len(batch), g)(params, t, l))
    ts = []
    for _ in range(1 if quick else 3):
        t0 = time.perf_counter()
        for batch, g in plan:
            t, l = batch_args(batch)
            jax.block_until_ready(fx(len(batch), g)(params, t, l))
        ts.append(time.perf_counter() - t0)
    dt_f = _median(ts)

    return {
        "continuous_decode_tok_s": useful / dt_c,
        "fixed_batch_tok_s": useful / dt_f,
        "continuous_speedup": dt_f / dt_c,
        "continuous_batch_occupancy": st["occupancy"],
        "peak_live_pages": st["peak_live_pages"],
        "continuous_fixed_equiv_pages": st["fixed_equiv_pages"],
        "continuous_slots": slots,
        "continuous_n_requests": n_req,
        "continuous_useful_tokens": useful,
        "continuous_rounds": st["rounds"],
        "continuous_bursts": st["bursts"],
    }


def speculative_ab(arch: str, *, prompt_len: int, quick: bool = False,
                   slots: int = 4, gen: int = 64, n_req: int = 12,
                   spec_k: int = 3, draft_repeats: int = 1) -> dict:
    """Speculative-vs-plain continuous serving on one arrival trace.

    Both engines serve the SAME deterministic trace on the same slots;
    the speculative leg drafts ``spec_k`` tokens per row with a
    ``draft_repeats``-deep layer-skip pass and verifies the chunk in one
    target-precision call.  ``spec_token_parity`` asserts the headline
    guarantee — every request's accepted stream equals the plain greedy
    engine's bit for bit — and ``spec_accept_rate`` (emitted tokens over
    ``live-row-rounds x (k+1)``) tracks how much of each draft survives.
    On CPU the draft pass is real compute on the critical path, so the
    speedup is honest-but-pessimistic; on accelerators the narrow-format
    draft is where the transprecision energy story cashes out.  Archs
    that cannot page carry nulls."""
    import jax
    from repro.launch.engine import ContinuousEngine, synthetic_trace
    from repro.models.registry import build_model

    if quick:
        slots, gen, n_req = 2, 16, 6
    keys = ("spec_decode_tok_s", "spec_plain_tok_s", "spec_speedup",
            "spec_accept_rate", "spec_token_parity")
    model = build_model(arch, policy="tp_bf16", reduced=True)
    why = model.cfg.paged_unsupported_reason()
    if why is not None:
        out = {k: None for k in keys}
        out["spec_unsupported"] = why
        return out
    model_pg = model.with_cfg(paged_kv=True, page_size=16)
    params = model_pg.init(jax.random.key(0))
    max_len = prompt_len + gen + spec_k        # draft lookahead headroom
    reqs = synthetic_trace(n_req, slots, prompt_len, gen, model.cfg.vocab)
    useful = sum(r.max_new for r in reqs)

    def leg(**kw):
        eng = ContinuousEngine(model_pg, params, slots=slots,
                               max_len=max_len, chunk=16, burst_cap=64,
                               **kw)
        eng.run(reqs)                              # compile + warm
        ts = []
        for _ in range(1 if quick else 3):
            t0 = time.perf_counter()
            fin, st = eng.run(reqs)
            ts.append(time.perf_counter() - t0)
        return useful / _median(ts), fin, st

    plain_rate, fin_p, _ = leg()
    spec_rate, fin_s, st = leg(spec_k=spec_k, draft_repeats=draft_repeats)
    return {
        "spec_decode_tok_s": spec_rate,
        "spec_plain_tok_s": plain_rate,
        "spec_speedup": spec_rate / plain_rate,
        "spec_accept_rate": st["spec_accept_rate"],
        "spec_token_parity": (
            len(fin_s) == len(fin_p) == n_req
            and all(a.tokens == b.tokens for a, b in zip(fin_s, fin_p))),
        "spec_k": spec_k,
        "spec_draft_repeats": draft_repeats,
        "spec_rounds": st["spec_rounds"],
        "spec_emitted": st["spec_emitted"],
    }


def robustness_soak(arch: str, *, prompt_len: int, quick: bool = False,
                    slots: int = 4, gen: int = 64, n_req: int = 24) -> dict:
    """Overload soak through the robustness machinery.

    The soak trace (``synthetic_trace(flavor="soak")``: arrival bursts far
    wider than ``slots``, long documents, mixed priorities, deadlines on
    the top tier, quality-sensitive ``no_degrade`` requests) is served on
    a page pool sized to about HALF the worst-case reservation, with a
    ``ServeFaultPlan`` injecting pool exhaustion, a straggler stall and
    masked NaN logits.  The engine must drain it completely — zero stuck,
    zero lost, every budget honored — by preempting (swap-to-host, fp8
    degraded where permitted), shedding with backoff and deadline-aware
    scheduling.  The counters land in BENCH_serve.json as the robustness
    trajectory; archs that cannot page carry nulls."""
    import jax
    from repro.launch.engine import ContinuousEngine, synthetic_trace
    from repro.models.paged import num_pages
    from repro.models.registry import build_model
    from repro.train.fault import ServeFaultPlan

    if quick:
        slots, gen, n_req = 2, 16, 8
    model = build_model(arch, policy="tp_bf16", reduced=True)
    why = model.cfg.paged_unsupported_reason()
    keys = ("soak_drained", "soak_requests", "soak_tok_s",
            "soak_preemptions", "soak_shed_events", "soak_degraded",
            "soak_deadline_miss_rate", "soak_poisoned_rounds",
            "soak_faults_exhaust")
    if why is not None:
        out = {k: None for k in keys}
        out["soak_unsupported"] = why
        return out
    page = 16
    model_pg = model.with_cfg(paged_kv=True, page_size=page)
    params = model_pg.init(jax.random.key(0))
    max_len = prompt_len + gen
    reqs = synthetic_trace(n_req, slots, prompt_len, gen, model.cfg.vocab,
                           flavor="soak")
    worst = max(num_pages(r.prompt_len + r.max_new, page) for r in reqs)
    # ~half the worst-case steady-state reservation: admission cannot hold
    # every slot's worst case, so preemption/shedding must engage
    n_pages = max(worst + 2, (slots * worst) // 2 + 1)
    plan = ServeFaultPlan(exhaust_at=(gen // 2, 3 * gen), exhaust_for=4,
                          slow_at=(gen // 4,), slow_s=0.01,
                          poison_at=tuple(range(gen // 2, gen // 2 + 4)),
                          mask_poison=True)
    eng = ContinuousEngine(model_pg, params, slots=slots, max_len=max_len,
                           chunk=16, n_pages=n_pages, preempt="swap",
                           degrade_fmt="fp8", fault_plan=plan)
    eng.run(reqs)                                  # compile + warm
    t0 = time.perf_counter()
    fin, st = eng.run(reqs)
    dt = time.perf_counter() - t0
    drained = (len(fin) == n_req
               and all(len(f.tokens) == r.max_new
                       for f, r in zip(fin, reqs)))
    return {
        "soak_drained": drained,
        "soak_requests": n_req,
        "soak_tok_s": sum(len(f.tokens) for f in fin) / dt,
        "soak_preemptions": st["preemptions"],
        "soak_shed_events": st["shed_events"],
        "soak_degraded": st["degraded"],
        "soak_deadline_miss_rate": st["deadline_miss_rate"],
        "soak_poisoned_rounds": st["poisoned_rounds"],
        "soak_faults_exhaust": st["faults_exhaust"],
        "soak_pool_pages": n_pages,
        "soak_deadline_total": st["deadline_total"],
    }


def ha_soak(arch: str, *, prompt_len: int, quick: bool = False,
            slots: int = 3, gen: int = 16, n_req: int = 12) -> dict:
    """Replica-HA soak: fleet survives a kill; a full loss replays.

    Two legs over ONE multi-turn ``flavor="session"`` trace (later turns
    re-send the whole conversation as a growing shared prefix, so a
    migrated session resumes mid-conversation):

    * **kill leg** — a 2-replica meshless fleet loses one replica to an
      injected kill mid-run; the survivor adopts the victim's in-flight
      requests by free-and-reingest and the fleet must DRAIN (every
      budget honored) with tokens identical to the unfailed fleet
      (``ha_token_parity``).
    * **replay leg** — a single-replica journaled fleet is killed with
      NO survivor; ``run_with_restarts`` restarts it and the journal
      replays every unfinished request from its last journaled token —
      ``ha_replay_parity`` gates the recovered streams against the same
      oracle.

    Archs that cannot page carry nulls, like the other serving legs."""
    import jax
    from repro.launch.engine import ReplicatedEngine, synthetic_trace
    from repro.launch.journal import RequestJournal
    from repro.models.registry import build_model
    from repro.train.fault import ReplicaFaultPlan, run_with_restarts

    if quick:
        slots, n_req = 2, 8
    keys = ("ha_drained", "ha_requests", "ha_kills", "ha_migrations",
            "ha_token_parity", "ha_replay_parity", "ha_tok_s",
            "ha_journal_records")
    model = build_model(arch, policy="tp_bf16", reduced=True)
    why = model.cfg.paged_unsupported_reason()
    if why is not None:
        out = {k: None for k in keys}
        out["ha_unsupported"] = why
        return out
    page = 16
    model_pg = model.with_cfg(paged_kv=True, page_size=page)
    params = model_pg.init(jax.random.key(0))
    reqs = synthetic_trace(n_req, slots, prompt_len, gen, model.cfg.vocab,
                           flavor="session")
    ml = max(r.prompt_len + r.max_new for r in reqs)

    def mk(n, **kw):
        return ReplicatedEngine(model_pg, params, replicas=n, slots=slots,
                                max_len=ml, chunk=16, burst_cap=2,
                                migrate="reingest", **kw)

    base, _ = mk(2).run(reqs)                      # oracle + compile warm
    t0 = time.perf_counter()
    fin, st = mk(2, replica_fault=ReplicaFaultPlan(
        replica=1, at_burst=2, mode="kill")).run(reqs)
    dt = time.perf_counter() - t0
    drained = (len(fin) == n_req
               and all(len(f.tokens) == r.max_new
                       for f, r in zip(fin, reqs)))
    parity = all(a.tokens == b.tokens for a, b in zip(fin, base))

    jr = RequestJournal()
    solo = mk(1, replica_fault=ReplicaFaultPlan(
        replica=0, at_burst=2, mode="kill"), journal=jr).bind(reqs)
    _, restarts = run_with_restarts(lambda: solo, max_restarts=2)
    fin2, _ = solo.run()        # answered from the journal's finish records
    replay_parity = (restarts >= 1
                     and all(a.tokens == b.tokens
                             for a, b in zip(fin2, base)))
    return {
        "ha_drained": drained,
        "ha_requests": n_req,
        "ha_kills": st["ha_kills"],
        "ha_migrations": st["ha_migrations"],
        "ha_token_parity": parity,
        "ha_replay_parity": replay_parity,
        "ha_tok_s": sum(len(f.tokens) for f in fin) / dt,
        "ha_journal_records": sum(jr.counts().values()),
    }


def flag_overhead(repeats: int = 3) -> dict:
    """Flag-telemetry overhead A/B on the fused decode kernel.

    Times ``kernels.ops.decode_attention`` over an fp8-container ragged KV
    strip with ``return_flags`` off vs on — the cost of accumulating the
    per-block IEEE OF/UF/NX/NV counters alongside the attention math
    (docs/KERNELS.md).  On CPU both sides run the Pallas interpreter, so
    the ratio is a loose upper bound; on TPU the counters are a handful of
    vector compares + integer adds per visited tile."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.policy import get_policy
    from repro.kernels import ops as kops

    pol = get_policy("em_fp8").replace(kv_fmt="fp8")
    rs = np.random.RandomState(0)
    b, h, hkv, s, d = 4, 8, 2, 256, 64
    q = jnp.asarray(rs.randn(b, h, 1, d), jnp.float32)
    k = jnp.asarray(rs.randn(b, hkv, s, d), jnp.float32)
    v = jnp.asarray(rs.randn(b, hkv, s, d), jnp.float32)
    kv_len = jnp.asarray([s, s // 2, 3, s], jnp.int32)

    plain = jax.jit(lambda q, k, v, l: kops.decode_attention(
        q, k, v, kv_len=l, policy=pol, interpret=True))
    flagged = jax.jit(lambda q, k, v, l: kops.decode_attention(
        q, k, v, kv_len=l, policy=pol, interpret=True, return_flags=True))
    t_off = _time_call(lambda: plain(q, k, v, kv_len), repeats)
    t_on = _time_call(lambda: flagged(q, k, v, kv_len)[0], repeats)
    return {
        "flag_decode_ms": t_off * 1e3,
        "flag_decode_flags_ms": t_on * 1e3,
        "flag_telemetry_overhead": t_on / t_off,
    }


def numerical_health_soak(arch: str, *, prompt_len: int,
                          quick: bool = False, slots: int = 4,
                          gen: int = 48, n_req: int = 12) -> dict:
    """Deterministic numerical-health soak: escalation + SDC-checked swap.

    Two engine runs prove the numerical-health gates end-to-end:

    * **escalation leg** — the fp32 wide-container pool with the
      ``fp8 -> fp16 -> fp16alt`` ladder and an injected write-side K/V
      overflow (``overflow_at`` scales the rows' K/V writes by 2^16): the
      saturating casts keep logits finite while OF pressure crosses the
      threshold, the engine re-ingests the pressured rows one rung wider
      between bursts, and every request still drains its full budget with
      zero poisoned rounds and no ``PoisonedLogitsError``.
    * **SDC leg** — swap-mode preemption on a half-sized page pool with a
      bit flip injected into the first swap payloads: every corruption
      must be caught by the CRC32 check at swap-in (injected == detected,
      zero undetected) and recovered by re-ingest with tokens IDENTICAL
      to an uncorrupted twin of the same run.

    Both legs replay one deterministic fault plan; archs that cannot page
    carry nulls, like the ragged/paged columns."""
    import jax
    from repro.core.policy import EscalationPolicy
    from repro.launch.engine import ContinuousEngine, synthetic_trace
    from repro.models.paged import num_pages
    from repro.models.registry import build_model
    from repro.train.fault import ServeFaultPlan

    if quick:
        slots, gen, n_req = 2, 16, 6
    keys = ("esc_soak_drained", "esc_soak_escalations",
            "esc_soak_escalated_requests", "esc_soak_deferred",
            "esc_soak_refused", "esc_soak_poisoned_rounds",
            "esc_soak_tok_s", "sdc_soak_injected", "sdc_soak_detected",
            "sdc_soak_reingest", "sdc_soak_token_parity")
    model = build_model(arch, policy="fp32", reduced=True)
    why = model.cfg.paged_unsupported_reason()
    if why is not None:
        out = {k: None for k in keys}
        out["health_soak_unsupported"] = why
        return out
    page = 16
    max_len = prompt_len + gen

    # -- escalation leg: overflow fault -> saturate -> escalate -> drain ----
    model_pg = model.with_cfg(paged_kv=True, page_size=page)
    params = model_pg.init(jax.random.key(0))
    reqs = synthetic_trace(n_req, slots, prompt_len, gen, model.cfg.vocab)
    worst = max(num_pages(r.prompt_len + r.max_new, page) for r in reqs)
    plan = ServeFaultPlan(overflow_at=(3, 4), overflow_scale=65536.0)
    eng = ContinuousEngine(
        model_pg, params, slots=slots, max_len=max_len, chunk=16,
        n_pages=slots * worst + 2, burst_cap=8, fault_plan=plan,
        escalate=EscalationPolicy(of_threshold=4))
    eng.run(reqs)                                  # compile + warm
    t0 = time.perf_counter()
    fin, st = eng.run(reqs)
    dt = time.perf_counter() - t0
    esc_drained = (len(fin) == n_req
                   and all(len(f.tokens) == r.max_new
                           for f, r in zip(fin, reqs)))
    out = {
        "esc_soak_drained": esc_drained,
        "esc_soak_escalations": st["escalations"],
        "esc_soak_escalated_requests": sum(1 for f in fin if f.escalated),
        "esc_soak_deferred": st["esc_deferred"],
        "esc_soak_refused": st["esc_refused"],
        "esc_soak_poisoned_rounds": st["poisoned_rounds"],
        "esc_soak_tok_s": sum(len(f.tokens) for f in fin) / dt,
    }

    # -- SDC leg: corrupted swap payloads must be detected + recovered ------
    # (bf16 pool under page pressure so swap preemption actually engages;
    # the clean twin pins the recovered tokens bit-for-bit.)
    model_sw = build_model(arch, policy="tp_bf16", reduced=True).with_cfg(
        paged_kv=True, page_size=page)
    params_sw = model_sw.init(jax.random.key(0))
    reqs_sw = synthetic_trace(n_req, slots, prompt_len, gen,
                              model_sw.cfg.vocab, flavor="soak")
    worst = max(num_pages(r.prompt_len + r.max_new, page) for r in reqs_sw)
    n_pages = max(worst + 2, (slots * worst) // 2 + 1)

    # exhaustion episode in BOTH twins (identical trajectories; corruption
    # alone differs) so swap preemption reliably engages at full size
    pressure = dict(exhaust_at=(gen // 2,), exhaust_for=4)

    def sdc_run(fault_plan):
        e = ContinuousEngine(model_sw, params_sw, slots=slots,
                             max_len=max_len, chunk=16, n_pages=n_pages,
                             preempt="swap", fault_plan=fault_plan)
        return e.run(reqs_sw)

    fin_clean, _ = sdc_run(ServeFaultPlan(**pressure))
    fin_sdc, st = sdc_run(ServeFaultPlan(corrupt_swap_at=tuple(range(4)),
                                         **pressure))
    out.update({
        "sdc_soak_injected": st["sdc_injected"],
        "sdc_soak_detected": st["sdc_detected"],
        "sdc_soak_reingest": st["sdc_reingest"],
        "sdc_soak_token_parity": (
            len(fin_sdc) == len(fin_clean) == n_req
            and all(a.tokens == b.tokens
                    for a, b in zip(fin_sdc, fin_clean))),
    })
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="one arch, short generation (CI smoke)")
    ap.add_argument("--shard-probe", default=None, metavar="ARCH",
                    help="internal re-entry: run the tensor-parallel A/B "
                         "in THIS process (expects forced host devices) "
                         "and print SHARD_JSON instead of benchmarking")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    if args.shard_probe:
        out = shard_probe(args.shard_probe, prompt_len=args.prompt_len)
        print("SHARD_JSON " + json.dumps(out))
        return out
    if args.quick:
        args.archs, args.gen, args.repeats = args.archs[:1], 16, 1

    import jax
    report = {"meta": {"backend": jax.default_backend(),
                       "device": str(jax.devices()[0]),
                       "quick": bool(args.quick)},
              "archs": {}}
    for arch in args.archs:
        print(f"[serve_decode] {arch} ...", flush=True)
        row = bench_arch(arch, batch=args.batch, prompt_len=args.prompt_len,
                         gen=args.gen, repeats=args.repeats,
                         quick=args.quick)
        report["archs"][arch] = row
        fmt = lambda x, unit: "n/a" if x is None else f"{x:.1f} {unit}"
        print(f"  prefill dense {row['prefill_dense_ms']:.1f} ms "
              f"/ pallas {row['prefill_pallas_ms']:.1f} ms "
              f"/ ragged {fmt(row['ragged_prefill_ms'], 'ms')} | "
              f"python {row['python_tok_s']:.1f} tok/s | "
              f"scan {row['scan_tok_s']:.1f} tok/s "
              f"({row['scan_speedup']:.2f}x) | "
              f"ragged {fmt(row['ragged_decode_tok_s'], 'tok/s')} | "
              f"paged {fmt(row['paged_decode_tok_s'], 'tok/s')} "
              f"(page={row['paged_page_size']}) | "
              f"scan+pallas(kv8) {row['scan_pallas_kv8_tok_s']:.1f} tok/s",
              flush=True)
        if row.get("continuous_decode_tok_s") is not None:
            print(f"  continuous {row['continuous_decode_tok_s']:.1f} tok/s "
                  f"vs fixed {row['fixed_batch_tok_s']:.1f} tok/s "
                  f"({row['continuous_speedup']:.2f}x) | occupancy "
                  f"{row['continuous_batch_occupancy']:.2f} | peak pages "
                  f"{row['peak_live_pages']}/"
                  f"{row['continuous_fixed_equiv_pages']}", flush=True)
        else:
            print(f"  continuous n/a "
                  f"({row.get('continuous_unsupported')})", flush=True)
        if row.get("spec_decode_tok_s") is not None:
            print(f"  speculative {row['spec_decode_tok_s']:.1f} tok/s "
                  f"vs plain {row['spec_plain_tok_s']:.1f} tok/s "
                  f"({row['spec_speedup']:.2f}x) | accept "
                  f"{row['spec_accept_rate']:.2f} (k={row['spec_k']}, "
                  f"draft_repeats={row['spec_draft_repeats']}) | "
                  f"parity={row['spec_token_parity']}", flush=True)
        else:
            print(f"  speculative n/a "
                  f"({row.get('spec_unsupported')})", flush=True)
        if row.get("soak_drained") is not None:
            print(f"  soak drained={row['soak_drained']} "
                  f"({row['soak_requests']} reqs, "
                  f"{row['soak_tok_s']:.1f} tok/s) | "
                  f"{row['soak_preemptions']} preempts, "
                  f"{row['soak_shed_events']} sheds, "
                  f"{row['soak_degraded']} degraded, miss-rate "
                  f"{row['soak_deadline_miss_rate']:.2f}, "
                  f"{row['soak_poisoned_rounds']} poisoned, "
                  f"{row['soak_faults_exhaust']} exhaustions", flush=True)
        else:
            print(f"  soak n/a ({row.get('soak_unsupported')})", flush=True)
        if row.get("ha_drained") is not None:
            print(f"  ha drained={row['ha_drained']} "
                  f"({row['ha_requests']} session reqs, "
                  f"{row['ha_tok_s']:.1f} tok/s) | "
                  f"{row['ha_kills']} kills, "
                  f"{row['ha_migrations']} migrations, "
                  f"parity={row['ha_token_parity']}, "
                  f"replay_parity={row['ha_replay_parity']} "
                  f"({row['ha_journal_records']} journal records)",
                  flush=True)
        else:
            print(f"  ha n/a ({row.get('ha_unsupported')})", flush=True)
        print(f"  flag telemetry {row['flag_telemetry_overhead']:.2f}x "
              f"({row['flag_decode_ms']:.1f} -> "
              f"{row['flag_decode_flags_ms']:.1f} ms)", flush=True)
        if row.get("shard_devices") is not None:
            print(f"  shard tp={row['shard_devices']}: "
                  f"{row['shard_decode_tok_s']:.1f} tok/s vs base "
                  f"{row['shard_base_tok_s']:.1f} tok/s "
                  f"({row['shard_speedup']:.2f}x), "
                  f"parity={row['shard_token_parity']} | dryrun "
                  f"{row.get('shard_dryrun_devices')} devices, peak "
                  f"{[f'{b/2**30:.1f}G' for b in row.get('shard_dryrun_peak_bytes', [])]}",
                  flush=True)
        elif not args.quick:
            print(f"  shard n/a ({row.get('shard_unsupported')})",
                  flush=True)
        if row.get("esc_soak_drained") is not None:
            print(f"  health esc drained={row['esc_soak_drained']} "
                  f"({row['esc_soak_escalations']} escalations, "
                  f"{row['esc_soak_escalated_requests']} reqs wider, "
                  f"{row['esc_soak_poisoned_rounds']} poisoned) | "
                  f"sdc {row['sdc_soak_injected']} injected / "
                  f"{row['sdc_soak_detected']} detected / "
                  f"{row['sdc_soak_reingest']} reingested, "
                  f"parity={row['sdc_soak_token_parity']}", flush=True)
        else:
            print(f"  health soak n/a "
                  f"({row.get('health_soak_unsupported')})", flush=True)

    if not args.quick:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"[serve_decode] wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
